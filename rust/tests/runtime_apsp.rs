//! Integration: the full AOT round-trip — jax/pallas HLO-text artifacts
//! loaded and executed on the PJRT CPU client from Rust, cross-validated
//! against the native BFS metrics for real paper topologies.
//!
//! Requires `make artifacts` (skips cleanly otherwise).

use lattice_networks::metrics::distance_distribution;
use lattice_networks::runtime::{ApspEngine, ApspKind};
use lattice_networks::topology;

fn engine() -> Option<ApspEngine> {
    match ApspEngine::open_default() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT tests: {err:#}");
            None
        }
    }
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn minplus_matches_bfs_on_crystals() {
    let Some(engine) = engine() else { return };
    for (name, g) in [
        ("PC(4)", topology::pc(4)),
        ("FCC(3)", topology::fcc(3)),
        ("BCC(2)", topology::bcc(2)),
        ("RTT(5)", topology::rtt(5)),
    ] {
        let bfs = distance_distribution(&g);
        let sum: usize = bfs.histogram.iter().enumerate().map(|(d, c)| d * c).sum();
        let out = engine.distance_summary(&g, ApspKind::MinPlus).unwrap();
        assert_eq!(out.diameter as usize, bfs.diameter, "{name}");
        assert_eq!(out.sum as usize, sum * g.order(), "{name}");
        assert!(
            (out.avg_distance - bfs.avg_distance).abs() < 1e-6,
            "{name}: pjrt {} vs bfs {}",
            out.avg_distance,
            bfs.avg_distance
        );
    }
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn gemm_matches_bfs_on_crystals() {
    let Some(engine) = engine() else { return };
    for (name, g) in [
        ("PC(3)", topology::pc(3)),
        ("FCC(2)", topology::fcc(2)),
        ("BCC(2)", topology::bcc(2)),
    ] {
        let bfs = distance_distribution(&g);
        let out = engine.distance_summary(&g, ApspKind::Gemm).unwrap();
        assert_eq!(out.diameter as usize, bfs.diameter, "{name}");
        assert!(
            (out.avg_distance - bfs.avg_distance).abs() < 1e-6,
            "{name}: pjrt {} vs bfs {}",
            out.avg_distance,
            bfs.avg_distance
        );
    }
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn both_kernels_agree() {
    let Some(engine) = engine() else { return };
    let g = topology::fcc4d(2); // 32 nodes, 4D
    let a = engine.distance_summary(&g, ApspKind::MinPlus).unwrap();
    let b = engine.distance_summary(&g, ApspKind::Gemm).unwrap();
    assert_eq!(a.diameter, b.diameter);
    assert!((a.sum - b.sum).abs() < 1e-3);
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn padding_choice_is_minimal_fit() {
    let Some(engine) = engine() else { return };
    let g = topology::pc(4); // 64 nodes -> should pad to the 64 artifact
    let out = engine.distance_summary(&g, ApspKind::MinPlus).unwrap();
    assert_eq!(out.padded_to, 64);
    let g2 = topology::pc(5); // 125 nodes -> 128
    let out2 = engine.distance_summary(&g2, ApspKind::MinPlus).unwrap();
    assert_eq!(out2.padded_to, 128);
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn oversized_topology_is_a_clean_error() {
    let Some(engine) = engine() else { return };
    let max = engine.max_order(ApspKind::MinPlus);
    let g = topology::pc(8); // 512 > 256 default artifacts
    if g.order() > max {
        let err = engine.distance_summary(&g, ApspKind::MinPlus);
        assert!(err.is_err());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }
}

#[test]
#[ignore = "requires PJRT/XLA artifacts: build with --features pjrt (xla crate) and run `make artifacts`"]
fn table1_avg_distance_formula_vs_pjrt() {
    // The paper's closed forms, validated through the XLA path too.
    let Some(engine) = engine() else { return };
    use lattice_networks::metrics::formulas;
    let a = 3;
    let out = engine
        .distance_summary(&topology::fcc(a), ApspKind::MinPlus)
        .unwrap();
    assert!(
        (out.avg_distance - formulas::avg_distance_fcc(a)).abs() < 1e-6,
        "FCC({a}): pjrt {} vs formula {}",
        out.avg_distance,
        formulas::avg_distance_fcc(a)
    );
}
