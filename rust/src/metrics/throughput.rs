//! The §3.4 analytic throughput bounds under uniform traffic.
//!
//! For edge-symmetric graphs, accepted load is bounded by `Δ / k̄`
//! (phits/cycle/node): `l N k̄ <= 2|E| = Δ N`. Mixed-radix tori are not
//! edge-symmetric; their bound is governed by the most loaded dimension:
//! `Δ / (n * k̄_max)` where `k̄_max` is the largest per-dimension average
//! distance (inferred from [7]).

use crate::lattice::LatticeGraph;
use crate::metrics::distance_distribution;

/// An analytic throughput bound (phits/cycle/node).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThroughputBound {
    /// The bound itself.
    pub phits_per_cycle_node: f64,
    /// Average distance used.
    pub avg_distance: f64,
    /// Whether the symmetric-graph formula applied.
    pub edge_symmetric: bool,
}

/// Per-dimension average distance of a ring of `a` nodes.
fn ring_avg(a: i64) -> f64 {
    let sum = if a % 2 == 0 { a * a / 4 } else { (a * a - 1) / 4 };
    sum as f64 / a as f64
}

/// Throughput bound for an arbitrary catalog graph. Mixed-radix tori get
/// the per-dimension formula; everything else the symmetric `Δ/k̄`.
pub fn max_throughput_bound(g: &LatticeGraph) -> ThroughputBound {
    let stats = distance_distribution(g);
    let degree = g.degree() as f64;
    let n = g.dim() as f64;
    // A torus is recognizable from its Hermite form: diagonal matrix.
    let h = g.hermite();
    let is_torus = (0..g.dim())
        .all(|i| (0..g.dim()).all(|j| i == j || h[(i, j)] == 0));
    let edge_symmetric = !is_torus || {
        // equal-radix tori are edge-symmetric
        let first = h[(0, 0)];
        (0..g.dim()).all(|i| h[(i, i)] == first)
    };
    if edge_symmetric {
        ThroughputBound {
            phits_per_cycle_node: degree / stats.avg_distance,
            avg_distance: stats.avg_distance,
            edge_symmetric: true,
        }
    } else {
        let kmax = (0..g.dim()).map(|i| ring_avg(h[(i, i)])).fold(0.0, f64::max);
        ThroughputBound {
            phits_per_cycle_node: degree / (n * kmax),
            avg_distance: stats.avg_distance,
            edge_symmetric: false,
        }
    }
}

/// The paper's §3.4 headline: FCC(a) vs T(2a,a,a) improvement factor, and
/// BCC(a) vs T(2a,2a,a). Returns `(fcc_gain, bcc_gain)` as fractions
/// (0.71 ≈ 71%).
pub fn section34_gains(a: i64) -> (f64, f64) {
    use crate::topology::{bcc, fcc, torus};
    let fcc_bound = max_throughput_bound(&fcc(a)).phits_per_cycle_node;
    let t1_bound = max_throughput_bound(&torus(&[2 * a, a, a])).phits_per_cycle_node;
    let bcc_bound = max_throughput_bound(&bcc(a)).phits_per_cycle_node;
    let t2_bound = max_throughput_bound(&torus(&[2 * a, 2 * a, a])).phits_per_cycle_node;
    (fcc_bound / t1_bound - 1.0, bcc_bound / t2_bound - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, torus};

    #[test]
    fn fcc_bound_matches_48_over_7a() {
        // §3.4: FCC(a) throughput bounded by 48/(7a) (asymptotically:
        // Δ=6, k̄ ≈ 7a/8).
        for a in [8i64, 16] {
            let b = max_throughput_bound(&fcc(a));
            let paper = 48.0 / (7.0 * a as f64);
            assert!(
                (b.phits_per_cycle_node - paper).abs() / paper < 0.02,
                "FCC({a}): {} vs {paper}",
                b.phits_per_cycle_node
            );
        }
    }

    #[test]
    fn bcc_bound_matches_192_over_35a() {
        for a in [8i64, 16] {
            let b = max_throughput_bound(&bcc(a));
            let paper = 192.0 / (35.0 * a as f64);
            assert!(
                (b.phits_per_cycle_node - paper).abs() / paper < 0.02,
                "BCC({a}): {} vs {paper}",
                b.phits_per_cycle_node
            );
        }
    }

    #[test]
    fn mixed_torus_bound_is_4_over_a() {
        // §3.4: both T(2a,a,a) and T(2a,2a,a) are bounded by 4/a.
        for a in [8i64, 16] {
            for sides in [vec![2 * a, a, a], vec![2 * a, 2 * a, a]] {
                let b = max_throughput_bound(&torus(&sides));
                assert!(!b.edge_symmetric);
                let paper = 4.0 / a as f64;
                assert!(
                    (b.phits_per_cycle_node - paper).abs() / paper < 0.01,
                    "{sides:?}: {} vs {paper}",
                    b.phits_per_cycle_node
                );
            }
        }
    }

    #[test]
    fn headline_gains() {
        // §3.4: +71% for FCC vs T(2a,a,a); +37% for BCC vs T(2a,2a,a).
        let (fcc_gain, bcc_gain) = section34_gains(16);
        assert!((fcc_gain - 0.71).abs() < 0.03, "fcc gain {fcc_gain}");
        assert!((bcc_gain - 0.37).abs() < 0.03, "bcc gain {bcc_gain}");
    }

    #[test]
    fn equal_radix_torus_is_edge_symmetric() {
        let b = max_throughput_bound(&torus(&[4, 4, 4]));
        assert!(b.edge_symmetric);
    }
}
