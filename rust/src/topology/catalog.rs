//! Textual topology specs shared by the CLI, config files, examples and
//! benches.
//!
//! Grammar (case-insensitive names):
//!
//! ```text
//! pc:A           FCC:A          bcc:A          rtt:A
//! 4d-bcc:A       4d-fcc:A       lip:A
//! pc4:A (= pc_nd(4, A))         fcc5:A  bcc5:A (nD families)
//! torus:AxBxC... (any radices)
//! t-rtt:A        pc-bcc:A       pc-fcc:A       bcc-fcc:A   (Table 2 hybrids)
//! ```

use anyhow::{anyhow, bail, Result};

use crate::lattice::LatticeGraph;

use super::*;

/// A parsed topology spec: canonical name + constructor result.
#[derive(Clone, Debug)]
pub struct TopologySpec {
    /// Canonical display name, e.g. `FCC(8)` or `T(16,8,8,8)`.
    pub name: String,
    /// The constructed graph.
    pub graph: LatticeGraph,
}

/// Parse a topology spec string (see module grammar).
pub fn parse(spec: &str) -> Result<TopologySpec> {
    let spec = spec.trim().to_lowercase();
    let (kind, arg) = spec
        .split_once(':')
        .ok_or_else(|| anyhow!("topology spec needs KIND:ARG, got {spec:?}"))?;

    let scalar = || -> Result<i64> {
        arg.parse::<i64>()
            .map_err(|_| anyhow!("bad size in topology spec {spec:?}"))
            .and_then(|a| {
                if a >= 1 {
                    Ok(a)
                } else {
                    bail!("size must be >= 1 in {spec:?}")
                }
            })
    };

    let (name, graph) = match kind {
        "pc" => (format!("PC({})", scalar()?), pc(scalar()?)),
        "fcc" => (format!("FCC({})", scalar()?), fcc(scalar()?)),
        "bcc" => (format!("BCC({})", scalar()?), bcc(scalar()?)),
        "rtt" => (format!("RTT({})", scalar()?), rtt(scalar()?)),
        "4d-bcc" | "bcc4" => (format!("4D-BCC({})", scalar()?), bcc4d(scalar()?)),
        "4d-fcc" | "fcc4" => (format!("4D-FCC({})", scalar()?), fcc4d(scalar()?)),
        "lip" => (format!("Lip({})", scalar()?), lip(scalar()?)),
        "t-rtt" => (
            format!("T(2{a},2{a})⊞RTT({a})", a = scalar()?),
            hybrid_t_rtt(scalar()?),
        ),
        "pc-bcc" => (
            format!("PC({})⊞BCC({})", 2 * scalar()?, scalar()?),
            hybrid_pc_bcc(scalar()?),
        ),
        "pc-fcc" => (
            format!("PC({})⊞FCC({})", 2 * scalar()?, scalar()?),
            hybrid_pc_fcc(scalar()?),
        ),
        "bcc-fcc" => (
            format!("BCC({a})⊞FCC({a})", a = scalar()?),
            hybrid_bcc_fcc(scalar()?),
        ),
        "torus" | "t" => {
            let sides: Result<Vec<i64>> = arg
                .split('x')
                .map(|s| {
                    s.parse::<i64>()
                        .map_err(|_| anyhow!("bad torus side {s:?} in {spec:?}"))
                })
                .collect();
            let sides = sides?;
            if sides.is_empty() || sides.iter().any(|&s| s < 1) {
                bail!("torus sides must be positive in {spec:?}");
            }
            let name = format!(
                "T({})",
                sides
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            );
            (name, torus(&sides))
        }
        other => {
            // nD families: pcN / fccN / bccN.
            let parse_nd = |prefix: &str| -> Option<usize> {
                other
                    .strip_prefix(prefix)
                    .and_then(|d| d.parse::<usize>().ok())
                    .filter(|&d| (2..=8).contains(&d))
            };
            if let Some(n) = parse_nd("pc") {
                (format!("{n}D-PC({})", scalar()?), pc_nd(n, scalar()?))
            } else if let Some(n) = parse_nd("fcc") {
                (format!("{n}D-FCC({})", scalar()?), fcc_nd(n, scalar()?))
            } else if let Some(n) = parse_nd("bcc") {
                (format!("{n}D-BCC({})", scalar()?), bcc_nd(n, scalar()?))
            } else {
                bail!("unknown topology kind {kind:?} (see topology::catalog docs)");
            }
        }
    };
    Ok(TopologySpec { name, graph })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_crystals() {
        assert_eq!(parse("pc:4").unwrap().graph.order(), 64);
        assert_eq!(parse("FCC:2").unwrap().graph.order(), 16);
        assert_eq!(parse("bcc:2").unwrap().graph.order(), 32);
        assert_eq!(parse("rtt:3").unwrap().graph.order(), 18);
    }

    #[test]
    fn parse_4d() {
        assert_eq!(parse("4d-fcc:8").unwrap().graph.order(), 8192);
        assert_eq!(parse("4d-bcc:4").unwrap().graph.order(), 2048);
        assert_eq!(parse("lip:2").unwrap().graph.order(), 256);
    }

    #[test]
    fn parse_torus() {
        let t = parse("torus:16x8x8x8").unwrap();
        assert_eq!(t.graph.order(), 8192);
        assert_eq!(t.name, "T(16,8,8,8)");
        assert_eq!(parse("t:4x4").unwrap().graph.order(), 16);
    }

    #[test]
    fn parse_hybrids() {
        assert_eq!(parse("t-rtt:2").unwrap().graph.order(), 32);
        assert_eq!(parse("pc-bcc:2").unwrap().graph.order(), 128);
        assert_eq!(parse("pc-fcc:1").unwrap().graph.order(), 8);
        assert_eq!(parse("bcc-fcc:1").unwrap().graph.order(), 4);
    }

    #[test]
    fn parse_nd_families() {
        assert_eq!(parse("pc4:2").unwrap().graph.order(), 16);
        assert_eq!(parse("fcc5:2").unwrap().graph.dim(), 5);
        assert_eq!(parse("bcc4:2").unwrap().graph.order(), bcc4d(2).order());
    }

    #[test]
    fn parse_errors() {
        assert!(parse("nope:3").is_err());
        assert!(parse("pc").is_err());
        assert!(parse("pc:0").is_err());
        assert!(parse("torus:4x0").is_err());
        assert!(parse("torus:axb").is_err());
    }
}
