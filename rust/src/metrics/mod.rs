//! Distance metrics and the paper's analytic models (§3.4).
//!
//! - [`bfs`]: exact single-source / all-source distance distributions.
//!   All paper topologies are vertex-transitive (Cayley graphs), so one
//!   BFS from node 0 gives the whole distance distribution — this is what
//!   lets us "computationally check" the closed forms up to 40k+ nodes in
//!   milliseconds. The kernels walk a flat neighbor table (the engine's
//!   `neighbor[u * ports + p]` layout) instead of reducing coordinate
//!   vectors per popped node; `*_flat` variants accept a prebuilt table.
//!   Also the faulted-graph reachability oracle
//!   ([`bfs_distances_faulted`], [`faulted_components`]) the resilience
//!   property suite compares the degraded engine against.
//! - [`formulas`]: the closed-form average-distance expressions of §3.4
//!   and the Table 1 / Table 2 diameter and average-distance models.
//! - [`throughput`]: the §3.4 throughput bounds (`Δ/k̄` for edge-symmetric
//!   graphs, `Δ/(n·k̄_max)` for mixed-radix tori).

pub mod bfs;
pub mod formulas;
pub mod throughput;

pub use bfs::{
    bfs_distances, bfs_distances_faulted, bfs_distances_faulted_flat, bfs_distances_flat,
    distance_distribution, faulted_components, faulted_components_flat, neighbor_table,
    DistanceStats,
};
pub use throughput::{max_throughput_bound, ThroughputBound};
