//! Bench: regenerate Figure 6 — throughput peak of T(8,8,8,4) vs
//! 4D-BCC(4). Scaled by default; `LATTICE_FULL=1` for paper size.

use lattice_networks::benchkit::Bench;
use lattice_networks::coordinator::experiments as exp;
use lattice_networks::sim::TrafficPattern;

fn main() {
    let full = std::env::var_os("LATTICE_FULL").is_some();
    let spec = exp::fig6_spec(full);
    let (cfg, seeds) = exp::fig_sim_config(full);
    let loads: Vec<f64> = if full {
        exp::default_loads()
    } else {
        vec![0.2, 0.4, 0.6, 0.8, 1.0]
    };

    let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &loads, seeds, cfg.clone())
        .expect("figure run");
    print!("{}", exp::throughput_table(&fig).render());
    print!("{}", exp::gain_table(&fig).render());

    let mut b = Bench::new("fig6");
    b.max_iters = 10;
    let g = lattice_networks::topology::catalog::parse(spec.lattice.1)
        .unwrap()
        .graph;
    let sim = lattice_networks::sim::Simulator::new(g, TrafficPattern::Uniform, cfg);
    b.run("sim-point/lattice@0.6", || {
        lattice_networks::benchkit::black_box(sim.run(0.6));
    });
}
