//! APSP on the XLA side: distance summaries of lattice graphs computed by
//! the AOT Pallas kernels, cross-validated against native BFS in tests.

use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::Result;

use crate::lattice::LatticeGraph;

use super::client::PjrtRuntime;
#[cfg(feature = "pjrt")]
use super::manifest::Artifact;
use super::manifest::Manifest;

/// Which L1 kernel family to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApspKind {
    /// Min-plus squaring (VPU kernel, log-diameter iterations).
    MinPlus,
    /// BFS-by-GEMM (MXU kernel, linear steps).
    Gemm,
}

impl ApspKind {
    pub fn model_name(&self) -> &'static str {
        match self {
            ApspKind::MinPlus => "apsp_minplus",
            ApspKind::Gemm => "apsp_gemm",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "minplus" | "min-plus" => Some(ApspKind::MinPlus),
            "gemm" | "bfs-gemm" => Some(ApspKind::Gemm),
            _ => None,
        }
    }
}

/// Distance summary computed by an artifact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistanceSummary {
    /// Sum of all pairwise distances.
    pub sum: f64,
    /// Diameter.
    pub diameter: u32,
    /// Average distance with the paper's `/(N-1)` convention.
    pub avg_distance: f64,
    /// Artifact size used (the padding target).
    pub padded_to: usize,
}

/// The APSP engine: runtime + manifest.
pub struct ApspEngine {
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    rt: PjrtRuntime,
    manifest: Manifest,
}

impl ApspEngine {
    /// Open the engine over an artifacts directory.
    pub fn open(dir: &Path) -> Result<Self> {
        Ok(Self { rt: PjrtRuntime::cpu()?, manifest: Manifest::load(dir)? })
    }

    /// Open over the default artifacts dir (env `LATTICE_ARTIFACTS`).
    pub fn open_default() -> Result<Self> {
        Self::open(&super::artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Largest topology order servable by `kind`.
    pub fn max_order(&self, kind: ApspKind) -> usize {
        self.manifest
            .sizes_of(kind.model_name())
            .last()
            .copied()
            .unwrap_or(0)
    }

    /// Compute the distance summary of `g` with the given kernel family.
    ///
    /// Without the `pjrt` feature this is unreachable in practice
    /// ([`ApspEngine::open`] already fails), but a stub keeps the call
    /// surface identical across builds.
    #[cfg(not(feature = "pjrt"))]
    pub fn distance_summary(&self, _g: &LatticeGraph, _kind: ApspKind) -> Result<DistanceSummary> {
        anyhow::bail!("PJRT/XLA runtime unavailable (build with --features pjrt)")
    }

    /// Compute the distance summary of `g` with the given kernel family.
    #[cfg(feature = "pjrt")]
    pub fn distance_summary(&self, g: &LatticeGraph, kind: ApspKind) -> Result<DistanceSummary> {
        let order = g.order();
        let artifact = self
            .manifest
            .best_fit(kind.model_name(), order)
            .with_context(|| {
                format!(
                    "no {} artifact fits order {order} (available: {:?}) — \
                     re-run `make artifacts` with larger --sizes",
                    kind.model_name(),
                    self.manifest.sizes_of(kind.model_name())
                )
            })?;
        let exe = self.rt.load_hlo(&self.manifest.path_of(artifact))?;

        let adj = self.build_adjacency(g, artifact, kind);
        let adj_lit = xla::Literal::vec1(&adj)
            .reshape(&[artifact.n as i64, artifact.n as i64])
            .context("reshaping adjacency literal")?;
        let n_real = xla::Literal::from(order as f32);

        let outputs = self.rt.execute_tuple(&exe, &[adj_lit, n_real])?;
        anyhow::ensure!(outputs.len() == 3, "expected 3 outputs, got {}", outputs.len());
        let sum = outputs[1].get_first_element::<f32>()? as f64;
        let max = outputs[2].get_first_element::<f32>()? as f64;
        Ok(DistanceSummary {
            sum,
            diameter: max as u32,
            // `sum` covers all ordered pairs; the paper's average-distance
            // convention divides the per-source sum by (N - 1).
            avg_distance: sum / (order as f64 * (order as f64 - 1.0)),
            padded_to: artifact.n,
        })
    }

    /// Padded one-hop matrix per the protocol in `python/compile/model.py`:
    /// min-plus wants costs (0 diag / 1 edge / INF elsewhere); gemm wants
    /// 0/1 adjacency with zero padding.
    #[cfg(feature = "pjrt")]
    fn build_adjacency(&self, g: &LatticeGraph, artifact: &Artifact, kind: ApspKind) -> Vec<f32> {
        let n = artifact.n;
        let order = g.order();
        let inf = self.manifest.inf;
        let mut adj = match kind {
            ApspKind::MinPlus => vec![inf; n * n],
            ApspKind::Gemm => vec![0f32; n * n],
        };
        if let ApspKind::MinPlus = kind {
            for v in 0..order {
                adj[v * n + v] = 0.0;
            }
        }
        for u in 0..order {
            for v in g.neighbors(u) {
                adj[u * n + v] = 1.0;
            }
        }
        adj
    }
}

// The PJRT integration tests live in rust/tests/runtime_apsp.rs (they need
// the artifacts built); unit tests here cover the adjacency protocol only.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse() {
        assert_eq!(ApspKind::parse("minplus"), Some(ApspKind::MinPlus));
        assert_eq!(ApspKind::parse("GEMM"), Some(ApspKind::Gemm));
        assert_eq!(ApspKind::parse("x"), None);
    }

    #[test]
    fn model_names_match_aot() {
        assert_eq!(ApspKind::MinPlus.model_name(), "apsp_minplus");
        assert_eq!(ApspKind::Gemm.model_name(), "apsp_gemm");
    }
}
