//! The paper's headline experiment (Figures 5–8), end to end: simulate the
//! BlueGene/Q-like mixed-radix torus against the symmetric crystal lift of
//! the same size under all four synthetic traffics, and report throughput
//! peaks, gains and latency curves.
//!
//! This is the end-to-end driver required by the reproduction: routing
//! tables are built from the Section 5 algorithms, the INSEE-equivalent
//! engine runs the Table 3 router model, and the coordinator aggregates
//! multi-seed sweeps.
//!
//! Default uses the scaled pair (512 nodes, minutes of CPU); pass `--full`
//! for the paper's 8192/2048-node configurations.
//!
//! ```sh
//! cargo run --release --example simulate_bluegene [-- --full]
//! ```

use lattice_networks::coordinator::experiments as exp;
use lattice_networks::sim::TrafficPattern;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full")
        || std::env::var_os("LATTICE_FULL").is_some();
    let (cfg, seeds) = exp::fig_sim_config(full);
    let loads = exp::default_loads();

    for spec in [exp::fig5_spec(full), exp::fig6_spec(full)] {
        eprintln!(
            "simulating {} : {} vs {} (4 traffics x {} loads x {} seeds)...",
            spec.id, spec.torus.0, spec.lattice.0, loads.len(), seeds
        );
        let t0 = std::time::Instant::now();
        let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &loads, seeds, cfg.clone())?;
        eprintln!("  done in {:.1}s", t0.elapsed().as_secs_f64());
        print!("{}", exp::throughput_table(&fig).render());
        print!("{}", exp::gain_table(&fig).render());
        print!("{}", exp::curve_table(&fig).render());
    }
    Ok(())
}
