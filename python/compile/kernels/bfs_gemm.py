"""L1 Pallas kernel: BFS frontier expansion as a *real* matmul (MXU path).

Distance-by-reachability: let ``R_t`` be the 0/1 reachability-within-t-hops
matrix (R_0 = I). One expansion step is

    R_{t+1} = ((R_t @ (I + A)) > 0)          -- a plain GEMM + threshold
    D      += (R_{t+1} == 0)                 -- unreached pairs age by one hop

After T >= diameter steps, ``D[i, j]`` equals the hop distance (pairs never
reached keep D = T, which the Rust side treats as "disconnected/overflow").

Unlike min-plus (see minplus.py), the inner product here is a *true*
multiply-accumulate over f32, i.e. exactly the operation the TPU MXU
systolic array implements — this is the kernel we would deploy on real
hardware, with the threshold/accumulate epilogue on the VPU. The BlockSpec
schedule is the canonical blocked GEMM: (bm, bk) x (bk, bn) VMEM panels,
reduction axis innermost, accumulator resident in the output block.

interpret=True for the same CPU-PJRT reason as minplus.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128


def _gemm_threshold_kernel(r_ref, m_ref, o_ref):
    """Blocked GEMM accumulating into the resident output block, with a
    ``> 0`` threshold epilogue applied on the final reduction step."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    # The MXU-shaped inner product. preferred_element_type pins the
    # accumulator to f32 regardless of input dtype (bf16-able on real TPUs).
    partial = jnp.dot(r_ref[...], m_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(k != 0)
    def _accum():
        o_ref[...] += partial

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = (o_ref[...] > 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def expand_frontier(
    reach: jax.Array, m: jax.Array, *, block: int = DEFAULT_BLOCK
) -> jax.Array:
    """One BFS expansion: (reach @ m > 0) as 0/1 f32, via the Pallas kernel.

    ``m`` should be I + A (0/1 adjacency plus identity). Shapes (n, n) with
    n divisible by ``block`` (aot.py pads to the artifact size).
    """
    n = reach.shape[0]
    assert reach.shape == (n, n) and m.shape == (n, n)
    bs = min(block, n)
    assert n % bs == 0, f"n={n} not divisible by block={bs}"
    grid = (n // bs, n // bs, n // bs)
    return pl.pallas_call(
        _gemm_threshold_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, k)),
            pl.BlockSpec((bs, bs), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(reach, m)
