#!/usr/bin/env python3
"""Summarize a lattice-networks telemetry trace (JSONL).

Reads the packet-lifecycle trace written by `--trace <path>` (one JSON
object per line, discriminated by "ev" — schema documented in
rust/src/sim/telemetry/trace.rs and DESIGN.md §Telemetry) and prints:

  - event counts per kind;
  - the stall-cause breakdown (credit / link / bubble / nic) with shares,
    plus the escape-drain count;
  - the per-port-class occupancy time series from the periodic probes
    (downsampled to at most 20 rows), alongside active-set size,
    in-flight phits and injection backlog;
  - the busiest directed links by hop-event traffic.

Stdlib only. Usage:

  lattice-networks workload --topology torus:16x16x16 --workload alltoall \
      --route-policy adaptive --seeds 1 \
      --trace /tmp/trace.jsonl --sample-every 100
  python3 scripts/trace_summary.py /tmp/trace.jsonl
"""

import json
import sys
from collections import Counter

MAX_SERIES_ROWS = 20
TOP_LINKS = 10

STALL_CAUSES = {
    "credit": "credit-starved",
    "link": "link-busy",
    "bubble": "bubble-blocked",
    "nic": "nic-serialization",
}


def summarize(path):
    events = Counter()
    stalls = Counter()
    escapes = 0
    links = Counter()  # (from, to) -> hop transfers
    probes = []  # (t, active, inflight, inj_backlog, port_occ)
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                sys.exit(f"{path}:{lineno}: not JSON: {e}")
            kind = ev.get("ev")
            if kind is None:
                sys.exit(f"{path}:{lineno}: missing 'ev' discriminator")
            events[kind] += 1
            if kind == "stall":
                stalls[ev["cause"]] += 1
            elif kind == "hop":
                links[(ev["from"], ev["to"])] += 1
                escapes += ev["esc"]
            elif kind == "probe":
                probes.append(
                    (
                        ev["t"],
                        ev["active"],
                        ev["inflight_phits"],
                        ev["inj_backlog"],
                        ev["port_occ"],
                    )
                )
    return events, stalls, escapes, links, probes


def print_events(events):
    print("== events ==")
    for kind, n in sorted(events.items(), key=lambda kv: -kv[1]):
        print(f"  {kind:<12} {n:>12,}")
    print(f"  {'total':<12} {sum(events.values()):>12,}")


def print_stalls(stalls, escapes):
    print("\n== stall-cause breakdown ==")
    total = sum(stalls.values())
    if total == 0:
        print("  no stall events (uncongested run)")
    for cause, label in STALL_CAUSES.items():
        n = stalls.get(cause, 0)
        share = 100.0 * n / total if total else 0.0
        print(f"  {label:<18} {n:>12,}  {share:5.1f}%")
    unknown = set(stalls) - set(STALL_CAUSES)
    if unknown:
        sys.exit(f"unknown stall causes in trace: {sorted(unknown)}")
    print(f"  {'escape drains':<18} {escapes:>12,}")


def print_series(probes):
    print("\n== probe time series ==")
    if not probes:
        print("  no probes (run without --sample-every)")
        return
    ports = len(probes[0][4])
    head = "  " + f"{'t':>8} {'active':>8} {'inflight':>9} {'backlog':>8}"
    head += "".join(f" {'occ[' + str(p) + ']':>8}" for p in range(ports))
    print(head)
    step = max(1, (len(probes) + MAX_SERIES_ROWS - 1) // MAX_SERIES_ROWS)
    shown = probes[::step]
    if shown[-1] is not probes[-1]:
        shown.append(probes[-1])  # always show the final sample
    for t, active, inflight, backlog, occ in shown:
        row = f"  {t:>8} {active:>8} {inflight:>9} {backlog:>8}"
        row += "".join(f" {x:>8}" for x in occ)
        print(row)
    if step > 1:
        print(f"  ({len(probes)} samples, downsampled 1:{step})")


def print_links(links):
    print("\n== busiest links (hop transfers) ==")
    if not links:
        print("  no hop events")
        return
    for (u, v), n in links.most_common(TOP_LINKS):
        print(f"  {u:>6} -> {v:<6} {n:>10,}")


def main():
    if len(sys.argv) != 2:
        sys.exit(__doc__.strip())
    path = sys.argv[1]
    events, stalls, escapes, links, probes = summarize(path)
    if not events:
        sys.exit(f"{path}: empty trace")
    print_events(events)
    print_stalls(stalls, escapes)
    print_series(probes)
    print_links(links)


if __name__ == "__main__":
    main()
