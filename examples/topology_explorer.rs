//! Topology explorer: reproduce the paper's §3.4 comparison story across
//! sizes — crystals vs equal-order mixed-radix tori — and print the
//! power-of-two upgrade path PC(a) → FCC(a) → BCC(a) → PC(2a).
//!
//! ```sh
//! cargo run --release --example topology_explorer
//! ```

use lattice_networks::coordinator::report::{f, Table};
use lattice_networks::metrics::{distance_distribution, formulas, max_throughput_bound};
use lattice_networks::topology;

fn main() {
    // Crystal vs torus at every matched order.
    let mut t = Table::new(
        "crystals vs equal-order mixed-radix tori",
        &["nodes", "topology", "diameter", "avg dist", "thrpt bound", "symmetric"],
    );
    for a in [4i64, 8] {
        let pairs: Vec<(String, lattice_networks::lattice::LatticeGraph)> = vec![
            (format!("FCC({a})"), topology::fcc(a)),
            (format!("T({},{a},{a})", 2 * a), topology::torus(&[2 * a, a, a])),
            (format!("BCC({a})"), topology::bcc(a)),
            (format!("T({},{},{a})", 2 * a, 2 * a), topology::torus(&[2 * a, 2 * a, a])),
        ];
        for (name, g) in pairs {
            let s = distance_distribution(&g);
            let b = max_throughput_bound(&g);
            t.row(vec![
                g.order().to_string(),
                name,
                s.diameter.to_string(),
                f(s.avg_distance, 3),
                f(b.phits_per_cycle_node, 4),
                g.is_symmetric().to_string(),
            ]);
        }
    }
    print!("{}", t.render());

    let (fcc_gain, bcc_gain) = lattice_networks::metrics::throughput::section34_gains(16);
    println!(
        "§3.4 headline gains at a=16: FCC {:+.0}% vs T(2a,a,a); BCC {:+.0}% vs T(2a,2a,a)\n",
        fcc_gain * 100.0,
        bcc_gain * 100.0
    );

    // The upgrade path: every power-of-two order has a symmetric crystal.
    let mut up = Table::new(
        "power-of-two upgrade path (§3.4): PC(a) → FCC(a) → BCC(a) → PC(2a)",
        &["step", "nodes", "diameter", "avg dist (model)"],
    );
    for t_exp in 1..=3u32 {
        let a = 2i64.pow(t_exp);
        let steps: Vec<(String, usize, usize, f64)> = vec![
            (
                format!("PC({a})"),
                topology::pc(a).order(),
                distance_distribution(&topology::pc(a)).diameter,
                formulas::avg_distance_pc(a),
            ),
            (
                format!("FCC({a})"),
                topology::fcc(a).order(),
                distance_distribution(&topology::fcc(a)).diameter,
                formulas::avg_distance_fcc(a),
            ),
            (
                format!("BCC({a})"),
                topology::bcc(a).order(),
                distance_distribution(&topology::bcc(a)).diameter,
                formulas::avg_distance_bcc(a),
            ),
        ];
        for (name, nodes, dia, avg) in steps {
            up.row(vec![name, nodes.to_string(), dia.to_string(), f(avg, 3)]);
        }
    }
    print!("{}", up.render());

    // Table 2 candidates at a glance.
    println!();
    print!(
        "{}",
        lattice_networks::coordinator::experiments::table2(&[2]).render()
    );
}
