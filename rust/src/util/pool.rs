//! Scoped helper-thread primitives (offline build — no rayon).
//!
//! Three abstractions, two consumers:
//!
//! - [`par_map`] — fork/join over an index range, returning results in
//!   input order. Used by the workload runner's multi-seed fan-out.
//! - [`with_helpers`] — raw scoped helpers running alongside the calling
//!   thread. Used by the parallel cycle engine, whose workers park on
//!   barriers across many cycles instead of forking per call.
//! - [`SpinBarrier`] — a sense-reversing hybrid spin-then-park barrier
//!   for the engine's per-cycle rendezvous, where a `std::sync::Barrier`
//!   (mutex + condvar on every crossing) costs more than the phase it
//!   fences.
//!
//! `par_map` and `with_helpers` are built on `std::thread::scope`, so
//! helper lifetimes are bounded by the call and borrowed captures need
//! no `'static`.
//!
//! # Send/Sync contract
//!
//! Results crossing from a helper back to the caller must be `T: Send`
//! (enforced by the bound on [`par_map`]); the closures run concurrently
//! on several threads and so must be `Sync` (shared by reference) with
//! any interior mutation synchronized by the caller. Both consumers use
//! the *exclusive-ownership hand-off* pattern: a storage slot is touched
//! by at most one thread at a time, with the transfer of ownership
//! ordered by a synchronizing operation (the scope join for `par_map`,
//! barrier generations for the engine), so the slot itself needs no
//! lock — see [`SlotCell`] and the engine's `CtxCell`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread::Thread;

/// Spin iterations a [`SpinBarrier`] waiter burns before parking. The
/// engine's Phase B lasts microseconds, so waiters nearly always catch
/// the release while spinning; the park path exists for oversubscribed
/// hosts and for the long gaps of a serial-fast-path stretch (helpers
/// sleep instead of burning a core).
const SPIN_LIMIT: usize = 1 << 14;

/// Sense-reversing hybrid spin-then-park barrier.
///
/// A crossing is one *generation*: the first `parties - 1` arrivals wait
/// for the generation counter to advance — spinning up to a budget, then
/// parking — and the last arrival advances it and unparks any sleepers.
/// Against `std::sync::Barrier` this removes the mutex + condvar
/// round-trip from the common (everyone-arrives-promptly) case: arrival
/// is one `fetch_add`, release is one store, and waiters observe it with
/// a plain atomic load.
///
/// # Memory ordering
///
/// The barrier publishes everything written before any party's `wait`
/// to every party after it returns:
///
/// - each arrival's `AcqRel` `fetch_add` on `arrived` makes its prior
///   writes visible to the last arriver (whose own `fetch_add` acquires
///   the whole release sequence);
/// - the last arriver's `Release` store to `generation` (and, on the
///   park path, the mutex critical section) then publishes the combined
///   history to every waiter, which observes it with an `Acquire` load.
///
/// `parties <= 1` crossings return immediately — the engine's serial
/// path costs nothing.
///
/// # Parking protocol
///
/// A waiter that exhausts its spin budget registers its [`Thread`]
/// handle under the `parked` mutex, *re-checking the generation inside
/// the critical section*: the releaser bumps the generation before
/// taking the same mutex to drain sleepers, so a waiter that saw the old
/// generation while holding the lock is guaranteed to be in the list
/// when the releaser drains it — no lost wakeup. Spurious unparks (a
/// next-generation waiter registered before an old drain finished, or a
/// stray token) are tolerated: the park loop re-checks the generation
/// after every wake.
pub struct SpinBarrier {
    parties: usize,
    spin: usize,
    arrived: AtomicUsize,
    generation: AtomicU64,
    parked: Mutex<Vec<Thread>>,
}

impl SpinBarrier {
    /// Barrier for `parties` threads with the default spin budget.
    pub fn new(parties: usize) -> Self {
        Self::with_spin(parties, SPIN_LIMIT)
    }

    /// Barrier with an explicit spin budget (`0` parks immediately —
    /// used by tests to force the slow path, and useful when waits are
    /// known to be long).
    pub fn with_spin(parties: usize, spin: usize) -> Self {
        Self {
            parties,
            spin,
            arrived: AtomicUsize::new(0),
            generation: AtomicU64::new(0),
            parked: Mutex::new(Vec::new()),
        }
    }

    /// Block until all `parties` threads have called `wait` for this
    /// generation.
    pub fn wait(&self) {
        if self.parties <= 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.arrived.fetch_add(1, Ordering::AcqRel) + 1 == self.parties {
            // Last arriver: reset the count for the next generation
            // (no party can re-arrive until the generation advances,
            // and the Release store below publishes the reset), open
            // the generation, and wake sleepers.
            self.arrived.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
            let mut parked = self.parked.lock().expect("barrier waiter panicked");
            for t in parked.drain(..) {
                t.unpark();
            }
            return;
        }
        for _ in 0..self.spin {
            if self.generation.load(Ordering::Acquire) != gen {
                return;
            }
            std::hint::spin_loop();
        }
        loop {
            {
                let mut parked = self.parked.lock().expect("barrier releaser panicked");
                if self.generation.load(Ordering::Acquire) != gen {
                    return;
                }
                parked.push(std::thread::current());
            }
            std::thread::park();
            if self.generation.load(Ordering::Acquire) != gen {
                return;
            }
        }
    }
}

/// Run `main` on the calling thread while `threads - 1` scoped helpers
/// run `helper(w)` for `w` in `1..threads` (the caller is worker 0).
/// Returns `main`'s value after every helper has exited.
///
/// Helpers are named `lattice-w{N}` so profiles, ThreadSanitizer
/// reports, and debugger thread lists identify which shard worker is
/// which.
///
/// With `threads <= 1` no thread is spawned and `main` simply runs —
/// callers get a zero-overhead serial path for free.
pub fn with_helpers<R>(
    threads: usize,
    helper: impl Fn(usize) + Sync,
    main: impl FnOnce() -> R,
) -> R {
    if threads <= 1 {
        return main();
    }
    std::thread::scope(|scope| {
        for w in 1..threads {
            let helper = &helper;
            std::thread::Builder::new()
                .name(format!("lattice-w{w}"))
                .spawn_scoped(scope, move || helper(w))
                .expect("failed to spawn helper thread");
        }
        main()
    })
}

/// One result slot of [`par_map`], written without a lock.
///
/// # Safety
///
/// The atomic work cursor hands each index to exactly one worker, which
/// is the only thread that ever writes slot `i`; no thread reads a slot
/// before `std::thread::scope` joins every helper, and the join
/// synchronizes-with each helper's writes. So all access is exclusive
/// and ordered — the `Sync` impl only asserts that hand-off discipline,
/// which is why it needs no more than the `T: Send` the public bound
/// already demands. A worker panic propagates out of the scope and the
/// slots are never read.
struct SlotCell<T>(UnsafeCell<Option<T>>);

unsafe impl<T: Send> Sync for SlotCell<T> {}

/// Map `f` over `0..n` on up to `workers` threads (`0` = one per
/// available core), returning results in input order. Work is claimed
/// dynamically (atomic cursor), so uneven item costs balance
/// automatically. One worker (or `n <= 1`) runs serially on the caller
/// with no spawning or locking.
///
/// Results land in a pre-sized slot per job: the cursor hands each `i`
/// to exactly one worker, which writes job `i`'s result straight into
/// slot `i` — no shared results vector to fight over, no post-run sort,
/// and (per the [`SlotCell`] ownership argument) no per-slot lock.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = if workers > 0 {
        workers
    } else {
        std::thread::available_parallelism().map_or(1, |w| w.get())
    }
    .min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<SlotCell<T>> = (0..n).map(|_| SlotCell(UnsafeCell::new(None))).collect();
    let work = |_w: usize| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let v = f(i);
        // Safety: the cursor gave `i` to this worker alone; see
        // `SlotCell`.
        unsafe { *slots[i].0.get() = Some(v) };
    };
    with_helpers(workers, &work, || work(0));
    slots
        .into_iter()
        .map(|slot| slot.0.into_inner().expect("par_map slot left unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    #[test]
    fn par_map_matches_serial_in_order() {
        let serial: Vec<usize> = (0..37).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 64] {
            assert_eq!(par_map(37, workers, |i| i * i), serial, "workers={workers}");
        }
        assert_eq!(par_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn with_helpers_runs_every_worker_once() {
        let hits = AtomicUsize::new(0);
        let r = with_helpers(
            5,
            |w| {
                assert!((1..5).contains(&w));
                hits.fetch_add(w, Ordering::Relaxed);
            },
            || 42,
        );
        assert_eq!(r, 42);
        assert_eq!(hits.load(Ordering::Relaxed), 1 + 2 + 3 + 4);
    }

    #[test]
    fn with_helpers_serial_spawns_nothing() {
        // threads <= 1: the helper closure must never run.
        let r = with_helpers(1, |_| panic!("helper ran"), || 7);
        assert_eq!(r, 7);
        let r = with_helpers(0, |_| panic!("helper ran"), || 8);
        assert_eq!(r, 8);
    }

    #[test]
    fn with_helpers_names_threads() {
        with_helpers(
            3,
            |w| {
                let name = std::thread::current().name().map(str::to_owned);
                assert_eq!(name.as_deref(), Some(format!("lattice-w{w}").as_str()));
            },
            || (),
        );
    }

    /// The engine's usage pattern: alternating phases fenced by two
    /// barriers, with a counter asserting that no thread enters phase
    /// `r + 1` before all increments of phase `r` are visible.
    fn phase_lockstep(parties: usize, spin: usize, rounds: usize) {
        let enter = SpinBarrier::with_spin(parties, spin);
        let exit = SpinBarrier::with_spin(parties, spin);
        let counter = AtomicUsize::new(0);
        let body = |w: usize| {
            for r in 0..rounds {
                if spin == 0 && w == r % parties {
                    // Stagger one arrival so the others exhaust their
                    // (zero) budget and actually park.
                    std::thread::sleep(Duration::from_millis(1));
                }
                counter.fetch_add(1, Ordering::Relaxed);
                enter.wait();
                assert_eq!(counter.load(Ordering::Relaxed), (r + 1) * parties);
                exit.wait();
            }
        };
        with_helpers(parties, &body, || body(0));
    }

    #[test]
    fn spin_barrier_orders_phases_across_rounds() {
        for parties in [1usize, 2, 3, 4, 7] {
            phase_lockstep(parties, SPIN_LIMIT, 200);
        }
    }

    #[test]
    fn spin_barrier_park_path_orders_phases() {
        // Zero spin budget forces every waiter through park/unpark.
        for parties in [2usize, 3, 4] {
            phase_lockstep(parties, 0, 25);
        }
    }
}
