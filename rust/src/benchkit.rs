//! Minimal benchmarking harness (offline build — no criterion; see
//! DESIGN.md §Substitutions).
//!
//! Provides warmup + repeated timed runs with median/mean/min reporting in
//! a criterion-like text format, plus throughput annotations. Benches are
//! `harness = false` binaries that call [`Bench::run`].
//!
//! ## Machine-readable output
//!
//! Every measurement can additionally be recorded as a JSON record
//! `{bench, case, iters, mean_ns, median_ns, min_ns, throughput, extra}`
//! (`throughput` is `{per_sec, unit}` for [`Bench::run_throughput`]
//! cases, `null` otherwise; `extra` is a caller-supplied raw JSON value
//! from [`Bench::run_throughput_extra`] — e.g. the table-build bench's
//! `{"route_bytes_per_node": …}` — `null` otherwise). Two ways to turn
//! it on:
//!
//! - `BENCH_JSON=<path>` in the environment, or
//! - `--json <path>` on the bench binary's command line (i.e.
//!   `cargo bench --bench engine_scaling -- --json out.json`; the flag
//!   wins over the environment variable).
//!
//! Both are handled by [`Bench::new`], so every bench binary supports
//! them without opt-in code.
//!
//! The file is written as one JSON array when the [`Bench`] drops (or on
//! an explicit [`Bench::flush_json`]) — the format behind the repo's
//! `BENCH_*.json` perf-trajectory points.

use std::path::PathBuf;
use std::time::{Duration, Instant};

/// One benchmark group.
pub struct Bench {
    name: String,
    /// Minimum wall time to spend measuring each case.
    pub budget: Duration,
    /// Max iterations per case.
    pub max_iters: u32,
    /// JSON sink: destination path + records accumulated so far.
    json: Option<(PathBuf, Vec<String>)>,
}

/// Measurement summary.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let budget_ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(600u64);
        let json = json_path_from_args()
            .or_else(|| std::env::var_os("BENCH_JSON").map(PathBuf::from))
            .map(|p| (p, Vec::new()));
        Self {
            name: name.to_string(),
            budget: Duration::from_millis(budget_ms),
            max_iters: 1000,
            json,
        }
    }

    /// Record measurements to a JSON file at `path` (overrides a
    /// `BENCH_JSON` destination).
    pub fn with_json_path(&mut self, path: impl Into<PathBuf>) -> &mut Self {
        self.json = Some((path.into(), self.json.take().map(|(_, r)| r).unwrap_or_default()));
        self
    }

    /// Time `f`, printing a criterion-like line. Returns the sample.
    pub fn run<F: FnMut()>(&mut self, case: &str, f: F) -> Sample {
        let s = self.measure(case, f);
        self.record(case, s, None, None);
        s
    }

    /// Like [`run`](Self::run) but annotates a throughput figure computed
    /// from the median (`items` per iteration).
    pub fn run_throughput<F: FnMut()>(
        &mut self,
        case: &str,
        items: u64,
        unit: &str,
        f: F,
    ) -> Sample {
        let s = self.measure(case, f);
        let per_sec = items as f64 / s.median.as_secs_f64();
        println!("{}/{:<40} thrpt: {:.3e} {unit}/s", self.name, case, per_sec);
        self.record(case, s, Some((per_sec, unit)), None);
        s
    }

    /// Like [`run_throughput`](Self::run_throughput) but additionally
    /// stores `extra` — which must be a valid raw JSON value — in the
    /// record's `extra` field (size accounting and other non-timing
    /// figures a gate wants alongside the sample).
    pub fn run_throughput_extra<F: FnMut()>(
        &mut self,
        case: &str,
        items: u64,
        unit: &str,
        extra: &str,
        f: F,
    ) -> Sample {
        let s = self.measure(case, f);
        let per_sec = items as f64 / s.median.as_secs_f64();
        println!("{}/{:<40} thrpt: {:.3e} {unit}/s  extra: {extra}", self.name, case, per_sec);
        self.record(case, s, Some((per_sec, unit)), Some(extra));
        s
    }

    fn measure<F: FnMut()>(&self, case: &str, mut f: F) -> Sample {
        // Warmup.
        f();
        let mut times: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && (times.len() as u32) < self.max_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let min = times[0];
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let s = Sample { iters: times.len() as u32, mean, median, min };
        println!(
            "{}/{:<40} time: [{} {} {}]  ({} iters)",
            self.name,
            case,
            fmt_dur(min),
            fmt_dur(median),
            fmt_dur(mean),
            s.iters
        );
        s
    }

    fn record(&mut self, case: &str, s: Sample, thrpt: Option<(f64, &str)>, extra: Option<&str>) {
        let Some((_, records)) = self.json.as_mut() else { return };
        let throughput = match thrpt {
            Some((per_sec, unit)) => {
                format!("{{\"per_sec\":{per_sec:.3},\"unit\":\"{}\"}}", json_escape(unit))
            }
            None => "null".to_string(),
        };
        records.push(format!(
            "{{\"bench\":\"{}\",\"case\":\"{}\",\"iters\":{},\"mean_ns\":{},\"median_ns\":{},\"min_ns\":{},\"throughput\":{},\"extra\":{}}}",
            json_escape(&self.name),
            json_escape(case),
            s.iters,
            s.mean.as_nanos(),
            s.median.as_nanos(),
            s.min.as_nanos(),
            throughput,
            extra.unwrap_or("null"),
        ));
    }

    /// Write the accumulated JSON records (a no-op without a sink). Runs
    /// automatically on drop; explicit calls let a bench flush early.
    pub fn flush_json(&mut self) -> std::io::Result<()> {
        let Some((path, records)) = self.json.as_ref() else { return Ok(()) };
        let body = format!("[\n{}\n]\n", records.join(",\n"));
        std::fs::write(path, body)?;
        eprintln!("wrote {} bench records to {}", records.len(), path.display());
        Ok(())
    }
}

impl Drop for Bench {
    fn drop(&mut self) {
        if let Err(e) = self.flush_json() {
            eprintln!("benchkit: failed to write JSON records: {e}");
        }
    }
}

/// The `--json <path>` argument of the binary's command line, if any
/// (benches are `harness = false`, so everything after `cargo bench ... --`
/// arrives in `std::env::args`). Consulted by [`Bench::new`].
fn json_path_from_args() -> Option<PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            return args.next().map(PathBuf::from);
        }
        if let Some(p) = a.strip_prefix("--json=") {
            return Some(PathBuf::from(p));
        }
    }
    None
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new("test");
        b.json = None; // keep unit tests hermetic even if BENCH_JSON is set
        b.budget = Duration::from_millis(5);
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(s.iters >= 1);
        assert!(s.min <= s.median && s.median <= s.mean.max(s.median));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }

    #[test]
    fn json_records_have_the_contract_shape() {
        let dir = std::env::temp_dir().join("benchkit_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        {
            let mut b = Bench::new("grp");
            b.budget = Duration::from_millis(2);
            b.max_iters = 3;
            b.with_json_path(&path);
            b.run("plain \"case\"", || {
                black_box(1 + 1);
            });
            b.run_throughput("tp", 100, "node-cycles", || {
                black_box(2 + 2);
            });
            b.run_throughput_extra("tpx", 100, "nodes", "{\"route_bytes_per_node\":12.5}", || {
                black_box(3 + 3);
            });
        } // drop flushes
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n") && text.ends_with("]\n"), "array framing: {text}");
        let keys = ["\"bench\":\"grp\"", "\"iters\":", "\"mean_ns\":", "\"median_ns\":", "\"min_ns\":"];
        for key in keys {
            assert_eq!(text.matches(key).count(), 3, "all records carry {key}: {text}");
        }
        assert!(text.contains("\\\"case\\\""), "quotes escaped: {text}");
        assert_eq!(text.matches("\"throughput\":null").count(), 1, "{text}");
        assert!(text.contains("\"unit\":\"node-cycles\""), "{text}");
        assert_eq!(text.matches("\"extra\":null").count(), 2, "{text}");
        assert!(text.contains("\"extra\":{\"route_bytes_per_node\":12.5}"), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\u000ay");
        assert_eq!(json_escape("plain"), "plain");
    }
}
