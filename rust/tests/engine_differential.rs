//! Active-set vs full-scan differential pins (DESIGN.md
//! §Engine-performance): the activity-proportional engine path must be
//! **bit-exact** with the retained full-network reference scan — same
//! `SimResult` / `WorkloadOutcome` down to every counter and latency
//! statistic, and the same RNG end-state (`rng_digest`), across policies,
//! VC counts, loads, seeds and both run regimes. Any divergence means the
//! worklist maintenance visited a node the full scan would not have acted
//! on (or vice versa), or perturbed the order RNG draws are consumed in.

use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};
use lattice_networks::workload::{Workload, WorkloadMessage};

/// Thread count under test: CI's `parallel-differential` job sweeps
/// `LATTICE_THREADS` over its matrix so every pin in this file doubles as
/// a serial-vs-parallel differential; unset means the serial default.
fn env_threads() -> usize {
    std::env::var("LATTICE_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Quick windows with a drain tail, so the differential covers the
/// drain regime (the scans run on an emptying network) too.
fn base_cfg(policy: RoutePolicy, num_vcs: usize, scan: ScanMode) -> SimConfig {
    SimConfig {
        warmup_cycles: 100,
        measure_cycles: 500,
        drain_cycles: 150,
        route_policy: policy,
        num_vcs,
        scan_mode: scan,
        threads: env_threads(),
        ..SimConfig::default()
    }
}

#[test]
fn open_loop_matches_full_scan_across_policy_vc_load_seed() {
    // T(8,4) has DOR-visible asymmetry and tie-heavy half-ring records;
    // FCC(2) is a twisted (non-torus) lattice.
    for g in [topology::torus(&[8, 4]), topology::fcc(2)] {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2] {
                for load in [0.1, 0.9] {
                    for seed in [1u64, 0xdead_beef] {
                        let run = |scan: ScanMode| {
                            let sim = Simulator::new(
                                g.clone(),
                                TrafficPattern::Uniform,
                                base_cfg(policy, num_vcs, scan),
                            );
                            sim.run_seeded(load, seed)
                        };
                        let a = run(ScanMode::ActiveSet);
                        let f = run(ScanMode::FullScan);
                        assert_eq!(
                            a.rng_digest,
                            f.rng_digest,
                            "RNG stream diverged: {} vcs={num_vcs} load={load} seed={seed}",
                            policy.name()
                        );
                        assert_eq!(
                            format!("{a:?}"),
                            format!("{f:?}"),
                            "result diverged: {} vcs={num_vcs} load={load} seed={seed}",
                            policy.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn closed_loop_matches_full_scan_across_policy_vc_seed() {
    let g = topology::torus(&[4, 4]);
    // A contended collective (alltoall) plus a dependency-chained stencil:
    // between them they exercise NIC serialization, dependency release,
    // head-of-line blocking and the drain tail.
    let alltoall = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    let stencil =
        generate(WorkloadKind::Stencil, &g, &WorkloadParams { iters: 3, ..Default::default() });
    for wl in [&alltoall, &stencil] {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2, 3] {
                for seed in [7u64, 99] {
                    let run = |scan: ScanMode| {
                        let cfg = base_cfg(policy, num_vcs, scan);
                        let cap = wl.suggested_max_cycles_for(&cfg);
                        Simulator::for_workload(g.clone(), cfg).run_workload_seeded(wl, seed, cap)
                    };
                    let a = run(ScanMode::ActiveSet);
                    let f = run(ScanMode::FullScan);
                    assert!(a.drained, "{} {} vcs={num_vcs}", wl.name, policy.name());
                    assert_eq!(
                        a.rng_digest,
                        f.rng_digest,
                        "RNG stream diverged: {} {} vcs={num_vcs} seed={seed}",
                        wl.name,
                        policy.name()
                    );
                    assert_eq!(
                        format!("{a:?}"),
                        format!("{f:?}"),
                        "outcome diverged: {} {} vcs={num_vcs} seed={seed}",
                        wl.name,
                        policy.name()
                    );
                }
            }
        }
    }
}

/// The LogGP knobs put future-dated ready times into the NIC send queues
/// (gap pacing, send/recv overheads) and stretch head flight
/// (`link_latency`) — a sender with nothing ready *now* must stay on the
/// worklist, not vanish. Multi-packet trains add injection-queue
/// head-of-line blocking on top.
#[test]
fn closed_loop_matches_full_scan_under_loggp_overheads_and_trains() {
    let g = topology::torus(&[4, 4]);
    let wl = generate(
        WorkloadKind::RingAllReduce,
        &g,
        &WorkloadParams { iters: 2, payload_phits: 80, ..Default::default() },
    );
    for policy in [RoutePolicy::Dor, RoutePolicy::AdaptiveMin] {
        for seed in [3u64, 21] {
            let run = |scan: ScanMode| {
                let cfg = SimConfig {
                    send_overhead: 12,
                    recv_overhead: 9,
                    packet_gap: 21,
                    link_latency: 3,
                    ..base_cfg(policy, 2, scan)
                };
                let cap = wl.suggested_max_cycles_for(&cfg);
                Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, seed, cap)
            };
            let a = run(ScanMode::ActiveSet);
            let f = run(ScanMode::FullScan);
            assert!(a.drained, "{} seed={seed}", policy.name());
            assert_eq!(format!("{a:?}"), format!("{f:?}"), "{} seed={seed}", policy.name());
        }
    }
}

/// An undrained (cycle-capped) run must agree between the scan modes too:
/// the cap cuts the simulation mid-flight, where any stale-worklist bug
/// (a node dropped while still holding traffic) shows up as differing
/// delivery counts.
#[test]
fn capped_undrained_runs_agree_between_scan_modes() {
    let g = topology::torus(&[4, 4]);
    let n = g.order() as u32;
    let messages =
        (0..n).map(|u| WorkloadMessage::new(u, (u + 5) % n, 0, vec![])).collect();
    let wl = Workload { name: "cut-short".into(), nodes: g.order(), messages };
    for cap in [3u64, 10, 25] {
        let run = |scan: ScanMode| {
            let cfg = base_cfg(RoutePolicy::AdaptiveMin, 2, scan);
            Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, 5, cap)
        };
        let a = run(ScanMode::ActiveSet);
        let f = run(ScanMode::FullScan);
        assert!(!a.drained, "cap {cap} unexpectedly drained");
        assert_eq!(format!("{a:?}"), format!("{f:?}"), "cap {cap}");
    }
}
