//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`, run once via `make
//! artifacts`) lowers the L2 APSP models — whose inner loops are the L1
//! Pallas kernels — to **HLO text**; this module loads that text with
//! `xla::HloModuleProto::from_text_file`, compiles it on the PJRT CPU
//! client, and executes it with topology adjacency matrices padded to the
//! artifact size. Python never runs at request time.
//!
//! HLO *text* (not serialized protos) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.
//!
//! - [`manifest`]: parse `artifacts/manifest.txt` (offline build — no JSON
//!   dependency; aot.py writes both forms).
//! - [`client`]: PJRT client + compiled-executable cache.
//! - [`apsp`]: the user-facing engine — distance summaries of lattice
//!   graphs computed on the XLA side, cross-validated against native BFS.
//!
//! The XLA backend is gated behind the `pjrt` cargo feature (the `xla`
//! crate cannot be vendored offline). Without it, [`ApspEngine::open`]
//! returns a descriptive error and everything else in the workspace is
//! unaffected.

pub mod apsp;
pub mod client;
pub mod manifest;

pub use apsp::{ApspEngine, ApspKind, DistanceSummary};
pub use client::PjrtRuntime;
pub use manifest::{Artifact, Manifest};

/// Default artifacts directory, overridable with `LATTICE_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("LATTICE_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
