//! Deterministic multi-threaded cycle driver (DESIGN.md
//! §Parallel-engine).
//!
//! Every cycle runs in three phases:
//!
//! - **Phase A (serial)**: the regime-specific closure — probes, calendar
//!   events, injection/packetization, closed-loop completions — followed
//!   by the active-set merge. Runs on the calling thread with exclusive
//!   access to [`State`].
//! - **Phase B (parallel)**: the arbitration kernel over the node space,
//!   sharded into contiguous index ranges (the lattice's natural cut
//!   planes). Each worker mutates only state owned by its shard's nodes
//!   (their FIFOs, occupancy bits, link/eject timers, per-link phit
//!   counters, popped packets) and *defers* every cross-node or global
//!   effect — downstream FIFO pushes, calendar events, stall counters,
//!   per-VC phits, trace events, RNG fingerprints — into its private
//!   [`ShardBuf`].
//! - **Phase C (serial)**: the buffers are merged in shard order, which
//!   is ascending producer-node order — exactly the order the serial
//!   scan produces its side effects in — so every thread count yields a
//!   bit-identical run.
//!
//! Determinism rests on two properties. First, per-node draws come from
//! counter-based streams keyed `(seed, node, cycle)`
//! ([`crate::sim::rng::NodeRng`]), so a node's draw sequence is a pure
//! function of the key — independent of which thread visits it and of
//! what other nodes did. Second, the Phase-B kernel is *pure per node*
//! given the Phase-A state snapshot: the cross-shard values it reads
//! (downstream `reserved` counts for eligibility and adaptive headroom)
//! are constant during Phase B, because pushes are deferred to Phase C
//! and releases happen only in Phase A's calendar drain. The workers
//! synchronize through two [`Barrier`]s per cycle; each worker's scratch
//! lives behind its own (never contended) [`Mutex`], so the exchange is
//! also ThreadSanitizer-clean by construction.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Barrier, Mutex};

use crate::sim::config::ScanMode;
use crate::sim::telemetry::{StallCause, StallCounters};
use crate::util::with_helpers;

use super::arbitration::ArbScratch;
use super::state::{Event, State};
use super::Simulator;

/// A cross-node FIFO push deferred out of Phase B: packet `pid` lands in
/// input FIFO `fi` (global index). The packet's `head_ready` /
/// `next_port` were already written into the arena by the producing
/// worker (the arena entry is owned by the one worker that popped the
/// packet), so the merge only replays the enqueue.
pub(super) struct Push {
    pub(super) fi: u32,
    pub(super) pid: u32,
}

/// A trace event deferred out of Phase B (only `hop` and `stall` occur
/// there; the writer itself is not thread-safe and stays on the main
/// thread). Replayed in shard order at the merge, which reproduces the
/// serial emission order.
pub(super) enum TraceEv {
    Hop { t: u64, land: u64, pid: u32, from: usize, to: usize, port: usize, vc: u8, esc: bool },
    Stall { t: u64, node: usize, port: i64, vc: i64, cause: StallCause },
}

/// Per-shard outbox: every effect of a Phase-B shard scan that crosses a
/// shard boundary or targets global state, in emission order.
pub(super) struct ShardBuf {
    pub(super) pushes: Vec<Push>,
    /// Deferred calendar events as `(delay, event)`; scheduled at the
    /// merge while `now` still names the cycle that produced them. All
    /// Phase-B delays are in `[1, packet_size]`, so no merged event can
    /// land in the calendar slot the current cycle already drained.
    pub(super) events: Vec<(u64, Event)>,
    pub(super) stalls: StallCounters,
    pub(super) vc_phits: Vec<u64>,
    pub(super) trace: Vec<TraceEv>,
    /// Commutative fingerprint of the shard's arbitration draws.
    pub(super) digest: u64,
    pub(super) draws: u64,
}

impl ShardBuf {
    fn new(vcs: usize) -> Self {
        Self {
            pushes: Vec::new(),
            events: Vec::new(),
            stalls: StallCounters::default(),
            vc_phits: vec![0; vcs],
            trace: Vec::new(),
            digest: 0,
            draws: 0,
        }
    }
}

/// One worker's private per-run storage: its outbox and its arbitration
/// scratch. Behind a `Mutex` purely to hand `&mut` access across the
/// scope boundary — worker `w` is the only locker during Phase B and the
/// main thread the only locker during Phase C, so the lock is never
/// contended.
pub(super) struct WorkerCtx {
    buf: ShardBuf,
    scratch: ArbScratch,
}

/// Shared `State` handle for the cycle workers. Safety contract: during
/// Phase B every worker mutates only node-owned state inside its shard
/// (plus arena entries of packets it popped) and reads only
/// phase-constant fields elsewhere; the barriers order those accesses
/// against the serial phases.
struct SharedState(*mut State);
unsafe impl Sync for SharedState {}

impl SharedState {
    /// Callers uphold the shard-disjointness contract above.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut State {
        unsafe { &mut *self.0 }
    }
}

/// Contiguous node ranges, one per worker — the lattice cut planes.
/// Sizes differ by at most one, so a thread count that doesn't divide
/// the node count (the CI matrix includes 7) still covers every node.
fn shard_bounds(nodes: usize, threads: usize) -> Vec<(u32, u32)> {
    let base = nodes / threads;
    let extra = nodes % threads;
    let mut out = Vec::with_capacity(threads);
    let mut lo = 0usize;
    for w in 0..threads {
        let len = base + usize::from(w < extra);
        out.push((lo as u32, (lo + len) as u32));
        lo += len;
    }
    out
}

impl Simulator {
    /// Run the phased cycle loop until `phase_a` returns `false`.
    ///
    /// `phase_a` owns the serial head of each cycle: it advances
    /// `st.now`, drains the calendar, injects/packetizes, and decides
    /// termination. The driver then runs the sharded arbitration kernel
    /// (Phase B) and merges the outboxes (Phase C) with `st.now` still
    /// at the cycle `phase_a` set.
    ///
    /// `threads = 1` runs the identical phase discipline on the calling
    /// thread alone (no helpers are spawned; the barriers are
    /// single-party no-ops), so the serial reference and the parallel
    /// engine are the same code path by construction.
    pub(super) fn run_phased(&self, st: &mut State, mut phase_a: impl FnMut(&mut State) -> bool) {
        let threads = self.cfg.threads.clamp(1, self.nodes);
        let bounds = shard_bounds(self.nodes, threads);
        let ctxs: Vec<Mutex<WorkerCtx>> = (0..threads)
            .map(|_| {
                Mutex::new(WorkerCtx {
                    buf: ShardBuf::new(self.cfg.num_vcs),
                    scratch: ArbScratch::new(self.ports + 1),
                })
            })
            .collect();
        let start = Barrier::new(threads);
        let end = Barrier::new(threads);
        let done = AtomicBool::new(false);
        let shared = SharedState(st as *mut State);
        let run_shard = |w: usize| {
            // Safety: shard w mutates only nodes in bounds[w]; see
            // `SharedState`.
            let st = unsafe { shared.get() };
            let ctx = &mut *ctxs[w].lock().expect("cycle worker panicked");
            let (lo, hi) = bounds[w];
            self.advance_shard(st, &mut ctx.buf, &mut ctx.scratch, lo, hi);
        };
        let helper = |w: usize| loop {
            start.wait();
            if done.load(Ordering::Acquire) {
                break;
            }
            run_shard(w);
            end.wait();
        };
        with_helpers(threads, &helper, || {
            loop {
                // Safety: helpers are parked at `start` (or `end` has
                // passed), so the main thread is the only `State` user
                // during Phases A and C.
                let st = unsafe { shared.get() };
                if !phase_a(st) {
                    break;
                }
                if self.cfg.scan_mode == ScanMode::ActiveSet {
                    st.active_nodes.merge();
                }
                start.wait();
                run_shard(0);
                end.wait();
                let st = unsafe { shared.get() };
                self.merge_shards(st, &ctxs);
            }
            done.store(true, Ordering::Release);
            start.wait();
        });
    }

    /// Phase C: drain every shard's outbox into `State`, in shard order
    /// (= ascending producer-node order, the serial scan's emission
    /// order — which is why the merge needs no sort).
    fn merge_shards(&self, st: &mut State, ctxs: &[Mutex<WorkerCtx>]) {
        let vcs = self.cfg.num_vcs;
        let node_base = self.ports * vcs;
        let qcap = self.cfg.queue_packets as usize;
        // Compact the active list *before* the buffered activations land
        // in `pending`: a node dropped by its shard this cycle and
        // re-activated by an incoming push must re-enter through
        // `pending`, keeping `list ∪ pending` disjoint.
        if self.cfg.scan_mode == ScanMode::ActiveSet {
            st.active_nodes.retain_members();
        }
        for ctx in ctxs {
            let ctx = &mut *ctx.lock().expect("cycle worker panicked");
            let buf = &mut ctx.buf;
            st.stalls.accumulate(&buf.stalls);
            buf.stalls = StallCounters::default();
            for (vc, phits) in buf.vc_phits.iter_mut().enumerate() {
                st.phits_by_vc[vc] += *phits;
                *phits = 0;
            }
            st.node_digest = st.node_digest.wrapping_add(buf.digest);
            st.node_draws += buf.draws;
            buf.digest = 0;
            buf.draws = 0;
            for (delay, ev) in buf.events.drain(..) {
                self.schedule(st, delay, ev);
            }
            for push in buf.pushes.drain(..) {
                let fi = push.fi as usize;
                let v = fi / node_base;
                let pkt = st.packets[push.pid as usize];
                let base = fi * qcap;
                st.inputs[fi].push(
                    &mut st.input_slots[base..base + qcap],
                    push.pid,
                    pkt.head_ready,
                    pkt.next_port,
                );
                st.occ[v] |= 1u64 << (fi - v * node_base);
                // The downstream node now holds queued traffic (its head
                // lands at now + latency, so whether it was scanned this
                // cycle moved nothing and drew no RNG either way).
                st.active_nodes.insert(v);
            }
            if let Some(tr) = st.trace.as_mut() {
                for ev in buf.trace.drain(..) {
                    match ev {
                        TraceEv::Hop { t, land, pid, from, to, port, vc, esc } => {
                            tr.hop(t, land, pid, from, to, port, vc, esc)
                        }
                        TraceEv::Stall { t, node, port, vc, cause } => {
                            tr.stall(t, node, port, vc, cause)
                        }
                    }
                }
            } else {
                buf.trace.clear();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::shard_bounds;

    #[test]
    fn shards_partition_the_node_space() {
        for nodes in [1usize, 2, 5, 64, 511, 512] {
            for threads in [1usize, 2, 3, 4, 7] {
                let threads = threads.min(nodes);
                let b = shard_bounds(nodes, threads);
                assert_eq!(b.len(), threads);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[threads - 1].1 as usize, nodes);
                for w in 1..threads {
                    assert_eq!(b[w].0, b[w - 1].1, "contiguous");
                }
                for &(lo, hi) in &b {
                    let len = (hi - lo) as usize;
                    assert!(len >= nodes / threads && len <= nodes / threads + 1);
                }
            }
        }
    }
}
