//! Quickstart: build a crystal network, inspect it, route on it, simulate
//! it, and cross-check distances through the PJRT AOT artifacts.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use lattice_networks::metrics::{distance_distribution, max_throughput_bound};
use lattice_networks::routing::{norm, HierarchicalRouter, Router};
use lattice_networks::runtime::{ApspEngine, ApspKind};
use lattice_networks::sim::{SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;

fn main() -> anyhow::Result<()> {
    // 1. Build BCC(4) — the paper's new 3D symmetric proposal (§3.3).
    let g = topology::bcc(4);
    println!("BCC(4): {} nodes, degree {}", g.order(), g.degree());
    println!("Hermite form:\n{}", g.hermite());

    // 2. Distance structure (Table 1 row).
    let stats = distance_distribution(&g);
    println!(
        "diameter {} (paper: floor(3a/2) = {}), avg distance {:.4}",
        stats.diameter,
        3 * 4 / 2,
        stats.avg_distance
    );
    println!("symmetric: {}", g.is_symmetric());
    let bound = max_throughput_bound(&g);
    println!(
        "uniform-traffic throughput bound: {:.4} phits/cycle/node\n",
        bound.phits_per_cycle_node
    );

    // 3. Minimal routing (Section 5, Algorithm 1/4).
    let router = HierarchicalRouter::new(g.clone());
    let (src, dst) = (vec![1, 5, 2], vec![7, 0, 3]);
    let record = router.route(&src, &dst);
    println!("route {src:?} -> {dst:?}: record {record:?} ({} hops)", norm(&record));

    // 4. One simulation point (§6.2 parameters, Table 3).
    let cfg = SimConfig { warmup_cycles: 500, measure_cycles: 3000, ..SimConfig::default() };
    let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
    let r = sim.run(0.4);
    println!(
        "\nsimulated at offered 0.4: accepted {:.4} phits/cycle/node, avg latency {:.1} cycles",
        r.accepted_load, r.avg_latency
    );

    // 5. Cross-check distances through the XLA/PJRT AOT path (L1 Pallas
    //    kernels lowered by `make artifacts`, executed from Rust).
    match ApspEngine::open_default() {
        Ok(engine) => {
            let out = engine.distance_summary(&g, ApspKind::MinPlus)?;
            println!(
                "\nPJRT min-plus APSP: diameter {}, avg {:.4} (BFS agrees: {})",
                out.diameter,
                out.avg_distance,
                (out.avg_distance - stats.avg_distance).abs() < 1e-6
            );
        }
        Err(e) => println!("\n(skipping PJRT check: {e} — run `make artifacts`)"),
    }
    Ok(())
}
