//! Workload message sets: the closed-loop counterpart of
//! [`crate::sim::TrafficPattern`].
//!
//! A [`Workload`] is a finite set of messages with happens-before
//! dependencies (a DAG). Each message carries a payload of
//! [`size_phits`](WorkloadMessage::size_phits) phits and is packetized by
//! the engine into a train of `ceil(size_phits / packet_size)` packets. The
//! cycle engine injects each message once every message it depends on has
//! been fully received — a message counts as received only when its *last*
//! packet drains ([`crate::sim::Simulator::run_workload`]) — and the figure
//! of merit is **completion time**: how many cycles until the network
//! drains, rather than steady-state latency/throughput.

use crate::sim::SimConfig;

/// Default message payload in phits (one Table 3 packet — the PR 1
/// single-packet model).
pub const DEFAULT_MSG_PHITS: u32 = 16;

/// One message: a `size_phits`-phit payload from `src` to `dst` that may
/// only be injected after all of `deps` (indices into the owning
/// workload's message vector) have been fully received.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkloadMessage {
    pub src: u32,
    pub dst: u32,
    /// Generator phase/round the message belongs to (reporting only).
    pub phase: u32,
    /// Messages that must be fully received before this one is eligible.
    pub deps: Vec<u32>,
    /// Payload in phits; the engine sends `ceil(size_phits / packet_size)`
    /// packets back-to-back from the source NIC.
    pub size_phits: u32,
}

impl WorkloadMessage {
    /// A message with the default single-packet payload
    /// ([`DEFAULT_MSG_PHITS`]).
    pub fn new(src: u32, dst: u32, phase: u32, deps: Vec<u32>) -> Self {
        Self { src, dst, phase, deps, size_phits: DEFAULT_MSG_PHITS }
    }

    /// Packets in this message's train under `packet_size`-phit packets.
    pub fn packets(&self, packet_size: u32) -> u32 {
        debug_assert!(packet_size > 0);
        self.size_phits.div_ceil(packet_size).max(1)
    }
}

/// A finite, dependency-ordered message set for one topology order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Workload {
    /// Display name, e.g. `stencil(iters=8)`.
    pub name: String,
    /// Node count of the topology this was generated for.
    pub nodes: usize,
    pub messages: Vec<WorkloadMessage>,
}

impl Workload {
    /// Number of messages.
    pub fn len(&self) -> usize {
        self.messages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.messages.is_empty()
    }

    /// Number of generator phases (max phase + 1).
    pub fn phases(&self) -> u32 {
        self.messages.iter().map(|m| m.phase + 1).max().unwrap_or(0)
    }

    /// Total payload over all messages, in phits.
    pub fn total_phits(&self) -> u64 {
        self.messages.iter().map(|m| m.size_phits as u64).sum()
    }

    /// Total packets the engine will inject for this workload.
    pub fn total_packets(&self, packet_size: u32) -> u64 {
        self.messages.iter().map(|m| m.packets(packet_size) as u64).sum()
    }

    /// Kahn's algorithm: true iff the dependency graph has no cycle.
    pub fn is_acyclic(&self) -> bool {
        let n = self.messages.len();
        let mut indegree = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, m) in self.messages.iter().enumerate() {
            indegree[i] = m.deps.len() as u32;
            for &d in &m.deps {
                dependents[d as usize].push(i as u32);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &j in &dependents[i] {
                indegree[j as usize] -= 1;
                if indegree[j as usize] == 0 {
                    queue.push(j as usize);
                }
            }
        }
        seen == n
    }

    /// Structural validation: endpoints in range, no self-messages, nonzero
    /// payloads, dep indices in range, and an acyclic dependency graph.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.messages.len() as u32;
        for (i, m) in self.messages.iter().enumerate() {
            if m.src as usize >= self.nodes || m.dst as usize >= self.nodes {
                return Err(format!("message {i}: endpoint out of range"));
            }
            if m.src == m.dst {
                return Err(format!("message {i}: self-message {}->{}", m.src, m.dst));
            }
            if m.size_phits == 0 {
                return Err(format!("message {i}: zero-phit payload"));
            }
            for &d in &m.deps {
                if d >= n {
                    return Err(format!("message {i}: dep {d} out of range"));
                }
                if d as usize == i {
                    return Err(format!("message {i}: depends on itself"));
                }
            }
        }
        if !self.is_acyclic() {
            return Err("dependency graph has a cycle".to_string());
        }
        Ok(())
    }

    /// The workload restricted to messages whose `(src, dst)` pair
    /// satisfies `routable` — the closed-loop engine's degraded-mode mask,
    /// with [`crate::sim::Simulator::fault_routable`] as the predicate:
    /// endpoints alive and at least one admissible minimal record between
    /// them.
    ///
    /// Dropping a message must not strand its dependents, so each
    /// dependent inherits the dropped message's own *kept ancestor
    /// frontier*: the nearest kept messages above it in the dependency
    /// DAG. That preserves every happens-before relation among the
    /// surviving messages (and therefore acyclicity), while letting the
    /// rest of a collective proceed around a dead participant — the
    /// degraded run measures the surviving communication, not a wedged
    /// dependency chain.
    ///
    /// Message order (and so the relative index order of kept messages)
    /// is preserved; dep lists come out sorted and duplicate-free.
    /// Requires an acyclic workload (the engine validates first).
    pub fn mask_unroutable(&self, mut routable: impl FnMut(u32, u32) -> bool) -> Workload {
        let n = self.messages.len();
        let keep: Vec<bool> = self.messages.iter().map(|m| routable(m.src, m.dst)).collect();
        // New index per kept message (original order preserved).
        let mut new_idx = vec![u32::MAX; n];
        let mut kept = 0u32;
        for (i, &k) in keep.iter().enumerate() {
            if k {
                new_idx[i] = kept;
                kept += 1;
            }
        }
        // Kahn order: every message pops after all of its deps, so the
        // frontier of each dep is resolved before its dependents ask for
        // it (deps may point at *later* indices — validate only requires
        // acyclicity, not index order).
        let mut indegree = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, m) in self.messages.iter().enumerate() {
            indegree[i] = m.deps.len() as u32;
            for &d in &m.deps {
                dependents[d as usize].push(i as u32);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for &j in &dependents[i] {
                indegree[j as usize] -= 1;
                if indegree[j as usize] == 0 {
                    queue.push(j as usize);
                }
            }
        }
        debug_assert_eq!(order.len(), n, "mask_unroutable needs an acyclic workload");
        // `frontier[i]`: for a dropped `i`, the new indices of the kept
        // messages standing in for it; for a kept `i`, its final dep list.
        let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &i in &order {
            let mut acc: Vec<u32> = Vec::new();
            for &d in &self.messages[i].deps {
                let d = d as usize;
                if keep[d] {
                    acc.push(new_idx[d]);
                } else {
                    acc.extend_from_slice(&frontier[d]);
                }
            }
            acc.sort_unstable();
            acc.dedup();
            frontier[i] = acc;
        }
        let mut messages = Vec::with_capacity(kept as usize);
        for (i, m) in self.messages.iter().enumerate() {
            if keep[i] {
                messages.push(WorkloadMessage {
                    src: m.src,
                    dst: m.dst,
                    phase: m.phase,
                    deps: std::mem::take(&mut frontier[i]),
                    size_phits: m.size_phits,
                });
            }
        }
        Workload { name: self.name.clone(), nodes: self.nodes, messages }
    }

    /// Conservative cycle cap for [`crate::sim::Simulator::run_workload`]:
    /// generously above any plausible completion time (packet-train
    /// serialization of the busiest source, the busiest destination —
    /// incast — plus the mean per-node backlog), so hitting it signals a
    /// modelling bug, not a slow network.
    ///
    /// Safe on unvalidated input: out-of-range endpoints and dep indices
    /// are skipped (the bound is meaningless for such a workload anyway,
    /// and the engine rejects it with a [`Self::validate`] error before
    /// any run).
    pub fn suggested_max_cycles(&self, packet_size: u32) -> u64 {
        self.max_cycles_inner(packet_size, 0, 0, 0, 1)
    }

    /// [`Self::suggested_max_cycles`] including the config's software
    /// overheads (`o_send`, `o_recv`, inter-packet gap) and per-hop wire
    /// latency (`link_latency`) in the bound.
    pub fn suggested_max_cycles_for(&self, cfg: &SimConfig) -> u64 {
        self.max_cycles_inner(
            cfg.packet_size,
            cfg.send_overhead,
            cfg.recv_overhead,
            cfg.packet_gap,
            cfg.link_latency,
        )
    }

    fn max_cycles_inner(
        &self,
        packet_size: u32,
        o_send: u64,
        o_recv: u64,
        gap: u64,
        link_latency: u64,
    ) -> u64 {
        let n = self.nodes.max(1) as u64;
        let total = self.messages.len();
        let mut total_pkts = 0u64;
        let mut per_src = vec![0u64; self.nodes];
        let mut per_dst = vec![0u64; self.nodes];
        // Packet-weighted endpoint loads (a K-packet message occupies its
        // source NIC and destination ejector K serialization slots).
        // Out-of-range endpoints are skipped, not indexed: the engine
        // computes this cap before validating, and a malformed workload
        // must surface as a `validate` error, not an index panic here.
        for m in &self.messages {
            let pkts = m.packets(packet_size) as u64;
            total_pkts += pkts;
            if let Some(s) = per_src.get_mut(m.src as usize) {
                *s += pkts;
            }
            if let Some(d) = per_dst.get_mut(m.dst as usize) {
                *d += pkts;
            }
        }
        let max_src = per_src.iter().copied().max().unwrap_or(0);
        let max_dst = per_dst.iter().copied().max().unwrap_or(0);
        let backlog = max_src + max_dst + total_pkts / n;
        // Endpoint backlog misses relay chains that visit distinct node
        // pairs (per-node load 1, chain length `total`), so also bound the
        // weighted critical path of the dependency DAG: each link costs
        // its software overheads plus NIC train serialization plus a
        // generous flight allowance (64 hops, each paying the per-hop
        // wire latency — `link_latency` multiplies head flight time, so
        // deep chains under a large LogGP `L` stay inside the cap).
        // Kahn-ordered longest-path DP; nodes on cycles never pop, which
        // is fine — `validate` rejects cycles before any run.
        let flight = 64 * link_latency.max(1);
        let weight = |m: &WorkloadMessage| {
            o_send + o_recv + m.packets(packet_size) as u64 * (packet_size as u64 + gap) + flight
        };
        // Same skip-don't-index rule for dep edges (see the endpoint loop).
        let in_range = |d: u32| (d as usize) < total;
        let mut indegree = vec![0u32; total];
        let mut dep_off = vec![0u32; total + 1];
        for m in &self.messages {
            for &d in &m.deps {
                if in_range(d) {
                    dep_off[d as usize + 1] += 1;
                }
            }
        }
        for i in 0..total {
            dep_off[i + 1] += dep_off[i];
        }
        let mut dependents = vec![0u32; dep_off[total] as usize];
        let mut fill = dep_off.clone();
        for (i, m) in self.messages.iter().enumerate() {
            for &d in &m.deps {
                if in_range(d) {
                    indegree[i] += 1;
                    dependents[fill[d as usize] as usize] = i as u32;
                    fill[d as usize] += 1;
                }
            }
        }
        let mut done: Vec<u64> = self.messages.iter().map(weight).collect();
        let mut queue: Vec<usize> = (0..total).filter(|&i| indegree[i] == 0).collect();
        let mut critical = 0u64;
        while let Some(i) = queue.pop() {
            critical = critical.max(done[i]);
            for k in dep_off[i]..dep_off[i + 1] {
                let j = dependents[k as usize] as usize;
                done[j] = done[j].max(done[i] + weight(&self.messages[j]));
                indegree[j] -= 1;
                if indegree[j] == 0 {
                    queue.push(j);
                }
            }
        }
        50_000
            + 8 * (packet_size as u64 + gap) * backlog
            + 8 * (o_send + o_recv) * backlog
            + 2 * critical
    }
}

/// Result of one closed-loop workload run.
#[derive(Clone, Debug)]
pub struct WorkloadOutcome {
    /// Cycle at which the last message completed — last packet fully
    /// received plus the receive overhead (equals the cycle cap when
    /// `drained` is false).
    pub completion_cycles: u64,
    /// Every message was delivered before the cycle cap.
    pub drained: bool,
    pub delivered_messages: u64,
    pub total_messages: u64,
    /// Payload phits of completed messages (sum of their `size_phits`).
    pub delivered_phits: u64,
    /// Packets drained at their destinations (message trains included).
    pub delivered_packets: u64,
    /// Mean per-message latency: first-packet injection-queue entry to
    /// message completion (last packet drained + receive overhead).
    pub avg_latency: f64,
    /// Median per-message latency (HDR estimate, ≤ 5% relative error —
    /// see [`crate::sim::stats::LatencyStats`]).
    pub p50_latency: f64,
    /// 90th-percentile per-message latency (HDR estimate).
    pub p90_latency: f64,
    /// 99th-percentile per-message latency (HDR estimate).
    pub p99_latency: f64,
    /// 99.9th-percentile per-message latency (HDR estimate).
    pub p999_latency: f64,
    pub max_latency: u64,
    /// Whole-run stall-cause attribution (credit-starved / link-busy /
    /// bubble-blocked / NIC-serialization) plus the escape-drain count —
    /// see [`StallCounters`](crate::sim::telemetry::StallCounters).
    pub stalls: crate::sim::telemetry::StallCounters,
    /// Utilization per directed port class over the run's cycle window
    /// (`2·dim` entries) — the closed-loop counterpart of
    /// [`SimResult::port_utilization`](crate::sim::SimResult).
    pub port_utilization: Vec<f64>,
    /// Max/mean utilization over the individual directed links (1.0 =
    /// perfectly balanced; 0.0 when nothing moved) — the per-workload
    /// balance figure the §3.4 story needs at the application level.
    pub link_util_spread: f64,
    /// Phits transferred per virtual channel (`num_vcs` entries); entry 0
    /// is the escape lane when the escape protocol is live.
    pub vc_phits: Vec<u64>,
    pub nodes: usize,
    /// RNG fingerprint of the run — shared definition with
    /// [`SimResult::rng_digest`](crate::sim::SimResult); the scan-mode
    /// and thread-count differential tests pin on it.
    pub rng_digest: u64,
    /// Total draws consumed from the per-node counter streams (see
    /// [`SimResult::rng_draws`](crate::sim::SimResult)).
    pub rng_draws: u64,
    /// Parallel-engine execution profile (serial-fast-path vs. sharded
    /// cycles) — shared definition with
    /// [`SimResult::engine`](crate::sim::SimResult); Debug-opaque so the
    /// thread-count differentials can compare whole-`Debug` outcomes.
    pub engine: crate::sim::EngineProfile,
}

impl WorkloadOutcome {
    /// Aggregate effective bandwidth in phits/(cycle·node) — the
    /// completion-time analogue of accepted load.
    pub fn effective_bandwidth(&self) -> f64 {
        if self.completion_cycles == 0 {
            return 0.0;
        }
        self.delivered_phits as f64 / (self.completion_cycles as f64 * self.nodes as f64)
    }

    /// Fraction of hop traffic carried by the escape channel (VC 0), in
    /// `[0, 1]`; 0.0 when nothing moved. Only meaningful when the escape
    /// protocol is live (adaptive policy, `num_vcs >= 2`).
    pub fn escape_share(&self) -> f64 {
        crate::sim::stats::escape_share(&self.vc_phits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(src: u32, dst: u32, deps: Vec<u32>) -> WorkloadMessage {
        WorkloadMessage::new(src, dst, 0, deps)
    }

    #[test]
    fn validate_catches_structural_errors() {
        let ok = Workload { name: "ok".into(), nodes: 4, messages: vec![msg(0, 1, vec![]), msg(1, 2, vec![0])] };
        assert!(ok.validate().is_ok());

        let self_msg = Workload { name: "s".into(), nodes: 4, messages: vec![msg(2, 2, vec![])] };
        assert!(self_msg.validate().is_err());

        let oob = Workload { name: "o".into(), nodes: 2, messages: vec![msg(0, 5, vec![])] };
        assert!(oob.validate().is_err());

        let bad_dep = Workload { name: "d".into(), nodes: 4, messages: vec![msg(0, 1, vec![9])] };
        assert!(bad_dep.validate().is_err());

        let zero = Workload {
            name: "z".into(),
            nodes: 4,
            messages: vec![WorkloadMessage { size_phits: 0, ..msg(0, 1, vec![]) }],
        };
        assert!(zero.validate().is_err());
    }

    #[test]
    fn cycle_detected() {
        let cyc = Workload {
            name: "cyc".into(),
            nodes: 4,
            messages: vec![msg(0, 1, vec![1]), msg(1, 2, vec![0])],
        };
        assert!(!cyc.is_acyclic());
        assert!(cyc.validate().is_err());
        let dag = Workload {
            name: "dag".into(),
            nodes: 4,
            messages: vec![msg(0, 1, vec![]), msg(1, 2, vec![0]), msg(2, 3, vec![0, 1])],
        };
        assert!(dag.is_acyclic());
    }

    #[test]
    fn packetization_rounds_up() {
        let m = |s: u32| WorkloadMessage { size_phits: s, ..msg(0, 1, vec![]) };
        assert_eq!(m(1).packets(16), 1);
        assert_eq!(m(16).packets(16), 1);
        assert_eq!(m(17).packets(16), 2);
        assert_eq!(m(256).packets(16), 16);
        assert_eq!(m(257).packets(16), 17);
        let wl = Workload { name: "p".into(), nodes: 4, messages: vec![m(17), m(16), m(1)] };
        assert_eq!(wl.total_phits(), 34);
        assert_eq!(wl.total_packets(16), 4);
    }

    #[test]
    fn mask_keeps_everything_when_all_pairs_route() {
        let wl = Workload {
            name: "all".into(),
            nodes: 4,
            messages: vec![msg(0, 1, vec![]), msg(1, 2, vec![0]), msg(2, 3, vec![0, 1])],
        };
        let masked = wl.mask_unroutable(|_, _| true);
        assert_eq!(masked.messages, wl.messages);
        assert!(masked.validate().is_ok());
    }

    #[test]
    fn mask_rewires_dependents_to_kept_ancestors() {
        // Chain 0 -> 1 -> 2; dropping the middle message hands its
        // dependent the dropped message's own dep.
        let wl = Workload {
            name: "chain".into(),
            nodes: 8,
            messages: vec![msg(0, 1, vec![]), msg(1, 7, vec![0]), msg(2, 3, vec![1])],
        };
        let masked = wl.mask_unroutable(|_, d| d != 7);
        assert_eq!(masked.messages.len(), 2);
        assert_eq!(masked.messages[0], msg(0, 1, vec![]));
        assert_eq!(masked.messages[1], msg(2, 3, vec![0]), "dep rewired past the dropped message");
        assert!(masked.validate().is_ok());
    }

    #[test]
    fn mask_drops_roots_and_dedups_inherited_deps() {
        // 3 depends on two dropped messages that share the same kept
        // ancestor: the inherited frontier must deduplicate. 4 depends
        // only on a dropped *root*: it must come out dependency-free.
        let wl = Workload {
            name: "fan".into(),
            nodes: 8,
            messages: vec![
                msg(0, 1, vec![]),
                msg(7, 2, vec![0]),
                msg(7, 3, vec![0]),
                msg(3, 4, vec![1, 2]),
                msg(7, 5, vec![]),
                msg(4, 5, vec![4]),
            ],
        };
        let masked = wl.mask_unroutable(|s, _| s != 7);
        assert_eq!(masked.messages.len(), 3);
        assert_eq!(masked.messages[0], msg(0, 1, vec![]));
        assert_eq!(masked.messages[1], msg(3, 4, vec![0]), "shared kept ancestor deduplicated");
        assert_eq!(masked.messages[2], msg(4, 5, vec![]), "dropped root leaves no dep behind");
        assert!(masked.validate().is_ok());
    }

    #[test]
    fn mask_handles_forward_dep_indices() {
        // validate() only requires acyclicity — dep indices may point
        // forward. 0 depends on the later message 2, which is dropped and
        // inherits from the still-later kept message 1.
        let wl = Workload {
            name: "fwd".into(),
            nodes: 8,
            messages: vec![msg(0, 1, vec![2]), msg(1, 2, vec![]), msg(7, 3, vec![1])],
        };
        assert!(wl.validate().is_ok());
        let masked = wl.mask_unroutable(|s, _| s != 7);
        assert_eq!(masked.messages.len(), 2);
        assert_eq!(masked.messages[0], msg(0, 1, vec![1]));
        assert_eq!(masked.messages[1], msg(1, 2, vec![]));
        assert!(masked.validate().is_ok());
    }

    #[test]
    fn suggested_cap_scales_with_incast() {
        let spread = Workload {
            name: "spread".into(),
            nodes: 16,
            messages: (0..16u32).map(|u| msg(u, (u + 1) % 16, vec![])).collect(),
        };
        let incast = Workload {
            name: "incast".into(),
            nodes: 16,
            messages: (1..16u32).flat_map(|u| (0..16).map(move |_| msg(u, 0, vec![]))).collect(),
        };
        assert!(incast.suggested_max_cycles(16) > spread.suggested_max_cycles(16));
    }

    #[test]
    fn suggested_cap_scales_with_message_size_and_overheads() {
        let big = Workload {
            name: "big".into(),
            nodes: 16,
            messages: (0..16u32)
                .map(|u| WorkloadMessage { size_phits: 4096, ..msg(u, (u + 1) % 16, vec![]) })
                .collect(),
        };
        let small = Workload {
            name: "small".into(),
            nodes: 16,
            messages: (0..16u32).map(|u| msg(u, (u + 1) % 16, vec![])).collect(),
        };
        assert!(big.suggested_max_cycles(16) > small.suggested_max_cycles(16));
        // With zero overheads the cfg-aware bound matches the plain one.
        let cfg = crate::sim::SimConfig::default();
        assert_eq!(small.suggested_max_cycles_for(&cfg), small.suggested_max_cycles(16));
        let loaded = crate::sim::SimConfig {
            send_overhead: 50,
            recv_overhead: 50,
            packet_gap: 20,
            ..cfg
        };
        assert!(small.suggested_max_cycles_for(&loaded) > small.suggested_max_cycles(16));
        // The LogGP L term multiplies head-flight time per hop, so the
        // cap must grow with it too (a chained workload under L = 100
        // must not spuriously report drained = false).
        let slow_wire = crate::sim::SimConfig { link_latency: 100, ..crate::sim::SimConfig::default() };
        assert!(small.suggested_max_cycles_for(&slow_wire) > small.suggested_max_cycles(16));
    }

    #[test]
    fn suggested_cap_is_safe_on_malformed_workloads() {
        // run_workload computes the cap before validating, so the bound
        // must not index-panic on out-of-range deps or endpoints — the
        // diagnosable `validate` error has to be what the caller sees.
        let bad_dep = Workload { name: "d".into(), nodes: 4, messages: vec![msg(0, 1, vec![99])] };
        assert!(bad_dep.suggested_max_cycles(16) > 0);
        let bad_endpoint =
            Workload { name: "e".into(), nodes: 2, messages: vec![msg(7, 9, vec![])] };
        assert!(bad_endpoint.suggested_max_cycles(16) > 0);
    }

    #[test]
    fn effective_bandwidth() {
        let o = WorkloadOutcome {
            completion_cycles: 100,
            drained: true,
            delivered_messages: 10,
            total_messages: 10,
            delivered_phits: 160,
            delivered_packets: 10,
            avg_latency: 20.0,
            p50_latency: 18.0,
            p90_latency: 26.0,
            p99_latency: 30.0,
            p999_latency: 38.0,
            max_latency: 40,
            stalls: crate::sim::telemetry::StallCounters::default(),
            port_utilization: vec![0.5; 4],
            link_util_spread: 1.0,
            vc_phits: vec![40, 120],
            nodes: 4,
            rng_digest: 0,
            rng_draws: 0,
            engine: Default::default(),
        };
        assert!((o.effective_bandwidth() - 0.4).abs() < 1e-12);
        assert!((o.escape_share() - 0.25).abs() < 1e-12);
    }
}
