//! Microbench: routing-record computation (Section 5 algorithms) and
//! routing-table construction — the hot path feeding the simulator.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::routing::{
    bcc::BccRouter, fcc::FccRouter, rtt::RttRouter, HierarchicalRouter, Router, RoutingTable,
};
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("routing");

    // Closed-form routers (Algorithms 2-4): per-record latency.
    let fcc = FccRouter::new(8);
    let g = fcc.graph().clone();
    let pairs: Vec<(Vec<i64>, Vec<i64>)> = (0..g.order())
        .step_by(7)
        .map(|v| (vec![0, 0, 0], g.label_of(v)))
        .collect();
    b.run_throughput("fcc8/closed-form", pairs.len() as u64, "records", || {
        for (s, d) in &pairs {
            black_box(fcc.route(s, d));
        }
    });

    let bcc = BccRouter::new(8);
    let gb = bcc.graph().clone();
    let bpairs: Vec<(Vec<i64>, Vec<i64>)> = (0..gb.order())
        .step_by(7)
        .map(|v| (vec![0, 0, 0], gb.label_of(v)))
        .collect();
    b.run_throughput("bcc8/closed-form", bpairs.len() as u64, "records", || {
        for (s, d) in &bpairs {
            black_box(bcc.route(s, d));
        }
    });

    let rtt = RttRouter::new(16);
    b.run_throughput("rtt16/closed-form", 512, "records", || {
        for x in 0..32 {
            for y in 0..16 {
                black_box(RttRouter::route_diff_min(16, x, y));
            }
        }
    });

    // Generic hierarchical router (Algorithm 1) on the same graphs.
    let hier = HierarchicalRouter::new(g.clone());
    b.run_throughput("fcc8/hierarchical", pairs.len() as u64, "records", || {
        for (s, d) in &pairs {
            black_box(hier.route(s, d));
        }
    });

    // Routing-table construction for the simulated networks.
    b.run("table-build/4d-fcc:4 (512 nodes)", || {
        black_box(RoutingTable::build_hierarchical(&topology::fcc4d(4)));
    });
    b.run("table-build/4d-bcc:2 (128 nodes)", || {
        black_box(RoutingTable::build_hierarchical(&topology::bcc4d(2)));
    });
    let _ = rtt;
}
