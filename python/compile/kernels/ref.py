"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but jnp primitives; pytest (python/tests/) asserts
``assert_allclose(kernel(x), ref(x))`` over hypothesis-driven shape/value
sweeps. These are also small enough to read as the *specification* of each
kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INF = jnp.float32(1e9)


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Min-plus (tropical) matrix product: C[i,j] = min_k A[i,k] + B[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def expand_frontier_ref(reach: jax.Array, m: jax.Array) -> jax.Array:
    """0/1 frontier expansion: (reach @ m > 0) as f32."""
    return (reach @ m > 0.0).astype(jnp.float32)


def apsp_minplus_ref(adj: jax.Array, iters: int) -> jax.Array:
    """APSP by repeated min-plus squaring of the one-hop matrix.

    ``adj``: one-hop cost matrix (0 diag, 1 edges, INF elsewhere).
    ``iters`` squarings cover paths of up to 2**iters hops.
    """
    d = adj
    for _ in range(iters):
        d = minplus_ref(d, d)
    return d


def apsp_gemm_ref(adj01: jax.Array, steps: int) -> jax.Array:
    """APSP by hop-by-hop reachability expansion.

    ``adj01``: 0/1 adjacency (no self loops). Returns hop distances, with
    unreached-within-``steps`` pairs left at ``steps``.
    """
    n = adj01.shape[0]
    m = jnp.minimum(adj01 + jnp.eye(n, dtype=adj01.dtype), 1.0)
    reach = jnp.eye(n, dtype=jnp.float32)
    dist = jnp.zeros((n, n), jnp.float32)
    for _ in range(steps):
        dist = dist + (reach == 0.0).astype(jnp.float32)
        reach = expand_frontier_ref(reach, m)
    return dist


def distance_stats_ref(dist: jax.Array, n_real: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sum of finite distances, max finite distance) over the top-left
    ``n_real`` x ``n_real`` corner of a padded distance matrix.

    Entries >= INF/2 (padding / unreachable) are ignored. ``n_real`` is a
    traced scalar so one artifact serves any topology size <= N.
    """
    n = dist.shape[0]
    idx = jnp.arange(n)
    valid = (idx[:, None] < n_real) & (idx[None, :] < n_real) & (dist < INF / 2)
    s = jnp.sum(jnp.where(valid, dist, 0.0))
    mx = jnp.max(jnp.where(valid, dist, -1.0))
    return s, mx
