//! The [`LatticeGraph`] type: `G(M)` with the Hermite-box labelling.

use crate::math::{floor_div, gcd, gcd_slice, hermite_normal_form, IMat};

/// A lattice graph `G(M)` (paper Definition 3).
///
/// Construction computes the Hermite normal form `H = M U` once; all node
/// labelling and reduction is relative to `H`, the canonical representative
/// of the right-equivalence class (right-equivalent matrices generate
/// isomorphic graphs).
///
/// Nodes are labelled by the Hermite box (Definition 26 with the paper's
/// recommended labelling set): `L = { x | 0 <= x_i < H[i][i] }`, and mapped
/// to dense indices `0..order` in mixed-radix order for array-backed
/// algorithms (BFS, the simulator, PJRT adjacency export).
#[derive(Clone, Debug)]
pub struct LatticeGraph {
    /// The generator matrix as given.
    m: IMat,
    /// Hermite normal form of `m`.
    h: IMat,
    /// Graph dimension `n` (degree is `2n`).
    n: usize,
    /// `|det M|` = number of nodes.
    order: usize,
    /// Diagonal of `h` (the labelling box sides).
    box_sides: Vec<i64>,
    /// Mixed-radix strides: `index = sum_i label[i] * stride[i]`.
    strides: Vec<usize>,
}

impl LatticeGraph {
    /// Build `G(M)` from any non-singular square integral matrix.
    ///
    /// # Panics
    /// If `m` is singular.
    pub fn new(m: IMat) -> Self {
        let n = m.dim();
        let h = hermite_normal_form(&m).h;
        let box_sides: Vec<i64> = (0..n).map(|i| h[(i, i)]).collect();
        let order = box_sides.iter().product::<i64>() as usize;
        // Row-major mixed radix: label[0] varies slowest.
        let mut strides = vec![0usize; n];
        let mut acc = 1usize;
        for i in (0..n).rev() {
            strides[i] = acc;
            acc *= box_sides[i] as usize;
        }
        Self { m, h, n, order, box_sides, strides }
    }

    /// Torus `T(a_1, ..., a_k)` as a lattice graph (Theorem 5).
    pub fn torus(sides: &[i64]) -> Self {
        assert!(sides.iter().all(|&a| a >= 1));
        Self::new(IMat::diag(sides))
    }

    /// The generator matrix `M` as given at construction.
    pub fn matrix(&self) -> &IMat {
        &self.m
    }

    /// The Hermite normal form of `M`.
    pub fn hermite(&self) -> &IMat {
        &self.h
    }

    /// Dimension `n` (number of generator axes; degree is `2n`).
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Node degree `2n`.
    pub fn degree(&self) -> usize {
        2 * self.n
    }

    /// Number of nodes `|det M|`.
    pub fn order(&self) -> usize {
        self.order
    }

    /// Labelling box sides (the Hermite diagonal).
    pub fn box_sides(&self) -> &[i64] {
        &self.box_sides
    }

    /// The "side" of the graph: `H[n-1][n-1]` (Definition 7).
    pub fn side(&self) -> i64 {
        self.box_sides[self.n - 1]
    }

    /// Reduce an arbitrary vector to its canonical label in the Hermite box.
    ///
    /// Works column-by-column from the last coordinate up: subtracting
    /// `q * H.col(i)` zeroes coordinate `i` into `[0, H[i][i])` and only
    /// perturbs coordinates `< i`, which are handled later.
    pub fn reduce(&self, v: &[i64]) -> Vec<i64> {
        debug_assert_eq!(v.len(), self.n);
        let mut x = v.to_vec();
        self.reduce_in_place(&mut x);
        x
    }

    /// In-place variant of [`reduce`](Self::reduce) for hot paths.
    pub fn reduce_in_place(&self, x: &mut [i64]) {
        for i in (0..self.n).rev() {
            let d = self.box_sides[i];
            let q = floor_div(x[i], d);
            if q != 0 {
                for r in 0..=i {
                    x[r] -= q * self.h[(r, i)];
                }
            }
            debug_assert!(0 <= x[i] && x[i] < d);
        }
    }

    /// Are two vectors congruent mod `M` (Definition 2)?
    pub fn congruent(&self, v: &[i64], w: &[i64]) -> bool {
        let diff: Vec<i64> = v.iter().zip(w).map(|(a, b)| a - b).collect();
        self.reduce(&diff).iter().all(|&x| x == 0)
    }

    /// Dense index of a canonical label.
    pub fn index_of(&self, label: &[i64]) -> usize {
        debug_assert!(label
            .iter()
            .zip(&self.box_sides)
            .all(|(&x, &d)| 0 <= x && x < d));
        label
            .iter()
            .zip(&self.strides)
            .map(|(&x, &s)| x as usize * s)
            .sum()
    }

    /// Label of a dense index.
    pub fn label_of(&self, mut idx: usize) -> Vec<i64> {
        debug_assert!(idx < self.order);
        let mut label = vec![0i64; self.n];
        for i in 0..self.n {
            label[i] = (idx / self.strides[i]) as i64;
            idx %= self.strides[i];
        }
        label
    }

    /// Dense index of an arbitrary (unreduced) vector.
    pub fn index_of_vec(&self, v: &[i64]) -> usize {
        self.index_of(&self.reduce(v))
    }

    /// The `2n` neighbor indices of a node, in `(+e_1, -e_1, +e_2, ...)`
    /// order (the order the simulator's port map relies on).
    pub fn neighbors(&self, idx: usize) -> Vec<usize> {
        let label = self.label_of(idx);
        let mut out = Vec::with_capacity(2 * self.n);
        let mut tmp = vec![0i64; self.n];
        for i in 0..self.n {
            for sign in [1i64, -1] {
                tmp.copy_from_slice(&label);
                tmp[i] += sign;
                self.reduce_in_place(&mut tmp);
                out.push(self.index_of(&tmp));
            }
        }
        out
    }

    /// Apply one generator hop: `label + sign * e_axis`, reduced.
    pub fn step(&self, idx: usize, axis: usize, sign: i64) -> usize {
        let mut label = self.label_of(idx);
        label[axis] += sign;
        self.reduce_in_place(&mut label);
        self.index_of(&label)
    }

    /// Order of an element `x` in `Z^n / M Z^n` (Section 2):
    /// `ord(x) = det / gcd(det, gcd(det * M^{-1} x))`, with
    /// `det * M^{-1} x = adj(M) x` computed exactly.
    pub fn element_order(&self, x: &[i64]) -> i64 {
        let det = self.h.det().abs();
        let adjx = self.h.adjugate_times_vec(x);
        let g = gcd(det, gcd_slice(&adjx));
        det / g
    }

    /// Order of the generator `e_i`.
    pub fn generator_order(&self, i: usize) -> i64 {
        let mut e = vec![0i64; self.n];
        e[i] = 1;
        self.element_order(&e)
    }

    /// Is the graph connected? (`G(M)` is connected iff the generators span
    /// the quotient; single BFS check.)
    pub fn is_connected(&self) -> bool {
        let mut seen = vec![false; self.order];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for v in self.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.order
    }

    /// Full adjacency as index pairs (each undirected edge reported once).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.order * self.n);
        for u in 0..self.order {
            for v in self.neighbors(u) {
                if u <= v {
                    out.push((u, v));
                }
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Are `self` and `other` right-equivalent (identical HNF)? Implies
    /// graph isomorphism (Definition 6 / [16]).
    pub fn right_equivalent(&self, other: &LatticeGraph) -> bool {
        self.h == other.h
    }

    /// Does a *signed-permutation* isomorphism `G(M1) ≅ G(P M1)`-style map
    /// onto `other` exist? (Covers all linear isomorphisms per Lemma 35:
    /// checks `HNF(P * M_self) == HNF(M_other)` over all signed perms.)
    pub fn isomorphic_linear(&self, other: &LatticeGraph) -> bool {
        if self.n != other.n || self.order != other.order {
            return false;
        }
        for p in crate::lattice::symmetry::signed_permutations(self.n) {
            let pm = p.matrix().mul(&self.m);
            if hermite_normal_form(&pm).h == other.h {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fcc(a: i64) -> LatticeGraph {
        LatticeGraph::new(IMat::from_rows(&[&[a, a, 0], &[a, 0, a], &[0, a, a]]))
    }

    fn bcc(a: i64) -> LatticeGraph {
        LatticeGraph::new(IMat::from_rows(&[
            &[-a, a, a],
            &[a, -a, a],
            &[a, a, -a],
        ]))
    }

    #[test]
    fn torus_order_and_degree() {
        let t = LatticeGraph::torus(&[4, 3, 2]);
        assert_eq!(t.order(), 24);
        assert_eq!(t.degree(), 6);
        assert_eq!(t.box_sides(), &[4, 3, 2]);
    }

    #[test]
    fn crystal_orders() {
        for a in 1..5 {
            assert_eq!(fcc(a).order(), (2 * a * a * a) as usize);
            assert_eq!(bcc(a).order(), (4 * a * a * a) as usize);
        }
    }

    #[test]
    fn label_index_roundtrip() {
        let g = fcc(3);
        for idx in 0..g.order() {
            assert_eq!(g.index_of(&g.label_of(idx)), idx);
        }
    }

    #[test]
    fn reduce_idempotent_and_congruent() {
        let g = bcc(2);
        // reduce(v) ≡ v (mod M) and reduce(reduce(v)) == reduce(v)
        for v in [[5i64, -3, 7], [-1, -1, -1], [100, 50, -75]] {
            let r = g.reduce(&v);
            assert_eq!(g.reduce(&r), r);
            assert!(g.congruent(&v, &r));
        }
    }

    #[test]
    fn neighbors_symmetric_relation() {
        let g = fcc(2);
        for u in 0..g.order() {
            for v in g.neighbors(u) {
                assert!(g.neighbors(v).contains(&u), "asymmetric edge {u}-{v}");
            }
        }
    }

    #[test]
    fn step_inverse() {
        let g = bcc(3);
        for idx in [0usize, 1, 17, g.order() - 1] {
            for axis in 0..3 {
                let fwd = g.step(idx, axis, 1);
                assert_eq!(g.step(fwd, axis, -1), idx);
            }
        }
    }

    #[test]
    fn generator_order_fcc() {
        // §5.2: in FCC(a), ord(e_3) = 2a.
        for a in 1..5 {
            assert_eq!(fcc(a).generator_order(2), 2 * a);
        }
    }

    #[test]
    fn generator_order_bcc() {
        // §5.2: in BCC(a), ord(e_3) = 2a.
        for a in 1..5 {
            assert_eq!(bcc(a).generator_order(2), 2 * a);
        }
    }

    #[test]
    fn generator_order_torus() {
        let t = LatticeGraph::torus(&[6, 10]);
        assert_eq!(t.generator_order(0), 6);
        assert_eq!(t.generator_order(1), 10);
    }

    #[test]
    fn connected_crystals() {
        assert!(fcc(2).is_connected());
        assert!(bcc(2).is_connected());
        assert!(LatticeGraph::torus(&[4, 4, 4]).is_connected());
    }

    #[test]
    fn edges_count_matches_degree() {
        let g = fcc(2);
        // 2n-regular graph (no multi-edges for sides >= 3; FCC(2) box is
        // (4,2,2) so some wrap pairs may coincide — count via neighbor sets)
        let edges = g.edges();
        assert!(!edges.is_empty());
        for (u, v) in &edges {
            assert!(g.neighbors(*u).contains(v));
        }
    }

    #[test]
    fn fcc_isomorphic_to_own_hermite() {
        let a = 3;
        let g1 = fcc(a);
        let g2 = LatticeGraph::new(IMat::from_rows(&[
            &[2 * a, a, a],
            &[0, a, 0],
            &[0, 0, a],
        ]));
        assert!(g1.right_equivalent(&g2));
        assert!(g1.isomorphic_linear(&g2));
    }

    #[test]
    fn pc_not_isomorphic_to_fcc() {
        // PC(2) has 8 nodes; FCC is 2a^3 — match orders: PC(2)=8 vs FCC...
        // use equal-order pair T(2,2,2) vs nothing; just check different HNF.
        let pc2 = LatticeGraph::torus(&[2, 2, 2]);
        let fcc_ = fcc(2); // 16 nodes
        assert!(!pc2.right_equivalent(&fcc_));
        assert!(!pc2.isomorphic_linear(&fcc_));
    }

    #[test]
    fn example10_cycle_length() {
        // Example 10: M = [[4,0,0],[0,4,2],[0,0,4]]; cycles of length 8
        // join the 4 copies of T(4,4).
        let g = LatticeGraph::new(IMat::from_rows(&[&[4, 0, 0], &[0, 4, 2], &[0, 0, 4]]));
        assert_eq!(g.generator_order(2), 8);
        assert_eq!(g.order(), 64);
    }
}
