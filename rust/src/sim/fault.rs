//! The fault model: dead links and dead routers (DESIGN.md §Fault-model).
//!
//! A [`FaultSet`] is derived once, at simulator construction, from the
//! four `SimConfig` fault knobs — explicit dead links
//! (`SimConfig::fault_links`), explicit dead nodes
//! (`SimConfig::fault_nodes`), and seeded Bernoulli fault rates over
//! undirected links and nodes (`link_fault_rate` / `node_fault_rate`) —
//! and is immutable for the lifetime of the simulator. Faults are
//! *fail-stop and symmetric*: a dead link carries nothing in either
//! direction, and a dead node additionally kills every link incident to
//! it (both directions) — it can neither inject, forward, nor eject.
//!
//! Determinism: the random faults come from a dedicated sequential
//! stream keyed off `SimConfig::seed` (never from any in-run stream), in
//! a canonical order — node Bernoulli trials in ascending node order,
//! then one trial per *undirected* link visited in node-major
//! representative order — so the same config always produces the same
//! topology damage, independent of scan mode, thread count, and of how
//! many runs the simulator executes. The derivation draws nothing when
//! the corresponding rate is zero, and [`FaultSet::build`] returns
//! `None` for an empty fault set, so an unfaulted config constructs a
//! simulator bit-identical to one that has never heard of faults.
//!
//! The routing consequences (DOR-suffix liveness, masked port selection,
//! the admission gate) live on `Simulator` in `engine/mod.rs`; this
//! module only answers "is this link / node dead?".

use crate::sim::config::SimConfig;
use crate::sim::rng::{splitmix64, Rng};

/// Salt mixed into `SimConfig::seed` to key the construction-time fault
/// stream: fault derivation must never share a stream with any in-run
/// draw, or an unrelated knob change would re-roll the damage.
const FAULT_STREAM_SALT: u64 = 0xFA17_0DE5_71A1_5EED;

/// Immutable fail-stop damage to a lattice network: per-directed-port
/// dead-link flags plus per-node dead flags, with undirected summary
/// counts. Built by [`FaultSet::build`]; symmetric by construction (the
/// reverse direction of port `p` is port `p ^ 1` at the neighbor, which
/// abelian Cayley adjacency guarantees leads back).
#[derive(Clone, Debug)]
pub struct FaultSet {
    /// `link_dead[u * ports + p]`: output port `p` of node `u` is dead.
    link_dead: Vec<bool>,
    /// `node_dead[u]`: router `u` is dead (all its ports are dead too).
    node_dead: Vec<bool>,
    ports: usize,
    /// Dead *undirected* links (each counted once, node-induced kills
    /// included).
    dead_links: usize,
    /// Dead nodes.
    dead_nodes: usize,
}

/// Is `(u, p)` the canonical representative of its undirected link
/// `{(u, p), (v, p ^ 1)}`? Exactly one of the two directed endpoints is:
/// the lexicographically smaller node, or the even port on a self-loop
/// (a width-1 axis steps back to `u` itself). On a width-2 axis both
/// ports of `u` lead to the same `v` but belong to two physically
/// distinct links — and both are representatives, as they must be.
fn is_representative(u: usize, p: usize, neighbor: &[u32], ports: usize) -> bool {
    let v = neighbor[u * ports + p] as usize;
    u < v || (u == v && p % 2 == 0)
}

impl FaultSet {
    /// Derive the fault set for a router network of `nodes` nodes with
    /// `ports` directed output ports each (`neighbor[u * ports + p]` =
    /// node behind port `p` of `u`). Returns `None` when the config has
    /// no fault source at all ([`SimConfig::has_faults`]), so the
    /// unfaulted engine carries no fault state whatsoever.
    ///
    /// # Panics
    ///
    /// Panics with a diagnosable message when an explicit fault names a
    /// node outside the network or a link between non-adjacent nodes
    /// (the CLI layer validates first and reports these as usage errors;
    /// reaching the panic means a programmatic caller skipped that).
    pub fn build(
        nodes: usize,
        ports: usize,
        neighbor: &[u32],
        cfg: &SimConfig,
    ) -> Option<Box<FaultSet>> {
        if !cfg.has_faults() {
            return None;
        }
        let mut f = FaultSet {
            link_dead: vec![false; nodes * ports],
            node_dead: vec![false; nodes],
            ports,
            dead_links: 0,
            dead_nodes: 0,
        };
        // Random damage first, from the dedicated construction stream:
        // node trials in ascending node order, then one trial per
        // undirected link in node-major representative order. Zero-rate
        // families draw nothing, so `--node-fault-rate 0.1` alone yields
        // the same dead-node set whether or not links are also swept.
        if cfg.node_fault_rate > 0.0 || cfg.link_fault_rate > 0.0 {
            let mut rng = Rng::new(splitmix64(cfg.seed ^ FAULT_STREAM_SALT));
            if cfg.node_fault_rate > 0.0 {
                for u in 0..nodes {
                    if rng.chance(cfg.node_fault_rate) {
                        f.node_dead[u] = true;
                    }
                }
            }
            if cfg.link_fault_rate > 0.0 {
                for u in 0..nodes {
                    for p in 0..ports {
                        if is_representative(u, p, neighbor, ports)
                            && rng.chance(cfg.link_fault_rate)
                        {
                            f.kill_link(u, p, neighbor);
                        }
                    }
                }
            }
        }
        // Explicit damage on top (idempotent over the random damage).
        for &node in &cfg.fault_nodes {
            assert!(
                (node as usize) < nodes,
                "fault-nodes: node {node} out of range (network has {nodes} nodes)"
            );
            f.node_dead[node as usize] = true;
        }
        for &(a, b) in &cfg.fault_links {
            assert!(
                (a as usize) < nodes && (b as usize) < nodes,
                "fault-links: {a}-{b} out of range (network has {nodes} nodes)"
            );
            let mut adjacent = false;
            for p in 0..ports {
                if neighbor[a as usize * ports + p] == b {
                    // Parallel links (a width-2 axis) die together: the
                    // spec names the node pair, not a specific channel.
                    f.kill_link(a as usize, p, neighbor);
                    adjacent = true;
                }
            }
            assert!(adjacent, "fault-links: nodes {a} and {b} are not adjacent");
        }
        // A dead node takes every incident link with it, both directions.
        for u in 0..nodes {
            if !f.node_dead[u] {
                continue;
            }
            for p in 0..ports {
                f.kill_link(u, p, neighbor);
            }
        }
        f.dead_nodes = f.node_dead.iter().filter(|&&d| d).count();
        f.dead_links = (0..nodes)
            .flat_map(|u| (0..ports).map(move |p| (u, p)))
            .filter(|&(u, p)| {
                is_representative(u, p, neighbor, ports) && f.link_dead[u * ports + p]
            })
            .count();
        Some(Box::new(f))
    }

    /// Kill the undirected link behind output port `p` of `u`: the port
    /// itself and its reverse at the neighbor (`p ^ 1` flips the sign
    /// bit of the directed-port encoding `p = 2*axis + (sign < 0)`).
    fn kill_link(&mut self, u: usize, p: usize, neighbor: &[u32]) {
        let v = neighbor[u * self.ports + p] as usize;
        debug_assert_eq!(
            neighbor[v * self.ports + (p ^ 1)] as usize, u,
            "abelian reverse-port invariant broken at ({u}, {p})"
        );
        self.link_dead[u * self.ports + p] = true;
        self.link_dead[v * self.ports + (p ^ 1)] = true;
    }

    /// Is output port `p` of node `u` dead?
    #[inline]
    pub fn is_link_dead(&self, u: usize, p: usize) -> bool {
        self.link_dead[u * self.ports + p]
    }

    /// Is the directed edge from `u` along `(axis, sign)` dead? The
    /// `(axis, sign)` form the BFS oracle speaks
    /// ([`crate::metrics::faulted_components`]).
    #[inline]
    pub fn is_edge_dead(&self, u: usize, axis: usize, sign: i64) -> bool {
        self.is_link_dead(u, 2 * axis + usize::from(sign < 0))
    }

    /// Is node `u` dead?
    #[inline]
    pub fn is_node_dead(&self, u: usize) -> bool {
        self.node_dead[u]
    }

    /// Dead-node mask, one flag per node (for the BFS oracle and the
    /// traffic layer).
    #[inline]
    pub fn node_dead_mask(&self) -> &[bool] {
        &self.node_dead
    }

    /// Number of dead undirected links (node-induced kills included).
    #[inline]
    pub fn dead_links(&self) -> usize {
        self.dead_links
    }

    /// Number of dead nodes.
    #[inline]
    pub fn dead_nodes(&self) -> usize {
        self.dead_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::LatticeGraph;
    use crate::topology::{fcc, torus};

    /// The engine's neighbor table (`with_table` builds the same thing).
    fn neighbor_table(g: &LatticeGraph) -> Vec<u32> {
        let (n, dim) = (g.order(), g.dim());
        let ports = 2 * dim;
        let mut neighbor = vec![0u32; n * ports];
        for u in 0..n {
            for axis in 0..dim {
                for (s, sign) in [(0usize, 1i64), (1, -1)] {
                    neighbor[u * ports + 2 * axis + s] = g.step(u, axis, sign) as u32;
                }
            }
        }
        neighbor
    }

    fn cfg_with(f: impl FnOnce(&mut SimConfig)) -> SimConfig {
        let mut cfg = SimConfig::default();
        f(&mut cfg);
        cfg
    }

    #[test]
    fn empty_fault_config_builds_nothing() {
        let g = torus(&[4, 4]);
        let nb = neighbor_table(&g);
        assert!(FaultSet::build(g.order(), 4, &nb, &SimConfig::default()).is_none());
    }

    #[test]
    fn explicit_link_fault_kills_both_directions_once() {
        let g = torus(&[4, 4]);
        let nb = neighbor_table(&g);
        let (u, ports) = (0usize, 4usize);
        let v = nb[u * ports] as usize; // +x neighbor of node 0
        let cfg = cfg_with(|c| c.fault_links = vec![(u as u32, v as u32)]);
        let f = FaultSet::build(g.order(), ports, &nb, &cfg).unwrap();
        assert_eq!(f.dead_links(), 1);
        assert_eq!(f.dead_nodes(), 0);
        assert!(f.is_link_dead(u, 0), "forward direction dead");
        assert!(f.is_link_dead(v, 1), "reverse direction dead");
        assert!(f.is_edge_dead(u, 0, 1) && f.is_edge_dead(v, 0, -1));
        // Nothing else died.
        let dead: usize = (0..g.order())
            .map(|w| (0..ports).filter(|&p| f.is_link_dead(w, p)).count())
            .sum();
        assert_eq!(dead, 2);
    }

    #[test]
    fn dead_node_kills_every_incident_link() {
        let g = fcc(2);
        let nb = neighbor_table(&g);
        let ports = 2 * g.dim();
        let cfg = cfg_with(|c| c.fault_nodes = vec![5]);
        let f = FaultSet::build(g.order(), ports, &nb, &cfg).unwrap();
        assert_eq!(f.dead_nodes(), 1);
        assert!(f.is_node_dead(5));
        assert_eq!(f.dead_links(), ports, "degree-many undirected links die");
        for p in 0..ports {
            assert!(f.is_link_dead(5, p), "outgoing port {p}");
            let v = nb[5 * ports + p] as usize;
            assert!(f.is_link_dead(v, p ^ 1), "incoming reverse of port {p}");
        }
    }

    #[test]
    fn random_faults_are_deterministic_per_seed() {
        let g = fcc(2);
        let nb = neighbor_table(&g);
        let ports = 2 * g.dim();
        let cfg = cfg_with(|c| {
            c.seed = 77;
            c.link_fault_rate = 0.2;
            c.node_fault_rate = 0.1;
        });
        let a = FaultSet::build(g.order(), ports, &nb, &cfg).unwrap();
        let b = FaultSet::build(g.order(), ports, &nb, &cfg).unwrap();
        assert_eq!(a.link_dead, b.link_dead);
        assert_eq!(a.node_dead, b.node_dead);
        let other = cfg_with(|c| {
            c.seed = 78;
            c.link_fault_rate = 0.2;
            c.node_fault_rate = 0.1;
        });
        let c = FaultSet::build(g.order(), ports, &nb, &other).unwrap();
        assert!(
            a.link_dead != c.link_dead || a.node_dead != c.node_dead,
            "different seed re-rolls the damage"
        );
    }

    #[test]
    fn rate_one_kills_every_undirected_link_exactly_once() {
        // Every (u, p) dead, and the undirected count is half the
        // directed count — the representative rule covered each link
        // exactly once (including the parallel links of a width-2 axis).
        let g = torus(&[4, 2]);
        let nb = neighbor_table(&g);
        let ports = 4;
        let cfg = cfg_with(|c| c.link_fault_rate = 1.0);
        let f = FaultSet::build(g.order(), ports, &nb, &cfg).unwrap();
        assert!((0..g.order()).all(|u| (0..ports).all(|p| f.is_link_dead(u, p))));
        assert_eq!(f.dead_links(), g.order() * ports / 2);
        assert_eq!(f.dead_nodes(), 0, "link faults leave routers alive");
    }

    #[test]
    fn explicit_and_random_damage_compose() {
        let g = torus(&[4, 4]);
        let nb = neighbor_table(&g);
        let cfg = cfg_with(|c| {
            c.link_fault_rate = 0.3;
            c.fault_nodes = vec![7];
        });
        let f = FaultSet::build(g.order(), 4, &nb, &cfg).unwrap();
        assert!(f.is_node_dead(7));
        assert!((0..4).all(|p| f.is_link_dead(7, p)));
        assert!(f.dead_links() >= 4);
    }

    #[test]
    #[should_panic(expected = "not adjacent")]
    fn non_adjacent_explicit_link_is_loud() {
        let g = torus(&[8, 8]);
        let nb = neighbor_table(&g);
        let cfg = cfg_with(|c| c.fault_links = vec![(0, 27)]);
        let _ = FaultSet::build(g.order(), 4, &nb, &cfg);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_explicit_node_is_loud() {
        let g = torus(&[4, 4]);
        let nb = neighbor_table(&g);
        let cfg = cfg_with(|c| c.fault_nodes = vec![16]);
        let _ = FaultSet::build(g.order(), 4, &nb, &cfg);
    }
}
