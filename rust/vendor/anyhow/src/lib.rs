//! Minimal offline stand-in for the `anyhow` crate (vendored because this
//! build environment has no crates.io access; see DESIGN.md
//! §Substitutions).
//!
//! Implements exactly the API subset the workspace uses:
//!
//! - [`Error`]: a context-chain error. `{}` prints the outermost message,
//!   `{:#}` the whole chain joined by `": "` (matching real anyhow), and
//!   `{:?}` the multi-line "Caused by" form.
//! - [`Result`] with the error type defaulted.
//! - [`Context`] on `Result` and `Option` (`.context(..)` /
//!   `.with_context(|| ..)`).
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! - A blanket `From<E: std::error::Error>` so `?` converts std errors.

use std::fmt;

/// A context-chain error: outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, which is
// what makes this blanket conversion coherent (same trick as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap lazily — the closure only runs on the error path.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
    }
}
