//! The Figure 4 lift tree: symmetric lifts of cubic crystal graphs.
//!
//! Nodes are Hermite matrices normalized to side units of `a = 2` (the
//! paper divides by `a`; we use 2 so "half the side" stays integral).
//! Each child is a symmetric Hermite lift of its parent whose side is at
//! least half the parent's side — exactly the restriction the paper uses
//! to keep the tree finite. Reproduces: the left branch of nD-PC tori with
//! their nD-BCC sibling leaves, and the right branch of nD-FCCs.

use crate::lattice::symmetry::is_linearly_symmetric;
use crate::math::{hermite_normal_form, IMat};

/// A node of the lift tree.
#[derive(Clone, Debug)]
pub struct TreeNode {
    /// Hermite matrix (side units: `a = 2`).
    pub matrix: IMat,
    /// Human name if it matches a known family ("PC", "FCC", "BCC", ...).
    pub name: String,
    /// Children (symmetric lifts, deduplicated by linear isomorphism).
    pub children: Vec<TreeNode>,
}

/// Enumerate the symmetric Hermite lifts of `h` with side in
/// `[ceil(side/2), side]`, deduplicated by right-equivalence *and* linear
/// isomorphism.
pub fn symmetric_lifts(h: &IMat) -> Vec<IMat> {
    let n = h.dim();
    let parent_side = h[(n - 1, n - 1)];
    let mut out: Vec<IMat> = Vec::new();
    for t in ((parent_side + 1) / 2)..=parent_side {
        // Enumerate the new Hermite column: c_i in [0, h_ii), last entry t.
        let box_sides: Vec<i64> = (0..n).map(|i| h[(i, i)]).collect();
        let total: i64 = box_sides.iter().product();
        for code in 0..total {
            let mut c = vec![0i64; n];
            let mut rem = code;
            for i in (0..n).rev() {
                c[i] = rem % box_sides[i];
                rem /= box_sides[i];
            }
            let mut m = IMat::zeros(n + 1, n + 1);
            for i in 0..n {
                for j in 0..n {
                    m[(i, j)] = h[(i, j)];
                }
                m[(i, n)] = c[i];
            }
            m[(n, n)] = t;
            if !is_linearly_symmetric(&m) {
                continue;
            }
            let hm = hermite_normal_form(&m).h;
            // Dedup against found lifts (linear isomorphism).
            let dup = out.iter().any(|prev| {
                prev == &hm
                    || crate::lattice::LatticeGraph::new(prev.clone())
                        .isomorphic_linear(&crate::lattice::LatticeGraph::new(hm.clone()))
            });
            if !dup {
                out.push(hm);
            }
        }
    }
    out
}

/// Name a normalized Hermite matrix if it matches a known family.
pub fn family_name(h: &IMat) -> String {
    let n = h.dim();
    // At n = 2 the BCC pattern [[2a, a], [0, a]] *is* the twisted torus:
    // the paper's Figure 4 labels it RTT, so name it first.
    if n == 2 && *h == IMat::from_rows(&[&[2, 1], &[0, 1]]) {
        return "RTT".to_string();
    }
    let named = [
        ("PC", crate::topology::pc_nd(n.max(2), 2)),
        ("BCC", if n >= 2 { crate::topology::bcc_nd(n, 1) } else { crate::topology::pc_nd(2, 2) }),
        ("FCC", if n >= 2 { crate::topology::fcc_nd(n, 1) } else { crate::topology::pc_nd(2, 2) }),
    ];
    for (name, g) in named {
        if g.dim() == n && hermite_normal_form(g.matrix()).h == *h {
            return format!("{n}D-{name}");
        }
    }
    if n == 2 && *h == IMat::from_rows(&[&[2, 1], &[0, 1]]) {
        return "RTT".to_string();
    }
    if n == 1 {
        return "cycle".to_string();
    }
    format!("{n}D-lattice")
}

/// Build the lift tree from the cycle up to `max_dim` dimensions.
///
/// `max_dim = 4` runs in well under a second; 5 takes a few seconds; 6 is
/// minutes (46k signed permutations per candidate) — gate it behind the
/// CLI's `--max-dim`.
pub fn build_tree(max_dim: usize) -> TreeNode {
    let root = IMat::diag(&[2]);
    build_node(root, max_dim)
}

fn build_node(h: IMat, max_dim: usize) -> TreeNode {
    let children = if h.dim() < max_dim {
        symmetric_lifts(&h)
            .into_iter()
            .map(|c| build_node(c, max_dim))
            .collect()
    } else {
        Vec::new()
    };
    TreeNode { name: family_name(&h), matrix: h, children }
}

/// Render the tree as indented text (the Figure 4 reproduction).
pub fn render(node: &TreeNode, depth: usize, out: &mut String) {
    let indent = "  ".repeat(depth);
    let flat: Vec<String> = (0..node.matrix.dim())
        .map(|i| format!("{:?}", node.matrix.row(i)))
        .collect();
    out.push_str(&format!("{indent}{} {}\n", node.name, flat.join(" ")));
    for c in &node.children {
        render(c, depth + 1, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_children_are_torus_and_rtt() {
        // Figure 4: the cycle's symmetric lifts are T(a,a) and RTT.
        let lifts = symmetric_lifts(&IMat::diag(&[2]));
        let names: Vec<String> = lifts.iter().map(family_name).collect();
        assert!(names.contains(&"2D-PC".to_string()), "{names:?}");
        assert!(names.contains(&"RTT".to_string()), "{names:?}");
        assert_eq!(lifts.len(), 2, "{names:?}");
    }

    #[test]
    fn torus_children_include_pc_and_bcc() {
        // Left branch: T(2,2) lifts to PC (diag(2,2,2)) and 3D-BCC.
        let lifts = symmetric_lifts(&IMat::diag(&[2, 2]));
        let names: Vec<String> = lifts.iter().map(family_name).collect();
        assert!(names.contains(&"3D-PC".to_string()), "{names:?}");
        assert!(names.contains(&"3D-BCC".to_string()), "{names:?}");
    }

    #[test]
    fn rtt_children_include_fcc() {
        // Right branch: RTT lifts to 3D-FCC.
        let rtt = IMat::from_rows(&[&[2, 1], &[0, 1]]);
        let lifts = symmetric_lifts(&rtt);
        let names: Vec<String> = lifts.iter().map(family_name).collect();
        assert!(names.contains(&"3D-FCC".to_string()), "{names:?}");
    }

    #[test]
    fn bcc_is_leaf() {
        // Theorem 20: BCC has no symmetric lift.
        let bcc = hermite_normal_form(crate::topology::bcc(1).matrix()).h;
        assert!(symmetric_lifts(&bcc).is_empty());
    }

    #[test]
    fn tree_to_dim4_structure() {
        let tree = build_tree(4);
        assert_eq!(tree.name, "cycle");
        assert_eq!(tree.children.len(), 2);
        // Each 3D-PC node has a 4D-PC child and a 4D-BCC leaf child.
        fn find<'a>(n: &'a TreeNode, name: &str) -> Option<&'a TreeNode> {
            if n.name == name {
                return Some(n);
            }
            n.children.iter().find_map(|c| find(c, name))
        }
        let pc3 = find(&tree, "3D-PC").expect("3D-PC in tree");
        let kid_names: Vec<&str> = pc3.children.iter().map(|c| c.name.as_str()).collect();
        assert!(kid_names.contains(&"4D-PC"), "{kid_names:?}");
        assert!(kid_names.contains(&"4D-BCC"), "{kid_names:?}");
        let fcc3 = find(&tree, "3D-FCC").expect("3D-FCC in tree");
        assert!(fcc3.children.iter().any(|c| c.name == "4D-FCC"));
        let bcc4 = find(&tree, "4D-BCC").expect("4D-BCC in tree");
        assert!(bcc4.children.is_empty(), "4D-BCC must be a leaf");
    }
}
