//! The synchronous cycle engine: virtual cut-through routers with
//! `num_vcs` virtual channels per link, bubble flow control, pluggable
//! per-hop route selection over minimal routing records, and a
//! Duato-style escape channel that makes the adaptive policies
//! deadlock-free.
//!
//! Model (see module docs in `sim/mod.rs` for the INSEE correspondence):
//! each node has `2n` input ports (one per incoming link) with `num_vcs`
//! FIFO queues each, an injection queue, and an ejection channel. One
//! packet transfer per link at a time; a transfer started at `t` holds the
//! link for the axis's serialization time (`ceil(packet_size /
//! axis_width)` cycles — 16 on a symmetric Table 3 link), delivers the
//! head downstream at `t + link_latency` (cut-through; the LogGP `L`
//! term), and frees the upstream buffer slot when the tail departs.
//!
//! Per-hop output ports come from the route-selection policy layer
//! ([`crate::sim::policy`]): packets carry their **remaining** signed
//! record, and the configured policy consumes one productive axis per hop
//! — deterministic dimension order (`Dor`, the historical engine, bit-
//! exact), a uniformly random productive axis (`RandomOrder`), or the
//! port with the most downstream headroom (`AdaptiveMin`). Every policy
//! is minimal: hop count always equals the record's L1 norm.
//!
//! **Virtual channels and the escape protocol** (DESIGN.md
//! §Virtual-channels): under `Dor` every VC is a plain parallel lane —
//! packets draw a VC at injection and keep it end-to-end, and DOR order
//! plus the bubble rule keeps each lane deadlock-free on its own. Under
//! the adaptive policies with `num_vcs >= 2`, VC 0 becomes the **escape
//! channel**: packets inject on an adaptive VC (`1..num_vcs`), and a
//! blocked adaptive head first retries the other productive ports on its
//! own VC, then drains into VC 0 on the DOR port (a ring-entering hop:
//! the full 2-slot bubble is required). Once on VC 0 a packet is
//! committed — it follows DOR on the escape lane to its destination —
//! so the escape subnetwork is exactly the provably deadlock-free
//! DOR+bubble network, and every blocked adaptive packet can always
//! eventually fall into it: adaptivity becomes safe at saturation.
//!
//! Two injection regimes share the router core:
//!
//! - **open loop** ([`Simulator::run`], `open_loop`): Bernoulli injection
//!   at a fixed offered load with a warmup/measure/drain window — the
//!   steady-state regime behind the paper's Figures 5–8;
//! - **closed loop** ([`Simulator::run_workload`], `closed_loop`): a
//!   finite, dependency-ordered message set (a
//!   [`Workload`](crate::workload::Workload)) is injected as its
//!   dependencies complete and the run lasts until the network drains,
//!   measuring **completion time** — the application-level regime behind
//!   the collective workload experiments.
//!
//! **Scan strategy** ([`SimConfig::scan_mode`], DESIGN.md
//! §Engine-performance): per-cycle work is proportional to *activity*,
//! not network size. The arbitration scan and the closed-loop NIC
//! packetizer visit maintained worklists — nodes with queued packets,
//! NICs with eligible messages — in ascending node order; every draw
//! comes from a per-node counter stream ([`crate::sim::rng::NodeRng`]),
//! so the results are bit-identical to the retained full-network
//! reference scan ([`ScanMode::FullScan`](crate::sim::ScanMode)), and
//! the open-loop Bernoulli injector samples geometric inter-arrival gaps
//! instead of drawing per node per cycle. Drain windows, closed-loop
//! dependency tails and low-load sweeps thus cost near-zero per idle
//! cycle; the `engine_scaling` bench records the speedup.
//!
//! **Parallel execution** ([`SimConfig::threads`], `parallel`, DESIGN.md
//! §Parallel-engine): every cycle runs a serial Phase A (events,
//! injection), a sharded Phase B (arbitration over contiguous node
//! ranges) and a serial Phase C (deferred cross-node effects merged in
//! node order). One code path serves every thread count, and per-node
//! counter streams make `threads = k` bit-identical to `threads = 1`
//! (pinned by `tests/parallel_differential.rs` and the CI thread matrix).
//!
//! **Telemetry** ([`crate::sim::telemetry`], DESIGN.md §Telemetry): the
//! engine carries observation-only hooks — always-on stall-cause counters
//! (`note_stall` in `arbitration`, NIC backlog in `closed_loop`) and, when
//! [`SimConfig::trace`] is set, packet-lifecycle JSONL events plus
//! periodic occupancy probes. The hooks draw no RNG and mutate no router
//! state, so results and `rng_digest` are bit-identical with tracing on
//! or off (pinned by `tests/telemetry_differential.rs`).
//!
//! File map: `state` holds the packet/FIFO/event arenas, the per-run
//! mutable state and the `ActiveSet` worklist; `arbitration` the
//! per-node output arbitration and link transfers (both scan flavours);
//! `parallel` the phased multi-threaded cycle driver and shard merge;
//! `injection` packet creation and source enqueue; `open_loop` /
//! `closed_loop` the two run regimes.

mod arbitration;
mod closed_loop;
mod injection;
mod open_loop;
mod parallel;
mod state;
#[cfg(test)]
mod tests;

use std::sync::Arc;

use crate::lattice::LatticeGraph;
use crate::routing::RoutingTable;

use super::artifacts::TopologyArtifacts;
use super::config::SimConfig;
use super::fault::FaultSet;
use super::policy::{port_of, RoutePolicy};
use super::traffic::TrafficPattern;

pub use crate::routing::MAX_DIM;

/// The simulator: shared immutable topology tables + per-config state +
/// per-run mutable state.
pub struct Simulator {
    /// Shared immutable topology tables (graph, neighbor table, labels,
    /// compact routes) — one bundle serves every simulator over the same
    /// graph (see [`TopologyArtifacts`]).
    art: Arc<TopologyArtifacts>,
    cfg: SimConfig,
    pattern: TrafficPattern,
    dim: usize,
    ports: usize,
    nodes: usize,
    /// Per-port link serialization time in cycles
    /// (`SimConfig::serialization_cycles` of the port's axis; both
    /// directions of an axis share a physical width). Config-derived, so
    /// per-simulator, not part of the shared artifacts.
    ser: Vec<u64>,
    /// The fault set, derived once from the config's fault knobs
    /// (`None` iff the config has no fault source — the unfaulted
    /// engine carries zero fault state and is bit-identical to the
    /// pre-fault code). Immutable, so every fault query is
    /// phase-constant and safe from any Phase-B shard.
    faults: Option<Box<FaultSet>>,
}

impl Simulator {
    /// Build against a shared artifact bundle — the primary constructor:
    /// every other constructor wraps it, and callers running many
    /// configurations over one topology (sweeps, experiment grids, seed
    /// fan-outs) should clone one `Arc` instead of rebuilding tables.
    pub fn with_artifacts(
        art: Arc<TopologyArtifacts>,
        pattern: TrafficPattern,
        cfg: SimConfig,
    ) -> Self {
        let dim = art.dim();
        assert!(
            cfg.queue_packets >= 1 && cfg.injection_queue_packets >= 1,
            "queue capacities must be at least one packet"
        );
        assert!(
            cfg.queue_packets <= u16::MAX as u32 && cfg.injection_queue_packets <= u16::MAX as u32,
            "queue capacities exceed u16 bookkeeping"
        );
        assert!(cfg.num_vcs >= 1, "at least one virtual channel is required");
        assert!(
            cfg.num_vcs <= SimConfig::max_vcs(dim),
            "occupancy bitmask supports at most 64 VC queues per node"
        );
        assert!(cfg.link_latency >= 1, "link_latency must be at least one cycle");
        assert!(cfg.threads >= 1, "at least one engine thread is required");
        assert!(
            cfg.axis_widths.iter().all(|&w| w >= 1),
            "axis widths must be at least 1"
        );
        let nodes = art.nodes();
        let ports = art.ports();
        let ser: Vec<u64> = (0..ports).map(|p| cfg.serialization_cycles(p / 2)).collect();
        let faults = FaultSet::build(nodes, ports, &art.neighbor, &cfg);
        Self { art, cfg, pattern, dim, ports, nodes, ser, faults }
    }

    /// Build a simulator with a prebuilt routing table (must belong to the
    /// same graph).
    pub fn with_table(
        g: LatticeGraph,
        table: &RoutingTable,
        pattern: TrafficPattern,
        cfg: SimConfig,
    ) -> Self {
        Self::with_artifacts(TopologyArtifacts::from_table(g, table), pattern, cfg)
    }

    /// Build with the best router for the graph: the Hermite-dispatched
    /// closed form for catalog families (torus / nD-BCC / nD-FCC / RTT),
    /// the hierarchical router otherwise — identical tables either way,
    /// built in parallel over the engine's configured thread count.
    pub fn new(g: LatticeGraph, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let art = TopologyArtifacts::build(g, cfg.threads);
        Self::with_artifacts(art, pattern, cfg)
    }

    /// Build for closed-loop workload runs (no synthetic traffic pattern is
    /// consulted in that mode).
    pub fn for_workload(g: LatticeGraph, cfg: SimConfig) -> Self {
        Self::new(g, TrafficPattern::Uniform, cfg)
    }

    /// The shared artifact bundle (clone the `Arc` to build sibling
    /// simulators without re-deriving the topology tables).
    pub fn artifacts(&self) -> &Arc<TopologyArtifacts> {
        &self.art
    }

    pub fn graph(&self) -> &LatticeGraph {
        self.art.graph()
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Is the Duato escape protocol live? VC 0 is pinned to DOR (the
    /// escape channel) exactly when an adaptive policy runs with at least
    /// one free VC beside the escape lane; under `Dor` — or with a single
    /// VC — every VC is a plain lane and the engine is bit-exact with the
    /// pre-escape code. Consumers of the per-VC statistics
    /// ([`SimResult::vc_phits`](crate::sim::SimResult) and friends)
    /// should gate escape-share reporting on this predicate.
    #[inline]
    pub fn escape_active(&self) -> bool {
        self.cfg.num_vcs >= 2 && self.cfg.route_policy != RoutePolicy::Dor
    }

    /// The fault set derived from the config's fault knobs, or `None`
    /// for a pristine network (see [`crate::sim::fault`]).
    #[inline]
    pub fn faults(&self) -> Option<&FaultSet> {
        self.faults.as_deref()
    }

    /// **DOR-suffix liveness** — the invariant the whole degraded-mode
    /// routing layer rests on (DESIGN.md §Fault-model): does the DOR
    /// completion of `record` from `start` (all remaining hops of axis
    /// 0, then axis 1, …) cross only live links and end at a live node?
    ///
    /// A packet state satisfying this is always deliverable: its DOR
    /// port is live, and taking it yields another state satisfying it —
    /// so the escape channel (VC 0, committed to DOR) can always finish
    /// the job, and Duato's deadlock-freedom argument survives the
    /// damage unchanged. Pure over immutable tables (O(remaining hops),
    /// no RNG, no state), hence safe from any Phase-B shard.
    pub(super) fn dor_suffix_live(
        &self,
        f: &FaultSet,
        start: usize,
        record: &[i16; MAX_DIM],
    ) -> bool {
        let mut u = start;
        for axis in 0..self.dim {
            let mut h = record[axis];
            while h != 0 {
                let p = port_of(axis, h) as usize;
                if f.is_link_dead(u, p) {
                    return false;
                }
                u = self.art.neighbor[u * self.ports + p] as usize;
                h -= h.signum();
            }
        }
        !f.is_node_dead(u)
    }

    /// Is the hop along productive `axis` allowed under faults: its link
    /// is live *and* the post-hop state keeps a live DOR completion. The
    /// masked route selection, the escape re-selection scan and the
    /// injection admission gate all build on this one predicate — which
    /// is what makes the invariant inductive: every hop the engine ever
    /// takes lands in a [`dor_suffix_live`](Self::dor_suffix_live)
    /// state.
    pub(super) fn hop_allowed(
        &self,
        f: &FaultSet,
        u: usize,
        record: &[i16; MAX_DIM],
        axis: usize,
    ) -> bool {
        let h = record[axis];
        debug_assert!(h != 0, "hop_allowed on an unproductive axis");
        let p = port_of(axis, h) as usize;
        if f.is_link_dead(u, p) {
            return false;
        }
        let v = self.art.neighbor[u * self.ports + p] as usize;
        let mut rec = *record;
        rec[axis] -= h.signum();
        self.dor_suffix_live(f, v, &rec)
    }

    /// Injection admission gate for one minimal record. `Dor` never
    /// deviates from dimension order, so it requires the *whole* DOR
    /// path live (the exact deliverability condition for that policy —
    /// strict admission keeps the detour-free DOR network's deadlock
    /// argument intact). The adaptive policies admit when *any*
    /// productive first hop keeps a live DOR completion: the packet's
    /// first transfer lands it in a `dor_suffix_live` state, after which
    /// the invariant guarantees delivery.
    pub(super) fn record_admissible(
        &self,
        f: &FaultSet,
        src: usize,
        record: &[i16; MAX_DIM],
    ) -> bool {
        if self.cfg.route_policy == RoutePolicy::Dor {
            return self.dor_suffix_live(f, src, record);
        }
        (0..self.dim).any(|axis| record[axis] != 0 && self.hop_allowed(f, src, record, axis))
    }

    /// Can the engine deliver a packet from `src` to `dst` under the
    /// current fault set? True iff both endpoints are live and at least
    /// one minimal routing record passes the admission gate (always
    /// true on a pristine network). This is the predicate
    /// [`Workload::mask_unroutable`](crate::workload::Workload::mask_unroutable)
    /// should be fed, and what the fault property suite compares against
    /// the BFS oracle: engine-routable implies oracle-reachable (the
    /// converse can fail — minimal routing does not walk around
    /// arbitrary damage).
    pub fn fault_routable(&self, src: usize, dst: usize) -> bool {
        let Some(f) = self.faults.as_deref() else {
            return true;
        };
        if f.is_node_dead(src) || f.is_node_dead(dst) {
            return false;
        }
        if src == dst {
            return true;
        }
        let mut diff = vec![0i64; self.dim];
        for (i, s) in diff.iter_mut().enumerate() {
            *s = self.art.labels[dst * self.dim + i] - self.art.labels[src * self.dim + i];
        }
        self.art.graph().reduce_in_place(&mut diff);
        let diff_idx = self.art.graph().index_of(&diff);
        self.art.routes.ties(diff_idx).iter().any(|rec| self.record_admissible(f, src, rec))
    }
}
