//! Fault-injection property suite (DESIGN.md §Fault-model).
//!
//! The degraded-mode engine makes three promises, and this file is their
//! teeth:
//!
//! 1. **Empty fault set is free.** A config with no faults constructs no
//!    `FaultSet` and takes none of the fault branches — results (whole
//!    `Debug` output and `rng_digest`) are bit-identical to the pristine
//!    engine, serial and parallel, both scan modes.
//! 2. **No dead hardware is ever driven.** Release-mode asserts inside
//!    `start_transfer` fire if a packet crosses a dead link or enters a
//!    dead router, and `assert_quiescent` (every drained closed-loop run)
//!    checks dead links carried zero phits — so merely *running* the
//!    faulted matrices below verifies the property end to end.
//! 3. **Admission agrees with the reachability oracle.** Packets are
//!    admitted only between endpoints the policy can actually connect
//!    through live hardware; `fault_routable` implies same-component in
//!    the BFS oracle (`metrics::bfs::faulted_components`), and every
//!    admitted closed-loop message is delivered (`drained`).
//!
//! The sweeps run crystals and mixed-radix tori across policies, VC
//! counts, fault rates, and seeds — small networks, many configurations.

use lattice_networks::metrics::faulted_components;
use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};

fn quick_cfg(policy: RoutePolicy, num_vcs: usize) -> SimConfig {
    SimConfig {
        warmup_cycles: 50,
        measure_cycles: 300,
        drain_cycles: 300,
        route_policy: policy,
        num_vcs,
        ..SimConfig::default()
    }
}

/// The canonical fault matrices: two crystals and a mixed-radix torus.
fn graphs() -> Vec<lattice_networks::lattice::LatticeGraph> {
    vec![topology::fcc(2), topology::bcc(2), topology::torus(&[4, 2, 2])]
}

// ---------------------------------------------------------------------------
// Promise 1: an empty fault set leaves the pristine engine untouched.
// ---------------------------------------------------------------------------

/// Explicitly-empty fault fields (zero rates, empty lists) must construct
/// no `FaultSet` and reproduce the default config bit-for-bit — across
/// thread counts and scan modes, open and closed loop. This is the
/// structural guarantee that the fault subsystem costs pristine runs
/// nothing: `faults` is `None`, so no fault branch is ever reachable.
#[test]
fn empty_fault_set_is_bit_identical_to_pristine_engine() {
    let g = topology::torus(&[8, 4]);
    let empty_faults = |cfg: SimConfig| SimConfig {
        fault_links: Vec::new(),
        fault_nodes: Vec::new(),
        link_fault_rate: 0.0,
        node_fault_rate: 0.0,
        ..cfg
    };
    for scan in ScanMode::ALL {
        for threads in [1usize, 4] {
            let cfg = SimConfig {
                scan_mode: scan,
                threads,
                serial_cutoff: 0,
                ..quick_cfg(RoutePolicy::AdaptiveMin, 2)
            };
            let pristine = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg.clone());
            assert!(pristine.faults().is_none(), "default config built a FaultSet");
            let explicit =
                Simulator::new(g.clone(), TrafficPattern::Uniform, empty_faults(cfg.clone()));
            assert!(explicit.faults().is_none(), "empty fault fields built a FaultSet");
            let a = pristine.run_seeded(0.4, 0xfa17);
            let b = explicit.run_seeded(0.4, 0xfa17);
            assert_eq!(a.rng_digest, b.rng_digest, "{scan:?} t{threads}");
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "{scan:?} t{threads}");

            // Closed loop: same structural guarantee through the workload
            // masking path (no faults => no mask, identical packetization).
            let wl = generate(
                WorkloadKind::AllToAll,
                &g,
                &WorkloadParams { iters: 1, ..Default::default() },
            );
            let cap = wl.suggested_max_cycles_for(&cfg);
            let a = Simulator::for_workload(g.clone(), cfg.clone())
                .run_workload_seeded(&wl, 7, cap);
            let b = Simulator::for_workload(g.clone(), empty_faults(cfg.clone()))
                .run_workload_seeded(&wl, 7, cap);
            assert!(a.drained);
            assert_eq!(a.rng_digest, b.rng_digest, "closed loop {scan:?} t{threads}");
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "closed loop {scan:?} t{threads}");
        }
    }
}

// ---------------------------------------------------------------------------
// Promise 2: the faulted engine never drives dead hardware.
// ---------------------------------------------------------------------------

/// The open-loop delivery matrix: policies × VC counts × crystals/tori ×
/// fault rates × seeds. The engine's release-mode asserts verify the
/// no-dead-hardware property on every transfer; the assertions here pin
/// the bookkeeping around it (admitted traffic flows and is accounted).
#[test]
fn open_loop_faulted_matrix_runs_clean() {
    for g in graphs() {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2] {
                for rate in [0.05, 0.2] {
                    for seed in [11u64, 12] {
                        let cfg = SimConfig {
                            link_fault_rate: rate,
                            ..quick_cfg(policy, num_vcs)
                        };
                        let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                        assert!(
                            sim.faults().is_some(),
                            "nonzero fault rate must build a FaultSet"
                        );
                        let r = sim.run_seeded(0.2, seed);
                        assert!(
                            r.delivered_packets <= r.injected_packets,
                            "{} vcs={num_vcs} rate={rate} seed={seed}: {r:?}",
                            policy.name()
                        );
                        // A mild fault rate leaves most pairs routable:
                        // traffic must actually flow through the detours.
                        if rate == 0.05 {
                            assert!(
                                r.injected_packets > 0 && r.delivered_packets > 0,
                                "{} vcs={num_vcs} seed={seed}: nothing moved: {r:?}",
                                policy.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Node faults compose with link faults: dead routers neither inject nor
/// eject (release asserts in the engine), and the run still completes.
#[test]
fn open_loop_node_and_link_faults_compose() {
    for g in graphs() {
        for policy in [RoutePolicy::Dor, RoutePolicy::AdaptiveMin] {
            let cfg = SimConfig {
                link_fault_rate: 0.05,
                node_fault_rate: 0.1,
                ..quick_cfg(policy, 2)
            };
            let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
            let f = sim.faults().expect("faults requested");
            let r = sim.run_seeded(0.2, 3);
            // Every arrival is accounted: injected or dropped at a full /
            // unroutable source (dead sources produce no arrivals at all).
            assert!(r.delivered_packets <= r.injected_packets, "{r:?}");
            let dead_nodes = f.node_dead_mask().iter().filter(|&&d| d).count();
            assert_eq!(dead_nodes, f.dead_nodes(), "mask and count disagree");
        }
    }
}

/// The fault-aware HotSpot pattern re-homes its hot node off dead
/// hardware, so hotspot traffic keeps flowing under node faults.
#[test]
fn hotspot_traffic_survives_node_faults() {
    let g = topology::torus(&[4, 4]);
    let cfg = SimConfig { node_fault_rate: 0.2, ..quick_cfg(RoutePolicy::AdaptiveMin, 2) };
    let sim = Simulator::new(g.clone(), TrafficPattern::HotSpot, cfg);
    if sim.faults().is_some_and(|f| f.dead_nodes() > 0) {
        let r = sim.run_seeded(0.2, 5);
        assert!(r.injected_packets > 0, "hotspot wedged on a dead hot node: {r:?}");
    }
}

// ---------------------------------------------------------------------------
// Promise 3: admission agrees with the BFS reachability oracle.
// ---------------------------------------------------------------------------

/// `fault_routable(s, d)` must imply the oracle can connect `s` and `d`
/// through live hardware: same component, both endpoints alive. (The
/// converse is intentionally false — routing stays inside minimal
/// records, so an oracle-reachable pair whose minimal paths are all cut
/// is *correctly* refused at admission; see the explicit-spec pins.)
#[test]
fn fault_routable_implies_oracle_reachability() {
    for g in graphs() {
        for policy in [RoutePolicy::Dor, RoutePolicy::RandomOrder, RoutePolicy::AdaptiveMin] {
            for rate in [0.1, 0.3] {
                let cfg = SimConfig {
                    link_fault_rate: rate,
                    node_fault_rate: 0.05,
                    ..quick_cfg(policy, 2)
                };
                let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                let f = sim.faults().expect("faults requested");
                let comp = faulted_components(sim.graph(), f.node_dead_mask(), |u, ax, sg| {
                    f.is_edge_dead(u, ax, sg)
                });
                let n = sim.graph().order();
                for s in 0..n {
                    for d in 0..n {
                        if s == d {
                            continue;
                        }
                        if sim.fault_routable(s, d) {
                            assert!(
                                comp[s] != u32::MAX && comp[s] == comp[d],
                                "{} rate={rate}: admitted {s}->{d} across components \
                                 ({:?} vs {:?})",
                                policy.name(),
                                comp[s],
                                comp[d]
                            );
                        }
                        if comp[s] == u32::MAX || comp[d] == u32::MAX {
                            assert!(
                                !sim.fault_routable(s, d),
                                "{} rate={rate}: dead endpoint admitted {s}->{d}",
                                policy.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

/// With no faults, every distinct pair is routable under every policy.
#[test]
fn pristine_network_routes_every_pair() {
    let g = topology::fcc(2);
    for policy in RoutePolicy::ALL {
        let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, quick_cfg(policy, 2));
        let n = sim.graph().order();
        for s in 0..n {
            for d in 0..n {
                assert!(sim.fault_routable(s, d), "{}: {s}->{d}", policy.name());
            }
        }
    }
}

/// Explicit fault specs kill exactly the named hardware, and admission is
/// policy-dependent in exactly the designed way: with the link `0 -> [1,0]`
/// cut on `T(4,4)`, the pair `(0, [1,1])` has a live minimal path that
/// starts on axis 1 — AdaptiveMin takes it, while DOR (whose fixed axis
/// order must cross the dead link first) correctly refuses at admission.
#[test]
fn explicit_link_fault_gates_admission_per_policy() {
    let g = topology::torus(&[4, 4]);
    let origin = g.index_of_vec(&[0, 0]) as u32;
    let right = g.index_of_vec(&[1, 0]); // one +e1 hop from the origin
    let diag = g.index_of_vec(&[1, 1]);
    let make = |policy: RoutePolicy| {
        let cfg = SimConfig {
            fault_links: vec![(origin, right as u32)],
            ..quick_cfg(policy, 2)
        };
        Simulator::new(g.clone(), TrafficPattern::Uniform, cfg)
    };
    let adaptive = make(RoutePolicy::AdaptiveMin);
    let f = adaptive.faults().expect("explicit link fault");
    // Both directions of the named link are dead; nothing else is.
    assert!(f.is_edge_dead(origin as usize, 0, 1));
    assert!(f.is_edge_dead(right, 0, -1));
    assert!(!f.is_edge_dead(right, 0, 1));
    assert_eq!(f.dead_links(), 1);
    assert_eq!(f.dead_nodes(), 0);
    // The only minimal record for origin -> right is the dead hop: no
    // policy may admit it (minimal routing does not detour the long way).
    assert!(!adaptive.fault_routable(origin as usize, right));
    assert!(!adaptive.fault_routable(right, origin as usize), "links die bidirectionally");
    // origin -> diag has two minimal orders; only one survives.
    assert!(adaptive.fault_routable(origin as usize, diag));
    let dor = make(RoutePolicy::Dor);
    assert!(
        !dor.fault_routable(origin as usize, diag),
        "DOR's fixed axis order crosses the dead link; strict admission must refuse"
    );
    // Unaffected pairs route under both.
    let far = g.index_of_vec(&[2, 2]);
    assert!(adaptive.fault_routable(origin as usize, far));
    assert!(dor.fault_routable(origin as usize, far));
}

/// An explicit node fault takes the router and all incident links down.
#[test]
fn explicit_node_fault_kills_incident_links() {
    let g = topology::torus(&[4, 4]);
    let victim = g.index_of_vec(&[1, 1]);
    let cfg = SimConfig {
        fault_nodes: vec![victim as u32],
        ..quick_cfg(RoutePolicy::AdaptiveMin, 2)
    };
    let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
    let f = sim.faults().expect("explicit node fault");
    assert!(f.is_node_dead(victim));
    assert_eq!(f.dead_nodes(), 1);
    assert_eq!(f.dead_links(), 4, "a degree-4 router takes 4 links down");
    for axis in 0..2 {
        for sign in [1i64, -1] {
            assert!(f.is_edge_dead(victim, axis, sign));
        }
    }
    // No pair involving the victim is routable; others detour around it.
    let n = g.order();
    for v in 0..n {
        assert!(!sim.fault_routable(victim, v));
        assert!(!sim.fault_routable(v, victim));
    }
    let r = sim.run_seeded(0.2, 9);
    assert!(r.delivered_packets > 0, "{r:?}");
}

// ---------------------------------------------------------------------------
// Closed loop: masked workloads drain to completion under faults.
// ---------------------------------------------------------------------------

/// Every message the routability mask keeps must be delivered: the run
/// drains, and `total_messages` equals the externally-computed mask (the
/// public `Workload::mask_unroutable` against the engine's own
/// `fault_routable`). A drained faulted run also executes the dead-
/// hardware quiescence checks in `assert_quiescent`.
#[test]
fn masked_workloads_drain_under_faults() {
    for g in [topology::torus(&[4, 4]), topology::fcc(2)] {
        let alltoall = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
        let stencil = generate(
            WorkloadKind::Stencil,
            &g,
            &WorkloadParams { iters: 2, ..Default::default() },
        );
        // The deadlock-free configurations: strict DOR at any VC count
        // (faults only ever *remove* packets from the pristine DOR
        // schedule), and the adaptive policies under the escape protocol
        // (vcs >= 2). Unprotected single-VC adaptivity can deadlock even
        // pristine, so it makes no drain promise to test.
        let configs = [
            (RoutePolicy::Dor, 1usize),
            (RoutePolicy::Dor, 2),
            (RoutePolicy::RandomOrder, 2),
            (RoutePolicy::AdaptiveMin, 2),
        ];
        for wl in [&alltoall, &stencil] {
            for (policy, num_vcs) in configs {
                let cfg = SimConfig {
                    link_fault_rate: 0.1,
                    node_fault_rate: 0.05,
                    ..quick_cfg(policy, num_vcs)
                };
                let sim = Simulator::for_workload(g.clone(), cfg.clone());
                let expected = wl
                    .mask_unroutable(|s, d| sim.fault_routable(s as usize, d as usize))
                    .messages
                    .len() as u64;
                let cap = wl.suggested_max_cycles_for(&cfg);
                let out = sim.run_workload_seeded(wl, 7, cap);
                assert!(
                    out.drained,
                    "{} {} vcs={num_vcs} wedged under faults",
                    wl.name,
                    policy.name()
                );
                assert_eq!(
                    out.total_messages,
                    expected,
                    "{} {}: engine mask disagrees with the public mask",
                    wl.name,
                    policy.name()
                );
                assert_eq!(out.delivered_messages, expected);
            }
        }
    }
}

/// Rate zero masks nothing: the closed loop keeps every message.
#[test]
fn zero_rate_mask_keeps_every_message() {
    let g = topology::torus(&[4, 4]);
    let wl = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    let cfg = quick_cfg(RoutePolicy::Dor, 2);
    let cap = wl.suggested_max_cycles_for(&cfg);
    let out = Simulator::for_workload(g, cfg).run_workload_seeded(&wl, 7, cap);
    assert!(out.drained);
    assert_eq!(out.total_messages, wl.messages.len() as u64);
}

// ---------------------------------------------------------------------------
// Fault derivation: deterministic, seed-scoped, RNG-stream isolated.
// ---------------------------------------------------------------------------

/// Random fault draws are a pure function of the config: two simulators
/// from the same config kill identical hardware, and the dedicated fault
/// RNG stream never touches the run's `rng_digest` (two fresh sims with
/// the same config produce bit-identical runs, fault draws included).
#[test]
fn random_fault_derivation_is_deterministic() {
    let g = topology::bcc(2);
    let cfg = SimConfig {
        link_fault_rate: 0.15,
        node_fault_rate: 0.05,
        ..quick_cfg(RoutePolicy::AdaptiveMin, 2)
    };
    let a = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg.clone());
    let b = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg.clone());
    let (fa, fb) = (a.faults().unwrap(), b.faults().unwrap());
    assert_eq!(fa.dead_links(), fb.dead_links());
    assert_eq!(fa.node_dead_mask(), fb.node_dead_mask());
    let dim = g.dim();
    for u in 0..g.order() {
        for axis in 0..dim {
            for sign in [1i64, -1] {
                assert_eq!(
                    fa.is_edge_dead(u, axis, sign),
                    fb.is_edge_dead(u, axis, sign),
                    "fault draw differs at ({u}, {axis}, {sign})"
                );
            }
        }
    }
    let ra = a.run_seeded(0.2, 21);
    let rb = b.run_seeded(0.2, 21);
    assert_eq!(format!("{ra:?}"), format!("{rb:?}"));
    // A different seed draws a different fault set (the fault stream is
    // salted off the run seed; identical draws would mean it ignored it).
    let other = Simulator::new(
        g.clone(),
        TrafficPattern::Uniform,
        SimConfig { seed: cfg.seed ^ 0x5eed, ..cfg },
    );
    let fo = other.faults().unwrap();
    let differs = (0..g.order()).any(|u| {
        (0..dim).any(|axis| {
            [1i64, -1].iter().any(|&s| fo.is_edge_dead(u, axis, s) != fa.is_edge_dead(u, axis, s))
        })
    }) || fo.node_dead_mask() != fa.node_dead_mask();
    assert!(differs, "fault draw ignored the run seed");
}
