//! Simulation measurement: accepted load, latency statistics.

/// Result of one simulation run at one offered load.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Offered load (phits/cycle/node).
    pub offered_load: f64,
    /// Accepted throughput (phits/cycle/node) over the measurement window.
    pub accepted_load: f64,
    /// Mean packet latency (cycles, injection to full reception) over
    /// packets delivered in the window.
    pub avg_latency: f64,
    /// 99th-percentile latency estimate.
    pub p99_latency: f64,
    /// Max observed latency.
    pub max_latency: u64,
    /// Packets delivered in the window.
    pub delivered_packets: u64,
    /// Packets whose latency was recorded: injected inside the window and
    /// delivered before the run ended (drain cycles extend this set to the
    /// stragglers; see `SimConfig::drain_cycles`).
    pub measured_packets: u64,
    /// Packets generated but dropped at a full source queue.
    pub source_dropped: u64,
    /// Total packets injected into the network during the whole run.
    pub injected_packets: u64,
    /// Per-dimension link utilization over the window: fraction of
    /// link-cycle capacity occupied by phits in each axis (2N
    /// unidirectional links per axis; a `w`-wide axis carries `w` phits
    /// per link-cycle). Backs the §3.4 resource-usage analysis.
    pub link_utilization: Vec<f64>,
    /// Utilization per directed port class (`2·dim` entries in
    /// `+e1, -e1, +e2, ...` order, aggregated over nodes): separates the
    /// two directions of each axis, which `link_utilization` folds
    /// together. Route-selection policies move load between these classes.
    pub port_utilization: Vec<f64>,
    /// Balance of the individual directed links: max/mean utilization over
    /// all `N·2·dim` links in the window (1.0 = perfectly balanced; 0.0
    /// when nothing moved). Fixed DOR ordering on asymmetric tori drives
    /// this up; the adaptive policies are measured by how far they pull it
    /// back down.
    pub link_util_spread: f64,
    /// Phits transferred per virtual channel in the window (`num_vcs`
    /// entries). When the escape protocol is live (adaptive policy,
    /// `num_vcs >= 2`), entry 0 is the escape lane, so
    /// `vc_phits[0] / vc_phits.sum()` is the fraction of hop traffic that
    /// had to drain through the deadlock-free DOR channel.
    pub vc_phits: Vec<u64>,
    /// Measurement window length (cycles).
    pub cycles: u64,
    /// Node count.
    pub nodes: usize,
    /// Digest of the simulator RNG state at the end of the run
    /// ([`Rng::state_digest`](crate::sim::rng::Rng::state_digest)) — a
    /// determinism fingerprint. Two runs with equal digests consumed the
    /// identical random-draw sequence; the active-set vs full-scan
    /// differential tests pin on it.
    pub rng_digest: u64,
}

impl SimResult {
    /// Fraction of hop traffic carried by the escape channel (VC 0), in
    /// `[0, 1]`; 0.0 when nothing moved. Only meaningful when the escape
    /// protocol is live (adaptive policy, `num_vcs >= 2`).
    pub fn escape_share(&self) -> f64 {
        escape_share(&self.vc_phits)
    }
}

/// VC-0 share of a per-VC phit histogram (0.0 when nothing moved) — the
/// one definition behind [`SimResult::escape_share`] and
/// [`WorkloadOutcome::escape_share`](crate::workload::WorkloadOutcome).
pub fn escape_share(vc_phits: &[u64]) -> f64 {
    let total: u64 = vc_phits.iter().sum();
    if total == 0 {
        0.0
    } else {
        vc_phits.first().copied().unwrap_or(0) as f64 / total as f64
    }
}

/// Online latency accumulator with a coarse histogram for percentiles.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    count: u64,
    sum: u64,
    max: u64,
    /// Histogram in 4-cycle buckets up to 4096 cycles (overflow bucket last).
    hist: Vec<u64>,
}

const BUCKET: u64 = 4;
const NBUCKETS: usize = 1024;

impl LatencyStats {
    pub fn new() -> Self {
        Self { count: 0, sum: 0, max: 0, hist: vec![0; NBUCKETS + 1] }
    }

    #[inline]
    pub fn record(&mut self, latency: u64) {
        self.count += 1;
        self.sum += latency;
        self.max = self.max.max(latency);
        let b = (latency / BUCKET) as usize;
        self.hist[b.min(NBUCKETS)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate percentile from the bucket histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (self.count as f64 * p).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (i as u64 * BUCKET + BUCKET / 2) as f64;
            }
        }
        self.max as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(0.99), 0.0);
    }

    #[test]
    fn mean_and_max() {
        let mut s = LatencyStats::new();
        for l in [10u64, 20, 30] {
            s.record(l);
        }
        assert_eq!(s.mean(), 20.0);
        assert_eq!(s.max(), 30);
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn percentile_monotone() {
        let mut s = LatencyStats::new();
        for l in 0..1000u64 {
            s.record(l);
        }
        let p50 = s.percentile(0.5);
        let p99 = s.percentile(0.99);
        assert!(p50 < p99);
        assert!((p50 - 500.0).abs() < 10.0, "p50={p50}");
        assert!((p99 - 990.0).abs() < 12.0, "p99={p99}");
    }

    #[test]
    fn overflow_bucket() {
        let mut s = LatencyStats::new();
        s.record(1_000_000);
        assert_eq!(s.max(), 1_000_000);
        assert!(s.percentile(1.0) >= 4096.0);
    }
}
