//! Deterministic PRNG for the simulator: xoshiro256++.
//!
//! Hand-rolled (this environment builds offline; see DESIGN.md
//! §Substitutions). xoshiro256++ passes BigCrush and is the default
//! generator of several stdlibs; determinism per seed is what the
//! experiment harness relies on for reproducibility.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire rejection-free multiply-shift bias is
    /// negligible for simulator n's; exactness is not required here).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Order-sensitive digest of the generator state — a determinism
    /// fingerprint: two runs that consumed the identical draw sequence
    /// from the same seed end with equal digests, and any divergence in
    /// draw order (an extra draw, a reordered draw) changes it. Backs the
    /// `rng_digest` fields of `SimResult` / `WorkloadOutcome` and the
    /// active-set vs full-scan differential tests.
    pub fn state_digest(&self) -> u64 {
        self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(1);
        let mean: f64 = (0..10_000).map(|_| rng.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}
