//! BFS-backed routing oracle: exact minimal records for any lattice graph.
//!
//! Ground truth for validating the closed-form and hierarchical routers.
//! O(N) per source; fine for every test-sized graph.

use std::collections::VecDeque;

use crate::lattice::LatticeGraph;

use super::{norm, Record, Router};

/// Exact (but slow) router: BFS with per-node predecessor steps.
pub struct OracleRouter {
    g: LatticeGraph,
}

impl OracleRouter {
    pub fn new(g: LatticeGraph) -> Self {
        Self { g }
    }

    /// Minimal distance from `src` to `dst` in hops.
    pub fn distance(&self, src: &[i64], dst: &[i64]) -> i64 {
        let r = self.route(src, dst);
        norm(&r)
    }

    /// BFS producing, for each node, one minimal record from `src`.
    /// Returns records indexed by node index.
    pub fn all_records_from(&self, src: &[i64]) -> Vec<Record> {
        let g = &self.g;
        let n = g.order();
        let dim = g.dim();
        let src_idx = g.index_of_vec(src);
        // step[v] = (axis, sign, parent) of the BFS tree edge into v.
        let mut step: Vec<Option<(usize, i64, usize)>> = vec![None; n];
        let mut seen = vec![false; n];
        let mut queue = VecDeque::new();
        seen[src_idx] = true;
        queue.push_back(src_idx);
        let mut tmp = vec![0i64; dim];
        while let Some(u) = queue.pop_front() {
            let label = g.label_of(u);
            for axis in 0..dim {
                for sign in [1i64, -1] {
                    tmp.copy_from_slice(&label);
                    tmp[axis] += sign;
                    g.reduce_in_place(&mut tmp);
                    let v = g.index_of(&tmp);
                    if !seen[v] {
                        seen[v] = true;
                        step[v] = Some((axis, sign, u));
                        queue.push_back(v);
                    }
                }
            }
        }
        // Reconstruct records by walking the tree.
        let mut records: Vec<Record> = vec![Vec::new(); n];
        let mut order: Vec<usize> = (0..n).collect();
        // Process in BFS distance order so parents are ready: recompute by
        // walking each chain (cheap; chains are <= diameter).
        for v in order.drain(..) {
            let mut r = vec![0i64; dim];
            let mut cur = v;
            while let Some((axis, sign, parent)) = step[cur] {
                r[axis] += sign;
                cur = parent;
            }
            records[v] = r;
        }
        records
    }
}

impl Router for OracleRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let records = self.all_records_from(src);
        records[self.g.index_of_vec(dst)].clone()
    }
}

/// BFS distances-only helper (used heavily in tests): minimal path length
/// between two labels.
pub fn bfs_distance(g: &LatticeGraph, src: &[i64], dst: &[i64]) -> i64 {
    let d = crate::metrics::bfs_distances(g, g.index_of_vec(src));
    d[g.index_of_vec(dst)] as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::is_valid_record;
    use crate::topology::{bcc, fcc, torus};

    #[test]
    fn oracle_records_are_valid_and_minimal() {
        for g in [torus(&[4, 4]), fcc(2), bcc(2)] {
            let oracle = OracleRouter::new(g.clone());
            let records = oracle.all_records_from(&vec![0; g.dim()]);
            let dist = crate::metrics::bfs_distances(&g, 0);
            for (v, r) in records.iter().enumerate() {
                assert!(is_valid_record(
                    &g,
                    &vec![0; g.dim()],
                    &g.label_of(v),
                    r
                ));
                assert_eq!(norm(r), dist[v] as i64, "node {v}");
            }
        }
    }

    #[test]
    fn oracle_example32() {
        // Example 32: FCC(4), (1,3,3) -> (6,0,1) has distance 4.
        let g = fcc(4);
        let oracle = OracleRouter::new(g);
        assert_eq!(oracle.distance(&[1, 3, 3], &[6, 0, 1]), 4);
    }
}
