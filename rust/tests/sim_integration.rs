//! Simulator integration: conservation laws, deadlock freedom under
//! sustained saturation, and the paper's qualitative results (crystals
//! beat equal-order mixed-radix tori).

use lattice_networks::sim::{SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;

fn cfg(warmup: u64, measure: u64) -> SimConfig {
    SimConfig { warmup_cycles: warmup, measure_cycles: measure, ..SimConfig::default() }
}

#[test]
fn conservation_injected_geq_delivered() {
    let sim = Simulator::new(topology::torus(&[4, 4, 4]), TrafficPattern::Uniform, cfg(200, 1500));
    for load in [0.2, 0.6, 1.0] {
        let r = sim.run(load);
        assert!(
            r.delivered_packets <= r.injected_packets,
            "load {load}: delivered {} > injected {}",
            r.delivered_packets,
            r.injected_packets
        );
    }
}

#[test]
fn sustained_saturation_no_deadlock_all_patterns_twisted() {
    // Bubble + DOR must keep every twisted network live at full load.
    for (tag, g) in [
        ("FCC(3)", topology::fcc(3)),
        ("BCC(2)", topology::bcc(2)),
        ("4D-FCC(2)", topology::fcc4d(2)),
        ("4D-BCC(2)", topology::bcc4d(2)),
    ] {
        for pattern in TrafficPattern::ALL {
            let sim = Simulator::new(g.clone(), pattern, cfg(300, 2500));
            let r = sim.run(1.0);
            assert!(
                r.delivered_packets > 50,
                "{tag}/{}: only {} delivered at saturation (deadlock?)",
                pattern.name(),
                r.delivered_packets
            );
        }
    }
}

#[test]
fn low_load_latency_tracks_distance() {
    // avg latency at near-zero load ≈ avg hops + packet size + eject.
    let g = topology::fcc(3);
    let stats = lattice_networks::metrics::distance_distribution(&g);
    let sim = Simulator::new(g, TrafficPattern::Uniform, cfg(500, 4000));
    let r = sim.run(0.02);
    let ps = 16.0;
    let expect = stats.avg_distance + ps;
    assert!(
        (r.avg_latency - expect).abs() < 8.0,
        "latency {:.1} vs model {:.1}",
        r.avg_latency,
        expect
    );
}

#[test]
fn crystal_beats_equal_order_torus_under_uniform() {
    // The §6.2 story at small scale: FCC(4) (128 nodes) vs T(8,4,4).
    let c = cfg(500, 3000);
    let fcc_peak = peak(&Simulator::new(topology::fcc(4), TrafficPattern::Uniform, c.clone()));
    let torus_peak = peak(&Simulator::new(
        topology::torus(&[8, 4, 4]),
        TrafficPattern::Uniform,
        c,
    ));
    assert!(
        fcc_peak > torus_peak,
        "FCC peak {fcc_peak:.3} should beat T(2a,a,a) peak {torus_peak:.3}"
    );
}

#[test]
fn bcc_beats_t2a2aa_under_uniform() {
    let c = cfg(500, 3000);
    let bcc_peak = peak(&Simulator::new(topology::bcc(2), TrafficPattern::Uniform, c.clone()));
    let torus_peak = peak(&Simulator::new(
        topology::torus(&[4, 4, 2]),
        TrafficPattern::Uniform,
        c,
    ));
    assert!(
        bcc_peak >= torus_peak * 0.95,
        "BCC peak {bcc_peak:.3} vs T(2a,2a,a) peak {torus_peak:.3}"
    );
}

fn peak(sim: &Simulator) -> f64 {
    [0.4, 0.6, 0.8, 1.0]
        .iter()
        .map(|&l| sim.run(l).accepted_load)
        .fold(0.0, f64::max)
}

#[test]
fn latency_increases_with_load() {
    let sim = Simulator::new(topology::fcc4d(2), TrafficPattern::Uniform, cfg(300, 2000));
    let low = sim.run(0.1).avg_latency;
    let high = sim.run(0.9).avg_latency;
    assert!(
        high > low,
        "latency must grow with load: {low:.1} -> {high:.1}"
    );
}

#[test]
fn antipodal_latency_higher_than_uniform() {
    // Antipodal packets travel the diameter: base latency must exceed
    // uniform's at the same low load.
    let g = topology::bcc4d(2);
    let c = cfg(300, 2000);
    let uni = Simulator::new(g.clone(), TrafficPattern::Uniform, c.clone()).run(0.05);
    let anti = Simulator::new(g, TrafficPattern::Antipodal, c).run(0.05);
    assert!(
        anti.avg_latency > uni.avg_latency,
        "antipodal {:.1} <= uniform {:.1}",
        anti.avg_latency,
        uni.avg_latency
    );
}

#[test]
fn bubble_off_can_deadlock_or_degrade() {
    // With bubble disabled, rings can deadlock; we only require the run to
    // terminate (engine robustness), not any particular throughput.
    let mut c = cfg(200, 1000);
    c.bubble = false;
    let sim = Simulator::new(topology::torus(&[4, 4]), TrafficPattern::Uniform, c);
    let r = sim.run(1.0);
    // Engine must not panic/hang; deadlocked networks deliver little.
    assert!(r.cycles == 1000);
}

#[test]
fn seeds_vary_results_slightly() {
    let sim = Simulator::new(topology::fcc(3), TrafficPattern::Uniform, cfg(200, 1500));
    let a = sim.run_seeded(0.5, 1);
    let b = sim.run_seeded(0.5, 2);
    assert_ne!(a.delivered_packets, b.delivered_packets);
    // ... but statistics agree within a few percent.
    let rel = (a.accepted_load - b.accepted_load).abs() / a.accepted_load;
    assert!(rel < 0.1, "seeds diverge too much: {rel}");
}
