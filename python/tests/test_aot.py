"""AOT path tests: lowering produces parseable HLO text with the agreed
interface, and the manifest describes it correctly."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot, model


def test_iters_for():
    assert model.minplus_iters_for(64) == 6
    assert model.minplus_iters_for(128) == 7
    assert model.minplus_iters_for(2) == 1


def test_gemm_steps_for():
    assert model.gemm_steps_for(64) == 33


@pytest.mark.parametrize("n", [16, 32])
def test_lower_minplus_hlo_text(n):
    lowered, meta = aot.lower_minplus(n, block=8)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert f"f32[{n},{n}]" in text
    assert meta["iters"] == model.minplus_iters_for(n)
    # while-loop lowering, not unrolled: one fusion body regardless of iters
    assert "while" in text


@pytest.mark.parametrize("n", [16])
def test_lower_gemm_hlo_text(n):
    lowered, meta = aot.lower_gemm(n, block=8)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "dot(" in text  # the MXU-shaped GEMM survived lowering
    assert meta["steps"] == model.gemm_steps_for(n)


def test_aot_main_writes_manifest(tmp_path):
    cmd = [
        sys.executable,
        "-m",
        "compile.aot",
        "--out-dir",
        str(tmp_path),
        "--sizes",
        "16",
        "--block",
        "8",
    ]
    subprocess.run(cmd, check=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["inf"] == 1e9
    names = {(a["name"], a["n"]) for a in manifest["artifacts"]}
    assert ("apsp_minplus", 16) in names
    assert ("apsp_gemm", 16) in names
    for a in manifest["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert a["outputs"] == ["dist f32[n,n]", "sum f32[]", "max f32[]"]


def test_repo_artifacts_exist_and_match_manifest():
    """`make artifacts` output is complete (guards the Rust integration)."""
    art = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(__file__))), "artifacts")
    if not os.path.exists(os.path.join(art, "manifest.json")):
        pytest.skip("run `make artifacts` first")
    manifest = json.loads(open(os.path.join(art, "manifest.json")).read())
    for a in manifest["artifacts"]:
        path = os.path.join(art, a["file"])
        assert os.path.exists(path), path
        head = open(path).read(32)
        assert head.startswith("HloModule")
