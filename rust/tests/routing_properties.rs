//! Property tests on routing: every router on every paper topology
//! produces valid, exactly-minimal records (BFS is the ground truth).

use lattice_networks::lattice::LatticeGraph;
use lattice_networks::math::IMat;
use lattice_networks::metrics::bfs_distances;
use lattice_networks::routing::{
    bcc::BccRouter, fcc::FccRouter, is_valid_record, norm, rtt::RttRouter, torus::TorusRouter,
    HierarchicalRouter, Router, RoutingTable,
};
use lattice_networks::sim::rng::Rng;
use lattice_networks::topology;

/// Assert a router is exactly minimal on all pairs from a random sample of
/// sources (full all-pairs when small).
fn assert_minimal<R: Router>(router: &R, tag: &str) {
    let g = router.graph().clone();
    let mut rng = Rng::new(0x90210);
    let sources: Vec<usize> = if g.order() <= 300 {
        (0..g.order()).collect()
    } else {
        (0..24).map(|_| rng.below(g.order())).collect()
    };
    for s in sources {
        let src = g.label_of(s);
        let dist = bfs_distances(&g, s);
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let r = router.route(&src, &dst);
            assert!(is_valid_record(&g, &src, &dst, &r), "{tag}: {src:?}->{dst:?} {r:?}");
            assert_eq!(
                norm(&r),
                dist[v] as i64,
                "{tag}: {src:?}->{dst:?} got {r:?}"
            );
            // Every tie is also minimal and valid.
            for t in router.route_ties(&src, &dst) {
                assert!(is_valid_record(&g, &src, &dst, &t), "{tag} tie {t:?}");
                assert_eq!(norm(&t), dist[v] as i64, "{tag} tie {t:?}");
            }
        }
    }
}

#[test]
fn closed_form_routers_minimal() {
    for a in [2i64, 3, 4] {
        assert_minimal(&FccRouter::new(a), &format!("FCC({a})"));
        assert_minimal(&BccRouter::new(a), &format!("BCC({a})"));
        assert_minimal(&RttRouter::new(a), &format!("RTT({a})"));
    }
    assert_minimal(&TorusRouter::new(topology::torus(&[6, 4, 2])), "T(6,4,2)");
}

#[test]
fn hierarchical_minimal_on_all_paper_topologies() {
    let graphs: Vec<(String, LatticeGraph)> = vec![
        ("PC(3)".into(), topology::pc(3)),
        ("FCC(3)".into(), topology::fcc(3)),
        ("BCC(2)".into(), topology::bcc(2)),
        ("4D-FCC(2)".into(), topology::fcc4d(2)),
        ("4D-BCC(2)".into(), topology::bcc4d(2)),
        ("Lip(1)".into(), topology::lip(1)),
        ("T⊞RTT(2)".into(), topology::hybrid_t_rtt(2)),
        ("PC⊞BCC(1)".into(), topology::hybrid_pc_bcc(1)),
        ("T(4,3,2)".into(), topology::torus(&[4, 3, 2])),
    ];
    for (tag, g) in graphs {
        assert_minimal(&HierarchicalRouter::new(g), &tag);
    }
}

#[test]
fn hierarchical_minimal_on_random_lattices() {
    // Random 2D/3D lattice graphs: Algorithm 1 must stay minimal.
    let mut rng = Rng::new(0x424242);
    let mut tested = 0;
    while tested < 12 {
        let n = 2 + rng.below(2);
        let data: Vec<i64> = (0..n * n)
            .map(|_| rng.below(9) as i64 - 4)
            .collect();
        let m = IMat::from_flat(n, &data);
        if m.det() == 0 || m.det().abs() > 300 {
            continue;
        }
        let g = LatticeGraph::new(m);
        if !g.is_connected() {
            continue;
        }
        assert_minimal(&HierarchicalRouter::new(g.clone()), &format!("rand{:?}", g.hermite()));
        tested += 1;
    }
}

#[test]
fn routing_table_consistent_with_direct_routing() {
    for (tag, g) in [
        ("FCC(3)", topology::fcc(3)),
        ("4D-BCC(2)", topology::bcc4d(2)),
    ] {
        let table = RoutingTable::build_hierarchical(&g);
        let router = HierarchicalRouter::new(g.clone());
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let s = rng.below(g.order());
            let d = rng.below(g.order());
            let tr = table.record_by_index(s, d);
            let rr = router.route(&g.label_of(s), &g.label_of(d));
            assert_eq!(norm(tr), norm(&rr), "{tag} {s}->{d}");
        }
    }
}

#[test]
fn record_application_reaches_destination_via_links() {
    // Walk the record hop by hop through actual graph steps (what the
    // simulator does) and land exactly on the destination.
    let g = topology::fcc4d(2);
    let router = HierarchicalRouter::new(g.clone());
    let mut rng = Rng::new(99);
    for _ in 0..300 {
        let s = rng.below(g.order());
        let d = rng.below(g.order());
        let rec = router.route(&g.label_of(s), &g.label_of(d));
        let mut cur = s;
        for (axis, &hops) in rec.iter().enumerate() {
            let sign = if hops >= 0 { 1 } else { -1 };
            for _ in 0..hops.abs() {
                cur = g.step(cur, axis, sign);
            }
        }
        assert_eq!(cur, d, "record {rec:?} from {s} missed {d}");
    }
}

#[test]
fn ties_cover_distinct_first_hops() {
    // Remark 30: random tie choice balances links — ties must actually
    // differ in their geometry for at least some pairs.
    let g = topology::pc(4);
    let router = HierarchicalRouter::new(g.clone());
    let mut multi = 0;
    for v in 0..g.order() {
        let ties = router.route_ties(&[0, 0, 0], &g.label_of(v));
        if ties.len() > 1 {
            multi += 1;
            // all distinct
            for i in 0..ties.len() {
                for j in i + 1..ties.len() {
                    assert_ne!(ties[i], ties[j]);
                }
            }
        }
    }
    assert!(multi > 0, "no tie sets found on an even torus (impossible)");
}
