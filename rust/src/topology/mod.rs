//! Named topology constructors and the topology catalog.
//!
//! Everything Section 3 and 4 of the paper name gets a constructor here:
//! the cubic crystal graphs (PC, FCC, BCC), mixed-radix tori, the
//! rectangular twisted torus, the symmetric 4D lifts (4D-BCC, 4D-FCC,
//! Lip), and the `⊞` hybrids of Table 2. [`catalog`] additionally parses
//! textual topology specs (`"fcc:8"`, `"torus:16x8x8x8"`, ...) so the CLI,
//! examples and benches share one naming scheme.

pub mod catalog;
pub mod racks;
pub mod tree;

use crate::lattice::{common_lift, LatticeGraph};
use crate::math::IMat;

/// Primitive cubic lattice graph `PC(a)` — the 3D torus of side `a`
/// (§3.1; isomorphic to the a-ary 3-cube by Theorem 5).
pub fn pc(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::diag(&[a, a, a]))
}

/// Face-centered cubic lattice graph `FCC(a)` (§3.2), order `2a^3`.
/// Isomorphic to the prismatic doubly twisted torus PDTT(a) (Prop. 15).
pub fn fcc(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[&[a, a, 0], &[a, 0, a], &[0, a, a]]))
}

/// Body-centered cubic lattice graph `BCC(a)` (§3.3), order `4a^3` —
/// the paper's new proposal.
pub fn bcc(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[&[-a, a, a], &[a, -a, a], &[a, a, -a]]))
}

/// Rectangular twisted torus `RTT(a) = G([[2a, a], [0, a]])` (Lemma 14,
/// [7, 9]) — the projection of FCC(a).
pub fn rtt(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[&[2 * a, a], &[0, a]]))
}

/// Mixed-radix torus `T(a_1, ..., a_n)`.
pub fn torus(sides: &[i64]) -> LatticeGraph {
    LatticeGraph::torus(sides)
}

/// The 4D body-centered hypercube lattice graph `4D-BCC(a)` (§4.1),
/// symmetric, order `8a^4`, projection `PC(2a)` (Prop. 17).
pub fn bcc4d(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[
        &[2 * a, 0, 0, a],
        &[0, 2 * a, 0, a],
        &[0, 0, 2 * a, a],
        &[0, 0, 0, a],
    ]))
}

/// The 4D face-centered lattice graph `4D-FCC(a)` (§4.1), symmetric,
/// order `2a^4`, projection `FCC(a)` (Prop. 18).
pub fn fcc4d(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[
        &[2 * a, a, a, a],
        &[0, a, 0, 0],
        &[0, 0, a, 0],
        &[0, 0, 0, a],
    ]))
}

/// The Lipschitz graph `Lip(a)` (Prop. 19): a symmetric lift of FCC(2a),
/// order `16a^4`, related to quaternion algebras [21].
pub fn lip(a: i64) -> LatticeGraph {
    assert!(a >= 1);
    LatticeGraph::new(IMat::from_rows(&[
        &[a, -a, -a, -a],
        &[a, a, -a, a],
        &[a, a, a, -a],
        &[a, -a, a, a],
    ]))
}

/// Generalized n-dimensional PC: the symmetric torus `T(a, ..., a)`
/// (left branch of the Figure 4 tree).
pub fn pc_nd(n: usize, a: i64) -> LatticeGraph {
    LatticeGraph::torus(&vec![a; n])
}

/// Generalized n-dimensional BCC (Figure 4): `diag(2a, ..., 2a)` with a
/// final column of `a`s — the nD-PC sibling leaf.
pub fn bcc_nd(n: usize, a: i64) -> LatticeGraph {
    assert!(n >= 2);
    let mut m = IMat::zeros(n, n);
    for i in 0..n - 1 {
        m[(i, i)] = 2 * a;
        m[(i, n - 1)] = a;
    }
    m[(n - 1, n - 1)] = a;
    LatticeGraph::new(m)
}

/// Generalized n-dimensional FCC (right branch of Figure 4): the Hermite
/// pattern `[[2a, a, ..., a], [0, aI]]`.
pub fn fcc_nd(n: usize, a: i64) -> LatticeGraph {
    assert!(n >= 2);
    let mut m = IMat::zeros(n, n);
    m[(0, 0)] = 2 * a;
    for j in 1..n {
        m[(0, j)] = a;
        m[(j, j)] = a;
    }
    LatticeGraph::new(m)
}

/// Table 2 hybrid: `T(2a, 2a) ⊞ RTT(a)` (3D, order `4a^3`).
pub fn hybrid_t_rtt(a: i64) -> LatticeGraph {
    LatticeGraph::new(common_lift(
        LatticeGraph::torus(&[2 * a, 2 * a]).matrix(),
        rtt(a).matrix(),
    ))
}

/// Table 2 hybrid: `PC(2a) ⊞ BCC(a)` (4D, order `8a^4`).
pub fn hybrid_pc_bcc(a: i64) -> LatticeGraph {
    LatticeGraph::new(common_lift(pc(2 * a).matrix(), bcc(a).matrix()))
}

/// Table 2 hybrid: `PC(2a) ⊞ FCC(a)` (5D, order `8a^5`).
pub fn hybrid_pc_fcc(a: i64) -> LatticeGraph {
    LatticeGraph::new(common_lift(pc(2 * a).matrix(), fcc(a).matrix()))
}

/// Table 2 hybrid: `BCC(a) ⊞ FCC(a)` (5D, order `4a^5`).
pub fn hybrid_bcc_fcc(a: i64) -> LatticeGraph {
    LatticeGraph::new(common_lift(bcc(a).matrix(), fcc(a).matrix()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_match_paper() {
        for a in [1i64, 2, 3] {
            assert_eq!(pc(a).order(), (a * a * a) as usize);
            assert_eq!(fcc(a).order(), (2 * a * a * a) as usize);
            assert_eq!(bcc(a).order(), (4 * a * a * a) as usize);
            assert_eq!(bcc4d(a).order(), (8 * a * a * a * a) as usize);
            assert_eq!(fcc4d(a).order(), (2 * a * a * a * a) as usize);
            assert_eq!(lip(a).order(), (16 * a * a * a * a) as usize);
            assert_eq!(rtt(a).order(), (2 * a * a) as usize);
        }
    }

    #[test]
    fn power_of_two_upgrade_path() {
        // §3.4: crystal graph exists for every power-of-two order:
        // PC(2^t)=2^{3t}, FCC(2^t)=2^{3t+1}, BCC(2^t)=2^{3t+2}.
        for t in 1..4u32 {
            let a = 2i64.pow(t);
            assert_eq!(pc(a).order(), 1usize << (3 * t));
            assert_eq!(fcc(a).order(), 1usize << (3 * t + 1));
            assert_eq!(bcc(a).order(), 1usize << (3 * t + 2));
            assert_eq!(pc(2 * a).order(), 1usize << (3 * t + 3));
        }
    }

    #[test]
    fn fcc_isomorphic_pdtt_structure() {
        // Prop. 15 consequence: every projection of FCC is RTT.
        let g = fcc(3);
        for i in 0..3 {
            assert!(g.project_over(i).isomorphic_linear(&rtt(3)));
        }
    }

    #[test]
    fn nd_families_match_3d() {
        assert!(pc_nd(3, 4).right_equivalent(&pc(4)));
        assert!(bcc_nd(3, 2).right_equivalent(&bcc(2)));
        assert!(fcc_nd(3, 2).right_equivalent(&fcc(2)));
        assert!(bcc_nd(4, 2).right_equivalent(&bcc4d(2)));
        assert!(fcc_nd(4, 2).right_equivalent(&fcc4d(2)));
    }

    #[test]
    fn nd_families_symmetric() {
        for n in 2..5usize {
            assert!(pc_nd(n, 2).is_symmetric(), "PC^{n}");
            assert!(bcc_nd(n, 2).is_symmetric(), "BCC^{n}");
            assert!(fcc_nd(n, 2).is_symmetric(), "FCC^{n}");
        }
    }

    #[test]
    fn lip_projection_is_fcc_2a() {
        // Prop. 19: Lip(a) is a lift of FCC(2a).
        for a in [1i64, 2] {
            let p = lip(a).projection_graph();
            assert!(
                p.isomorphic_linear(&fcc(2 * a)),
                "Lip({a}) projection vs FCC({})",
                2 * a
            );
        }
    }

    #[test]
    fn hybrid_orders() {
        for a in [1i64, 2] {
            assert_eq!(hybrid_t_rtt(a).order(), (4 * a * a * a) as usize);
            assert_eq!(hybrid_pc_bcc(a).order(), (8 * a.pow(4)) as usize);
            assert_eq!(hybrid_pc_fcc(a).order(), (8 * a.pow(5)) as usize);
            assert_eq!(hybrid_bcc_fcc(a).order(), (4 * a.pow(5)) as usize);
        }
    }

    #[test]
    fn hybrid_dimensions_match_table2() {
        let a = 2;
        assert_eq!(hybrid_t_rtt(a).dim(), 3);
        assert_eq!(hybrid_pc_bcc(a).dim(), 4);
        assert_eq!(hybrid_pc_fcc(a).dim(), 5);
        assert_eq!(hybrid_bcc_fcc(a).dim(), 5);
    }
}
