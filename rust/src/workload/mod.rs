//! Closed-loop application workloads on the cycle engine.
//!
//! The open-loop simulator ([`crate::sim`]) measures steady-state latency
//! and throughput under synthetic injection; this subsystem measures what
//! applications feel: the **completion time** of finite, dependency-ordered
//! communication patterns — halo exchange, all-to-all, ring and
//! recursive-doubling all-reduce, random permutation, and hotspot incast —
//! the scenario diversity behind the paper's near-neighbor vs global
//! traffic claims.
//!
//! - [`spec`]: the [`Workload`] message-set model (single-packet messages
//!   with happens-before deps), validation, and [`WorkloadOutcome`].
//! - [`gen`]: the pattern generators ([`WorkloadKind`]).
//! - [`driver`]: [`WorkloadRunner`] — multi-seed averaged completion-time
//!   measurement over a shared simulator, plus the [`par_map`] worker pool
//!   reused by the coordinator experiments.
//!
//! Execution itself lives in the engine
//! ([`crate::sim::Simulator::run_workload`]): messages are injected as
//! their dependencies complete and the run lasts until the network drains.
//!
//! ```no_run
//! use lattice_networks::sim::SimConfig;
//! use lattice_networks::topology;
//! use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams, WorkloadRunner};
//!
//! let g = topology::fcc(4);
//! let wl = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
//! let runner = WorkloadRunner { sim: SimConfig::fast(), ..Default::default() };
//! let point = runner.run("FCC(4)", &g, &wl);
//! println!("all-to-all drained in {:.0} cycles", point.completion_cycles);
//! ```

pub mod driver;
pub mod gen;
pub mod spec;

pub use driver::{par_map, CompletionPoint, WorkloadRunner};
pub use gen::{generate, WorkloadKind, WorkloadParams};
pub use spec::{Workload, WorkloadMessage, WorkloadOutcome};
