//! Parser for `artifacts/manifest.txt` (the Rust-facing twin of
//! `manifest.json`; line-based because this build is fully offline).
//!
//! Format:
//! ```text
//! inf=1e+09
//! artifact name=apsp_minplus n=64 block=64 iters=6 file=apsp_minplus_n64.hlo.txt
//! artifact name=apsp_gemm n=64 block=64 steps=33 file=apsp_gemm_n64.hlo.txt
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT artifact entry.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    /// Model name: `apsp_minplus` or `apsp_gemm`.
    pub name: String,
    /// Matrix size the model was lowered at.
    pub n: usize,
    /// Pallas block size baked into the kernel.
    pub block: usize,
    /// Iteration count (`iters` for min-plus squaring, `steps` for gemm).
    pub iters: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// INF sentinel used by the padding protocol.
    pub inf: f32,
    pub artifacts: Vec<Artifact>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir.to_path_buf())
    }

    /// Parse manifest text.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut inf = None;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(v) = line.strip_prefix("inf=") {
                inf = Some(v.parse::<f32>().with_context(|| format!("line {}", lineno + 1))?);
                continue;
            }
            let Some(rest) = line.strip_prefix("artifact ") else {
                bail!("manifest line {} unrecognized: {line:?}", lineno + 1);
            };
            let kv: HashMap<&str, &str> = rest
                .split_whitespace()
                .filter_map(|tok| tok.split_once('='))
                .collect();
            let get = |k: &str| -> Result<&str> {
                kv.get(k)
                    .copied()
                    .with_context(|| format!("manifest line {}: missing {k}=", lineno + 1))
            };
            let iters = if let Some(v) = kv.get("iters") {
                v.parse()?
            } else {
                get("steps")?.parse()?
            };
            artifacts.push(Artifact {
                name: get("name")?.to_string(),
                n: get("n")?.parse()?,
                block: get("block")?.parse()?,
                iters,
                file: get("file")?.to_string(),
            });
        }
        let inf = inf.context("manifest missing inf=")?;
        if artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        Ok(Self { inf, artifacts, dir })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// Smallest artifact of `name` whose size fits `order` nodes.
    pub fn best_fit(&self, name: &str, order: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.name == name && a.n >= order)
            .min_by_key(|a| a.n)
    }

    /// All available sizes for a model name.
    pub fn sizes_of(&self, name: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.name == name)
            .map(|a| a.n)
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
inf=1e+09
artifact name=apsp_minplus n=64 block=64 iters=6 file=apsp_minplus_n64.hlo.txt
artifact name=apsp_gemm n=64 block=64 steps=33 file=apsp_gemm_n64.hlo.txt
artifact name=apsp_minplus n=128 block=64 iters=7 file=apsp_minplus_n128.hlo.txt
";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.inf, 1e9);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[0].iters, 6);
        assert_eq!(m.artifacts[1].iters, 33); // steps= accepted
        assert_eq!(m.sizes_of("apsp_minplus"), vec![64, 128]);
    }

    #[test]
    fn best_fit_picks_smallest_sufficient() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.best_fit("apsp_minplus", 50).unwrap().n, 64);
        assert_eq!(m.best_fit("apsp_minplus", 65).unwrap().n, 128);
        assert!(m.best_fit("apsp_minplus", 1000).is_none());
        assert!(m.best_fit("nope", 8).is_none());
    }

    #[test]
    fn parse_errors() {
        assert!(Manifest::parse("inf=1e9\n", PathBuf::new()).is_err()); // no artifacts
        assert!(Manifest::parse("artifact name=x n=1 block=1 iters=1 file=f\n", PathBuf::new()).is_err()); // no inf
        assert!(Manifest::parse("inf=1e9\nbogus line\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("inf=1e9\nartifact name=x n=1 file=f\n", PathBuf::new()).is_err()); // missing block
    }

    #[test]
    fn repo_manifest_parses() {
        // Guard the real `make artifacts` output when present.
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.best_fit("apsp_minplus", 64).is_some());
            assert!(m.best_fit("apsp_gemm", 64).is_some());
        }
    }
}
