//! The synchronous cycle engine: virtual cut-through routers with 3 VCs,
//! bubble flow control, DOR service over minimal routing records.
//!
//! Model (see module docs in `sim/mod.rs` for the INSEE correspondence):
//! each node has `2n` input ports (one per incoming link) with `vc_count`
//! FIFO queues each, an injection queue, and an ejection channel. One
//! packet transfer per link at a time; a transfer started at `t` holds the
//! link until `t + packet_size` (16-phit serialization), delivers the head
//! downstream at `t + 1` (cut-through), and frees the upstream buffer slot
//! at `t + packet_size` (tail departure).
//!
//! Two injection regimes share the router core:
//!
//! - **open loop** ([`Simulator::run`]): Bernoulli injection at a fixed
//!   offered load with a warmup/measure/drain window — the steady-state
//!   regime behind the paper's Figures 5–8;
//! - **closed loop** ([`Simulator::run_workload`]): a finite,
//!   dependency-ordered message set (a [`Workload`]) is injected as its
//!   dependencies complete and the run lasts until the network drains,
//!   measuring **completion time** — the application-level regime behind
//!   the collective workload experiments.

use std::collections::VecDeque;

use crate::lattice::LatticeGraph;
use crate::routing::{Record, RoutingTable};
use crate::workload::{Workload, WorkloadOutcome};

use super::config::SimConfig;
use super::rng::Rng;
use super::stats::{LatencyStats, SimResult};
use super::traffic::{Traffic, TrafficPattern};

/// Max supported graph dimension (the paper uses up to 6).
pub const MAX_DIM: usize = 6;

const NO_AXIS: u8 = u8::MAX;

/// A packet in flight.
#[derive(Clone, Copy, Debug)]
struct Packet {
    /// Remaining signed hops per dimension.
    record: [i16; MAX_DIM],
    /// Virtual channel (0..vc_count), fixed end-to-end.
    vc: u8,
    /// Axis of the last hop (`NO_AXIS` right after injection) — bubble
    /// condition: entering a new dimensional ring needs 2 free slots.
    last_axis: u8,
    /// Injection cycle (for latency).
    inject_time: u64,
    /// Cycle at which the head is present and routable at the current node.
    head_ready: u64,
    /// Cached desired output port (recomputed on every hop; `ports` value
    /// means ejection). Avoids re-deriving DOR per cycle on the hot scan.
    next_port: u8,
}

/// FIFO bookkeeping over an externally owned slot arena.
///
/// Capacities come from [`SimConfig`] at run time, so the packet-id slots
/// live in per-run arenas (`State::input_slots` / `State::inj_slots`, one
/// contiguous `cap`-sized window per queue) instead of a fixed-size inline
/// array; every method takes its window. `len` counts queued packets;
/// `reserved` additionally counts slots whose packet has been forwarded but
/// whose tail has not yet fully left (VCT keeps the space claimed until the
/// tail drains).
#[derive(Clone, Copy, Debug)]
struct Fifo {
    head: u16,
    len: u16,
    reserved: u16,
    /// Cached output port of the head packet — the arbitration scan reads
    /// only the FIFO metadata, never the packet arena (cache locality is
    /// the engine's top bottleneck; see EXPERIMENTS.md §Perf).
    head_port: u8,
    /// Cached `head_ready` of the head packet.
    head_ready: u64,
}

impl Fifo {
    const EMPTY: Fifo = Fifo {
        head: 0,
        len: 0,
        reserved: 0,
        head_port: 0,
        head_ready: 0,
    };

    #[inline]
    fn push(&mut self, slots: &mut [u32], pid: u32, ready: u64, port: u8) {
        debug_assert!((self.len as usize) < slots.len());
        let tail = (self.head as usize + self.len as usize) % slots.len();
        slots[tail] = pid;
        if self.len == 0 {
            self.head_ready = ready;
            self.head_port = port;
        }
        self.len += 1;
        self.reserved += 1;
    }

    #[inline]
    fn front(&self, slots: &[u32]) -> Option<u32> {
        (self.len > 0).then(|| slots[self.head as usize])
    }

    /// Refresh the cached head metadata after a pop.
    #[inline]
    fn refresh_head(&mut self, slots: &[u32], packets: &[Packet]) {
        if self.len > 0 {
            let pkt = &packets[slots[self.head as usize] as usize];
            self.head_ready = pkt.head_ready;
            self.head_port = pkt.next_port;
        }
    }

    #[inline]
    fn pop(&mut self, slots: &[u32]) -> u32 {
        debug_assert!(self.len > 0);
        let pid = slots[self.head as usize];
        self.head = ((self.head as usize + 1) % slots.len()) as u16;
        self.len -= 1;
        // `reserved` stays up; released by the tail-departure event.
        pid
    }

    #[inline]
    fn release(&mut self) {
        debug_assert!(self.reserved > 0);
        self.reserved -= 1;
    }
}

/// Deferred events, bucketed on a calendar ring (all delays equal the
/// packet serialization time, so the ring is tiny).
#[derive(Clone, Copy, Debug)]
enum Event {
    /// Tail left an input buffer: release its reservation.
    FreeInput(u32),
    /// Tail left an injection queue slot.
    FreeInj(u32),
    /// Tail fully received at the destination: complete delivery.
    Deliver(u32),
}

/// Compact routing store: tie sets of i16 records per difference index.
struct CompactRoutes {
    offsets: Vec<u32>,
    records: Vec<[i16; MAX_DIM]>,
}

impl CompactRoutes {
    fn build(table: &RoutingTable) -> Self {
        let g = table.graph();
        let n = g.order();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut records = Vec::new();
        offsets.push(0u32);
        for v in 0..n {
            // tie set for difference = label(v) (src = 0)
            for tie in table.ties_by_index(0, v) {
                records.push(compact(tie));
            }
            offsets.push(records.len() as u32);
        }
        Self { offsets, records }
    }

    #[inline]
    fn ties(&self, diff_idx: usize) -> &[[i16; MAX_DIM]] {
        &self.records[self.offsets[diff_idx] as usize..self.offsets[diff_idx + 1] as usize]
    }
}

/// DOR output port of a remaining record: lowest nonzero dimension
/// (`ports` = ejection).
#[inline]
fn port_of_record(record: &[i16; MAX_DIM], dim: usize, ports: usize) -> u8 {
    for (axis, &h) in record.iter().enumerate().take(dim) {
        if h != 0 {
            return (2 * axis + usize::from(h < 0)) as u8;
        }
    }
    ports as u8
}

fn compact(r: &Record) -> [i16; MAX_DIM] {
    let mut out = [0i16; MAX_DIM];
    for (i, &x) in r.iter().enumerate() {
        out[i] = i16::try_from(x).expect("hop count exceeds i16");
    }
    out
}

/// The simulator: immutable tables + per-run mutable state.
pub struct Simulator {
    g: LatticeGraph,
    cfg: SimConfig,
    pattern: TrafficPattern,
    dim: usize,
    ports: usize,
    nodes: usize,
    /// `neighbor[u * ports + p]`: node reached from `u` via port `p`
    /// (`p = 2*axis + (sign < 0)`).
    neighbor: Vec<u32>,
    /// Flattened labels, `dim` entries per node.
    labels: Vec<i64>,
    routes: CompactRoutes,
}

/// Per-run mutable state.
struct State {
    packets: Vec<Packet>,
    free_pids: Vec<u32>,
    /// Input FIFOs: `(u * ports + p) * vc_count + vc`.
    inputs: Vec<Fifo>,
    /// Slot arena for the input FIFOs: `queue_packets` ids per queue.
    input_slots: Vec<u32>,
    /// Injection queue per node.
    inj: Vec<Fifo>,
    /// Slot arena for the injection queues: `injection_queue_packets` ids
    /// per node.
    inj_slots: Vec<u32>,
    /// Per-node occupancy bitmask over the local input FIFOs
    /// (bit = p_in * vc_count + vc): lets the arbitration scan visit only
    /// non-empty queues (the dominant cost at low/mid load).
    occ: Vec<u64>,
    /// Link busy-until per `(u, p)`.
    link_busy: Vec<u64>,
    /// Ejection channel busy-until per node.
    eject_busy: Vec<u64>,
    /// Calendar ring of deferred events.
    calendar: Vec<Vec<Event>>,
    rng: Rng,
    // measurement
    now: u64,
    measure_start: u64,
    measure_end: u64,
    delivered_phits: u64,
    delivered_packets: u64,
    /// Phits transferred per dimension axis during the measurement window
    /// (the §3.4 link-utilization instrumentation).
    phits_by_axis: [u64; MAX_DIM],
    injected_packets: u64,
    source_dropped: u64,
    latency: LatencyStats,
    /// Destination node per live packet (parallel to `packets`).
    dests: Vec<u32>,
}

impl Simulator {
    /// Build a simulator with a prebuilt routing table (must belong to the
    /// same graph).
    pub fn with_table(g: LatticeGraph, table: &RoutingTable, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let dim = g.dim();
        assert!(dim <= MAX_DIM, "dimension {dim} exceeds MAX_DIM");
        assert!(
            cfg.queue_packets >= 1 && cfg.injection_queue_packets >= 1,
            "queue capacities must be at least one packet"
        );
        assert!(
            cfg.queue_packets <= u16::MAX as u32 && cfg.injection_queue_packets <= u16::MAX as u32,
            "queue capacities exceed u16 bookkeeping"
        );
        assert!(
            2 * dim * cfg.vc_count <= 64,
            "occupancy bitmask supports at most 64 VC queues per node"
        );
        let nodes = g.order();
        let ports = 2 * dim;
        let mut neighbor = vec![0u32; nodes * ports];
        let mut labels = vec![0i64; nodes * dim];
        for u in 0..nodes {
            let label = g.label_of(u);
            labels[u * dim..(u + 1) * dim].copy_from_slice(&label);
            for axis in 0..dim {
                for (s, sign) in [(0usize, 1i64), (1, -1)] {
                    neighbor[u * ports + 2 * axis + s] = g.step(u, axis, sign) as u32;
                }
            }
        }
        let routes = CompactRoutes::build(table);
        Self { g, cfg, pattern, dim, ports, nodes, neighbor, labels, routes }
    }

    /// Build with the best available router for the graph (hierarchical —
    /// exactly minimal for any lattice graph).
    pub fn new(g: LatticeGraph, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let table = RoutingTable::build_hierarchical(&g);
        Self::with_table(g, &table, pattern, cfg)
    }

    /// Build for closed-loop workload runs (no synthetic traffic pattern is
    /// consulted in that mode).
    pub fn for_workload(g: LatticeGraph, cfg: SimConfig) -> Self {
        Self::new(g, TrafficPattern::Uniform, cfg)
    }

    pub fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Fresh per-run state with the given RNG seed and measurement window.
    fn make_state(&self, rng_seed: u64, measure_start: u64, measure_end: u64) -> State {
        let cfg = &self.cfg;
        let cal_len = cfg.packet_size as usize + 2;
        let qcap = cfg.queue_packets as usize;
        let icap = cfg.injection_queue_packets as usize;
        let n_inputs = self.nodes * self.ports * cfg.vc_count;
        State {
            packets: Vec::with_capacity(4096),
            free_pids: Vec::new(),
            inputs: vec![Fifo::EMPTY; n_inputs],
            input_slots: vec![0u32; n_inputs * qcap],
            inj: vec![Fifo::EMPTY; self.nodes],
            inj_slots: vec![0u32; self.nodes * icap],
            occ: vec![0u64; self.nodes],
            link_busy: vec![0u64; self.nodes * self.ports],
            eject_busy: vec![0u64; self.nodes],
            calendar: vec![Vec::new(); cal_len],
            rng: Rng::new(rng_seed),
            now: 0,
            measure_start,
            measure_end,
            delivered_phits: 0,
            delivered_packets: 0,
            phits_by_axis: [0; MAX_DIM],
            injected_packets: 0,
            source_dropped: 0,
            latency: LatencyStats::new(),
            dests: Vec::with_capacity(4096),
        }
    }

    /// Run one simulation at `offered_load` phits/(cycle·node).
    pub fn run(&self, offered_load: f64) -> SimResult {
        self.run_seeded(offered_load, self.cfg.seed)
    }

    /// Run with an explicit RNG seed (multi-seed averaging reuses the
    /// simulator's routing tables across runs).
    pub fn run_seeded(&self, offered_load: f64, seed: u64) -> SimResult {
        let cfg = &self.cfg;
        let mut st = self.make_state(
            seed ^ (offered_load.to_bits().rotate_left(17)),
            cfg.warmup_cycles,
            cfg.warmup_cycles + cfg.measure_cycles,
        );
        let traffic = Traffic::build(self.pattern, &self.g, &mut st.rng);
        let inject_prob = offered_load / cfg.packet_size as f64;
        // Injection stops when the measurement window closes; the drain
        // cycles only let in-flight packets finish so their latencies are
        // recorded (see `apply_events`).
        let inject_until = cfg.warmup_cycles + cfg.measure_cycles;
        let total = inject_until + cfg.drain_cycles;

        let mut scratch = vec![0i64; self.dim];
        // Per-cycle arbitration scratch: one winner slot per output port
        // (+1 for ejection), with reservoir counts for random choice.
        let mut winners: Vec<CandSlot> = vec![CandSlot::NONE; self.ports + 1];

        for now in 0..total {
            st.now = now;
            self.apply_events(&mut st);
            if now < inject_until {
                self.inject(&mut st, &traffic, inject_prob, &mut scratch);
            }
            self.advance(&mut st, &mut winners);
        }

        // Per-axis link utilization: fraction of link-cycles carrying phits
        // (2N unidirectional links per axis).
        let denom = 2.0 * self.nodes as f64 * cfg.measure_cycles as f64;
        let link_utilization: Vec<f64> = (0..self.dim)
            .map(|a| st.phits_by_axis[a] as f64 / denom)
            .collect();
        SimResult {
            offered_load,
            link_utilization,
            accepted_load: st.delivered_phits as f64
                / (cfg.measure_cycles as f64 * self.nodes as f64),
            avg_latency: st.latency.mean(),
            p99_latency: st.latency.percentile(0.99),
            max_latency: st.latency.max(),
            delivered_packets: st.delivered_packets,
            measured_packets: st.latency.count(),
            source_dropped: st.source_dropped,
            injected_packets: st.injected_packets,
            cycles: cfg.measure_cycles,
            nodes: self.nodes,
        }
    }

    /// Run a closed-loop workload to completion with the config seed and a
    /// conservative cycle cap (see [`Workload::suggested_max_cycles_for`]).
    pub fn run_workload(&self, wl: &Workload) -> WorkloadOutcome {
        self.run_workload_seeded(wl, self.cfg.seed, wl.suggested_max_cycles_for(&self.cfg))
    }

    /// Closed-loop mode: inject the workload's messages as their
    /// dependencies complete, run until every message has been delivered
    /// (or `max_cycles` elapses), and report the completion time.
    ///
    /// Each message is packetized into `ceil(size_phits / packet_size)`
    /// packets. A message becomes *eligible* `send_overhead` cycles after
    /// all of its `deps` have completed; eligible messages wait in a
    /// per-source FIFO and the source NIC serializes one train at a time —
    /// successive packets enter the injection queue as capacity frees up,
    /// at least `packet_gap` cycles apart (the gap paces the NIC, so it
    /// also spaces the first packet of one train from the last packet of
    /// the previous train on the same node). A message *completes*
    /// (releasing its dependents) `recv_overhead` cycles after its **last**
    /// packet fully drains at the destination. Latency is measured per
    /// message, from first-packet injection-queue entry to completion.
    ///
    /// With `send_overhead = recv_overhead = packet_gap = 0` and every
    /// `size_phits <= packet_size`, the dynamics (and the RNG stream) are
    /// exactly the single-packet-per-message model.
    ///
    /// # Panics
    ///
    /// Panics with a diagnosable message if `wl` fails
    /// [`Workload::validate`] — a malformed dependency DAG is a modelling
    /// bug, never a slow network.
    pub fn run_workload_seeded(&self, wl: &Workload, seed: u64, max_cycles: u64) -> WorkloadOutcome {
        assert_eq!(
            wl.nodes, self.nodes,
            "workload was generated for order {} but the topology has {} nodes",
            wl.nodes, self.nodes
        );
        if let Err(e) = wl.validate() {
            panic!("malformed workload {:?}: {e}", wl.name);
        }
        let cfg = &self.cfg;
        let ps = cfg.packet_size as u64;
        let (o_send, o_recv, gap) = (cfg.send_overhead, cfg.recv_overhead, cfg.packet_gap);
        let icap = cfg.injection_queue_packets as usize;
        let total = wl.messages.len();
        // Measure everything: the whole run is the workload.
        let mut st = self.make_state(seed, 0, u64::MAX);

        // Dependency bookkeeping: dependents in CSR form plus per-message
        // outstanding-dependency counts.
        let mut remaining = vec![0u32; total];
        let mut dep_off = vec![0u32; total + 1];
        for m in &wl.messages {
            for &d in &m.deps {
                dep_off[d as usize + 1] += 1;
            }
        }
        for i in 0..total {
            dep_off[i + 1] += dep_off[i];
        }
        let mut dependents = vec![0u32; dep_off[total] as usize];
        let mut fill = dep_off.clone();
        for (i, m) in wl.messages.iter().enumerate() {
            remaining[i] = m.deps.len() as u32;
            for &d in &m.deps {
                dependents[fill[d as usize] as usize] = i as u32;
                fill[d as usize] += 1;
            }
        }

        // Per-message packetization state: packets still to drain, and the
        // cycle the first packet entered the injection queue (latency base).
        let mut pkts_left: Vec<u32> =
            wl.messages.iter().map(|m| m.packets(cfg.packet_size)).collect();
        let mut first_inject = vec![0u64; total];

        // Per-node NIC send queues: dependency-satisfied messages with
        // their earliest first-packet cycle (completion of deps + o_send).
        // Entries are pushed in nondecreasing ready order, so head-of-line
        // blocking on the ready time is exact, and the NIC serializes one
        // message train at a time.
        let mut sendq: Vec<VecDeque<(u32, u64)>> = vec![VecDeque::new(); self.nodes];
        for (i, m) in wl.messages.iter().enumerate() {
            if m.deps.is_empty() {
                sendq[m.src as usize].push_back((i as u32, o_send));
            }
        }
        // Head-of-line train progress per node: packets already enqueued,
        // and the earliest cycle the next packet may enter (the LogGP gap).
        let mut head_sent = vec![0u32; self.nodes];
        let mut head_next = vec![0u64; self.nodes];

        // Messages whose last packet drained, waiting out o_recv. Deliver
        // events fire in nondecreasing cycle order and o_recv is constant,
        // so a FIFO stays time-sorted.
        let mut pending_done: VecDeque<(u64, u32)> = VecDeque::new();

        // Completion bookkeeping shared by the o_recv == 0 fast path and
        // the deferred path: record the message, release its dependents.
        #[allow(clippy::too_many_arguments)]
        fn finish_message(
            mid: usize,
            t: u64,
            wl: &Workload,
            o_send: u64,
            dep_off: &[u32],
            dependents: &[u32],
            remaining: &mut [u32],
            sendq: &mut [VecDeque<(u32, u64)>],
            first_inject: &[u64],
            st: &mut State,
            delivered_msgs: &mut usize,
            completion: &mut u64,
        ) {
            st.latency.record(t - first_inject[mid]);
            st.delivered_phits += wl.messages[mid].size_phits as u64;
            *delivered_msgs += 1;
            *completion = t;
            for k in dep_off[mid]..dep_off[mid + 1] {
                let dep = dependents[k as usize] as usize;
                remaining[dep] -= 1;
                if remaining[dep] == 0 {
                    sendq[wl.messages[dep].src as usize].push_back((dep as u32, t + o_send));
                }
            }
        }

        // Message id per live packet (parallel to the packet arena).
        let mut msg_of: Vec<u32> = Vec::new();
        let mut delivered_msgs = 0usize;
        let mut completion = 0u64;
        let mut drained = total == 0;
        let mut scratch = vec![0i64; self.dim];
        let mut winners: Vec<CandSlot> = vec![CandSlot::NONE; self.ports + 1];

        for now in 0..max_cycles {
            st.now = now;
            // Deferred events, with closed-loop delivery bookkeeping: the
            // last packet of a message completes it (possibly after the
            // receive overhead), which may make dependents eligible.
            let slot = (now % (ps + 2)) as usize;
            let events = std::mem::take(&mut st.calendar[slot]);
            for ev in events {
                match ev {
                    Event::FreeInput(fifo) => st.inputs[fifo as usize].release(),
                    Event::FreeInj(node) => st.inj[node as usize].release(),
                    Event::Deliver(pid) => {
                        st.delivered_packets += 1;
                        let mid = msg_of[pid as usize] as usize;
                        pkts_left[mid] -= 1;
                        if pkts_left[mid] == 0 {
                            if o_recv == 0 {
                                finish_message(
                                    mid, now, wl, o_send, &dep_off, &dependents,
                                    &mut remaining, &mut sendq, &first_inject, &mut st,
                                    &mut delivered_msgs, &mut completion,
                                );
                            } else {
                                pending_done.push_back((now + o_recv, mid as u32));
                            }
                        }
                        st.free_pids.push(pid);
                    }
                }
            }
            // Receive-overhead completions due this cycle.
            while let Some(&(t, mid)) = pending_done.front() {
                if t > now {
                    break;
                }
                pending_done.pop_front();
                finish_message(
                    mid as usize, t, wl, o_send, &dep_off, &dependents,
                    &mut remaining, &mut sendq, &first_inject, &mut st,
                    &mut delivered_msgs, &mut completion,
                );
            }
            if delivered_msgs == total {
                drained = true;
                break;
            }
            // Closed-loop injection: each NIC packetizes its head-of-line
            // eligible message into the injection queue while capacity
            // lasts, honoring the first-packet ready time and the
            // inter-packet gap.
            for u in 0..self.nodes {
                while (st.inj[u].reserved as usize) < icap {
                    let Some(&(mid, eligible)) = sendq[u].front() else { break };
                    // The LogGP gap paces every packet the NIC emits, so
                    // the first packet of a new train also waits out the
                    // gap from the previous train's last packet.
                    let ready =
                        if head_sent[u] == 0 { eligible.max(head_next[u]) } else { head_next[u] };
                    if ready > now {
                        break;
                    }
                    let midx = mid as usize;
                    let m = &wl.messages[midx];
                    let pid = self.new_packet(&mut st, u, m.dst as usize, &mut scratch);
                    if msg_of.len() < st.packets.len() {
                        msg_of.resize(st.packets.len(), 0);
                    }
                    msg_of[pid as usize] = mid;
                    st.injected_packets += 1;
                    if head_sent[u] == 0 {
                        first_inject[midx] = now;
                    }
                    head_sent[u] += 1;
                    head_next[u] = now + gap;
                    if head_sent[u] == m.packets(self.cfg.packet_size) {
                        sendq[u].pop_front();
                        head_sent[u] = 0;
                    }
                }
            }
            self.advance(&mut st, &mut winners);
        }

        WorkloadOutcome {
            completion_cycles: if drained { completion } else { max_cycles },
            drained,
            delivered_messages: delivered_msgs as u64,
            total_messages: total as u64,
            delivered_phits: st.delivered_phits,
            delivered_packets: st.delivered_packets,
            avg_latency: st.latency.mean(),
            p99_latency: st.latency.percentile(0.99),
            max_latency: st.latency.max(),
            nodes: self.nodes,
        }
    }

    #[inline]
    fn apply_events(&self, st: &mut State) {
        let ps = self.cfg.packet_size as u64;
        let slot = (st.now % (ps + 2)) as usize;
        let events = std::mem::take(&mut st.calendar[slot]);
        for ev in events {
            match ev {
                Event::FreeInput(fifo) => st.inputs[fifo as usize].release(),
                Event::FreeInj(node) => st.inj[node as usize].release(),
                Event::Deliver(pid) => {
                    let p = st.packets[pid as usize];
                    let lat = st.now - p.inject_time;
                    // Throughput counts deliveries inside the window;
                    // latency follows the *injection* time, so stragglers
                    // delivered during the drain still contribute their
                    // (long) latencies instead of silently vanishing.
                    if st.now >= st.measure_start && st.now < st.measure_end {
                        st.delivered_phits += ps;
                        st.delivered_packets += 1;
                    }
                    if p.inject_time >= st.measure_start && p.inject_time < st.measure_end {
                        st.latency.record(lat);
                    }
                    st.free_pids.push(pid);
                }
            }
        }
    }

    #[inline]
    fn schedule(&self, st: &mut State, delay: u64, ev: Event) {
        let ps = self.cfg.packet_size as u64;
        let slot = ((st.now + delay) % (ps + 2)) as usize;
        st.calendar[slot].push(ev);
    }

    fn inject(&self, st: &mut State, traffic: &Traffic, prob: f64, scratch: &mut [i64]) {
        if prob <= 0.0 {
            return;
        }
        let cap = self.cfg.injection_queue_packets;
        for u in 0..self.nodes {
            if !st.rng.chance(prob) {
                continue;
            }
            let Some(dest) = traffic.destination_of(u, &mut st.rng) else {
                continue;
            };
            if st.inj[u].reserved as u32 >= cap {
                st.source_dropped += 1;
                continue;
            }
            self.new_packet(st, u, dest, scratch);
            st.injected_packets += 1;
        }
    }

    /// Route, allocate and source-enqueue one packet from `u` to `dest`
    /// (shared by the open-loop Bernoulli injector and the closed-loop
    /// workload driver). The caller must ensure the source queue has room.
    fn new_packet(&self, st: &mut State, u: usize, dest: usize, scratch: &mut [i64]) -> u32 {
        // Difference label -> routing tie set -> random minimal record.
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = self.labels[dest * self.dim + i] - self.labels[u * self.dim + i];
        }
        self.g.reduce_in_place(scratch);
        let diff_idx = self.g.index_of(scratch);
        let ties = self.routes.ties(diff_idx);
        let record = ties[st.rng.below(ties.len())];
        let vc = st.rng.below(self.cfg.vc_count) as u8;
        let next_port = port_of_record(&record, self.dim, self.ports);
        let pid = self.alloc_packet(
            st,
            Packet {
                record,
                vc,
                last_axis: NO_AXIS,
                inject_time: st.now,
                head_ready: st.now,
                next_port,
            },
            dest as u32,
        );
        let icap = self.cfg.injection_queue_packets as usize;
        let base = u * icap;
        st.inj[u].push(&mut st.inj_slots[base..base + icap], pid, st.now, next_port);
        pid
    }

    #[inline]
    fn alloc_packet(&self, st: &mut State, p: Packet, dest: u32) -> u32 {
        if let Some(pid) = st.free_pids.pop() {
            st.packets[pid as usize] = p;
            st.dests[pid as usize] = dest;
            pid
        } else {
            st.packets.push(p);
            st.dests.push(dest);
            (st.packets.len() - 1) as u32
        }
    }

    /// Arbitration + transfers for every node.
    fn advance(&self, st: &mut State, winners: &mut [CandSlot]) {
        let vc_count = self.cfg.vc_count;
        let cap = self.cfg.queue_packets;
        let icap = self.cfg.injection_queue_packets as usize;
        // In-transit traffic outranks injection only when configured
        // (Table 3 / BG/Q behaviour); otherwise both compete in one class.
        let transit_class = self.cfg.transit_priority;
        let node_base = self.ports * vc_count;
        for u in 0..self.nodes {
            let mut mask = st.occ[u];
            let inj_head = st.inj[u].front(&st.inj_slots[u * icap..(u + 1) * icap]);
            if mask == 0 && inj_head.is_none() {
                continue; // idle node: nothing can move
            }
            for w in winners.iter_mut() {
                *w = CandSlot::NONE;
            }
            // Transit candidates: heads of the non-empty input FIFOs only.
            // Everything needed (ready time, output port, VC, bubble
            // "entering" test) is derivable from the FIFO entry itself.
            while mask != 0 {
                let bit = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let fifo_idx = u * node_base + bit;
                let fifo = &st.inputs[fifo_idx];
                if fifo.head_ready > st.now {
                    continue;
                }
                let port = fifo.head_port as usize;
                let vc = bit % vc_count;
                let entering = port < self.ports && (bit / vc_count) / 2 != port / 2;
                if !self.eligible(st, u, port, entering, vc, cap) {
                    continue;
                }
                winners[port].offer(transit_class, Cand { fifo: fifo_idx as u32, is_inj: false }, &mut st.rng);
            }
            // Injection candidate (always "entering" for the bubble rule).
            if let Some(pid) = inj_head {
                let fifo = &st.inj[u];
                if fifo.head_ready <= st.now {
                    let port = fifo.head_port as usize;
                    let vc = st.packets[pid as usize].vc as usize;
                    if self.eligible(st, u, port, true, vc, cap) {
                        winners[port].offer(false, Cand { fifo: u as u32, is_inj: true }, &mut st.rng);
                    }
                }
            }
            // Fire winners.
            for port in 0..winners.len() {
                let Some(cand) = winners[port].get() else { continue };
                self.start_transfer(st, u, port, cand);
            }
        }
    }

    /// Can the head packet move through output `port` of node `u` now?
    /// `entering` = the hop starts a new dimensional ring (bubble rule).
    #[inline]
    fn eligible(&self, st: &State, u: usize, port: usize, entering: bool, vc: usize, cap: u32) -> bool {
        if port == self.ports {
            // Ejection.
            return st.eject_busy[u] <= st.now;
        }
        if st.link_busy[u * self.ports + port] > st.now {
            return false;
        }
        let need = if self.cfg.bubble && entering { 2 } else { 1 };
        let v = self.neighbor[u * self.ports + port] as usize;
        let fifo = &st.inputs[(v * self.ports + port) * self.cfg.vc_count + vc];
        (fifo.reserved as u32) + need <= cap
    }

    /// Commit a transfer of the head packet of `cand` through `port`.
    fn start_transfer(&self, st: &mut State, u: usize, port: usize, cand: Cand) {
        let ps = self.cfg.packet_size as u64;
        let vc_count = self.cfg.vc_count;
        let node_base = self.ports * vc_count;
        let qcap = self.cfg.queue_packets as usize;
        let icap = self.cfg.injection_queue_packets as usize;
        let pid = if cand.is_inj {
            let base = u * icap;
            let slots = &st.inj_slots[base..base + icap];
            let pid = st.inj[u].pop(slots);
            st.inj[u].refresh_head(slots, &st.packets);
            self.schedule(st, ps, Event::FreeInj(u as u32));
            pid
        } else {
            let fi = cand.fifo as usize;
            let base = fi * qcap;
            let slots = &st.input_slots[base..base + qcap];
            let pid = st.inputs[fi].pop(slots);
            st.inputs[fi].refresh_head(slots, &st.packets);
            if st.inputs[fi].len == 0 {
                st.occ[u] &= !(1u64 << (fi - u * node_base));
            }
            self.schedule(st, ps, Event::FreeInput(cand.fifo));
            pid
        };
        if port == self.ports {
            // Ejection: tail fully received at now + ps.
            debug_assert_eq!(st.dests[pid as usize] as usize, u, "eject at wrong node");
            st.eject_busy[u] = st.now + ps;
            self.schedule(st, ps, Event::Deliver(pid));
            return;
        }
        let axis = port / 2;
        let sign: i16 = if port % 2 == 0 { 1 } else { -1 };
        let v = self.neighbor[u * self.ports + port] as usize;
        st.link_busy[u * self.ports + port] = st.now + ps;
        if st.now >= st.measure_start && st.now < st.measure_end {
            st.phits_by_axis[axis] += ps;
        }
        let (vc, next_port) = {
            let pkt = &mut st.packets[pid as usize];
            pkt.record[axis] -= sign;
            pkt.last_axis = axis as u8;
            pkt.head_ready = st.now + 1;
            pkt.next_port = port_of_record(&pkt.record, self.dim, self.ports);
            (pkt.vc as usize, pkt.next_port)
        };
        let local = port * vc_count + vc;
        let fi = v * node_base + local;
        let base = fi * qcap;
        st.inputs[fi].push(&mut st.input_slots[base..base + qcap], pid, st.now + 1, next_port);
        st.occ[v] |= 1u64 << local;
    }
}

/// A transfer candidate (which FIFO holds it).
#[derive(Clone, Copy, Debug)]
struct Cand {
    fifo: u32,
    is_inj: bool,
}

/// Reservoir-sampling winner slot per output port: random arbitration with
/// strict transit-over-injection priority (when the priority class is
/// asserted by the caller).
#[derive(Clone, Copy, Debug)]
struct CandSlot {
    cand: Option<Cand>,
    transit: bool,
    count: u32,
}

impl CandSlot {
    const NONE: CandSlot = CandSlot { cand: None, transit: false, count: 0 };

    #[inline]
    fn offer(&mut self, is_transit: bool, cand: Cand, rng: &mut Rng) {
        if is_transit && !self.transit {
            // Transit preempts any injection candidate.
            *self = CandSlot { cand: Some(cand), transit: true, count: 1 };
            return;
        }
        if is_transit == self.transit {
            self.count += 1;
            if self.count == 1 || rng.below(self.count as usize) == 0 {
                self.cand = Some(cand);
            }
        }
        // injection offered while transit held: ignored.
    }

    #[inline]
    fn get(&self) -> Option<Cand> {
        self.cand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{fcc, torus};
    use crate::workload::{Workload, WorkloadMessage};

    fn quick_cfg() -> SimConfig {
        SimConfig {
            warmup_cycles: 200,
            measure_cycles: 1000,
            drain_cycles: 0,
            ..SimConfig::default()
        }
    }

    #[test]
    fn zero_load_zero_traffic() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.0);
        assert_eq!(r.delivered_packets, 0);
        assert_eq!(r.accepted_load, 0.0);
    }

    #[test]
    fn low_load_accepted_equals_offered() {
        let sim = Simulator::new(torus(&[4, 4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.1);
        assert!(r.delivered_packets > 0);
        // At 10% load a torus is far from saturation: accepted ~ offered.
        assert!(
            (r.accepted_load - 0.1).abs() < 0.03,
            "accepted {} vs offered 0.1",
            r.accepted_load
        );
        assert_eq!(r.source_dropped, 0, "no drops far below saturation");
    }

    #[test]
    fn latency_bounded_below_by_distance() {
        // At very low load latency ~ hops + packet_size.
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.02);
        let ps = sim.config().packet_size as f64;
        assert!(r.avg_latency >= ps, "latency {} < packet size", r.avg_latency);
        assert!(
            r.avg_latency < ps + 30.0,
            "uncongested latency too high: {}",
            r.avg_latency
        );
    }

    #[test]
    fn saturation_accepts_less_than_offered() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(1.0);
        assert!(r.accepted_load < 0.99);
        assert!(r.source_dropped > 0);
        // but still substantial:
        assert!(r.accepted_load > 0.2, "throughput collapsed: {}", r.accepted_load);
    }

    #[test]
    fn no_deadlock_at_high_load_twisted() {
        // Twisted topology + full load; bubble must keep packets moving.
        let sim = Simulator::new(fcc(2), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(1.0);
        assert!(r.delivered_packets > 100, "only {} delivered", r.delivered_packets);
    }

    #[test]
    fn deterministic_given_seed() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let a = sim.run(0.3);
        let b = sim.run(0.3);
        assert_eq!(a.delivered_packets, b.delivered_packets);
        assert_eq!(a.avg_latency, b.avg_latency);
    }

    #[test]
    fn all_patterns_deliver() {
        for pattern in TrafficPattern::ALL {
            let sim = Simulator::new(torus(&[4, 4]), pattern, quick_cfg());
            let r = sim.run(0.2);
            assert!(r.delivered_packets > 0, "{:?}", pattern);
        }
    }

    #[test]
    fn throughput_monotone_then_saturates() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let lo = sim.run(0.1).accepted_load;
        let mid = sim.run(0.3).accepted_load;
        assert!(mid > lo);
    }

    #[test]
    fn deep_queues_beyond_legacy_cap() {
        // Queue capacities now come from SimConfig (the engine used to
        // hard-cap FIFO slots at 8 packets and assert on deeper configs).
        let cfg = SimConfig {
            queue_packets: 16,
            injection_queue_packets: 12,
            ..quick_cfg()
        };
        let deep = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, cfg).run(1.0);
        assert!(deep.delivered_packets > 0);
        assert!(deep.accepted_load > 0.2, "throughput collapsed: {}", deep.accepted_load);
    }

    #[test]
    fn drain_records_straggler_latencies() {
        // Identical dynamics inside the window; the drain additionally
        // records packets injected in the window but delivered after it.
        let g = torus(&[4, 4]);
        let no_drain =
            Simulator::new(g.clone(), TrafficPattern::Uniform, quick_cfg()).run(1.0);
        let cfg = SimConfig { drain_cycles: 800, ..quick_cfg() };
        let drain = Simulator::new(g, TrafficPattern::Uniform, cfg).run(1.0);
        assert_eq!(drain.delivered_packets, no_drain.delivered_packets);
        assert!(
            drain.measured_packets > no_drain.measured_packets,
            "drain {} vs {}",
            drain.measured_packets,
            no_drain.measured_packets
        );
        assert!(drain.max_latency >= no_drain.max_latency);
    }

    #[test]
    fn workload_single_message_delivers() {
        let g = torus(&[4, 4]);
        let wl = Workload {
            name: "one".into(),
            nodes: g.order(),
            messages: vec![WorkloadMessage::new(0, 5, 0, vec![])],
        };
        let sim = Simulator::for_workload(g, quick_cfg());
        let out = sim.run_workload(&wl);
        assert!(out.drained);
        assert_eq!(out.delivered_messages, 1);
        assert_eq!(out.delivered_packets, 1);
        // Node 5 of T(4,4) is 2 hops from node 0: head flight + tail
        // serialization exactly.
        let ps = sim.config().packet_size as u64;
        assert_eq!(out.completion_cycles, 2 + ps);
    }

    #[test]
    fn workload_multi_packet_train_serializes() {
        // A 4-packet message on a unique minimal path: the source link
        // serializes the train, so completion is hops + 4·ps exactly.
        let g = torus(&[4, 4]);
        let ps = quick_cfg().packet_size;
        let wl = Workload {
            name: "train".into(),
            nodes: g.order(),
            messages: vec![WorkloadMessage {
                size_phits: 4 * ps,
                ..WorkloadMessage::new(0, 1, 0, vec![])
            }],
        };
        let sim = Simulator::for_workload(g, quick_cfg());
        let out = sim.run_workload(&wl);
        assert!(out.drained);
        assert_eq!(out.delivered_messages, 1);
        assert_eq!(out.delivered_packets, 4);
        assert_eq!(out.delivered_phits, 4 * ps as u64);
        assert_eq!(out.completion_cycles, 1 + 4 * ps as u64);
    }

    #[test]
    fn workload_chain_slower_than_independent_pair() {
        let g = torus(&[4, 4]);
        let pair = Workload {
            name: "pair".into(),
            nodes: g.order(),
            messages: vec![
                WorkloadMessage::new(0, 2, 0, vec![]),
                WorkloadMessage::new(1, 3, 0, vec![]),
            ],
        };
        let chain = Workload {
            name: "chain".into(),
            nodes: g.order(),
            messages: vec![
                WorkloadMessage::new(0, 2, 0, vec![]),
                WorkloadMessage::new(2, 0, 1, vec![0]),
            ],
        };
        let sim = Simulator::for_workload(g, quick_cfg());
        let a = sim.run_workload(&pair);
        let b = sim.run_workload(&chain);
        assert!(a.drained && b.drained);
        let ps = sim.config().packet_size as u64;
        assert!(
            b.completion_cycles >= a.completion_cycles + ps,
            "chain {} vs pair {}",
            b.completion_cycles,
            a.completion_cycles
        );
    }

    #[test]
    fn workload_deterministic_and_capped() {
        let g = fcc(2);
        let n = g.order();
        let messages: Vec<WorkloadMessage> = (0..n as u32)
            .map(|u| WorkloadMessage::new(u, (u + 3) % n as u32, 0, vec![]))
            .collect();
        let wl = Workload { name: "shift".into(), nodes: n, messages };
        let sim = Simulator::for_workload(g, quick_cfg());
        let a = sim.run_workload_seeded(&wl, 7, 100_000);
        let b = sim.run_workload_seeded(&wl, 7, 100_000);
        assert_eq!(a.completion_cycles, b.completion_cycles);
        assert_eq!(a.avg_latency, b.avg_latency);
        // An absurdly small cap reports an undrained run, not a hang.
        let capped = sim.run_workload_seeded(&wl, 7, 4);
        assert!(!capped.drained);
        assert_eq!(capped.completion_cycles, 4);
        assert!(capped.delivered_messages < wl.messages.len() as u64);
    }

    #[test]
    #[should_panic(expected = "malformed workload")]
    fn workload_bad_dep_panics_diagnosably() {
        // A dep index past the end must fail validation with a message,
        // not an opaque index-out-of-bounds deep in the cycle loop.
        let g = torus(&[4, 4]);
        let wl = Workload {
            name: "bad-dag".into(),
            nodes: g.order(),
            messages: vec![WorkloadMessage::new(0, 1, 0, vec![99])],
        };
        let sim = Simulator::for_workload(g, quick_cfg());
        sim.run_workload(&wl);
    }

    #[test]
    #[should_panic(expected = "malformed workload")]
    fn workload_bad_endpoint_panics_diagnosably() {
        // Same guarantee for an out-of-range endpoint: the pre-validation
        // cycle-cap computation must not index-panic on it.
        let g = torus(&[4, 4]);
        let wl = Workload {
            name: "bad-endpoint".into(),
            nodes: g.order(),
            messages: vec![WorkloadMessage::new(0, 99, 0, vec![])],
        };
        let sim = Simulator::for_workload(g, quick_cfg());
        sim.run_workload(&wl);
    }
}
