//! Algorithm 3: closed-form routing in the rectangular twisted torus
//! `RTT(a) = G([[2a, a], [0, a]])` (from [10]).

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;
use crate::topology::rtt as rtt_graph;

use super::{norm, Record, Router};

/// Closed-form minimal router for `RTT(a)`.
pub struct RttRouter {
    g: LatticeGraph,
    a: i64,
}

impl RttRouter {
    pub fn new(a: i64) -> Self {
        Self { g: rtt_graph(a), a }
    }

    /// Algorithm 3 on a difference vector `(x, y) = v_d - v_s`.
    pub fn route_diff(a: i64, x: i64, y: i64) -> (i64, i64) {
        let p = rem_euclid(x + y + a, 2 * a);
        let q = rem_euclid(y - x + a, 2 * a);
        let x1 = (p - q) / 2;
        let y1 = (p + q - 2 * a) / 2;
        (x1, y1)
    }

    /// Algorithm 3 can return a non-strictly-minimal record on boundary
    /// ties; the minimal set is recovered by also considering the three
    /// sibling candidates shifted by the lattice generators (columns
    /// `(2a, 0)` and `(a, a)`). This keeps the router exactly minimal for
    /// every pair (validated against the BFS oracle in tests).
    pub fn route_diff_min(a: i64, x: i64, y: i64) -> (i64, i64) {
        let (x1, y1) = Self::route_diff(a, x, y);
        let mut best = (x1, y1);
        let mut best_n = x1.abs() + y1.abs();
        for (dx, dy) in [
            (2 * a, 0),
            (-2 * a, 0),
            (a, a),
            (-a, -a),
            (a, -a),
            (-a, a),
        ] {
            let (cx, cy) = (x1 + dx, y1 + dy);
            let n = cx.abs() + cy.abs();
            if n < best_n {
                best = (cx, cy);
                best_n = n;
            }
        }
        best
    }

    pub fn a(&self) -> i64 {
        self.a
    }
}

impl Router for RttRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let (x, y) = (dst[0] - src[0], dst[1] - src[1]);
        let (rx, ry) = Self::route_diff_min(self.a, x, y);
        vec![rx, ry]
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        let (x, y) = (dst[0] - src[0], dst[1] - src[1]);
        let (x1, y1) = Self::route_diff_min(self.a, x, y);
        let best = x1.abs() + y1.abs();
        let mut out = vec![vec![x1, y1]];
        let a = self.a;
        for (dx, dy) in [
            (2 * a, 0),
            (-2 * a, 0),
            (a, a),
            (-a, -a),
            (a, -a),
            (-a, a),
            (3 * a, a),
            (-3 * a, -a),
        ] {
            let cand = vec![x1 + dx, y1 + dy];
            if norm(&cand) == best && !out.contains(&cand) {
                out.push(cand);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::{is_valid_record, oracle::bfs_distance};

    #[test]
    fn example32_subroutes() {
        // From Example 32 (a = 4): min route (0,0)->(5,1) is norm 4
        // ((1,-3) in the paper text has norm 4);
        // min route (4,0)->(5,1) is (1,1), norm 2.
        let (x, y) = RttRouter::route_diff_min(4, 5 - 0, 1 - 0);
        assert_eq!(x.abs() + y.abs(), 4);
        let (x, y) = RttRouter::route_diff_min(4, 5 - 4, 1 - 0);
        assert_eq!((x, y), (1, 1));
    }

    #[test]
    fn all_pairs_minimal_vs_oracle() {
        for a in 1..7i64 {
            let router = RttRouter::new(a);
            let g = router.graph().clone();
            let dist = crate::metrics::bfs_distances(&g, 0);
            let src = vec![0i64, 0];
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r), "a={a} dst={dst:?}");
                assert_eq!(
                    norm(&r),
                    dist[v] as i64,
                    "a={a} dst={dst:?} got {r:?}"
                );
            }
        }
    }

    #[test]
    fn all_sources_not_just_zero() {
        // Records depend only on the difference, but exercise the API.
        let a = 4;
        let router = RttRouter::new(a);
        let g = router.graph().clone();
        for s in [[1i64, 2], [7, 3], [5, 0]] {
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&s, &dst);
                assert!(is_valid_record(&g, &s, &dst, &r));
                assert_eq!(norm(&r), bfs_distance(&g, &s, &dst));
            }
        }
    }

    #[test]
    fn ties_valid() {
        let a = 4;
        let router = RttRouter::new(a);
        let g = router.graph().clone();
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let best = bfs_distance(&g, &[0, 0], &dst);
            for r in router.route_ties(&[0, 0], &dst) {
                assert!(is_valid_record(&g, &[0, 0], &dst, &r));
                assert_eq!(norm(&r), best);
            }
        }
    }

    #[test]
    fn rtt_diameter_is_a() {
        // [7]: the RTT(a) diameter equals a.
        for a in 2..8i64 {
            let g = RttRouter::new(a).graph().clone();
            let s = crate::metrics::distance_distribution(&g);
            assert_eq!(s.diameter as i64, a);
        }
    }
}
