//! Small shared utilities. Currently: the scoped thread-pool primitives
//! behind both `workload::par_map` (multi-seed fan-out) and the parallel
//! cycle engine (`sim/engine/parallel.rs`).

pub mod pool;

pub use pool::{par_map, with_helpers, SpinBarrier};
