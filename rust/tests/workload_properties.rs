//! Workload subsystem integration: generator structure (deterministic,
//! acyclic, counted), closed-loop execution on the cycle engine, and the
//! paper's qualitative claim that near-neighbor traffic completes far
//! faster than global traffic at equal message volume on a torus.

use lattice_networks::sim::{SimConfig, Simulator};
use lattice_networks::topology;
use lattice_networks::workload::{
    generate, WorkloadKind, WorkloadParams, WorkloadRunner,
};

fn cfg() -> SimConfig {
    SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() }
}

#[test]
fn generators_are_deterministic_counted_and_acyclic() {
    let g = topology::torus(&[4, 4, 4]); // n = 64, dim 3
    let p = WorkloadParams { iters: 5, ..Default::default() };
    for kind in WorkloadKind::ALL {
        let a = generate(kind, &g, &p);
        let b = generate(kind, &g, &p);
        assert_eq!(a, b, "{} must be deterministic for a fixed seed", a.name);
        assert!(a.validate().is_ok(), "{}: {:?}", a.name, a.validate());
        assert!(a.is_acyclic(), "{}", a.name);
        assert!(!a.is_empty(), "{}", a.name);
    }
    // Exact counts on n = 64, degree 6:
    assert_eq!(generate(WorkloadKind::Stencil, &g, &p).len(), 5 * 64 * 6);
    assert_eq!(generate(WorkloadKind::AllToAll, &g, &p).len(), 64 * 63);
    assert_eq!(generate(WorkloadKind::RingAllReduce, &g, &p).len(), 2 * 63 * 64);
    assert_eq!(generate(WorkloadKind::RecursiveDoubling, &g, &p).len(), 64 * 6);
    assert_eq!(generate(WorkloadKind::Permutation, &g, &p).len(), 5 * 64);
    assert_eq!(generate(WorkloadKind::Hotspot, &g, &p).len(), 5 * 63);
}

#[test]
fn every_workload_drains_on_crystals_and_tori() {
    let p = WorkloadParams { iters: 2, ..Default::default() };
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    for (name, g) in [
        ("FCC(2)", topology::fcc(2)),
        ("BCC(2)", topology::bcc(2)),
        ("T(4,4)", topology::torus(&[4, 4])),
    ] {
        for kind in WorkloadKind::ALL {
            let wl = generate(kind, &g, &p);
            let point = runner.run(name, &g, &wl);
            assert!(point.drained, "{name}/{}: undrained", wl.name);
            assert!(point.completion_cycles > 0.0);
            assert!(point.effective_bandwidth > 0.0);
        }
    }
}

#[test]
fn halo_exchange_beats_alltoall_at_equal_volume_on_torus() {
    // The paper's qualitative near-neighbor vs global ordering, measured
    // at the application level: on a 3D torus, ~10 rounds of halo
    // exchange (3840 messages) complete far faster than one personalized
    // all-to-all (4032 messages) of the same total volume.
    let g = topology::torus(&[4, 4, 4]);
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    let halo = generate(
        WorkloadKind::Stencil,
        &g,
        &WorkloadParams { iters: 10, ..Default::default() },
    );
    let a2a = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    // Equal volume within ~5%.
    let ratio = halo.len() as f64 / a2a.len() as f64;
    assert!((0.9..=1.1).contains(&ratio), "volume ratio {ratio}");
    let halo_pt = runner.run("T(4,4,4)", &g, &halo);
    let a2a_pt = runner.run("T(4,4,4)", &g, &a2a);
    assert!(halo_pt.drained && a2a_pt.drained);
    assert!(
        halo_pt.completion_cycles < a2a_pt.completion_cycles,
        "halo {} should beat all-to-all {}",
        halo_pt.completion_cycles,
        a2a_pt.completion_cycles
    );
}

#[test]
fn hotspot_is_ejection_bound() {
    // N-1 senders x iters messages into one ejection channel: completion
    // is at least (messages x packet_size) at the hot node.
    let g = topology::torus(&[4, 4]);
    let iters = 4;
    let wl = generate(WorkloadKind::Hotspot, &g, &WorkloadParams { iters, ..Default::default() });
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    let p = runner.run("T(4,4)", &g, &wl);
    assert!(p.drained);
    let floor = (wl.len() as u64 * 16) as f64;
    assert!(
        p.completion_cycles >= floor,
        "completion {} below the serialization floor {floor}",
        p.completion_cycles
    );
}

#[test]
fn crystal_completes_alltoall_no_slower_than_matched_torus() {
    // The tentpole claim at small scale: FCC(3) (54 nodes) vs T(6,3,3).
    let fcc = topology::fcc(3);
    let torus = topology::torus(&[6, 3, 3]);
    assert_eq!(fcc.order(), torus.order());
    let runner = WorkloadRunner { sim: cfg(), seeds: 2, ..Default::default() };
    let wl_f = generate(WorkloadKind::AllToAll, &fcc, &WorkloadParams::default());
    let wl_t = generate(WorkloadKind::AllToAll, &torus, &WorkloadParams::default());
    let pf = runner.run("FCC(3)", &fcc, &wl_f);
    let pt = runner.run("T(6,3,3)", &torus, &wl_t);
    assert!(pf.drained && pt.drained);
    assert!(
        pf.completion_cycles <= pt.completion_cycles * 1.05,
        "FCC {} vs torus {}",
        pf.completion_cycles,
        pt.completion_cycles
    );
}

#[test]
fn engine_workload_mode_matches_runner() {
    // The runner's single-seed numbers are exactly the engine's.
    let g = topology::fcc(2);
    let wl = generate(WorkloadKind::RingAllReduce, &g, &WorkloadParams::default());
    let sim = Simulator::for_workload(g.clone(), cfg());
    let direct = sim.run_workload_seeded(&wl, cfg().seed, wl.suggested_max_cycles(16));
    let runner = WorkloadRunner { sim: cfg(), seeds: 1, ..Default::default() };
    let point = runner.run_with(&sim, "FCC(2)", &wl);
    assert_eq!(point.completion_cycles, direct.completion_cycles as f64);
    assert_eq!(point.avg_latency, direct.avg_latency);
}
