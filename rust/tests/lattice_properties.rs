//! Property tests on the lattice-algebra invariants (hand-rolled
//! deterministic randomized sweeps; offline build carries no proptest —
//! see DESIGN.md §Substitutions).

use lattice_networks::lattice::{common_lift, LatticeGraph};
use lattice_networks::math::{hermite_normal_form, hnf::is_hermite, IMat};
use lattice_networks::sim::rng::Rng;

/// Deterministic random non-singular matrix with entries in [-bound, bound].
fn random_matrix(rng: &mut Rng, n: usize, bound: i64) -> IMat {
    loop {
        let data: Vec<i64> = (0..n * n)
            .map(|_| rng.below((2 * bound + 1) as usize) as i64 - bound)
            .collect();
        let m = IMat::from_flat(n, &data);
        let det = m.det().abs();
        if det != 0 && det < 4000 {
            return m;
        }
    }
}

#[test]
fn prop_hnf_canonical_and_right_equivalent() {
    let mut rng = Rng::new(0xdead);
    for _ in 0..200 {
        let n = 2 + rng.below(3); // 2..4
        let m = random_matrix(&mut rng, n, 6);
        let r = hermite_normal_form(&m);
        assert!(is_hermite(&r.h));
        assert!(r.u.is_unimodular());
        assert_eq!(m.mul(&r.u), r.h);
        assert_eq!(r.h.det().abs(), m.det().abs());
        // Canonicity: HNF of the HNF is itself.
        assert_eq!(hermite_normal_form(&r.h).h, r.h);
        // Right-multiplying by a random unimodular matrix keeps the HNF.
        let p = random_unimodular(&mut rng, n);
        let m2 = m.mul(&p);
        assert_eq!(hermite_normal_form(&m2).h, r.h);
    }
}

fn random_unimodular(rng: &mut Rng, n: usize) -> IMat {
    // Product of random elementary column ops applied to I.
    let mut u = IMat::identity(n);
    for _ in 0..8 {
        let a = rng.below(n);
        let b = rng.below(n);
        if a != b {
            u.add_col_multiple(a, b, rng.below(7) as i64 - 3);
        }
        if rng.below(4) == 0 {
            u.negate_col(a);
        }
    }
    assert!(u.is_unimodular());
    u
}

#[test]
fn prop_reduce_is_canonical_and_congruent() {
    let mut rng = Rng::new(0xbeef);
    for _ in 0..50 {
        let n = 2 + rng.below(2);
        let m = random_matrix(&mut rng, n, 5);
        let g = LatticeGraph::new(m);
        for _ in 0..30 {
            let v: Vec<i64> = (0..n).map(|_| rng.below(201) as i64 - 100).collect();
            let r = g.reduce(&v);
            // In box.
            for (x, d) in r.iter().zip(g.box_sides()) {
                assert!(0 <= *x && x < d, "{r:?} outside box {:?}", g.box_sides());
            }
            // Congruent and idempotent.
            assert!(g.congruent(&v, &r));
            assert_eq!(g.reduce(&r), r);
        }
    }
}

#[test]
fn prop_label_index_bijection() {
    let mut rng = Rng::new(0xcafe);
    for _ in 0..30 {
        let n = 2 + rng.below(2);
        let m = random_matrix(&mut rng, n, 4);
        let g = LatticeGraph::new(m);
        if g.order() > 2000 {
            continue;
        }
        let mut seen = vec![false; g.order()];
        for idx in 0..g.order() {
            let l = g.label_of(idx);
            let back = g.index_of(&l);
            assert_eq!(back, idx);
            assert!(!seen[idx]);
            seen[idx] = true;
        }
    }
}

#[test]
fn prop_neighbors_regular_degree_relation() {
    let mut rng = Rng::new(0xf00d);
    for _ in 0..20 {
        let n = 2 + rng.below(2);
        let m = random_matrix(&mut rng, n, 4);
        let g = LatticeGraph::new(m);
        if g.order() > 600 {
            continue;
        }
        for u in 0..g.order() {
            let nb = g.neighbors(u);
            assert_eq!(nb.len(), 2 * n);
            for v in nb {
                assert!(g.neighbors(v).contains(&u));
            }
        }
    }
}

#[test]
fn prop_element_order_divides_group_order() {
    let mut rng = Rng::new(0x5eed);
    for _ in 0..40 {
        let n = 2 + rng.below(2);
        let m = random_matrix(&mut rng, n, 5);
        let g = LatticeGraph::new(m);
        for i in 0..n {
            let ord = g.generator_order(i);
            assert!(ord >= 1);
            assert_eq!(
                g.order() as i64 % ord,
                0,
                "ord(e_{i}) = {ord} does not divide {}",
                g.order()
            );
            // Walking ord steps returns to start.
            let mut idx = 0usize;
            for _ in 0..ord {
                idx = g.step(idx, i, 1);
            }
            assert_eq!(idx, 0);
        }
    }
}

#[test]
fn prop_common_lift_embeds_both() {
    let mut rng = Rng::new(0xabcd);
    for _ in 0..25 {
        let m1 = random_matrix(&mut rng, 2, 4);
        let m2 = random_matrix(&mut rng, 2, 4);
        let lift = common_lift(&m1, &m2);
        let gl = LatticeGraph::new(lift.clone());
        let g1 = LatticeGraph::new(m1);
        let g2 = LatticeGraph::new(m2);
        // Orders divide by construction.
        assert_eq!(gl.order() % g1.order(), 0);
        assert_eq!(gl.order() % g2.order(), 0);
        // Dimension bounds of Theorem 24(ii).
        assert!(gl.dim() >= g1.dim().max(g2.dim()));
        assert!(gl.dim() <= g1.dim() + g2.dim());
    }
}

#[test]
fn prop_projection_partitions_graph() {
    let mut rng = Rng::new(0x1234);
    for _ in 0..20 {
        let n = 3;
        let m = random_matrix(&mut rng, n, 3);
        let g = LatticeGraph::new(m);
        if g.order() > 800 {
            continue;
        }
        let p = g.project();
        let proj = LatticeGraph::new(p.b.clone());
        // side * |projection| = |graph|
        assert_eq!(proj.order() * p.side as usize, g.order());
        // cycle invariants from Section 2
        assert_eq!(p.cycle_len % p.side, 0);
        assert_eq!(p.cycle_len * p.num_cycles, g.order() as i64);
        assert_eq!(p.intersections_per_copy, p.cycle_len / p.side);
        // the realized cycle closes with the right length
        assert_eq!(g.cycle_through(0).len() as i64, p.cycle_len);
    }
}

#[test]
fn prop_symmetric_families_symmetric() {
    use lattice_networks::lattice::symmetry::{
        is_linearly_symmetric, symmetric_family_alt, symmetric_family_circulant,
    };
    let mut rng = Rng::new(0x777);
    let mut checked = 0;
    while checked < 60 {
        let a = rng.below(9) as i64 - 4;
        let b = rng.below(9) as i64 - 4;
        let c = rng.below(9) as i64 - 4;
        for m in [symmetric_family_circulant(a, b, c), symmetric_family_alt(a, b, c)] {
            if m.det() != 0 {
                assert!(is_linearly_symmetric(&m), "family member {m:?}");
                checked += 1;
            }
        }
    }
}
