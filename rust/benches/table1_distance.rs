//! Bench: regenerate Table 1 (distance properties of cubic crystals vs
//! mixed-radix tori) and time the exact-BFS machinery behind it.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::coordinator::experiments;
use lattice_networks::metrics::distance_distribution;
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("table1");

    // The table itself (the paper artifact).
    let t = experiments::table1(&[2, 4, 8, 16]);
    print!("{}", t.render());

    // Timings for the underlying distance computations.
    for a in [8i64, 16] {
        let pc = topology::pc(a);
        let fcc = topology::fcc(a);
        let bcc = topology::bcc(a);
        b.run_throughput(&format!("bfs/PC({a})"), pc.order() as u64, "nodes", || {
            black_box(distance_distribution(&pc));
        });
        b.run_throughput(&format!("bfs/FCC({a})"), fcc.order() as u64, "nodes", || {
            black_box(distance_distribution(&fcc));
        });
        b.run_throughput(&format!("bfs/BCC({a})"), bcc.order() as u64, "nodes", || {
            black_box(distance_distribution(&bcc));
        });
    }

    // Full-table regeneration cost.
    b.run("regenerate", || {
        black_box(experiments::table1(&[2, 4, 8]));
    });
}
