//! Minimal routing in lattice graphs (paper Section 5).
//!
//! A **routing record** (Definition after 26) for source `v_s` and
//! destination `v_d` is any `r ∈ Z^n` with `v_d - v_s ≡ r (mod M)`; its
//! Minkowski (L1) norm is the path length, component `i` giving the signed
//! hop count along dimension `i`. Minimal routing finds a record of
//! minimum norm.
//!
//! Implementations:
//! - [`oracle`]: BFS-backed minimal records — the ground truth every other
//!   router is validated against.
//! - [`torus`]: classical per-dimension DOR for `T(a_1, ..., a_n)`.
//! - [`rtt`]: Algorithm 3 — closed form for the rectangular twisted torus.
//! - [`fcc`]: Algorithm 2 — FCC(a) via two RTT calls.
//! - [`bcc`]: Algorithm 4 — BCC(a) via two `T(2a, 2a)` calls (with the
//!   paper's typo corrected; see DESIGN.md §Routing-notes).
//! - [`hierarchical`]: Algorithm 1 — generic minimal routing for *any*
//!   lattice graph by recursion over projections (Theorem 29).
//! - [`table`]: Cayley-exploiting precomputed record tables (records
//!   depend only on `v_d - v_s`), including tie sets for Remark 30's
//!   randomized balancing.
//! - [`dispatch`]: Hermite-form classification choosing the closed-form
//!   router for catalog families (hierarchical off-catalog), with tie
//!   sets pinned record-for-record to the hierarchical builder's.
//! - [`compact`]: the CSR `[i16; MAX_DIM]` record store the simulator's
//!   hot path reads, built directly from a router over parallel shards.

pub mod bcc;
pub mod compact;
pub mod dispatch;
pub mod fcc;
pub mod hierarchical;
pub mod nd;
pub mod oracle;
pub mod rtt;
pub mod table;
pub mod torus;

pub use compact::CompactRoutes;
pub use dispatch::{classify, DispatchRouter, RouterKind};
pub use hierarchical::HierarchicalRouter;
pub use table::RoutingTable;

use crate::lattice::LatticeGraph;

/// Max supported graph dimension (the paper uses up to 6). Bounds the
/// compact fixed-width records and the engine's per-packet state.
pub const MAX_DIM: usize = 6;

/// A routing record: signed hop counts per dimension.
pub type Record = Vec<i64>;

/// Minkowski (L1) norm of a record = path length in hops.
pub fn norm(r: &[i64]) -> i64 {
    r.iter().map(|x| x.abs()).sum()
}

/// A minimal router for a specific lattice graph.
pub trait Router {
    /// The graph this router serves.
    fn graph(&self) -> &LatticeGraph;

    /// One minimal routing record from `src` to `dst` (canonical labels).
    fn route(&self, src: &[i64], dst: &[i64]) -> Record;

    /// All minimal records (the tie set of Remark 30). Default: the one
    /// record from [`route`](Router::route).
    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        vec![self.route(src, dst)]
    }
}

/// Validate that `r` is a routing record for `(src, dst)`: congruence
/// check per Definition 2.
pub fn is_valid_record(g: &LatticeGraph, src: &[i64], dst: &[i64], r: &[i64]) -> bool {
    let n = g.dim();
    let mut reached: Vec<i64> = (0..n).map(|i| src[i] + r[i]).collect();
    g.reduce_in_place(&mut reached);
    reached == g.reduce(dst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::fcc as fcc_graph;

    #[test]
    fn norm_is_l1() {
        assert_eq!(norm(&[1, -3, 2]), 6);
        assert_eq!(norm(&[]), 0);
        assert_eq!(norm(&[0, 0]), 0);
    }

    #[test]
    fn record_validation() {
        let g = fcc_graph(4);
        // Example 32: from (1,3,3) to (6,0,1), r = (1,1,-2) is valid.
        assert!(is_valid_record(&g, &[1, 3, 3], &[6, 0, 1], &[1, 1, -2]));
        // The rejected candidate r1 = (1,-3,2) is also a valid record
        // (just not minimal).
        assert!(is_valid_record(&g, &[1, 3, 3], &[6, 0, 1], &[1, -3, 2]));
        // A wrong record is not.
        assert!(!is_valid_record(&g, &[1, 3, 3], &[6, 0, 1], &[1, 1, -1]));
    }
}
