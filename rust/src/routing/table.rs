//! Precomputed routing tables exploiting the Cayley property.
//!
//! Routing records depend only on the *difference* `v_d - v_s (mod M)`,
//! so one table of `N` entries indexed by the reduced difference serves
//! every pair. Each entry stores the full tie set (Remark 30) so callers
//! can randomize among minimal paths for link balance. This is what the
//! simulator's injection path uses: an O(1) lookup, no per-packet
//! arithmetic.

use crate::lattice::LatticeGraph;
use crate::sim::rng::Rng;

use super::{norm, Record, Router};

/// Routing table: `records[diff_index]` = the minimal tie set for that
/// source→destination difference.
pub struct RoutingTable {
    g: LatticeGraph,
    records: Vec<Vec<Record>>,
}

impl RoutingTable {
    /// Build from any router by walking every difference label once.
    pub fn build<R: Router>(router: &R) -> Self {
        let g = router.graph().clone();
        let zero = vec![0i64; g.dim()];
        let records = (0..g.order())
            .map(|v| {
                let dst = g.label_of(v);
                let ties = router.route_ties(&zero, &dst);
                debug_assert!(!ties.is_empty());
                ties
            })
            .collect();
        Self { g, records }
    }

    /// Build with the generic hierarchical router.
    pub fn build_hierarchical(g: &LatticeGraph) -> Self {
        Self::build(&super::HierarchicalRouter::new(g.clone()))
    }

    /// The graph served.
    pub fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    /// Tie set for a reduced difference index — the table's native key
    /// and the allocation-free fast path: `ties_by_index` materializes
    /// two labels plus a difference vector per call, which the compact
    /// build and the engine's injection lookup pay per node; a caller
    /// that already holds the difference index borrows the row directly.
    #[inline]
    pub fn ties_by_diff(&self, diff_idx: usize) -> &[Record] {
        &self.records[diff_idx]
    }

    /// Tie set for a difference given by node indices.
    pub fn ties_by_index(&self, src_idx: usize, dst_idx: usize) -> &[Record] {
        let src = self.g.label_of(src_idx);
        let dst = self.g.label_of(dst_idx);
        let diff: Vec<i64> = dst.iter().zip(&src).map(|(d, s)| d - s).collect();
        self.ties_by_diff(self.g.index_of_vec(&diff))
    }

    /// One record (the first tie) for a pair of node indices.
    pub fn record_by_index(&self, src_idx: usize, dst_idx: usize) -> &Record {
        &self.ties_by_index(src_idx, dst_idx)[0]
    }

    /// A uniformly random tie for a pair, drawn with the simulator RNG's
    /// bounded draw. (The old signature took a raw `pick` value and
    /// indexed `pick % ties.len()`, which is modulo-biased whenever the
    /// tie count does not divide the caller's draw range; `Rng::below`'s
    /// multiply-shift draw is the engine's uniform bounded pick.)
    pub fn pick_by_index(&self, src_idx: usize, dst_idx: usize, rng: &mut Rng) -> &Record {
        let ties = self.ties_by_index(src_idx, dst_idx);
        &ties[rng.below(ties.len())]
    }

    /// Maximum record norm in the table — the routed diameter.
    pub fn routed_diameter(&self) -> i64 {
        self.records
            .iter()
            .map(|ties| norm(&ties[0]))
            .max()
            .unwrap_or(0)
    }

    /// Average record norm over all differences (≈ average distance with
    /// the `N` normalization, not `N - 1`).
    pub fn average_norm(&self) -> f64 {
        let sum: i64 = self.records.iter().map(|t| norm(&t[0])).sum();
        sum as f64 / self.records.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::bcc::BccRouter;
    use crate::routing::fcc::FccRouter;
    use crate::topology::{bcc, fcc};

    #[test]
    fn table_matches_router_for_all_pairs() {
        let router = FccRouter::new(2);
        let table = RoutingTable::build(&router);
        let g = router.graph().clone();
        for s in 0..g.order() {
            for d in 0..g.order() {
                let src = g.label_of(s);
                let dst = g.label_of(d);
                let direct = router.route(&src, &dst);
                let table_r = table.record_by_index(s, d);
                assert_eq!(norm(&direct), norm(table_r), "{src:?}->{dst:?}");
            }
        }
    }

    #[test]
    fn routed_diameter_matches_bfs() {
        let router = BccRouter::new(2);
        let table = RoutingTable::build(&router);
        let stats = crate::metrics::distance_distribution(&bcc(2));
        assert_eq!(table.routed_diameter(), stats.diameter as i64);
    }

    #[test]
    fn hierarchical_table_on_fcc() {
        let g = fcc(2);
        let table = RoutingTable::build_hierarchical(&g);
        let stats = crate::metrics::distance_distribution(&g);
        assert_eq!(table.routed_diameter(), stats.diameter as i64);
        // average over differences equals sum/N
        let expect = stats
            .histogram
            .iter()
            .enumerate()
            .map(|(d, c)| d * c)
            .sum::<usize>() as f64
            / g.order() as f64;
        assert!((table.average_norm() - expect).abs() < 1e-9);
    }

    #[test]
    fn pick_draws_every_tie_and_only_ties() {
        let router = FccRouter::new(2);
        let table = RoutingTable::build(&router);
        let g = router.graph();
        let mut rng = Rng::new(42);
        // find a pair with >1 tie
        let mut found = false;
        for d in 0..g.order() {
            let ties: Vec<Record> = table.ties_by_index(0, d).to_vec();
            if ties.len() > 1 {
                let mut seen = vec![false; ties.len()];
                for _ in 0..64 * ties.len() {
                    let r = table.pick_by_index(0, d, &mut rng);
                    let idx = ties.iter().position(|t| t == r).expect("pick outside tie set");
                    seen[idx] = true;
                }
                assert!(seen.iter().all(|&s| s), "every tie reachable: {seen:?}");
                found = true;
                break;
            }
        }
        assert!(found, "expected at least one tie set with multiple records");
    }
}
