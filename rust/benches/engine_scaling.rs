//! Macrobench: cycle-engine throughput across (nodes × load × policy ×
//! regime × scan mode) — the perf story behind the active-set refactor
//! (DESIGN.md §Engine-performance).
//!
//! Every case is measured under both scan modes, so one run records the
//! active-set speedup over the retained full-scan reference directly.
//! The interesting regimes:
//!
//! - `open@0.05`: low-load open loop — few packets in flight, the
//!   full scan burns O(nodes) per cycle on idle routers;
//! - `open@0.9`: saturation — everything is active, so active-set
//!   bookkeeping must cost ~nothing (the ≤5% regression budget);
//! - `chain`: a serial closed-loop relay (one message train in flight at
//!   a time) — the dependency-tail regime where per-cycle activity is a
//!   handful of nodes regardless of network size;
//! - `open@0.9+trace`: saturation with the JSONL lifecycle trace and
//!   probes enabled — the telemetry overhead case (DESIGN.md
//!   §Telemetry). The delta against the matching `open@0.9` case is the
//!   cost of *using* the trace; the `open@0.9` cases themselves carry
//!   the always-on stall counters, so their trajectory vs the seed
//!   baseline bounds the telemetry-off overhead.
//!
//! Emit machine-readable records with `--json <path>` (or `BENCH_JSON`);
//! relative paths resolve in the bench's CWD, the `rust/` package root.
//! `scripts/bench_engine.sh` regenerates the repo's committed
//! perf-trajectory baseline (`BENCH_engine.json` at the repository root,
//! budget pinned to `BENCH_BUDGET_MS=300` for comparable numbers).

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{Workload, WorkloadMessage};

/// Serial neighbour relay: message `i` rides `node i -> i+1 (mod N)` and
/// depends on message `i-1`, so at most one train is ever in flight — the
/// closed-loop dependency-tail regime at its purest.
fn chain_workload(nodes: usize, len: u32) -> Workload {
    let n = nodes as u32;
    let messages = (0..len)
        .map(|i| {
            let deps = if i == 0 { vec![] } else { vec![i - 1] };
            WorkloadMessage::new(i % n, (i + 1) % n, i, deps)
        })
        .collect();
    Workload { name: format!("chain({len})"), nodes, messages }
}

fn main() {
    // `--json <path>` / `BENCH_JSON` are handled by `Bench::new`.
    let mut b = Bench::new("engine_scaling");
    b.max_iters = 20;

    let open_cfg = |policy: RoutePolicy, scan: ScanMode| SimConfig {
        warmup_cycles: 0,
        measure_cycles: 2_000,
        route_policy: policy,
        scan_mode: scan,
        ..SimConfig::default()
    };

    for (name, g) in [
        ("T(8,8,8)", topology::torus(&[8, 8, 8])),
        ("T(16,16,16)", topology::torus(&[16, 16, 16])),
    ] {
        let nodes = g.order() as u64;
        let chain = chain_workload(g.order(), 256);
        for policy in [RoutePolicy::Dor, RoutePolicy::AdaptiveMin] {
            for scan in ScanMode::ALL {
                let cfg = open_cfg(policy, scan);
                let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                // Open loop: node-cycles per second is the engine metric.
                for load in [0.05, 0.9] {
                    b.run_throughput(
                        &format!("{name}/open@{load}/{}/{}", policy.name(), scan.name()),
                        nodes * cycles,
                        "node-cycles",
                        || {
                            black_box(sim.run(load));
                        },
                    );
                }
                // Saturated open loop with the lifecycle trace streaming
                // to a scratch file: the telemetry overhead case. Only
                // the adaptive policy (the event-heaviest: stalls and
                // escape drains on top of hops) — the off/on delta, not
                // policy coverage, is the point.
                if policy == RoutePolicy::AdaptiveMin {
                    let path = std::env::temp_dir().join(format!(
                        "lattice_bench_trace_{}_{nodes}_{}.jsonl",
                        std::process::id(),
                        scan.name()
                    ));
                    let traced = Simulator::new(
                        g.clone(),
                        TrafficPattern::Uniform,
                        SimConfig {
                            trace: Some(path.to_string_lossy().into_owned()),
                            sample_every: 100,
                            ..open_cfg(policy, scan)
                        },
                    );
                    b.run_throughput(
                        &format!("{name}/open@0.9+trace/{}/{}", policy.name(), scan.name()),
                        nodes * cycles,
                        "node-cycles",
                        || {
                            black_box(traced.run(0.9));
                        },
                    );
                    std::fs::remove_file(&path).ok();
                }
                // Closed loop: the serial chain's cycle count is seed-
                // deterministic, so one reference run sizes the metric.
                let cap = chain.suggested_max_cycles_for(sim.config());
                let seed = sim.config().seed;
                let ref_cycles = sim.run_workload_seeded(&chain, seed, cap).completion_cycles;
                b.run_throughput(
                    &format!("{name}/chain/{}/{}", policy.name(), scan.name()),
                    nodes * ref_cycles,
                    "node-cycles",
                    || {
                        black_box(sim.run_workload_seeded(&chain, seed, cap));
                    },
                );
            }
        }
    }
}
