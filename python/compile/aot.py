"""AOT lowering: JAX models -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which the published ``xla`` 0.1.6 crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``). The HLO text parser on the Rust
side (``HloModuleProto::from_text_file``) reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Emits, for each N in --sizes:

    artifacts/apsp_minplus_n{N}.hlo.txt   (adj f32[N,N], n_real f32[]) ->
    artifacts/apsp_gemm_n{N}.hlo.txt        (dist f32[N,N], sum f32[], max f32[])

plus ``artifacts/manifest.json`` describing every artifact (entry name,
size, iteration counts, input/output protocol) for the Rust loader.

Run via ``make artifacts`` (no-op when inputs are unchanged). Build-time
only; never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_SIZES = (64, 128, 256)
DEFAULT_BLOCK = 64  # divides every default size; 128-lane alignment at N>=128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, see runtime/artifact.rs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_minplus(n: int, block: int):
    iters = model.minplus_iters_for(n)
    fn = functools.partial(model.apsp_minplus, iters=iters, block=block)
    adj = jax.ShapeDtypeStruct((n, n), jnp.float32)
    n_real = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(fn).lower(adj, n_real), {"iters": iters}


def lower_gemm(n: int, block: int):
    steps = model.gemm_steps_for(n)
    fn = functools.partial(model.apsp_gemm, steps=steps, block=block)
    adj = jax.ShapeDtypeStruct((n, n), jnp.float32)
    n_real = jax.ShapeDtypeStruct((), jnp.float32)
    return jax.jit(fn).lower(adj, n_real), {"steps": steps}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", type=int, nargs="*", default=list(DEFAULT_SIZES))
    ap.add_argument("--block", type=int, default=DEFAULT_BLOCK)
    ap.add_argument(
        "--skip-gemm",
        action="store_true",
        help="emit only the min-plus artifacts (gemm ones are larger to lower)",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest: dict = {"inf": 1e9, "artifacts": []}

    for n in args.sizes:
        block = min(args.block, n)
        assert n % block == 0, f"size {n} not divisible by block {block}"
        jobs = [("apsp_minplus", lower_minplus)]
        if not args.skip_gemm:
            jobs.append(("apsp_gemm", lower_gemm))
        for name, lower in jobs:
            lowered, meta = lower(n, block)
            text = to_hlo_text(lowered)
            fname = f"{name}_n{n}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "n": n,
                    "block": block,
                    "file": fname,
                    "inputs": ["adj f32[n,n]", "n_real f32[]"],
                    "outputs": ["dist f32[n,n]", "sum f32[]", "max f32[]"],
                    **meta,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")

    # Line-based twin of the JSON manifest for the Rust loader (this build
    # is fully offline — no serde_json; see DESIGN.md §Substitutions).
    lines = [f"inf={manifest['inf']}"]
    for a in manifest["artifacts"]:
        extra = "iters" if "iters" in a else "steps"
        lines.append(
            f"artifact name={a['name']} n={a['n']} block={a['block']} "
            f"{extra}={a[extra]} file={a['file']}"
        )
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
