//! Dense exact integer matrices (`i64`), column-major semantics.
//!
//! The paper manipulates generator matrices by *columns* (right
//! equivalence, Definition 6), so columns are the first-class accessor.
//! Storage is row-major `Vec<i64>` for cache-friendly row reduction, with
//! `col`/`set_col` helpers on top.

use std::fmt;

/// A dense `rows x cols` integer matrix.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct IMat {
    rows: usize,
    cols: usize,
    data: Vec<i64>, // row-major
}

impl IMat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    /// Identity of dimension `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1;
        }
        m
    }

    /// Build from row slices; all rows must have equal length.
    pub fn from_rows(rows: &[&[i64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Build an `n x n` matrix from a flat row-major slice.
    pub fn from_flat(n: usize, data: &[i64]) -> Self {
        assert_eq!(data.len(), n * n);
        Self { rows: n, cols: n, data: data.to_vec() }
    }

    /// Square diagonal matrix.
    pub fn diag(d: &[i64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Dimension of a square matrix (panics if non-square).
    pub fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "dim() on non-square matrix");
        self.rows
    }

    /// Column `j` as a vector.
    pub fn col(&self, j: usize) -> Vec<i64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[i64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn set_col(&mut self, j: usize, v: &[i64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Swap columns `a` and `b` (a right-unimodular operation).
    pub fn swap_cols(&mut self, a: usize, b: usize) {
        for i in 0..self.rows {
            self.data.swap(i * self.cols + a, i * self.cols + b);
        }
    }

    /// Swap rows `a` and `b`.
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        if a == b {
            return;
        }
        for j in 0..self.cols {
            let (x, y) = (a * self.cols + j, b * self.cols + j);
            self.data.swap(x, y);
        }
    }

    /// Negate column `j` (right-unimodular).
    pub fn negate_col(&mut self, j: usize) {
        for i in 0..self.rows {
            self[(i, j)] = -self[(i, j)];
        }
    }

    /// `col_a += k * col_b` (right-unimodular for any integer `k`).
    pub fn add_col_multiple(&mut self, a: usize, b: usize, k: i64) {
        for i in 0..self.rows {
            let v = self[(i, b)];
            self[(i, a)] += k * v;
        }
    }

    /// Matrix product (exact; panics on dimension mismatch).
    pub fn mul(&self, other: &IMat) -> IMat {
        assert_eq!(self.cols, other.rows, "mul dimension mismatch");
        let mut out = IMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += aik * other[(k, j)];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn mul_vec(&self, v: &[i64]) -> Vec<i64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * v[j]).sum())
            .collect()
    }

    /// Transpose.
    pub fn transpose(&self) -> IMat {
        let mut out = IMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Exact determinant by fraction-free (Bareiss) elimination.
    pub fn det(&self) -> i64 {
        let n = self.dim();
        if n == 0 {
            return 1;
        }
        // Bareiss over i128 to keep intermediates exact.
        let mut a: Vec<Vec<i128>> = (0..n)
            .map(|i| (0..n).map(|j| self[(i, j)] as i128).collect())
            .collect();
        let mut sign = 1i128;
        let mut prev = 1i128;
        for k in 0..n - 1 {
            if a[k][k] == 0 {
                // find pivot
                let Some(p) = (k + 1..n).find(|&i| a[i][k] != 0) else {
                    return 0;
                };
                a.swap(k, p);
                sign = -sign;
            }
            for i in k + 1..n {
                for j in k + 1..n {
                    a[i][j] = (a[i][j] * a[k][k] - a[i][k] * a[k][j]) / prev;
                }
                a[i][k] = 0;
            }
            prev = a[k][k];
        }
        let d = sign * a[n - 1][n - 1];
        i64::try_from(d).expect("determinant overflows i64")
    }

    /// Adjugate matrix: `adj(M) * M = det(M) * I`. Computed from cofactors
    /// (n <= 6 throughout the paper, so O(n^5) is irrelevant).
    pub fn adjugate(&self) -> IMat {
        let n = self.dim();
        let mut adj = IMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let minor = self.minor(i, j);
                let c = minor.det();
                let sign = if (i + j) % 2 == 0 { 1 } else { -1 };
                adj[(j, i)] = sign * c; // note transpose
            }
        }
        adj
    }

    /// Minor: delete row `i`, column `j`.
    pub fn minor(&self, i: usize, j: usize) -> IMat {
        let n = self.dim();
        let mut out = IMat::zeros(n - 1, n - 1);
        let mut r = 0;
        for ii in 0..n {
            if ii == i {
                continue;
            }
            let mut c = 0;
            for jj in 0..n {
                if jj == j {
                    continue;
                }
                out[(r, c)] = self[(ii, jj)];
                c += 1;
            }
            r += 1;
        }
        out
    }

    /// Is this matrix unimodular (integral with determinant +-1)?
    pub fn is_unimodular(&self) -> bool {
        self.rows == self.cols && self.det().abs() == 1
    }

    /// Does `self * x = det * y` have an integral solution for every column
    /// of `rhs`? i.e. is `self^{-1} * rhs` an integer matrix? Exact test via
    /// the adjugate: `M^{-1} R = adj(M) R / det(M)`.
    pub fn inverse_times_is_integral(&self, rhs: &IMat) -> bool {
        let det = self.det();
        assert!(det != 0, "singular matrix");
        let prod = self.adjugate().mul(rhs);
        prod.data.iter().all(|&x| x % det == 0)
    }

    /// `M^{-1} * rhs` if integral (else None). Exact via adjugate.
    pub fn inverse_times(&self, rhs: &IMat) -> Option<IMat> {
        let det = self.det();
        assert!(det != 0, "singular matrix");
        let prod = self.adjugate().mul(rhs);
        if prod.data.iter().all(|&x| x % det == 0) {
            let mut out = prod;
            for x in &mut out.data {
                *x /= det;
            }
            Some(out)
        } else {
            None
        }
    }

    /// `adj(M) * v` — used with `det` for element-order computation
    /// (`det(M) M^{-1} x = adj(M) x`).
    pub fn adjugate_times_vec(&self, v: &[i64]) -> Vec<i64> {
        self.adjugate().mul_vec(v)
    }

    /// Direct sum `M1 (+) M2`: block diagonal.
    pub fn direct_sum(&self, other: &IMat) -> IMat {
        let (r1, c1) = (self.rows, self.cols);
        let mut out = IMat::zeros(r1 + other.rows, c1 + other.cols);
        for i in 0..r1 {
            for j in 0..c1 {
                out[(i, j)] = self[(i, j)];
            }
        }
        for i in 0..other.rows {
            for j in 0..other.cols {
                out[(r1 + i, c1 + j)] = other[(i, j)];
            }
        }
        out
    }

    /// Leading principal submatrix of size `k`.
    pub fn leading(&self, k: usize) -> IMat {
        let mut out = IMat::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                out[(i, j)] = self[(i, j)];
            }
        }
        out
    }

    /// All entries.
    pub fn entries(&self) -> &[i64] {
        &self.data
    }
}

impl std::ops::Index<(usize, usize)> for IMat {
    type Output = i64;
    fn index(&self, (i, j): (usize, usize)) -> &i64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for IMat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut i64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for IMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "IMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        write!(f, "]")
    }
}

impl fmt::Display for IMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for i in 0..self.rows {
            let row: Vec<String> = self.row(i).iter().map(|x| format!("{x:4}")).collect();
            writeln!(f, "[{} ]", row.join(" "))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn det_small() {
        assert_eq!(IMat::identity(3).det(), 1);
        assert_eq!(IMat::diag(&[2, 3, 4]).det(), 24);
        let m = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(m.det(), -2);
    }

    #[test]
    fn det_fcc_bcc() {
        // Paper: |det| = 2a^3 for FCC, 4a^3 for BCC.
        for a in 1..6 {
            let fcc = IMat::from_rows(&[&[a, a, 0], &[a, 0, a], &[0, a, a]]);
            assert_eq!(fcc.det().abs(), 2 * a * a * a);
            let bcc = IMat::from_rows(&[&[-a, a, a], &[a, -a, a], &[a, a, -a]]);
            assert_eq!(bcc.det().abs(), 4 * a * a * a);
        }
    }

    #[test]
    fn det_zero_singular() {
        let m = IMat::from_rows(&[&[1, 2], &[2, 4]]);
        assert_eq!(m.det(), 0);
    }

    #[test]
    fn adjugate_identity() {
        let m = IMat::from_rows(&[&[2, 1, 0], &[0, 3, 1], &[1, 0, 4]]);
        let adj = m.adjugate();
        let prod = adj.mul(&m);
        let det = m.det();
        assert_eq!(prod, {
            let mut d = IMat::zeros(3, 3);
            for i in 0..3 {
                d[(i, i)] = det;
            }
            d
        });
    }

    #[test]
    fn mul_identity() {
        let m = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(m.mul(&IMat::identity(2)), m);
        assert_eq!(IMat::identity(2).mul(&m), m);
    }

    #[test]
    fn mul_vec_works() {
        let m = IMat::from_rows(&[&[1, 2], &[3, 4]]);
        assert_eq!(m.mul_vec(&[1, 1]), vec![3, 7]);
    }

    #[test]
    fn inverse_times_integral() {
        let m = IMat::diag(&[2, 2]);
        let rhs = IMat::from_rows(&[&[4, 2], &[0, 6]]);
        let q = m.inverse_times(&rhs).unwrap();
        assert_eq!(q, IMat::from_rows(&[&[2, 1], &[0, 3]]));
        let rhs2 = IMat::from_rows(&[&[1, 0], &[0, 1]]);
        assert!(m.inverse_times(&rhs2).is_none());
    }

    #[test]
    fn direct_sum_blocks() {
        let a = IMat::diag(&[2]);
        let b = IMat::diag(&[3, 4]);
        let s = a.direct_sum(&b);
        assert_eq!(s, IMat::diag(&[2, 3, 4]));
    }

    #[test]
    fn col_ops_preserve_det_abs() {
        let mut m = IMat::from_rows(&[&[4, 1, 3], &[0, 5, 2], &[0, 0, 6]]);
        let d = m.det().abs();
        m.swap_cols(0, 2);
        assert_eq!(m.det().abs(), d);
        m.negate_col(1);
        assert_eq!(m.det().abs(), d);
        m.add_col_multiple(0, 1, 7);
        assert_eq!(m.det().abs(), d);
    }
}
