//! Route-selection policy layer integration: every policy stays minimal
//! (validated against the BFS oracle), `Dor` reproduces the pre-refactor
//! packet-level schedule exactly, and the non-DOR policies obey the same
//! conservation and determinism contracts as the historical engine.

use lattice_networks::metrics::bfs_distances;
use lattice_networks::sim::{RoutePolicy, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{Workload, WorkloadMessage};

const PS: u64 = 16; // default packet_size

fn cfg(policy: RoutePolicy) -> SimConfig {
    SimConfig { warmup_cycles: 0, measure_cycles: 0, route_policy: policy, ..SimConfig::default() }
}

/// Minimality property: a lone packet reaches any destination in exactly
/// `norm(record)` hops under every policy. On an idle network the head
/// moves one link per cycle and the tail serializes once at ejection, so
/// a single-message workload completes in exactly `dist + packet_size`
/// cycles, where `dist` is the BFS oracle distance — any detour, stall or
/// non-productive hop would show up as extra cycles.
#[test]
fn every_policy_is_minimal_against_bfs_oracle() {
    for g in [topology::torus(&[4, 4]), topology::fcc(2), topology::torus(&[8, 4])] {
        let dist = bfs_distances(&g, 0);
        for policy in RoutePolicy::ALL {
            let sim = Simulator::for_workload(g.clone(), cfg(policy));
            for d in 1..g.order() {
                let wl = Workload {
                    name: format!("one->{d}"),
                    nodes: g.order(),
                    messages: vec![WorkloadMessage::new(0, d as u32, 0, vec![])],
                };
                // Two seeds: the RNG-consuming policies must stay minimal
                // whichever productive axis they happen to draw.
                for seed in [1u64, 7] {
                    let out = sim.run_workload_seeded(&wl, seed, 100_000);
                    assert!(out.drained, "{} dest {d}", policy.name());
                    assert_eq!(
                        out.completion_cycles,
                        dist[d] as u64 + PS,
                        "policy {} is not minimal to dest {d} (bfs {})",
                        policy.name(),
                        dist[d]
                    );
                }
            }
        }
    }
}

/// Regression pin: `Dor` reproduces the pre-refactor engine's packet-level
/// schedule — at every VC count, including `num_vcs = 1` (the pre-escape
/// single-VC engine, the configuration the escape PR must leave
/// bit-exact). Three chained phases of a diagonal neighbour shift on a
/// seeded 4×4 torus force every packet's full trajectory — each (1,1)
/// difference has a unique minimal record, every link carries exactly one
/// packet per phase, and each output port sees one candidate, so no RNG
/// draw (tie pick, VC pick, arbitration) can perturb the schedule, and
/// under `Dor` the escape protocol is off at any VC count. Under DOR (x
/// before y) each phase is exactly `2 + packet_size` cycles of head
/// flight + tail serialization and the phases chain back-to-back: the
/// completion time, packet count and every latency statistic are pinned to
/// the values the pre-refactor engine produced, for any seed.
#[test]
fn dor_pins_pre_refactor_schedule_on_seeded_torus() {
    let g = topology::torus(&[4, 4]);
    let n = g.order() as u32;
    let mut messages = Vec::new();
    for phase in 0..3u32 {
        for u in 0..n {
            let label = g.label_of(u as usize);
            let dst = g.index_of_vec(&[label[0] + 1, label[1] + 1]) as u32;
            let deps = if phase == 0 { vec![] } else { vec![(phase - 1) * n + u] };
            messages.push(WorkloadMessage::new(u, dst, phase, deps));
        }
    }
    let wl = Workload { name: "diag-chain".into(), nodes: g.order(), messages };
    for num_vcs in [1usize, 2, 3] {
        let sim = Simulator::for_workload(
            g.clone(),
            SimConfig { num_vcs, ..cfg(RoutePolicy::Dor) },
        );
        for seed in [0xdead_beef_u64, 1, 42] {
            let out = sim.run_workload_seeded(&wl, seed, 10_000);
            assert!(out.drained);
            assert_eq!(
                out.completion_cycles,
                3 * (2 + PS),
                "schedule drift at seed {seed}, {num_vcs} VCs"
            );
            assert_eq!(out.delivered_packets, 3 * 16);
            assert_eq!(out.delivered_messages, 3 * 16);
            assert_eq!(out.avg_latency, (2 + PS) as f64);
            assert_eq!(out.max_latency, 2 + PS);
        }
    }
}

/// The deadlock regression the escape channel exists for. Every node of
/// T(4,4) floods message trains to the node `(+2, +2)` away: every
/// minimal record is one of the half-ring ties `(±2, ±2)`, so at
/// saturation every packet must turn between an x ring and a y ring, and
/// the four turn quadrants form the classic cyclic channel dependency
/// that minimal adaptive routing cannot break on its own. With tight
/// 2-packet queues and a single VC, `AdaptiveMin` genuinely wedges: the
/// rings fill with packets that have exhausted one axis and wait forever
/// for a 2-slot bubble in the other ring. With `num_vcs = 2` the same
/// pressure must drain for every seed — blocked packets fall into the
/// DOR escape channel (visibly: the VC-0 phit counter is nonzero), which
/// bubble flow control keeps deadlock-free.
#[test]
fn escape_vc_unjams_adversarial_turn_cycle() {
    let g = topology::torus(&[4, 4]);
    let n = g.order() as u32;
    let mut messages = Vec::new();
    for round in 0..12u32 {
        for u in 0..n {
            let label = g.label_of(u as usize);
            let dst = g.index_of_vec(&[label[0] + 2, label[1] + 2]) as u32;
            messages.push(WorkloadMessage::new(u, dst, round, vec![]));
        }
    }
    let wl = Workload { name: "turn-cycle".into(), nodes: g.order(), messages };
    let mk = |num_vcs: usize| SimConfig {
        num_vcs,
        queue_packets: 2,
        ..cfg(RoutePolicy::AdaptiveMin)
    };
    let seeds = [1u64, 2, 3, 4, 5, 6];
    // Escape side: every seed drains (load 1.0 completes under
    // AdaptiveMin) and the escape lane demonstrably carried traffic.
    let sim2 = Simulator::for_workload(g.clone(), mk(2));
    for &seed in &seeds {
        let out = sim2.run_workload_seeded(&wl, seed, 200_000);
        assert!(
            out.drained,
            "escape run wedged at seed {seed}: {}/{} messages",
            out.delivered_messages, out.total_messages
        );
        assert_eq!(out.delivered_messages, out.total_messages);
        assert!(out.vc_phits[0] > 0, "escape lane never used at seed {seed}");
        assert!(out.escape_share() > 0.0 && out.escape_share() < 1.0, "{}", out.escape_share());
        // The stall attribution must tell the same story the phit counters
        // do: the only way onto VC 0 is an escape drain (injection never
        // draws VC 0 while the protocol is live), and each drain's own
        // transfer already counts phits on VC 0 — while the committed
        // packet keeps accumulating VC-0 phits on its remaining DOR hops.
        assert!(out.stalls.escape_drains > 0, "VC-0 phits without escape drains at seed {seed}");
        assert!(
            out.stalls.escape_drains * PS <= out.vc_phits[0],
            "drain count {} inconsistent with VC-0 phits {} at seed {seed}",
            out.stalls.escape_drains,
            out.vc_phits[0]
        );
    }
    // Single-VC side: the same pressure must demonstrably deadlock
    // unprotected adaptive routing for at least one seed (an undrained
    // run at a cap ~20x the escape-side completion is a wedge, not a slow
    // network; in practice every seed wedges).
    let sim1 = Simulator::for_workload(g, mk(1));
    let outcomes: Vec<_> =
        seeds.iter().map(|&seed| sim1.run_workload_seeded(&wl, seed, 100_000)).collect();
    let wedged = outcomes.iter().filter(|out| !out.drained).count();
    assert!(
        wedged >= 1,
        "single-VC AdaptiveMin never deadlocked on the adversarial turn-cycle pattern"
    );
    for out in outcomes.iter().filter(|out| !out.drained) {
        // A wedged single-VC run must be attributed to exhausted
        // downstream credits — the turn cycle holds every buffer full —
        // and with one VC there is no escape lane to drain into.
        assert!(
            out.stalls.credit_starved > 0,
            "wedged run reported no credit-starved stalls: {:?}",
            out.stalls
        );
        assert_eq!(out.stalls.escape_drains, 0, "escape drains without an escape lane");
    }
}

/// The policies genuinely differ where ties exist: on an antipodal-heavy
/// pattern the adaptive and random policies must still deliver everything
/// a torus run delivers under Dor (conservation), deterministically per
/// seed, and the spread instrumentation must rank the fixed ordering no
/// better-balanced than the per-hop spreading policies are required to be
/// sane (spread >= 1 whenever traffic moved).
#[test]
fn policies_conserve_and_report_balance_under_global_traffic() {
    let mk = |policy: RoutePolicy| {
        let cfg = SimConfig {
            warmup_cycles: 200,
            measure_cycles: 1200,
            route_policy: policy,
            ..SimConfig::default()
        };
        Simulator::new(topology::torus(&[8, 4, 4]), TrafficPattern::RandomPairings, cfg)
    };
    for policy in RoutePolicy::ALL {
        let sim = mk(policy);
        let r = sim.run(0.7);
        assert!(r.delivered_packets > 0, "{}", policy.name());
        assert!(r.delivered_packets <= r.injected_packets, "{}", policy.name());
        assert!(r.link_util_spread >= 1.0, "{}: spread {}", policy.name(), r.link_util_spread);
        let again = sim.run(0.7);
        assert_eq!(r.delivered_packets, again.delivered_packets, "{}", policy.name());
    }
}
