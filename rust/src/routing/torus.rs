//! Per-dimension torus routing (the classical DOR record).

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;

use super::{Record, Router};

/// Closed-form minimal router for `T(a_1, ..., a_n)`.
pub struct TorusRouter {
    g: LatticeGraph,
    sides: Vec<i64>,
}

impl TorusRouter {
    /// Build from a torus graph (panics if the graph is not a torus —
    /// i.e. its Hermite form is not diagonal).
    pub fn new(g: LatticeGraph) -> Self {
        let n = g.dim();
        let h = g.hermite();
        for i in 0..n {
            for j in 0..n {
                assert!(
                    i == j || h[(i, j)] == 0,
                    "TorusRouter on non-torus matrix {h:?}"
                );
            }
        }
        let sides = g.box_sides().to_vec();
        Self { g, sides }
    }

    /// Route a single ring dimension: minimal signed displacement.
    pub fn ring_route(delta: i64, a: i64) -> i64 {
        let d = rem_euclid(delta, a);
        if 2 * d <= a {
            d
        } else {
            d - a
        }
    }

    /// Both minimal ring displacements when `|delta| = a/2` (tie), else one.
    pub fn ring_route_ties(delta: i64, a: i64) -> Vec<i64> {
        let d = rem_euclid(delta, a);
        if d == 0 {
            vec![0]
        } else if 2 * d == a {
            vec![d, d - a]
        } else if 2 * d < a {
            vec![d]
        } else {
            vec![d - a]
        }
    }
}

impl Router for TorusRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        src.iter()
            .zip(dst)
            .zip(&self.sides)
            .map(|((&s, &d), &a)| Self::ring_route(d - s, a))
            .collect()
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        // Cartesian product of per-dimension tie options, in the
        // hierarchical router's emission order: dimension 0 varies
        // fastest (the recursion appends the outermost dimension last,
        // so the innermost dimensions cycle first). The tie order is
        // RNG-stream-load-bearing — the engine draws
        // `rng.below(ties.len())` into this list — so dispatching the
        // table build through this router instead of the hierarchical
        // one must preserve it record-for-record.
        let mut out: Vec<Record> = vec![Vec::new()];
        for ((&s, &d), &a) in src.iter().zip(dst).zip(&self.sides) {
            let opts = Self::ring_route_ties(d - s, a);
            let mut next = Vec::with_capacity(out.len() * opts.len());
            for &o in &opts {
                for partial in &out {
                    let mut r = partial.clone();
                    r.push(o);
                    next.push(r);
                }
            }
            out = next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::{is_valid_record, norm, oracle::bfs_distance};
    use crate::topology::torus;

    #[test]
    fn ring_route_cases() {
        assert_eq!(TorusRouter::ring_route(3, 8), 3);
        assert_eq!(TorusRouter::ring_route(5, 8), -3);
        assert_eq!(TorusRouter::ring_route(4, 8), 4); // tie -> positive
        assert_eq!(TorusRouter::ring_route(-3, 8), -3);
        assert_eq!(TorusRouter::ring_route(0, 8), 0);
        assert_eq!(TorusRouter::ring_route(7, 8), -1);
    }

    #[test]
    fn ring_ties() {
        assert_eq!(TorusRouter::ring_route_ties(4, 8), vec![4, -4]);
        assert_eq!(TorusRouter::ring_route_ties(2, 8), vec![2]);
        assert_eq!(TorusRouter::ring_route_ties(0, 8), vec![0]);
    }

    #[test]
    fn torus_routes_minimal_all_pairs() {
        for sides in [vec![4i64, 4], vec![5, 3], vec![4, 2, 6]] {
            let g = torus(&sides);
            let router = TorusRouter::new(g.clone());
            let src = vec![0i64; g.dim()];
            let dist = crate::metrics::bfs_distances(&g, 0);
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r), "{sides:?} {dst:?}");
                assert_eq!(norm(&r), dist[v] as i64, "{sides:?} {dst:?}");
            }
        }
    }

    #[test]
    fn ties_are_all_minimal_and_valid() {
        let g = torus(&[4, 4]);
        let router = TorusRouter::new(g.clone());
        let ties = router.route_ties(&[0, 0], &[2, 2]);
        assert_eq!(ties.len(), 4);
        // Hierarchical emission order: dimension 0 varies fastest.
        assert_eq!(
            ties,
            vec![vec![2, 2], vec![-2, 2], vec![2, -2], vec![-2, -2]]
        );
        for r in &ties {
            assert!(is_valid_record(&g, &[0, 0], &[2, 2], r));
            assert_eq!(norm(r), bfs_distance(&g, &[0, 0], &[2, 2]));
        }
    }
}
