"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in ref.py over
hypothesis-driven sweeps of shapes, block sizes and value distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bfs_gemm, minplus, ref

jax.config.update("jax_platform_name", "cpu")

# Shapes stay small: interpret-mode pallas is a correctness vehicle, not a
# perf one, and hypothesis runs dozens of cases.
SIZES = [4, 8, 16, 32]
BLOCKS = [2, 4, 8, 16, 32]


def _divisible_pairs():
    return [(n, b) for n in SIZES for b in BLOCKS if b <= n and n % b == 0]


# ---------------------------------------------------------------- min-plus


@pytest.mark.parametrize("n,block", _divisible_pairs())
def test_minplus_matches_ref_uniform(n, block):
    rng = np.random.default_rng(n * 1000 + block)
    a = rng.uniform(0.0, 50.0, (n, n)).astype(np.float32)
    b = rng.uniform(0.0, 50.0, (n, n)).astype(np.float32)
    got = minplus.minplus(jnp.array(a), jnp.array(b), block=block)
    npt.assert_allclose(got, ref.minplus_ref(a, b), rtol=1e-6)


@pytest.mark.parametrize("n,block", _divisible_pairs())
def test_minplus_with_inf_sentinels(n, block):
    """Distance-matrix-shaped inputs: 0 diagonal, 1s, INF sentinels."""
    rng = np.random.default_rng(n * 7 + block)
    a = np.where(rng.uniform(size=(n, n)) < 0.5, 1.0, float(ref.INF)).astype(
        np.float32
    )
    np.fill_diagonal(a, 0.0)
    got = minplus.minplus(jnp.array(a), jnp.array(a), block=block)
    npt.assert_allclose(got, ref.minplus_ref(a, a), rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n_idx=st.integers(0, len(SIZES) - 1),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1.0, 100.0, 1e6]),
)
def test_minplus_hypothesis(n_idx, seed, scale):
    n = SIZES[n_idx]
    block = max(b for b in BLOCKS if b <= n and n % b == 0)
    rng = np.random.default_rng(seed)
    a = (rng.uniform(0, scale, (n, n))).astype(np.float32)
    b = (rng.uniform(0, scale, (n, n))).astype(np.float32)
    got = minplus.minplus(jnp.array(a), jnp.array(b), block=block)
    npt.assert_allclose(got, ref.minplus_ref(a, b), rtol=1e-5)


def test_minplus_identity():
    """Min-plus identity: diag 0, off-diag INF leaves the operand unchanged."""
    n = 8
    ident = np.full((n, n), float(ref.INF), np.float32)
    np.fill_diagonal(ident, 0.0)
    rng = np.random.default_rng(3)
    a = rng.uniform(0, 10, (n, n)).astype(np.float32)
    npt.assert_allclose(minplus.minplus(jnp.array(a), jnp.array(ident), block=4), a)
    npt.assert_allclose(minplus.minplus(jnp.array(ident), jnp.array(a), block=4), a)


def test_minplus_associative():
    n = 8
    rng = np.random.default_rng(4)
    a, b, c = (rng.uniform(0, 10, (n, n)).astype(np.float32) for _ in range(3))
    ab_c = minplus.minplus(minplus.minplus(jnp.array(a), jnp.array(b)), jnp.array(c))
    a_bc = minplus.minplus(jnp.array(a), minplus.minplus(jnp.array(b), jnp.array(c)))
    npt.assert_allclose(ab_c, a_bc, rtol=1e-6)


def test_minplus_rejects_bad_block():
    with pytest.raises(AssertionError):
        minplus.minplus(jnp.zeros((6, 6)), jnp.zeros((6, 6)), block=4)


# ---------------------------------------------------------------- bfs-gemm


@pytest.mark.parametrize("n,block", _divisible_pairs())
@pytest.mark.parametrize("density", [0.1, 0.4])
def test_expand_frontier_matches_ref(n, block, density):
    rng = np.random.default_rng(n * 31 + block)
    r = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    m = (rng.uniform(size=(n, n)) < density).astype(np.float32)
    got = bfs_gemm.expand_frontier(jnp.array(r), jnp.array(m), block=block)
    npt.assert_allclose(got, ref.expand_frontier_ref(r, m))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_idx=st.integers(0, len(SIZES) - 1))
def test_expand_frontier_hypothesis(seed, n_idx):
    n = SIZES[n_idx]
    block = max(b for b in BLOCKS if b <= n and n % b == 0)
    rng = np.random.default_rng(seed)
    r = (rng.uniform(size=(n, n)) < rng.uniform(0.05, 0.9)).astype(np.float32)
    m = (rng.uniform(size=(n, n)) < rng.uniform(0.05, 0.9)).astype(np.float32)
    got = bfs_gemm.expand_frontier(jnp.array(r), jnp.array(m), block=block)
    npt.assert_allclose(got, ref.expand_frontier_ref(r, m))


def test_expand_frontier_idempotent_on_closure():
    """Expanding the transitive closure by itself changes nothing."""
    n = 8
    rng = np.random.default_rng(9)
    m = (rng.uniform(size=(n, n)) < 0.3).astype(np.float32)
    m = np.minimum(m + np.eye(n, dtype=np.float32), 1.0)
    closure = np.eye(n, dtype=np.float32)
    for _ in range(n):
        closure = ref.expand_frontier_ref(closure, m)
    again = bfs_gemm.expand_frontier(jnp.array(np.array(closure)), jnp.array(m), block=4)
    npt.assert_allclose(again, closure)


def test_outputs_are_binary():
    n = 8
    rng = np.random.default_rng(11)
    r = (rng.uniform(size=(n, n)) < 0.5).astype(np.float32)
    m = (rng.uniform(size=(n, n)) < 0.5).astype(np.float32)
    out = np.asarray(bfs_gemm.expand_frontier(jnp.array(r), jnp.array(m), block=4))
    assert set(np.unique(out)).issubset({0.0, 1.0})
