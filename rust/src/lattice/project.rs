//! Projections and lifts (Definition 7) and the cycle decomposition that
//! hierarchical routing exploits (Theorem 29 / Example 10).
//!
//! With `M ≅ [[B, c], [0, a]]` (its Hermite form), `G(M)` decomposes into
//! `a` disjoint copies of the projection `G(B)`, joined by
//! `|det M| / ord(e_n)` parallel cycles of length `ord(e_n)`; each cycle
//! intersects each copy in `ord(e_n) / a` vertices.

use crate::math::IMat;

use super::LatticeGraph;

/// The projection decomposition of a lattice graph over `e_n`.
#[derive(Clone, Debug)]
pub struct Projection {
    /// Projection generator `B` (the leading `(n-1) x (n-1)` Hermite block).
    pub b: IMat,
    /// The lift column `c` (top `n-1` entries of the last Hermite column).
    pub c: Vec<i64>,
    /// The side `a = H[n-1][n-1]`.
    pub side: i64,
    /// `ord(e_n)` — length of the cycles joining the copies.
    pub cycle_len: i64,
    /// Number of parallel cycles, `|det M| / ord(e_n)`.
    pub num_cycles: i64,
    /// Vertices of each cycle lying in one copy, `ord(e_n) / side`.
    pub intersections_per_copy: i64,
}

impl LatticeGraph {
    /// Project over the last generator `e_n` (Definition 7).
    pub fn project(&self) -> Projection {
        let n = self.dim();
        assert!(n >= 2, "cannot project a 1-dimensional lattice graph");
        let h = self.hermite();
        let b = h.leading(n - 1);
        let c: Vec<i64> = (0..n - 1).map(|i| h[(i, n - 1)]).collect();
        let side = h[(n - 1, n - 1)];
        let cycle_len = self.generator_order(n - 1);
        let det = self.order() as i64;
        Projection {
            b,
            c,
            side,
            cycle_len,
            num_cycles: det / cycle_len,
            intersections_per_copy: cycle_len / side,
        }
    }

    /// The projection as a lattice graph `G(B)`.
    pub fn projection_graph(&self) -> LatticeGraph {
        LatticeGraph::new(self.project().b)
    }

    /// Project over an arbitrary generator `e_i`: swap rows `i` and `n-1`
    /// (an automorphic relabelling) and project over `e_n`.
    pub fn project_over(&self, i: usize) -> LatticeGraph {
        let n = self.dim();
        assert!(i < n);
        let mut m = self.matrix().clone();
        m.swap_rows(i, n - 1);
        LatticeGraph::new(m).projection_graph()
    }

    /// Iteratively project over a set of generator axes (descending order
    /// internally so indices stay valid).
    pub fn project_over_set(&self, axes: &[usize]) -> LatticeGraph {
        let mut axes = axes.to_vec();
        axes.sort_unstable();
        axes.dedup();
        assert!(axes.iter().all(|&i| i < self.dim()));
        let mut g = self.clone();
        for &i in axes.iter().rev() {
            g = g.project_over(i);
        }
        g
    }

    /// Lift: build `G([[B, c], [0, a]])` from this graph's matrix as `B`.
    /// The result has `a` disjoint copies of `self` as projections.
    pub fn lift(&self, c: &[i64], a: i64) -> LatticeGraph {
        let n = self.dim();
        assert_eq!(c.len(), n);
        assert!(a > 0);
        let mut m = IMat::zeros(n + 1, n + 1);
        for i in 0..n {
            for j in 0..n {
                m[(i, j)] = self.matrix()[(i, j)];
            }
            m[(i, n)] = c[i];
        }
        m[(n, n)] = a;
        LatticeGraph::new(m)
    }

    /// Enumerate the cycle `v + <e_n>` through node `v` (as indices),
    /// in `+e_n` step order. Used by routing and the Figure 2 demo.
    pub fn cycle_through(&self, idx: usize) -> Vec<usize> {
        let n = self.dim();
        let len = self.generator_order(n - 1);
        let mut out = Vec::with_capacity(len as usize);
        let mut cur = idx;
        for _ in 0..len {
            out.push(cur);
            cur = self.step(cur, n - 1, 1);
        }
        debug_assert_eq!(cur, idx, "cycle did not close");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fcc(a: i64) -> LatticeGraph {
        LatticeGraph::new(IMat::from_rows(&[&[a, a, 0], &[a, 0, a], &[0, a, a]]))
    }

    fn bcc(a: i64) -> LatticeGraph {
        LatticeGraph::new(IMat::from_rows(&[&[-a, a, a], &[a, -a, a], &[a, a, -a]]))
    }

    #[test]
    fn pc_projection_is_2d_torus() {
        // Lemma 13: projection of PC(a) is T(a, a).
        let g = LatticeGraph::torus(&[5, 5, 5]);
        let p = g.projection_graph();
        assert!(p.right_equivalent(&LatticeGraph::torus(&[5, 5])));
    }

    #[test]
    fn fcc_projection_is_rtt() {
        // Lemma 14: projection of FCC(a) is RTT(a) = G([[2a, a], [0, a]]).
        for a in 2..5 {
            let p = fcc(a).projection_graph();
            let rtt = LatticeGraph::new(IMat::from_rows(&[&[2 * a, a], &[0, a]]));
            assert!(p.right_equivalent(&rtt), "a={a}");
        }
    }

    #[test]
    fn bcc_projection_is_2d_torus_2a() {
        // Lemma 16: projection of BCC(a) is T(2a, 2a).
        for a in 2..5 {
            let p = bcc(a).projection_graph();
            assert!(p.right_equivalent(&LatticeGraph::torus(&[2 * a, 2 * a])));
        }
    }

    #[test]
    fn example10_decomposition() {
        // Example 10: 4 copies of T(4,4) joined by cycles of length 8,
        // each intersecting each copy in 2 vertices.
        let g = LatticeGraph::new(IMat::from_rows(&[&[4, 0, 0], &[0, 4, 2], &[0, 0, 4]]));
        let p = g.project();
        assert_eq!(p.side, 4);
        assert_eq!(p.cycle_len, 8);
        assert_eq!(p.num_cycles, 8);
        assert_eq!(p.intersections_per_copy, 2);
        assert!(LatticeGraph::new(p.b).right_equivalent(&LatticeGraph::torus(&[4, 4])));
    }

    #[test]
    fn cycle_through_closes_and_has_right_length() {
        let g = fcc(3);
        let cyc = g.cycle_through(0);
        assert_eq!(cyc.len(), 6); // ord(e_3) = 2a
        // all distinct
        let mut sorted = cyc.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), cyc.len());
    }

    #[test]
    fn lift_then_project_roundtrip() {
        let base = LatticeGraph::torus(&[4, 4]);
        let lifted = base.lift(&[2, 2], 4);
        assert_eq!(lifted.order(), 64);
        let p = lifted.projection_graph();
        assert!(p.right_equivalent(&base));
    }

    #[test]
    fn projections_of_symmetric_graph_isomorphic() {
        // Theorem 11 on FCC(2): all three projections are RTT(2).
        let g = fcc(2);
        let p0 = g.project_over(0);
        let p1 = g.project_over(1);
        let p2 = g.project_over(2);
        assert!(p0.isomorphic_linear(&p1));
        assert!(p1.isomorphic_linear(&p2));
    }

    #[test]
    fn project_over_set_dimension() {
        let g = bcc(2);
        let p = g.project_over_set(&[1, 2]);
        assert_eq!(p.dim(), 1);
    }

    #[test]
    fn four_d_bcc_projection_is_pc2a() {
        // Proposition 17: projection of 4D-BCC(a) is PC(2a).
        for a in [1i64, 2] {
            let m = IMat::from_rows(&[
                &[2 * a, 0, 0, a],
                &[0, 2 * a, 0, a],
                &[0, 0, 2 * a, a],
                &[0, 0, 0, a],
            ]);
            let g = LatticeGraph::new(m);
            assert_eq!(g.order(), (8 * a * a * a * a) as usize);
            let p = g.projection_graph();
            assert!(p.right_equivalent(&LatticeGraph::torus(&[2 * a, 2 * a, 2 * a])));
        }
    }

    #[test]
    fn four_d_fcc_projection_is_fcc() {
        // Proposition 18: projection of 4D-FCC(a) is FCC(a).
        for a in [2i64, 3] {
            let m = IMat::from_rows(&[
                &[2 * a, a, a, a],
                &[0, a, 0, 0],
                &[0, 0, a, 0],
                &[0, 0, 0, a],
            ]);
            let g = LatticeGraph::new(m);
            assert_eq!(g.order(), (2 * a * a * a * a) as usize);
            let p = g.projection_graph();
            assert!(p.right_equivalent(&fcc(a)));
        }
    }
}
