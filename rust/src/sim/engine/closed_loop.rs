//! The closed-loop (finite-workload) run regime: a dependency-ordered
//! message set is packetized and injected as its dependencies complete,
//! with LogGP-style software overheads charged per message and per packet,
//! and the run lasts until the network drains — the application-level
//! regime behind the collective workload experiments.
//!
//! The per-cycle NIC packetizer visits only the worklist of nodes with a
//! dependency-satisfied message queued (under the default
//! [`ScanMode::ActiveSet`](crate::sim::ScanMode); the full-scan reference
//! path visits every node), in ascending node order — so a closed-loop
//! tail, where a handful of NICs feed a long dependency chain, costs
//! per-cycle work proportional to those NICs, not the network size. The
//! packetizer runs in the serial Phase A of the phased cycle driver
//! (`parallel.rs`); its route/VC draws come from each source's own
//! injection stream, so they are independent of scan mode and thread
//! count.
//!
//! Outcomes carry the same per-port utilization and link-balance spread
//! instrumentation as the open loop (computed over the run's actual cycle
//! window) plus per-VC phit counts, and every drained run is checked for
//! per-VC credit conservation (`assert_quiescent`): all buffer
//! reservations — escape-channel transfers included — must have been
//! returned by the time the workload completes, and both active sets
//! (arbitration nodes, NIC senders) must have emptied.

use std::collections::VecDeque;

use crate::sim::config::ScanMode;
use crate::sim::telemetry::StallCause;
use crate::workload::{Workload, WorkloadOutcome};

use super::state::{scan_active, ActiveSet, Event, State};
use super::Simulator;

impl Simulator {
    /// Run a closed-loop workload to completion with the config seed and a
    /// conservative cycle cap (see [`Workload::suggested_max_cycles_for`]).
    pub fn run_workload(&self, wl: &Workload) -> WorkloadOutcome {
        self.run_workload_seeded(wl, self.cfg.seed, wl.suggested_max_cycles_for(&self.cfg))
    }

    /// Closed-loop mode: inject the workload's messages as their
    /// dependencies complete, run until every message has been delivered
    /// (or `max_cycles` elapses), and report the completion time.
    ///
    /// Each message is packetized into `ceil(size_phits / packet_size)`
    /// packets. A message becomes *eligible* `send_overhead` cycles after
    /// all of its `deps` have completed; eligible messages wait in a
    /// per-source FIFO and the source NIC serializes one train at a time —
    /// successive packets enter the injection queue as capacity frees up,
    /// at least `packet_gap` cycles apart (the gap paces the NIC, so it
    /// also spaces the first packet of one train from the last packet of
    /// the previous train on the same node). A message *completes*
    /// (releasing its dependents) `recv_overhead` cycles after its **last**
    /// packet fully drains at the destination. Latency is measured per
    /// message, from first-packet injection-queue entry to completion.
    ///
    /// With `send_overhead = recv_overhead = packet_gap = 0` and every
    /// `size_phits <= packet_size`, the dynamics (and the RNG stream) are
    /// exactly the single-packet-per-message model.
    ///
    /// # Panics
    ///
    /// Panics with a diagnosable message if `wl` fails
    /// [`Workload::validate`] — a malformed dependency DAG is a modelling
    /// bug, never a slow network.
    pub fn run_workload_seeded(&self, wl: &Workload, seed: u64, max_cycles: u64) -> WorkloadOutcome {
        assert_eq!(
            wl.nodes, self.nodes,
            "workload was generated for order {} but the topology has {} nodes",
            wl.nodes, self.nodes
        );
        if let Err(e) = wl.validate() {
            panic!("malformed workload {:?}: {e}", wl.name);
        }
        // Degraded network: restrict the workload to pairs the fault set
        // left routable (dead endpoints, or pairs with no admissible
        // minimal record). Dependents of a dropped message inherit its
        // kept ancestors, so the surviving collective proceeds around the
        // dead participants instead of wedging — and the outcome's
        // message totals describe what actually ran. A pristine network
        // takes the borrow straight through, bit-identically.
        let wl_masked;
        let wl = if self.faults.is_some() {
            wl_masked = wl.mask_unroutable(|s, d| self.fault_routable(s as usize, d as usize));
            &wl_masked
        } else {
            wl
        };
        let cfg = &self.cfg;
        let ps = cfg.packet_size as u64;
        let (o_send, o_recv, gap) = (cfg.send_overhead, cfg.recv_overhead, cfg.packet_gap);
        let icap = cfg.injection_queue_packets as usize;
        let active_scan = cfg.scan_mode == ScanMode::ActiveSet;
        let total = wl.messages.len();
        // Measure everything: the whole run is the workload.
        let mut st = State::new(self, seed, 0, u64::MAX);

        // Dependency bookkeeping: dependents in CSR form plus per-message
        // outstanding-dependency counts.
        let mut remaining = vec![0u32; total];
        let mut dep_off = vec![0u32; total + 1];
        for m in &wl.messages {
            for &d in &m.deps {
                dep_off[d as usize + 1] += 1;
            }
        }
        for i in 0..total {
            dep_off[i + 1] += dep_off[i];
        }
        let mut dependents = vec![0u32; dep_off[total] as usize];
        let mut fill = dep_off.clone();
        for (i, m) in wl.messages.iter().enumerate() {
            remaining[i] = m.deps.len() as u32;
            for &d in &m.deps {
                dependents[fill[d as usize] as usize] = i as u32;
                fill[d as usize] += 1;
            }
        }

        // Per-message packetization state: packets still to drain, and the
        // cycle the first packet entered the injection queue (latency base).
        let mut pkts_left: Vec<u32> =
            wl.messages.iter().map(|m| m.packets(cfg.packet_size)).collect();
        let mut first_inject = vec![0u64; total];

        // Per-node NIC send queues: dependency-satisfied messages with
        // their earliest first-packet cycle (completion of deps + o_send).
        // Entries are pushed in nondecreasing ready order, so head-of-line
        // blocking on the ready time is exact, and the NIC serializes one
        // message train at a time. `senders` is the worklist of nodes with
        // a non-empty send queue (the packetizer's active set).
        let mut sendq: Vec<VecDeque<(u32, u64)>> = vec![VecDeque::new(); self.nodes];
        let mut senders = ActiveSet::new(self.nodes);
        for (i, m) in wl.messages.iter().enumerate() {
            if m.deps.is_empty() {
                sendq[m.src as usize].push_back((i as u32, o_send));
                senders.insert(m.src as usize);
            }
        }
        // Head-of-line train progress per node: packets already enqueued,
        // and the earliest cycle the next packet may enter (the LogGP gap).
        let mut head_sent = vec![0u32; self.nodes];
        let mut head_next = vec![0u64; self.nodes];

        // Messages whose last packet drained, waiting out o_recv. Deliver
        // events fire in nondecreasing cycle order and o_recv is constant,
        // so a FIFO stays time-sorted.
        let mut pending_done: VecDeque<(u64, u32)> = VecDeque::new();

        // Completion bookkeeping shared by the o_recv == 0 fast path and
        // the deferred path: record the message, release its dependents
        // (whose sources join the sender worklist).
        #[allow(clippy::too_many_arguments)]
        fn finish_message(
            mid: usize,
            t: u64,
            wl: &Workload,
            o_send: u64,
            dep_off: &[u32],
            dependents: &[u32],
            remaining: &mut [u32],
            sendq: &mut [VecDeque<(u32, u64)>],
            senders: &mut ActiveSet,
            first_inject: &[u64],
            st: &mut State,
            delivered_msgs: &mut usize,
            completion: &mut u64,
        ) {
            st.latency.record(t - first_inject[mid]);
            if let Some(tr) = st.trace.as_mut() {
                tr.msg_done(t, mid as u32, t - first_inject[mid]);
            }
            st.delivered_phits += wl.messages[mid].size_phits as u64;
            *delivered_msgs += 1;
            *completion = t;
            for k in dep_off[mid]..dep_off[mid + 1] {
                let dep = dependents[k as usize] as usize;
                remaining[dep] -= 1;
                if remaining[dep] == 0 {
                    let src = wl.messages[dep].src as usize;
                    sendq[src].push_back((dep as u32, t + o_send));
                    senders.insert(src);
                }
            }
        }

        // One NIC's packetizer turn: enqueue head-of-line packets while
        // injection capacity lasts, honoring the first-packet ready time
        // and the inter-packet gap. Returns whether the node still has
        // eligible messages queued (the sender-worklist keep criterion).
        // A node with an empty send queue returns `false` without drawing
        // RNG — the case the full scan skips.
        #[allow(clippy::too_many_arguments)]
        let packetize = |u: usize,
                         st: &mut State,
                         sendq: &mut [VecDeque<(u32, u64)>],
                         head_sent: &mut [u32],
                         head_next: &mut [u64],
                         first_inject: &mut [u64],
                         msg_of: &mut Vec<u32>,
                         scratch: &mut [i64],
                         now: u64| {
            while (st.inj[u].reserved as usize) < icap {
                let Some(&(mid, eligible)) = sendq[u].front() else { break };
                // The LogGP gap paces every packet the NIC emits, so
                // the first packet of a new train also waits out the
                // gap from the previous train's last packet.
                let ready =
                    if head_sent[u] == 0 { eligible.max(head_next[u]) } else { head_next[u] };
                if ready > now {
                    break;
                }
                let midx = mid as usize;
                let m = &wl.messages[midx];
                // Every masked-in message is admissible (the mask used the
                // same predicate the admission gate applies), so a `None`
                // here is a routability-oracle bug, not a fault artifact.
                let pid = self.new_packet(st, u, m.dst as usize, scratch).unwrap_or_else(|| {
                    panic!(
                        "workload message {midx} (node {u} -> {}) passed the routability \
                         mask but was rejected by the fault admission gate",
                        m.dst
                    )
                });
                if msg_of.len() < st.packets.len() {
                    msg_of.resize(st.packets.len(), 0);
                }
                msg_of[pid as usize] = mid;
                st.injected_packets += 1;
                if head_sent[u] == 0 {
                    first_inject[midx] = now;
                    if st.trace.is_some() {
                        let phits = m.size_phits as u64;
                        let packs = m.packets(self.cfg.packet_size) as u64;
                        let dst = m.dst as usize;
                        if let Some(tr) = st.trace.as_mut() {
                            tr.packetize(now, mid, u, dst, phits, packs);
                        }
                    }
                }
                head_sent[u] += 1;
                head_next[u] = now + gap;
                if head_sent[u] == m.packets(self.cfg.packet_size) {
                    sendq[u].pop_front();
                    head_sent[u] = 0;
                }
            }
            // A NIC cycle ending with send-queue work left over is the
            // closed-loop stall class: the network (full injection
            // queue), the LogGP pacing (gap/overheads) or plain train
            // serialization is holding messages back at the source.
            let backlog = !sendq[u].is_empty();
            if backlog {
                st.stalls.nic_serialization += 1;
                if let Some(tr) = st.trace.as_mut() {
                    tr.stall(now, u, -1, -1, StallCause::NicSerialization);
                }
            }
            backlog
        };

        // Message id per live packet (parallel to the packet arena).
        let mut msg_of: Vec<u32> = Vec::new();
        let mut delivered_msgs = 0usize;
        let mut completion = 0u64;
        let mut drained = total == 0;
        let mut scratch = vec![0i64; self.dim];
        // Periodic network-state probes, only with a trace open; the NIC
        // send backlog (messages queued behind the packetizer) is the
        // closed-loop-specific probe column.
        let sample_every = if st.trace.is_some() { cfg.sample_every } else { 0 };

        // Phase A of each cycle (serial): probe, event drain with
        // completion bookkeeping, termination checks, NIC packetization.
        // The phased driver then runs the sharded arbitration kernel.
        let mut now = 0u64;
        self.run_phased(&mut st, |st| {
            if drained || now == max_cycles {
                return false;
            }
            st.now = now;
            if sample_every > 0 && now % sample_every == 0 {
                let backlog: u64 = sendq.iter().map(|q| q.len() as u64).sum();
                self.sample_probe(st, backlog);
            }
            // Deferred events, with closed-loop delivery bookkeeping: the
            // last packet of a message completes it (possibly after the
            // receive overhead), which may make dependents eligible.
            let slot = (now % (ps + 2)) as usize;
            let events = std::mem::take(&mut st.calendar[slot]);
            for ev in events {
                match ev {
                    Event::FreeInput(fifo) => st.inputs[fifo as usize].release(),
                    Event::FreeInj(node) => st.inj[node as usize].release(),
                    Event::Deliver(pid) => {
                        st.delivered_packets += 1;
                        if st.trace.is_some() {
                            let node = st.dests[pid as usize] as usize;
                            let inj_t = st.packets[pid as usize].inject_time;
                            if let Some(tr) = st.trace.as_mut() {
                                tr.deliver(now, pid, node, inj_t);
                            }
                        }
                        let mid = msg_of[pid as usize] as usize;
                        pkts_left[mid] -= 1;
                        if pkts_left[mid] == 0 {
                            if o_recv == 0 {
                                finish_message(
                                    mid, now, wl, o_send, &dep_off, &dependents,
                                    &mut remaining, &mut sendq, &mut senders, &first_inject,
                                    st, &mut delivered_msgs, &mut completion,
                                );
                            } else {
                                pending_done.push_back((now + o_recv, mid as u32));
                            }
                        }
                        st.free_pids.push(pid);
                    }
                }
            }
            // Receive-overhead completions due this cycle.
            while let Some(&(t, mid)) = pending_done.front() {
                if t > now {
                    break;
                }
                pending_done.pop_front();
                finish_message(
                    mid as usize, t, wl, o_send, &dep_off, &dependents,
                    &mut remaining, &mut sendq, &mut senders, &first_inject,
                    st, &mut delivered_msgs, &mut completion,
                );
            }
            if delivered_msgs == total {
                drained = true;
                return false;
            }
            // Closed-loop injection: each NIC with queued eligible
            // messages packetizes its head-of-line train. The sender
            // worklist is visited in ascending node order (compacting out
            // emptied queues in place), so `new_packet`'s route/VC draws
            // happen in exactly the full-scan order.
            if active_scan {
                scan_active!(senders, |u| packetize(
                    u,
                    st,
                    &mut sendq,
                    &mut head_sent,
                    &mut head_next,
                    &mut first_inject,
                    &mut msg_of,
                    &mut scratch,
                    now,
                ));
            } else {
                for u in 0..self.nodes {
                    packetize(
                        u, st, &mut sendq, &mut head_sent, &mut head_next,
                        &mut first_inject, &mut msg_of, &mut scratch, now,
                    );
                }
            }
            now += 1;
            true
        });

        if drained {
            // A fully drained run must have returned every buffer credit
            // on every VC — the escape path in particular must not leak
            // reservations — and the arbitration worklist must be empty
            // (see `assert_quiescent`). The NIC sender worklist must have
            // emptied too: a drained workload has no message left to send.
            self.assert_quiescent(&st);
            if active_scan {
                assert!(
                    senders.is_empty(),
                    "NIC sender set not empty after drain: {} listed, {} pending",
                    senders.list.len(),
                    senders.pending.len()
                );
            }
        }
        if let Some(tr) = st.trace.as_mut() {
            tr.flush();
        }
        // Balance instrumentation over the cycles the run actually used
        // (the whole run is the measurement window in closed-loop mode).
        let window = if drained { completion } else { max_cycles };
        let (port_utilization, link_util_spread) = self.port_stats(&st, window);
        let rng_digest = st.rng_digest();
        let (_, rng_draws) = st.node_stream_fingerprint();
        WorkloadOutcome {
            completion_cycles: window,
            drained,
            delivered_messages: delivered_msgs as u64,
            total_messages: total as u64,
            delivered_phits: st.delivered_phits,
            delivered_packets: st.delivered_packets,
            avg_latency: st.latency.mean(),
            p50_latency: st.latency.percentile(0.5),
            p90_latency: st.latency.percentile(0.9),
            p99_latency: st.latency.percentile(0.99),
            p999_latency: st.latency.percentile(0.999),
            max_latency: st.latency.max(),
            stalls: st.stalls,
            port_utilization,
            link_util_spread,
            vc_phits: st.phits_by_vc,
            nodes: self.nodes,
            rng_digest,
            rng_draws,
            engine: st.profile,
        }
    }
}
