//! Shared immutable topology artifacts.
//!
//! Everything the engine derives from a lattice graph alone — the flat
//! neighbor table, the flattened labels, and the compact routing store —
//! is pure topology: no `SimConfig` knob reaches it. [`TopologyArtifacts`]
//! bundles those tables behind an `Arc` so one build serves every
//! simulator sharing the graph: a load sweep's points and seeds, an
//! experiment's policy × VC × load grid, and the workload runner's seed
//! fan-out all construct `Simulator`s against the same bundle instead of
//! re-deriving the tables per run (previously the dominant setup cost —
//! one full hierarchical routing walk per simulator).
//!
//! Per-config state stays out of the bundle by design: the serialization
//! vector depends on `SimConfig::axis_widths` and the fault set on the
//! config's fault knobs, so both remain per-`Simulator` (the ablation
//! drivers vary them across simulators sharing one bundle).
//!
//! The bundle is deterministic: the parallel shards are fixed-size node
//! chunks stitched in order, so the tables are byte-identical for every
//! `threads` value (and, via the dispatch routers' record-for-record tie
//! equality, identical to the legacy serial `RoutingTable` path).

use std::sync::Arc;

use crate::lattice::LatticeGraph;
use crate::routing::{CompactRoutes, RoutingTable, MAX_DIM};
use crate::util::pool::par_map;

/// Nodes per parallel shard for the neighbor/label build (fixed so the
/// stitched output is thread-count invariant).
const CHUNK: usize = 4096;

/// Immutable per-topology tables shared across simulators via `Arc`.
pub struct TopologyArtifacts {
    g: LatticeGraph,
    dim: usize,
    ports: usize,
    nodes: usize,
    /// `neighbor[u * ports + p]`: node reached from `u` via port `p`
    /// (`p = 2*axis + (sign < 0)`).
    pub(crate) neighbor: Vec<u32>,
    /// Flattened labels, `dim` entries per node.
    pub(crate) labels: Vec<i64>,
    /// Compact CSR tie sets per difference index.
    pub(crate) routes: CompactRoutes,
}

impl TopologyArtifacts {
    /// Build with the dispatched closed-form router (hierarchical
    /// off-catalog) over `threads` workers (`1` = serial, `0` = one per
    /// core).
    pub fn build(g: LatticeGraph, threads: usize) -> Arc<Self> {
        let routes = CompactRoutes::build(&g, threads);
        Self::assemble(g, routes, threads)
    }

    /// Build from a prebuilt routing table (must belong to the same
    /// graph) — the explicit-router path used by router comparisons.
    pub fn from_table(g: LatticeGraph, table: &RoutingTable) -> Arc<Self> {
        let routes = CompactRoutes::from_table(table);
        Self::assemble(g, routes, 1)
    }

    fn assemble(g: LatticeGraph, routes: CompactRoutes, threads: usize) -> Arc<Self> {
        let dim = g.dim();
        assert!(dim <= MAX_DIM, "dimension {dim} exceeds MAX_DIM");
        let nodes = g.order();
        let ports = 2 * dim;
        assert_eq!(routes.len(), nodes, "routing store does not match the graph");
        let chunks = nodes.div_ceil(CHUNK).max(1);
        let parts: Vec<(Vec<u32>, Vec<i64>)> = par_map(chunks, threads, |c| {
            let lo = c * CHUNK;
            let hi = ((c + 1) * CHUNK).min(nodes);
            let mut nb = vec![0u32; (hi - lo) * ports];
            let mut lb = vec![0i64; (hi - lo) * dim];
            let mut tmp = vec![0i64; dim];
            for u in lo..hi {
                let label = g.label_of(u);
                lb[(u - lo) * dim..(u - lo + 1) * dim].copy_from_slice(&label);
                for axis in 0..dim {
                    for (s, sign) in [(0usize, 1i64), (1, -1)] {
                        tmp.copy_from_slice(&label);
                        tmp[axis] += sign;
                        g.reduce_in_place(&mut tmp);
                        nb[(u - lo) * ports + 2 * axis + s] = g.index_of(&tmp) as u32;
                    }
                }
            }
            (nb, lb)
        });
        let mut neighbor = Vec::with_capacity(nodes * ports);
        let mut labels = Vec::with_capacity(nodes * dim);
        for (nb, lb) in parts {
            neighbor.extend_from_slice(&nb);
            labels.extend_from_slice(&lb);
        }
        Arc::new(Self { g, dim, ports, nodes, neighbor, labels, routes })
    }

    /// The lattice graph the tables were derived from.
    pub fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Ports per node (`2 * dim`).
    pub fn ports(&self) -> usize {
        self.ports
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// The flat neighbor table (`nodes * ports` entries).
    pub fn neighbor_table(&self) -> &[u32] {
        &self.neighbor
    }

    /// The compact routing store.
    pub fn routes(&self) -> &CompactRoutes {
        &self.routes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, torus};

    #[test]
    fn neighbor_table_matches_graph_steps() {
        for g in [torus(&[5, 4]), bcc(2)] {
            let art = TopologyArtifacts::build(g.clone(), 2);
            assert_eq!(art.nodes(), g.order());
            assert_eq!(art.ports(), 2 * g.dim());
            for u in 0..g.order() {
                assert_eq!(
                    &art.labels[u * art.dim..(u + 1) * art.dim],
                    g.label_of(u).as_slice()
                );
                for axis in 0..g.dim() {
                    for (s, sign) in [(0usize, 1i64), (1, -1)] {
                        assert_eq!(
                            art.neighbor[u * art.ports + 2 * axis + s] as usize,
                            g.step(u, axis, sign),
                            "node {u} axis {axis} sign {sign}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn build_is_thread_count_invariant() {
        let g = torus(&[6, 6, 3]);
        let a1 = TopologyArtifacts::build(g.clone(), 1);
        let a4 = TopologyArtifacts::build(g, 4);
        assert_eq!(a1.neighbor, a4.neighbor);
        assert_eq!(a1.labels, a4.labels);
        assert_eq!(a1.routes.total_records(), a4.routes.total_records());
    }
}
