//! Minimal CLI argument parser (offline build — no clap).
//!
//! Model: `prog <subcommand> [positionals] [--key value | --flag]`.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positionals: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

/// Option keys that take a value (everything else after `--` is a flag).
const VALUED: &[&str] = &[
    "traffic", "load", "loads", "seeds", "cycles", "warmup", "kind", "out",
    "max-dim", "a", "config", "workers", "sizes", "set", "topology",
    "workload", "iters", "max-cycles", "hot", "msg-phits", "send-overhead",
    "recv-overhead", "packet-gap", "route-policy", "link-latency",
    "axis-widths", "num-vcs", "scan-mode", "trace", "sample-every",
    "threads", "serial-cutoff", "fault-links", "fault-nodes",
    "link-fault-rate", "node-fault-rate", "rates",
];

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter();
        let Some(sub) = it.next() else {
            bail!("missing subcommand; try `help`");
        };
        out.subcommand = sub;
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if VALUED.contains(&key) {
                    let Some(v) = it.next() else {
                        bail!("option --{key} needs a value");
                    };
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str) -> Result<Option<f64>> {
        self.opt(name)
            .map(|v| v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad --{name} {v:?}")))
            .transpose()
    }

    pub fn opt_usize(&self, name: &str) -> Result<Option<usize>> {
        self.opt(name)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow::anyhow!("bad --{name} {v:?}")))
            .transpose()
    }

    /// Parse a comma-separated list of positive integers, e.g.
    /// `--msg-phits 16,256,4096` (a single value is a one-element list).
    pub fn opt_u32s(&self, name: &str) -> Result<Option<Vec<u32>>> {
        let Some(v) = self.opt(name) else { return Ok(None) };
        let parsed: Result<Vec<u32>, _> = v.split(',').map(str::trim).map(str::parse).collect();
        let xs = parsed.map_err(|_| anyhow::anyhow!("bad --{name} {v:?} (want ints like 16,256)"))?;
        if xs.is_empty() || xs.contains(&0) {
            bail!("--{name} values must be positive");
        }
        Ok(Some(xs))
    }

    /// Parse a comma-separated list of floats, e.g. `--rates 0.02,0.1`.
    pub fn opt_f64s(&self, name: &str) -> Result<Option<Vec<f64>>> {
        let Some(v) = self.opt(name) else { return Ok(None) };
        let parsed: Result<Vec<f64>, _> = v.split(',').map(str::trim).map(str::parse).collect();
        let xs =
            parsed.map_err(|_| anyhow::anyhow!("bad --{name} {v:?} (want floats like 0.02,0.1)"))?;
        if xs.is_empty() {
            bail!("--{name} needs at least one value");
        }
        Ok(Some(xs))
    }

    /// Parse `--loads 0.1:1.0:0.1` (from:to:step) or `0.1,0.2,0.5`.
    pub fn opt_loads(&self) -> Result<Option<Vec<f64>>> {
        let Some(v) = self.opt("loads") else { return Ok(None) };
        if v.contains(':') {
            let parts: Vec<&str> = v.split(':').collect();
            if parts.len() != 3 {
                bail!("--loads range must be from:to:step");
            }
            let (from, to, step): (f64, f64, f64) =
                (parts[0].parse()?, parts[1].parse()?, parts[2].parse()?);
            if step <= 0.0 || to < from {
                bail!("bad --loads range {v:?}");
            }
            let mut out = Vec::new();
            let mut l = from;
            while l <= to + 1e-9 {
                out.push((l * 1e9).round() / 1e9);
                l += step;
            }
            Ok(Some(out))
        } else {
            let loads: Result<Vec<f64>, _> = v.split(',').map(str::parse).collect();
            Ok(Some(loads.map_err(|_| anyhow::anyhow!("bad --loads {v:?}"))?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_shape() {
        let a = parse("sim fcc:4 --traffic uniform --load 0.5 --full");
        assert_eq!(a.subcommand, "sim");
        assert_eq!(a.positionals, vec!["fcc:4"]);
        assert_eq!(a.opt("traffic"), Some("uniform"));
        assert_eq!(a.opt_f64("load").unwrap(), Some(0.5));
        assert!(a.flag("full"));
        assert!(!a.flag("csv"));
    }

    #[test]
    fn workload_options() {
        let a = parse("workload --topology fcc:4 --workload alltoall --iters 4 --max-cycles 9000");
        assert_eq!(a.subcommand, "workload");
        assert!(a.positionals.is_empty());
        assert_eq!(a.opt("topology"), Some("fcc:4"));
        assert_eq!(a.opt("workload"), Some("alltoall"));
        assert_eq!(a.opt_usize("iters").unwrap(), Some(4));
        assert_eq!(a.opt_usize("max-cycles").unwrap(), Some(9000));
    }

    #[test]
    fn msg_phits_list() {
        let a = parse("workload --topology fcc:4 --msg-phits 16,256,4096 --send-overhead 10");
        assert_eq!(a.opt_u32s("msg-phits").unwrap(), Some(vec![16, 256, 4096]));
        assert_eq!(a.opt_usize("send-overhead").unwrap(), Some(10));
        let single = parse("workload --msg-phits 64");
        assert_eq!(single.opt_u32s("msg-phits").unwrap(), Some(vec![64]));
        assert_eq!(single.opt_u32s("packet-gap").unwrap(), None);
        assert!(parse("workload --msg-phits 16,0").opt_u32s("msg-phits").is_err());
        assert!(parse("workload --msg-phits nope").opt_u32s("msg-phits").is_err());
    }

    #[test]
    fn routing_and_link_options_are_valued() {
        let a = parse(
            "sim fcc:4 --route-policy adaptive --link-latency 3 --axis-widths 2,1,1 --num-vcs 2",
        );
        assert_eq!(a.opt("route-policy"), Some("adaptive"));
        assert_eq!(a.opt_usize("link-latency").unwrap(), Some(3));
        assert_eq!(a.opt_u32s("axis-widths").unwrap(), Some(vec![2, 1, 1]));
        assert_eq!(a.opt_u32s("num-vcs").unwrap(), Some(vec![2]));
        assert!(a.positionals == vec!["fcc:4"], "values must not leak into positionals");
        assert!(parse("sim x --axis-widths 2,0").opt_u32s("axis-widths").is_err());
        // The policies experiment sweeps a comma list; zero VCs is invalid.
        assert_eq!(parse("sim x --num-vcs 1,2").opt_u32s("num-vcs").unwrap(), Some(vec![1, 2]));
        assert!(parse("sim x --num-vcs 0").opt_u32s("num-vcs").is_err());
    }

    /// Regression: `scan-mode` was missing from `VALUED`, so
    /// `--scan-mode full` silently parsed as a flag plus a stray
    /// positional and the option never reached the engine. The telemetry
    /// options ride the same contract.
    #[test]
    fn scan_mode_and_telemetry_options_are_valued() {
        let a = parse(
            "sim fcc:4 --scan-mode full --trace /tmp/t.jsonl --sample-every 100 --threads 4",
        );
        assert_eq!(a.opt("scan-mode"), Some("full"));
        assert_eq!(a.opt("trace"), Some("/tmp/t.jsonl"));
        assert_eq!(a.opt_usize("sample-every").unwrap(), Some(100));
        assert_eq!(a.opt_usize("threads").unwrap(), Some(4));
        assert_eq!(a.positionals, vec!["fcc:4"], "values must not leak into positionals");
        assert!(!a.flag("scan-mode"));
        assert!(!a.flag("threads"));
    }

    /// The fault knobs ride the `VALUED` contract like `scan-mode` does:
    /// a spec that silently parsed as a flag would run a *pristine*
    /// network while claiming to inject faults.
    #[test]
    fn fault_options_are_valued() {
        let a = parse(
            "sim fcc:4 --fault-links 0-1,4-12 --fault-nodes 3,9 \
             --link-fault-rate 0.05 --node-fault-rate 0.01 --rates 0.02,0.1",
        );
        assert_eq!(a.opt("fault-links"), Some("0-1,4-12"));
        assert_eq!(a.opt("fault-nodes"), Some("3,9"));
        assert_eq!(a.opt_f64("link-fault-rate").unwrap(), Some(0.05));
        assert_eq!(a.opt_f64("node-fault-rate").unwrap(), Some(0.01));
        assert_eq!(a.opt_f64s("rates").unwrap(), Some(vec![0.02, 0.1]));
        assert_eq!(a.positionals, vec!["fcc:4"], "values must not leak into positionals");
        assert!(!a.flag("fault-links"));
        assert!(parse("sim x --rates nope").opt_f64s("rates").is_err());
    }

    #[test]
    fn loads_range() {
        let a = parse("sweep pc:4 --loads 0.1:0.3:0.1");
        assert_eq!(a.opt_loads().unwrap().unwrap(), vec![0.1, 0.2, 0.3]);
        let b = parse("sweep pc:4 --loads 0.25,0.75");
        assert_eq!(b.opt_loads().unwrap().unwrap(), vec![0.25, 0.75]);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(Vec::<String>::new()).is_err());
        assert!(Args::parse(vec!["sim".into(), "--load".into()]).is_err());
        let a = parse("sweep x --loads 0.5:0.1:0.1");
        assert!(a.opt_loads().is_err());
    }
}
