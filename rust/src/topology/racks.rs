//! Physical organization (§6.1): rack packaging of lattice networks.
//!
//! The paper's observation: manufacturers split each dimension between
//! "inside the rack" and "across racks" (Cray's T(25,32,16) as a
//! 25 × 8 × 1 grid of 1 × 4 × 16-node racks), and for lattice graphs the
//! same packaging works — 2D projections live inside racks and the
//! remaining dimensions become inter-rack cabling whose offsets realize
//! the twist columns. This module computes the cabling consequences of a
//! rack shape for any lattice graph.

use crate::lattice::LatticeGraph;

/// A rack shape: how many label units of each dimension live in one rack.
#[derive(Clone, Debug)]
pub struct RackLayout {
    /// Nodes per rack along each graph dimension (must divide the
    /// labelling box side of that dimension).
    pub rack_dims: Vec<i64>,
}

/// Packaging statistics for a (graph, layout) pair.
#[derive(Clone, Debug, PartialEq)]
pub struct RackStats {
    /// Number of racks.
    pub racks: usize,
    /// Nodes per rack.
    pub nodes_per_rack: usize,
    /// Undirected links fully inside some rack.
    pub internal_links: usize,
    /// Undirected links between racks (cables).
    pub external_cables: usize,
    /// Fraction of links internal (cheap backplane vs cables).
    pub internal_fraction: f64,
}

impl RackLayout {
    pub fn new(rack_dims: &[i64]) -> Self {
        assert!(rack_dims.iter().all(|&d| d >= 1));
        Self { rack_dims: rack_dims.to_vec() }
    }

    /// Rack id of a node (mixed-radix over rack-grid coordinates).
    pub fn rack_of(&self, g: &LatticeGraph, idx: usize) -> usize {
        let label = g.label_of(idx);
        let mut rack = 0usize;
        for (i, (&x, &rd)) in label.iter().zip(&self.rack_dims).enumerate() {
            let grid = (g.box_sides()[i] / rd) as usize;
            rack = rack * grid + (x / rd) as usize;
        }
        rack
    }

    /// Compute packaging statistics.
    pub fn stats(&self, g: &LatticeGraph) -> RackStats {
        let n = g.dim();
        assert_eq!(self.rack_dims.len(), n, "layout dims != graph dims");
        for (i, &rd) in self.rack_dims.iter().enumerate() {
            assert_eq!(
                g.box_sides()[i] % rd,
                0,
                "rack dim {rd} does not divide box side {}",
                g.box_sides()[i]
            );
        }
        let nodes_per_rack: i64 = self.rack_dims.iter().product();
        let racks = g.order() / nodes_per_rack as usize;
        let mut internal = 0usize;
        let mut external = 0usize;
        for (u, v) in g.edges() {
            if self.rack_of(g, u) == self.rack_of(g, v) {
                internal += 1;
            } else {
                external += 1;
            }
        }
        let total = internal + external;
        RackStats {
            racks,
            nodes_per_rack: nodes_per_rack as usize,
            internal_links: internal,
            external_cables: external,
            internal_fraction: internal as f64 / total as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, torus};

    #[test]
    fn cray_style_packaging() {
        // Scaled Cray example: T(5,8,4) in racks of 1x4x4.
        let g = torus(&[5, 8, 4]);
        let layout = RackLayout::new(&[1, 4, 4]);
        let s = layout.stats(&g);
        assert_eq!(s.nodes_per_rack, 16);
        assert_eq!(s.racks, 10);
        assert_eq!(s.internal_links + s.external_cables, g.edges().len());
        assert!(s.internal_fraction > 0.3);
    }

    #[test]
    fn whole_machine_one_rack() {
        let g = torus(&[4, 4]);
        let layout = RackLayout::new(&[4, 4]);
        let s = layout.stats(&g);
        assert_eq!(s.racks, 1);
        assert_eq!(s.external_cables, 0);
        assert_eq!(s.internal_fraction, 1.0);
    }

    #[test]
    fn single_node_racks_all_external() {
        let g = torus(&[4, 4]);
        let layout = RackLayout::new(&[1, 1]);
        let s = layout.stats(&g);
        assert_eq!(s.racks, 16);
        assert_eq!(s.internal_links, 0);
    }

    #[test]
    fn crystal_packaging_projection_in_rack() {
        // §6.1: pack the 2D projection inside racks — FCC(2) box is
        // (4, 2, 2); put each (x-row, y) plane slice into a rack.
        let g = fcc(2);
        let layout = RackLayout::new(&[4, 2, 1]);
        let s = layout.stats(&g);
        assert_eq!(s.nodes_per_rack, 8);
        assert_eq!(s.racks, 2);
        assert!(s.internal_fraction > 0.4, "{s:?}");
    }

    #[test]
    fn bcc_rackable_like_a_torus() {
        // The twist lives in the wrap offsets, not in rack count.
        let g = bcc(2);
        let layout = RackLayout::new(&[4, 4, 1]);
        let s = layout.stats(&g);
        assert_eq!(s.racks, 2);
        let gt = torus(&[4, 4, 2]);
        let st = RackLayout::new(&[4, 4, 1]).stats(&gt);
        assert_eq!(s.racks, st.racks);
        assert_eq!(s.nodes_per_rack, st.nodes_per_rack);
    }

    #[test]
    #[should_panic(expected = "does not divide")]
    fn indivisible_layout_rejected() {
        let g = torus(&[5, 4]);
        RackLayout::new(&[2, 4]).stats(&g);
    }
}
