//! Lattice graphs `G(M)` — the paper's algebraic core (Section 2).
//!
//! A lattice graph is the Cayley graph of `Z^n / M Z^n` with the
//! orthonormal generator set `{±e_1, ..., ±e_n}`: nodes are integer
//! vectors modulo the column span of a non-singular `M`, and `v ~ w` iff
//! `v - w ≡ ±e_i (mod M)`. Tori, twisted tori, and all the crystal
//! networks of Section 3 are instances.
//!
//! Submodules:
//! - [`graph`]: the [`LatticeGraph`] type — labelling (Hermite box,
//!   Definition 26), canonical reduction, adjacency, element orders.
//! - [`project`]: projections and lifts (Definition 7) and the cycle
//!   structure joining projection copies (Example 10 / Figure 2).
//! - [`common_lift`]: the `⊞` common-lift operator (Theorem 24).
//! - [`symmetry`]: signed permutations, the `PM = MQ` automorphism test
//!   (Lemma 36) and the linear-symmetry test (Definition 37), plus the
//!   Theorem 12 / Theorem 47 classifier families.

pub mod common_lift;
pub mod graph;
pub mod partition;
pub mod project;
pub mod symmetry;

pub use common_lift::common_lift;
pub use graph::LatticeGraph;
pub use partition::Partition;
pub use project::Projection;
pub use symmetry::{is_linearly_symmetric, signed_permutations, SignedPerm};
