//! Compact CSR routing store — the topology plane's primary record
//! representation.
//!
//! `CompactRoutes` keeps every difference label's minimal tie set
//! (Remark 30) as fixed-width `[i16; MAX_DIM]` records behind a CSR
//! offset array: `ties(diff_idx)` is one slice borrow on the injection
//! hot path, and the whole store is two flat allocations — no
//! per-difference `Vec<Vec<i64>>` boxes. It used to be an engine-private
//! compaction of a fully materialized [`RoutingTable`]; now it is built
//! *directly* from a router, sharded over [`par_map`], so simulator
//! construction never materializes the boxed table at all.
//!
//! Determinism: the parallel build shards the node range into
//! fixed-size chunks and stitches the ordered per-chunk results, so the
//! store is byte-identical for every worker count — and because the
//! dispatch routers emit tie sets record-for-record equal to the
//! hierarchical builder's (see [`super::dispatch`]), it is also
//! byte-identical to the legacy serial `RoutingTable` path.

use crate::lattice::LatticeGraph;
use crate::util::pool::par_map;

use super::dispatch::DispatchRouter;
use super::table::RoutingTable;
use super::{Record, Router, MAX_DIM};

/// Nodes per parallel build shard. Fixed (not derived from the worker
/// count) so the chunk boundaries — and therefore the stitched output —
/// are identical for every `threads` value.
const CHUNK: usize = 4096;

/// Compact routing store: tie sets of i16 records per difference index.
pub struct CompactRoutes {
    offsets: Vec<u32>,
    records: Vec<[i16; MAX_DIM]>,
}

impl CompactRoutes {
    /// Build directly from the best closed-form router for `g` (falling
    /// back to the hierarchical router off-catalog), sharded over
    /// `threads` workers (`1` = serial, `0` = one per core).
    pub fn build(g: &LatticeGraph, threads: usize) -> Self {
        Self::build_with(g, &DispatchRouter::new(g), threads)
    }

    /// Build from an explicit router over fixed-size node shards.
    pub fn build_with<R: Router + Sync>(g: &LatticeGraph, router: &R, threads: usize) -> Self {
        let dim = g.dim();
        assert!(dim <= MAX_DIM, "dimension {dim} exceeds MAX_DIM");
        let n = g.order();
        let zero = vec![0i64; dim];
        let chunks = n.div_ceil(CHUNK).max(1);
        let parts: Vec<(Vec<u32>, Vec<[i16; MAX_DIM]>)> = par_map(chunks, threads, |c| {
            let lo = c * CHUNK;
            let hi = ((c + 1) * CHUNK).min(n);
            let mut counts = Vec::with_capacity(hi - lo);
            let mut recs = Vec::with_capacity((hi - lo) * 2);
            for v in lo..hi {
                let ties = router.route_ties(&zero, &g.label_of(v));
                debug_assert!(!ties.is_empty());
                counts.push(ties.len() as u32);
                for tie in &ties {
                    recs.push(compact(tie));
                }
            }
            (counts, recs)
        });
        let total: usize = parts.iter().map(|(_, r)| r.len()).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut records = Vec::with_capacity(total);
        offsets.push(0u32);
        let mut acc = 0u32;
        for (counts, recs) in parts {
            for c in counts {
                acc += c;
                offsets.push(acc);
            }
            records.extend_from_slice(&recs);
        }
        Self { offsets, records }
    }

    /// Compact a fully materialized routing table (the legacy path; kept
    /// as the serial reference twin the `table_build` bench and the
    /// dispatch differential compare against).
    pub fn from_table(table: &RoutingTable) -> Self {
        let g = table.graph();
        assert!(g.dim() <= MAX_DIM, "dimension {} exceeds MAX_DIM", g.dim());
        let n = g.order();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut records = Vec::new();
        offsets.push(0u32);
        for v in 0..n {
            // tie set for difference = label(v) (src = 0)
            for tie in table.ties_by_diff(v) {
                records.push(compact(tie));
            }
            offsets.push(records.len() as u32);
        }
        Self { offsets, records }
    }

    /// Tie set for a reduced difference index.
    #[inline]
    pub fn ties(&self, diff_idx: usize) -> &[[i16; MAX_DIM]] {
        &self.records[self.offsets[diff_idx] as usize..self.offsets[diff_idx + 1] as usize]
    }

    /// Number of difference entries (= graph order).
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total records stored across all tie sets.
    pub fn total_records(&self) -> usize {
        self.records.len()
    }

    /// Store footprint in bytes (offsets + records), the `table_build`
    /// bench's bytes/node numerator.
    pub fn bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<u32>()
            + self.records.len() * std::mem::size_of::<[i16; MAX_DIM]>()
    }
}

fn compact(r: &Record) -> [i16; MAX_DIM] {
    let mut out = [0i16; MAX_DIM];
    for (i, &x) in r.iter().enumerate() {
        out[i] = i16::try_from(x).expect("hop count exceeds i16");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc_nd, rtt, torus};

    fn assert_same(a: &CompactRoutes, b: &CompactRoutes, tag: &str) {
        assert_eq!(a.offsets, b.offsets, "{tag}: offsets differ");
        assert_eq!(a.records, b.records, "{tag}: records differ");
    }

    #[test]
    fn direct_build_matches_table_compaction() {
        for (tag, g) in [
            ("T(5,4)", torus(&[5, 4])),
            ("T(3,3,3)", torus(&[3, 3, 3])),
            ("BCC(2)", bcc(2)),
            ("RTT(3)", rtt(3)),
            ("4D-FCC(2)", fcc_nd(4, 2)),
        ] {
            let table = RoutingTable::build_hierarchical(&g);
            let legacy = CompactRoutes::from_table(&table);
            let direct = CompactRoutes::build(&g, 1);
            assert_same(&legacy, &direct, tag);
        }
    }

    #[test]
    fn parallel_build_is_thread_count_invariant() {
        let g = torus(&[6, 5, 4]);
        let serial = CompactRoutes::build(&g, 1);
        for threads in [2, 3, 4, 8] {
            let par = CompactRoutes::build(&g, threads);
            assert_same(&serial, &par, &format!("threads={threads}"));
        }
    }

    #[test]
    fn csr_accounting_is_consistent() {
        let g = bcc(2);
        let c = CompactRoutes::build(&g, 2);
        assert_eq!(c.len(), g.order());
        let total: usize = (0..c.len()).map(|v| c.ties(v).len()).sum();
        assert_eq!(total, c.total_records());
        assert!(c.bytes() >= c.total_records() * std::mem::size_of::<[i16; MAX_DIM]>());
        // the zero difference routes with the single empty record
        assert_eq!(c.ties(0), &[[0i16; MAX_DIM]]);
    }
}
