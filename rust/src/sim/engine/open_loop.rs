//! The open-loop (steady-state) run regime: Bernoulli injection at a
//! fixed offered load over a warmup/measure/drain window — the regime
//! behind the paper's Figures 5–8 — plus the end-of-run statistics
//! (throughput, latency, and the per-axis / per-port link utilization
//! that makes routing-policy balance measurable).
//!
//! The Bernoulli process is realized as an *arrival calendar*: instead of
//! one `chance` draw per node per cycle, each node draws the geometric
//! gap to its next arrival ([`geometric_gap`]) and sits in a min-heap
//! keyed `(cycle, node)` until then. The two formulations induce the
//! identical per-cycle law, but the calendar consumes RNG state only at
//! arrivals — so idle (or lightly loaded) nodes cost nothing per cycle,
//! matching the activity-proportional arbitration scan, and the stream is
//! independent of scan mode and thread count by construction.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::stats::SimResult;
use crate::sim::traffic::Traffic;

use super::injection::geometric_gap;
use super::state::State;
use super::Simulator;

/// Destination redraw budget per degraded-mode arrival: how many times a
/// source re-draws before writing the arrival off as source-dropped. Dead
/// or unreachable destinations are rare at realistic fault rates (the
/// redraw fires at probability ≈ the dead-node fraction), so 16 makes a
/// wasted arrival vanishingly unlikely while bounding the work — and the
/// draw count stays a pure function of the node's own stream, preserving
/// scan-mode and thread invariance.
const FAULT_REDRAWS: usize = 16;

impl Simulator {
    /// Run one simulation at `offered_load` phits/(cycle·node).
    pub fn run(&self, offered_load: f64) -> SimResult {
        self.run_seeded(offered_load, self.cfg.seed)
    }

    /// Run with an explicit RNG seed (multi-seed averaging reuses the
    /// simulator's routing tables across runs).
    pub fn run_seeded(&self, offered_load: f64, seed: u64) -> SimResult {
        let cfg = &self.cfg;
        let mut st = State::new(
            self,
            seed ^ (offered_load.to_bits().rotate_left(17)),
            cfg.warmup_cycles,
            cfg.warmup_cycles + cfg.measure_cycles,
        );
        let traffic = Traffic::build_with_faults(
            self.pattern,
            self.art.graph(),
            &mut st.rng,
            self.faults.as_deref().map(|f| f.node_dead_mask()),
        );
        let inject_prob = offered_load / cfg.packet_size as f64;
        // Injection stops when the measurement window closes; the drain
        // cycles only let in-flight packets finish so their latencies are
        // recorded (see `apply_events`).
        let inject_until = cfg.warmup_cycles + cfg.measure_cycles;
        let total = inject_until + cfg.drain_cycles;
        let cap = cfg.injection_queue_packets;

        let mut scratch = vec![0i64; self.dim];
        // Arrival calendar: min-heap of (cycle, node). Popping in
        // ascending order visits same-cycle arrivals in node order —
        // the order the per-node `chance` loop drew in.
        let mut arrivals: BinaryHeap<Reverse<(u64, u32)>> = BinaryHeap::new();
        for u in 0..self.nodes {
            // A dead node sources nothing — it never even enters the
            // calendar, so (like an idle node) it consumes zero RNG
            // state and the live nodes' streams are untouched by it.
            if self.faults.as_deref().is_some_and(|f| f.is_node_dead(u)) {
                continue;
            }
            if let Some(g) = geometric_gap(&mut st.inj_rng[u], inject_prob) {
                // Gap counts trials: the first success of a run starting
                // at cycle 0 lands at g - 1.
                let t = g - 1;
                if t < inject_until {
                    arrivals.push(Reverse((t, u as u32)));
                }
            }
        }

        // Periodic network-state probes, only with a trace open (the
        // untraced loop carries one extra never-taken branch per cycle).
        let sample_every = if st.trace.is_some() { cfg.sample_every } else { 0 };

        // Phase A of each cycle (serial): probe, calendar drain, arrivals.
        // The phased driver then runs the sharded arbitration kernel.
        let mut now = 0u64;
        self.run_phased(&mut st, |st| {
            if now == total {
                return false;
            }
            st.now = now;
            if sample_every > 0 && now % sample_every == 0 {
                self.sample_probe(st, 0);
            }
            self.apply_events(st);
            while let Some(&Reverse((t, u))) = arrivals.peek() {
                if t != now {
                    break;
                }
                arrivals.pop();
                let u = u as usize;
                match self.faults.as_deref() {
                    None => {
                        if let Some(dest) = traffic.destination_of(u, &mut st.inj_rng[u]) {
                            if (st.inj[u].reserved as u32) < cap {
                                let pid = self.new_packet(st, u, dest, &mut scratch);
                                debug_assert!(pid.is_some(), "pristine network always admits");
                                st.injected_packets += 1;
                            } else {
                                st.source_dropped += 1;
                            }
                        }
                    }
                    Some(f) => {
                        // Degraded arrival: re-draw past dead or
                        // unreachable destinations, up to the redraw
                        // budget. The capacity check moves in front of
                        // the draws (the faulted stream owes no
                        // bit-compatibility to the pristine one) so a
                        // backlogged source spends no RNG at all.
                        if (st.inj[u].reserved as u32) >= cap {
                            st.source_dropped += 1;
                        } else {
                            let mut injected = false;
                            let mut had_dest = false;
                            for _ in 0..FAULT_REDRAWS {
                                let Some(dest) = traffic.destination_of(u, &mut st.inj_rng[u])
                                else {
                                    break;
                                };
                                had_dest = true;
                                if !f.is_node_dead(dest)
                                    && self.new_packet(st, u, dest, &mut scratch).is_some()
                                {
                                    injected = true;
                                    break;
                                }
                            }
                            if injected {
                                st.injected_packets += 1;
                            } else if had_dest {
                                st.source_dropped += 1;
                            }
                        }
                    }
                }
                if let Some(g) = geometric_gap(&mut st.inj_rng[u], inject_prob) {
                    let t = now + g;
                    if t < inject_until {
                        arrivals.push(Reverse((t, u as u32)));
                    }
                }
            }
            now += 1;
            true
        });
        if let Some(tr) = st.trace.as_mut() {
            tr.flush();
        }
        self.collect_stats(st, offered_load)
    }

    /// Fold the run's counters into a [`SimResult`].
    fn collect_stats(&self, st: State, offered_load: f64) -> SimResult {
        let cfg = &self.cfg;
        // One guarded window length for every rate: a degenerate
        // `measure_cycles = 0` run reports clean zeros, not NaNs.
        let mc = cfg.measure_cycles.max(1) as f64;
        // Per-axis link utilization: fraction of link-cycle capacity
        // carrying phits (2N unidirectional links per axis, `axis_width`
        // phits per link-cycle).
        let denom = 2.0 * self.nodes as f64 * mc;
        let axis_phits = |a: usize| -> u64 {
            (0..self.nodes)
                .map(|u| {
                    st.phits_by_link[u * self.ports + 2 * a]
                        + st.phits_by_link[u * self.ports + 2 * a + 1]
                })
                .sum()
        };
        let link_utilization: Vec<f64> = (0..self.dim)
            .map(|a| axis_phits(a) as f64 / (denom * cfg.axis_width(a) as f64))
            .collect();
        // Directed-port classes and the per-link balance spread (the
        // route-policy instrumentation: max/mean utilization over the
        // individual directed links) — shared with the closed-loop
        // workload outcome via `port_stats`.
        let (port_utilization, link_util_spread) =
            self.port_stats(&st, cfg.measure_cycles.max(1));
        let rng_digest = st.rng_digest();
        let (_, rng_draws) = st.node_stream_fingerprint();
        SimResult {
            offered_load,
            link_utilization,
            port_utilization,
            link_util_spread,
            vc_phits: st.phits_by_vc.clone(),
            accepted_load: st.delivered_phits as f64 / (mc * self.nodes as f64),
            avg_latency: st.latency.mean(),
            p50_latency: st.latency.percentile(0.5),
            p90_latency: st.latency.percentile(0.9),
            p99_latency: st.latency.percentile(0.99),
            p999_latency: st.latency.percentile(0.999),
            max_latency: st.latency.max(),
            delivered_packets: st.delivered_packets,
            measured_packets: st.latency.count(),
            source_dropped: st.source_dropped,
            injected_packets: st.injected_packets,
            stalls: st.stalls,
            cycles: cfg.measure_cycles,
            nodes: self.nodes,
            rng_digest,
            rng_draws,
            engine: st.profile,
        }
    }
}
