//! Workload generators: application-level communication patterns compiled
//! to dependency-ordered message sets.
//!
//! Six families (the near-neighbor ↔ global spectrum the paper argues
//! about):
//!
//! - [`stencil`] — halo exchange: every node sends one face message to
//!   each of its `2n` lattice neighbors per round; a node's round-`r`
//!   sends wait for all of its round-`r−1` receptions (bulk-synchronous
//!   stencil codes).
//! - [`all_to_all`] — personalized all-to-all in `N−1` shift phases
//!   (transpose style); each source serializes its own phases (one
//!   outstanding message per node — closed loop).
//! - [`ring_all_reduce`] — reduce-scatter + all-gather on the rank ring:
//!   `2(N−1)` steps, step `s` of rank `i` waits on step `s−1` of its ring
//!   predecessor (the classic bandwidth-optimal all-reduce).
//! - [`recursive_doubling`] — hypercube-style all-reduce: partner
//!   `i XOR 2^r` per round, each round waits on the previous exchange.
//! - [`permutation`] — a fixed random derangement, `iters` chained
//!   messages per source (adversarial global pattern).
//! - [`hotspot`] — incast: every node sends `iters` chained messages to
//!   one hot node (ejection-bandwidth bound).
//!
//! # Message sizes
//!
//! [`WorkloadParams::payload_phits`] sets the application payload and each
//! family maps it to per-message sizes the way the real collective would:
//!
//! - `stencil`, `alltoall`, `permutation`, `hotspot`: `payload_phits` per
//!   message (the halo face / per-destination chunk).
//! - `allreduce-ring`: `payload_phits` is the reduce vector `V`; each of
//!   the `2(N−1)` steps ships one `max(1, ceil(V/N))`-phit chunk (the
//!   bandwidth-optimal V/N chunking, rounded up so the chunks cover the
//!   whole vector).
//! - `allreduce-rd`: `payload_phits` is the reduce vector `V`; every
//!   recursive-doubling round exchanges the whole vector.
//!
//! With the default `payload_phits = 16` (one Table 3 packet) every family
//! degenerates to the single-packet-per-message model.

use crate::lattice::LatticeGraph;
use crate::sim::rng::Rng;

use super::spec::{Workload, WorkloadMessage, DEFAULT_MSG_PHITS};

/// Workload family selector (the closed-loop analogue of
/// [`crate::sim::TrafficPattern`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    Stencil,
    AllToAll,
    RingAllReduce,
    RecursiveDoubling,
    Permutation,
    Hotspot,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 6] = [
        WorkloadKind::Stencil,
        WorkloadKind::AllToAll,
        WorkloadKind::RingAllReduce,
        WorkloadKind::RecursiveDoubling,
        WorkloadKind::Permutation,
        WorkloadKind::Hotspot,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Stencil => "stencil",
            WorkloadKind::AllToAll => "alltoall",
            WorkloadKind::RingAllReduce => "allreduce-ring",
            WorkloadKind::RecursiveDoubling => "allreduce-rd",
            WorkloadKind::Permutation => "permutation",
            WorkloadKind::Hotspot => "hotspot",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "stencil" | "halo" => Some(WorkloadKind::Stencil),
            "alltoall" | "a2a" | "transpose" => Some(WorkloadKind::AllToAll),
            "allreduce-ring" | "ring" => Some(WorkloadKind::RingAllReduce),
            "allreduce-rd" | "rd" | "recursive-doubling" => Some(WorkloadKind::RecursiveDoubling),
            "permutation" | "perm" => Some(WorkloadKind::Permutation),
            "hotspot" | "incast" => Some(WorkloadKind::Hotspot),
            _ => None,
        }
    }
}

/// Generator knobs shared across families.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadParams {
    /// Rounds for `stencil`, chained messages per source for
    /// `permutation`/`hotspot` (ignored by the fixed-schedule collectives).
    pub iters: usize,
    /// Generator seed (the `permutation` matching).
    pub seed: u64,
    /// Hot node for `hotspot`.
    pub hot: usize,
    /// Application payload in phits (see the module docs for the
    /// per-family mapping). Default: one 16-phit packet.
    pub payload_phits: u32,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        Self { iters: 8, seed: 0x1ce_b00da, hot: 0, payload_phits: DEFAULT_MSG_PHITS }
    }
}

/// Build the workload of `kind` for graph `g`.
pub fn generate(kind: WorkloadKind, g: &LatticeGraph, p: &WorkloadParams) -> Workload {
    let size = p.payload_phits.max(1);
    match kind {
        WorkloadKind::Stencil => stencil(g, p.iters, size),
        WorkloadKind::AllToAll => all_to_all(g, size),
        WorkloadKind::RingAllReduce => ring_all_reduce(g, size),
        WorkloadKind::RecursiveDoubling => recursive_doubling(g, size),
        WorkloadKind::Permutation => permutation(g, p.iters, p.seed, size),
        WorkloadKind::Hotspot => hotspot(g, p.iters, p.hot, size),
    }
}

/// Halo exchange: `rounds` bulk-synchronous rounds of one `size_phits`
/// message per lattice face; round `r` sends of a node depend on all of
/// its round `r−1` receptions.
pub fn stencil(g: &LatticeGraph, rounds: usize, size_phits: u32) -> Workload {
    let n = g.order();
    let dim = g.dim();
    let mut messages = Vec::new();
    let mut prev_in: Vec<Vec<u32>> = vec![Vec::new(); n];
    for r in 0..rounds {
        let mut cur_in: Vec<Vec<u32>> = vec![Vec::new(); n];
        for u in 0..n {
            for axis in 0..dim {
                for sign in [1i64, -1] {
                    let v = g.step(u, axis, sign);
                    if v == u {
                        continue; // radix-1 dimension: no halo partner
                    }
                    let id = messages.len() as u32;
                    messages.push(WorkloadMessage {
                        src: u as u32,
                        dst: v as u32,
                        phase: r as u32,
                        deps: prev_in[u].clone(),
                        size_phits,
                    });
                    cur_in[v].push(id);
                }
            }
        }
        prev_in = cur_in;
    }
    Workload { name: format!("stencil(rounds={rounds})"), nodes: n, messages }
}

/// Personalized all-to-all in `N−1` shift phases: phase `p` sends a
/// `size_phits` chunk `u → (u + p) mod N`; each source chains its own
/// phases (one outstanding message per node).
pub fn all_to_all(g: &LatticeGraph, size_phits: u32) -> Workload {
    let n = g.order();
    let mut messages = Vec::with_capacity(n.saturating_sub(1) * n);
    for p in 1..n {
        for u in 0..n {
            let deps = if p > 1 { vec![((p - 2) * n + u) as u32] } else { Vec::new() };
            messages.push(WorkloadMessage {
                src: u as u32,
                dst: ((u + p) % n) as u32,
                phase: (p - 1) as u32,
                deps,
                size_phits,
            });
        }
    }
    Workload { name: "alltoall".into(), nodes: n, messages }
}

/// Ring all-reduce over the rank ring `i → i+1 mod N`: `2(N−1)` steps
/// (reduce-scatter then all-gather); step `s` of rank `i` waits on step
/// `s−1` of its ring predecessor — the data dependency that defines the
/// collective's critical path. `vector_phits` is the reduce vector `V`;
/// each step ships one `max(1, ceil(V/N))`-phit chunk — ceil, matching
/// the packetization convention, so the N chunks cover the full vector
/// even when `N ∤ V` and volume comparisons against recursive doubling
/// stay honest.
pub fn ring_all_reduce(g: &LatticeGraph, vector_phits: u32) -> Workload {
    let n = g.order();
    let steps = if n >= 2 { 2 * (n - 1) } else { 0 };
    let chunk = vector_phits.div_ceil(n.max(1) as u32).max(1);
    let mut messages = Vec::with_capacity(steps * n);
    for s in 0..steps {
        for i in 0..n {
            let deps = if s > 0 { vec![((s - 1) * n + (i + n - 1) % n) as u32] } else { Vec::new() };
            messages.push(WorkloadMessage {
                src: i as u32,
                dst: ((i + 1) % n) as u32,
                phase: s as u32,
                deps,
                size_phits: chunk,
            });
        }
    }
    Workload { name: "allreduce-ring".into(), nodes: n, messages }
}

/// Recursive-doubling all-reduce: round `r` pairs `u` with `u XOR 2^r`
/// (nodes whose partner falls outside a non-power-of-two order idle that
/// round); a node's round-`r` send waits on its round-`r−1` reception.
/// Every round exchanges the full `vector_phits` reduce vector.
pub fn recursive_doubling(g: &LatticeGraph, vector_phits: u32) -> Workload {
    let n = g.order();
    let mut messages = Vec::new();
    let mut prev_in: Vec<Option<u32>> = vec![None; n];
    let mut r = 0usize;
    while (1usize << r) < n {
        let bit = 1usize << r;
        let mut cur_in: Vec<Option<u32>> = vec![None; n];
        for u in 0..n {
            let v = u ^ bit;
            if v >= n {
                continue;
            }
            let deps = prev_in[u].map(|d| vec![d]).unwrap_or_default();
            let id = messages.len() as u32;
            messages.push(WorkloadMessage {
                src: u as u32,
                dst: v as u32,
                phase: r as u32,
                deps,
                size_phits: vector_phits,
            });
            cur_in[v] = Some(id);
        }
        prev_in = cur_in;
        r += 1;
    }
    Workload { name: "allreduce-rd".into(), nodes: n, messages }
}

/// A fixed random derangement: every node sends `iters` chained
/// `size_phits` messages to its (fixed-point-free) partner.
pub fn permutation(g: &LatticeGraph, iters: usize, seed: u64, size_phits: u32) -> Workload {
    let n = g.order();
    if n < 2 {
        return Workload { name: format!("permutation(iters={iters})"), nodes: n, messages: Vec::new() };
    }
    let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    // Deterministically repair fixed points: value `i` lives only at
    // position `i`, so swapping with the next position cannot create a new
    // fixed point.
    for i in 0..n {
        if perm[i] as usize == i {
            let j = (i + 1) % n;
            perm.swap(i, j);
        }
    }
    let mut messages = Vec::with_capacity(n * iters);
    for it in 0..iters {
        for u in 0..n {
            let deps = if it > 0 { vec![((it - 1) * n + u) as u32] } else { Vec::new() };
            messages.push(WorkloadMessage {
                src: u as u32,
                dst: perm[u],
                phase: it as u32,
                deps,
                size_phits,
            });
        }
    }
    Workload { name: format!("permutation(iters={iters})"), nodes: n, messages }
}

/// Incast: every node except `hot` sends `iters` chained `size_phits`
/// messages to `hot`; completion is bounded below by the hot node's
/// ejection bandwidth.
pub fn hotspot(g: &LatticeGraph, iters: usize, hot: usize, size_phits: u32) -> Workload {
    let n = g.order();
    assert!(hot < n, "hot node {hot} out of range for order {n}");
    let senders = n.saturating_sub(1);
    let mut messages = Vec::with_capacity(senders * iters);
    for it in 0..iters {
        for u in 0..n {
            if u == hot {
                continue;
            }
            // Same source order every iteration: the previous chained
            // message sits exactly `senders` entries back.
            let deps = if it > 0 { vec![(messages.len() - senders) as u32] } else { Vec::new() };
            messages.push(WorkloadMessage {
                src: u as u32,
                dst: hot as u32,
                phase: it as u32,
                deps,
                size_phits,
            });
        }
    }
    Workload { name: format!("hotspot(iters={iters})"), nodes: n, messages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{fcc, torus};

    const P: u32 = DEFAULT_MSG_PHITS;

    #[test]
    fn message_counts() {
        let g = torus(&[4, 4]); // n = 16, dim 2
        assert_eq!(stencil(&g, 2, P).len(), 2 * 16 * 4);
        assert_eq!(all_to_all(&g, P).len(), 16 * 15);
        assert_eq!(ring_all_reduce(&g, P).len(), 2 * 15 * 16);
        assert_eq!(recursive_doubling(&g, P).len(), 16 * 4); // log2(16) rounds
        assert_eq!(permutation(&g, 3, 1, P).len(), 3 * 16);
        assert_eq!(hotspot(&g, 2, 0, P).len(), 2 * 15);
    }

    #[test]
    fn all_generated_workloads_validate() {
        for g in [torus(&[4, 4]), torus(&[3, 3, 3]), fcc(2)] {
            for kind in WorkloadKind::ALL {
                let wl = generate(kind, &g, &WorkloadParams::default());
                assert!(wl.validate().is_ok(), "{} on {} nodes: {:?}", wl.name, g.order(), wl.validate());
                assert!(wl.is_acyclic(), "{}", wl.name);
            }
        }
    }

    #[test]
    fn payload_maps_per_family() {
        let g = torus(&[4, 4]); // n = 16
        let p = WorkloadParams { payload_phits: 4096, ..Default::default() };
        // Per-message families carry the payload verbatim.
        for kind in [
            WorkloadKind::Stencil,
            WorkloadKind::AllToAll,
            WorkloadKind::Permutation,
            WorkloadKind::Hotspot,
            WorkloadKind::RecursiveDoubling,
        ] {
            let wl = generate(kind, &g, &p);
            assert!(wl.messages.iter().all(|m| m.size_phits == 4096), "{}", wl.name);
        }
        // Ring chunks the vector V/N.
        let ring = generate(WorkloadKind::RingAllReduce, &g, &p);
        assert!(ring.messages.iter().all(|m| m.size_phits == 4096 / 16));
        // Non-divisible vectors round the chunk up (ceil, not floor), so
        // the 16 chunks cover all 100 phits: 16·7 = 112 ≥ 100.
        let ragged = generate(
            WorkloadKind::RingAllReduce,
            &g,
            &WorkloadParams { payload_phits: 100, ..Default::default() },
        );
        assert!(ragged.messages.iter().all(|m| m.size_phits == 7));
        // Tiny vectors clamp to one phit, never zero.
        let tiny = generate(
            WorkloadKind::RingAllReduce,
            &g,
            &WorkloadParams { payload_phits: 4, ..Default::default() },
        );
        assert!(tiny.messages.iter().all(|m| m.size_phits == 1));
        assert!(tiny.validate().is_ok());
    }

    #[test]
    fn default_payload_is_single_packet() {
        let g = fcc(2);
        for kind in WorkloadKind::ALL {
            let wl = generate(kind, &g, &WorkloadParams::default());
            assert!(
                wl.messages.iter().all(|m| m.packets(P) == 1),
                "{} must be single-packet at the default payload",
                wl.name
            );
        }
    }

    #[test]
    fn stencil_round_dependencies() {
        let g = torus(&[4, 4]);
        let wl = stencil(&g, 3, P);
        assert_eq!(wl.phases(), 3);
        let per_round = 16 * 4;
        for (i, m) in wl.messages.iter().enumerate() {
            if i < per_round {
                assert!(m.deps.is_empty(), "round 0 must be dependency-free");
            } else {
                // Each node receives 4 halo messages per round on a 2D torus.
                assert_eq!(m.deps.len(), 4, "message {i}");
                for &d in &m.deps {
                    let dep = &wl.messages[d as usize];
                    assert_eq!(dep.dst, m.src, "deps must be the sender's receptions");
                    assert_eq!(dep.phase + 1, m.phase);
                }
            }
        }
    }

    #[test]
    fn permutation_is_deterministic_derangement() {
        let g = fcc(2);
        let a = permutation(&g, 2, 42, P);
        let b = permutation(&g, 2, 42, P);
        assert_eq!(a, b, "same seed, same workload");
        let c = permutation(&g, 2, 43, P);
        assert_ne!(a, c, "different seed, different matching");
        for m in &a.messages {
            assert_ne!(m.src, m.dst);
        }
    }

    #[test]
    fn ring_deps_follow_predecessor() {
        let g = torus(&[3, 3]); // n = 9
        let wl = ring_all_reduce(&g, P);
        let n = 9;
        for s in 1..(2 * (n - 1)) {
            for i in 0..n {
                let m = &wl.messages[s * n + i];
                assert_eq!(m.deps.len(), 1);
                let dep = &wl.messages[m.deps[0] as usize];
                // The predecessor's previous-step send was addressed to us.
                assert_eq!(dep.dst as usize, i);
            }
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(WorkloadKind::parse("halo"), Some(WorkloadKind::Stencil));
        assert_eq!(WorkloadKind::parse("A2A"), Some(WorkloadKind::AllToAll));
        assert_eq!(WorkloadKind::parse("nope"), None);
    }
}
