//! Differential suite: the closed-form dispatch routers vs the
//! hierarchical reference (Algorithm 1), record for record.
//!
//! The engine draws its tie choice as `rng.below(ties.len())`, so the
//! tie *count and order* — not just the set — are RNG-stream-load-
//! bearing. Equality here is what keeps the dispatched fast path
//! bit-identical to the historical hierarchical build (no re-pin of the
//! differential suites), and byte-equality of the compact stores is
//! what lets `TopologyArtifacts` swap build paths freely.

use lattice_networks::lattice::LatticeGraph;
use lattice_networks::metrics::bfs_distances;
use lattice_networks::routing::{
    classify, is_valid_record, norm, CompactRoutes, DispatchRouter, HierarchicalRouter, Router,
    RouterKind, RoutingTable,
};
use lattice_networks::sim::rng::Rng;
use lattice_networks::topology;

/// The dispatch catalog at radices beyond the a <= 2 unit tests, plus
/// every hybrid (which must fall back without changing any record).
fn catalog() -> Vec<(String, LatticeGraph)> {
    vec![
        // Tori (diagonal Hermite forms): odd, even, mixed radices.
        ("T(5)".into(), topology::torus(&[5])),
        ("T(8,8)".into(), topology::torus(&[8, 8])),
        ("T(7,5,3)".into(), topology::torus(&[7, 5, 3])),
        ("T(6,4,2)".into(), topology::torus(&[6, 4, 2])),
        ("PC(4)".into(), topology::pc(4)),
        // RTT = the 2D FCC pattern (Remark 33's base case).
        ("RTT(3)".into(), topology::rtt(3)),
        ("RTT(4)".into(), topology::rtt(4)),
        // 3D crystals.
        ("FCC(3)".into(), topology::fcc(3)),
        ("BCC(3)".into(), topology::bcc(3)),
        // Higher-dimensional lifts.
        ("4D-FCC(2)".into(), topology::fcc4d(2)),
        ("4D-BCC(2)".into(), topology::bcc4d(2)),
        ("4D-FCC(3)".into(), topology::fcc_nd(4, 3)),
        ("4D-BCC(3)".into(), topology::bcc_nd(4, 3)),
        ("5D-FCC(2)".into(), topology::fcc_nd(5, 2)),
        ("5D-BCC(2)".into(), topology::bcc_nd(5, 2)),
        // Hybrids and the Lip lattice: off the closed-form catalog.
        ("T⊞RTT(2)".into(), topology::hybrid_t_rtt(2)),
        ("PC⊞BCC(2)".into(), topology::hybrid_pc_bcc(2)),
        ("PC⊞FCC(2)".into(), topology::hybrid_pc_fcc(2)),
        ("BCC⊞FCC(2)".into(), topology::hybrid_bcc_fcc(2)),
        ("Lip(1)".into(), topology::lip(1)),
    ]
}

/// All sources for small graphs, a seeded sample for larger ones.
fn sources(g: &LatticeGraph, seed: u64) -> Vec<usize> {
    if g.order() <= 300 {
        (0..g.order()).collect()
    } else {
        let mut rng = Rng::new(seed);
        (0..24).map(|_| rng.below(g.order())).collect()
    }
}

#[test]
fn dispatch_matches_hierarchical_record_for_record() {
    for (tag, g) in catalog() {
        let dispatch = DispatchRouter::new(&g);
        let hier = HierarchicalRouter::new(g.clone());
        for s in sources(&g, 0xd15b_a7c4) {
            let src = g.label_of(s);
            for v in 0..g.order() {
                let dst = g.label_of(v);
                assert_eq!(
                    dispatch.route_ties(&src, &dst),
                    hier.route_ties(&src, &dst),
                    "{tag} [{}]: tie records diverge for {src:?} -> {dst:?}",
                    dispatch.kind_name()
                );
            }
        }
    }
}

#[test]
fn dispatch_ties_are_exactly_minimal_against_bfs() {
    for (tag, g) in catalog() {
        let dispatch = DispatchRouter::new(&g);
        for s in sources(&g, 0xbf50_0c1e) {
            let src = g.label_of(s);
            let dist = bfs_distances(&g, s);
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let ties = dispatch.route_ties(&src, &dst);
                assert!(!ties.is_empty(), "{tag}: empty tie set {src:?} -> {dst:?}");
                for (i, t) in ties.iter().enumerate() {
                    assert!(is_valid_record(&g, &src, &dst, t), "{tag}: invalid tie {t:?}");
                    assert_eq!(norm(t), dist[v] as i64, "{tag}: non-minimal tie {t:?}");
                    assert!(
                        !ties[..i].contains(t),
                        "{tag}: duplicate tie {t:?} for {src:?} -> {dst:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn crystal_families_dispatch_off_the_hierarchical_path() {
    // The families the closed forms cover must actually classify — a
    // silent fall-back to Hierarchical would pass the differentials
    // while losing the entire build speedup.
    let expect: Vec<(&str, LatticeGraph, RouterKind)> = vec![
        ("T(7,5,3)", topology::torus(&[7, 5, 3]), RouterKind::Torus { sides: vec![7, 5, 3] }),
        ("RTT(4)", topology::rtt(4), RouterKind::FccNd { n: 2, a: 4 }),
        ("FCC(3)", topology::fcc(3), RouterKind::FccNd { n: 3, a: 3 }),
        ("BCC(3)", topology::bcc(3), RouterKind::BccNd { n: 3, a: 3 }),
        ("5D-FCC(2)", topology::fcc_nd(5, 2), RouterKind::FccNd { n: 5, a: 2 }),
        ("4D-BCC(3)", topology::bcc_nd(4, 3), RouterKind::BccNd { n: 4, a: 3 }),
    ];
    for (tag, g, kind) in expect {
        assert_eq!(classify(&g), kind, "{tag}");
    }
}

#[test]
fn compact_store_identical_across_build_paths() {
    // Serial dispatch, parallel dispatch, and the legacy table
    // compaction must produce byte-identical CSR stores.
    let cases: Vec<(&str, LatticeGraph)> = vec![
        ("T(6,5,4)", topology::torus(&[6, 5, 4])),
        ("BCC(3)", topology::bcc(3)),
        ("RTT(5)", topology::rtt(5)),
        ("4D-FCC(2)", topology::fcc4d(2)),
        ("PC⊞BCC(2)", topology::hybrid_pc_bcc(2)),
    ];
    for (tag, g) in cases {
        let legacy = CompactRoutes::from_table(&RoutingTable::build_hierarchical(&g));
        for threads in [1usize, 3, 4, 8] {
            let built = CompactRoutes::build(&g, threads);
            assert_eq!(built.len(), legacy.len(), "{tag} t{threads}");
            assert_eq!(built.total_records(), legacy.total_records(), "{tag} t{threads}");
            assert_eq!(built.bytes(), legacy.bytes(), "{tag} t{threads}");
            for i in 0..legacy.len() {
                assert_eq!(built.ties(i), legacy.ties(i), "{tag} t{threads} diff {i}");
            }
        }
    }
}
