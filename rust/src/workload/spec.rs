//! Workload message sets: the closed-loop counterpart of
//! [`crate::sim::TrafficPattern`].
//!
//! A [`Workload`] is a finite set of single-packet messages with
//! happens-before dependencies (a DAG). The cycle engine injects each
//! message once every message it depends on has been fully received
//! ([`crate::sim::Simulator::run_workload`]), and the figure of merit is
//! **completion time** — how many cycles until the network drains — rather
//! than steady-state latency/throughput.

/// One message: a single packet from `src` to `dst` that may only be
/// injected after all of `deps` (indices into the owning workload's
/// message vector) have been delivered.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkloadMessage {
    pub src: u32,
    pub dst: u32,
    /// Generator phase/round the message belongs to (reporting only).
    pub phase: u32,
    /// Messages that must be fully received before this one is eligible.
    pub deps: Vec<u32>,
}

/// A finite, dependency-ordered message set for one topology order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Workload {
    /// Display name, e.g. `stencil(iters=8)`.
    pub name: String,
    /// Node count of the topology this was generated for.
    pub nodes: usize,
    pub messages: Vec<WorkloadMessage>,
}

impl Workload {
    /// Number of messages.
    pub fn len(&self) -> usize {
        self.messages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.messages.is_empty()
    }

    /// Number of generator phases (max phase + 1).
    pub fn phases(&self) -> u32 {
        self.messages.iter().map(|m| m.phase + 1).max().unwrap_or(0)
    }

    /// Kahn's algorithm: true iff the dependency graph has no cycle.
    pub fn is_acyclic(&self) -> bool {
        let n = self.messages.len();
        let mut indegree = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (i, m) in self.messages.iter().enumerate() {
            indegree[i] = m.deps.len() as u32;
            for &d in &m.deps {
                dependents[d as usize].push(i as u32);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = queue.pop() {
            seen += 1;
            for &j in &dependents[i] {
                indegree[j as usize] -= 1;
                if indegree[j as usize] == 0 {
                    queue.push(j as usize);
                }
            }
        }
        seen == n
    }

    /// Structural validation: endpoints in range, no self-messages, dep
    /// indices in range, and an acyclic dependency graph.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.messages.len() as u32;
        for (i, m) in self.messages.iter().enumerate() {
            if m.src as usize >= self.nodes || m.dst as usize >= self.nodes {
                return Err(format!("message {i}: endpoint out of range"));
            }
            if m.src == m.dst {
                return Err(format!("message {i}: self-message {}->{}", m.src, m.dst));
            }
            for &d in &m.deps {
                if d >= n {
                    return Err(format!("message {i}: dep {d} out of range"));
                }
                if d as usize == i {
                    return Err(format!("message {i}: depends on itself"));
                }
            }
        }
        if !self.is_acyclic() {
            return Err("dependency graph has a cycle".to_string());
        }
        Ok(())
    }

    /// Conservative cycle cap for [`crate::sim::Simulator::run_workload`]:
    /// generously above any plausible completion time (serialization of
    /// the busiest source, the busiest destination — incast — plus the
    /// mean per-node backlog), so hitting it signals a modelling bug, not
    /// a slow network.
    pub fn suggested_max_cycles(&self, packet_size: u32) -> u64 {
        let n = self.nodes.max(1) as u64;
        let total = self.messages.len() as u64;
        let mut per_src = vec![0u64; self.nodes];
        let mut per_dst = vec![0u64; self.nodes];
        for m in &self.messages {
            per_src[m.src as usize] += 1;
            per_dst[m.dst as usize] += 1;
        }
        let max_src = per_src.iter().copied().max().unwrap_or(0);
        let max_dst = per_dst.iter().copied().max().unwrap_or(0);
        50_000 + 8 * packet_size as u64 * (max_src + max_dst + total / n)
    }
}

/// Result of one closed-loop workload run.
#[derive(Clone, Debug)]
pub struct WorkloadOutcome {
    /// Cycle at which the last message was fully received (equals the
    /// cycle cap when `drained` is false).
    pub completion_cycles: u64,
    /// Every message was delivered before the cycle cap.
    pub drained: bool,
    pub delivered_messages: u64,
    pub total_messages: u64,
    pub delivered_phits: u64,
    /// Mean per-message latency, injection-queue entry to full reception.
    pub avg_latency: f64,
    pub p99_latency: f64,
    pub max_latency: u64,
    pub nodes: usize,
}

impl WorkloadOutcome {
    /// Aggregate effective bandwidth in phits/(cycle·node) — the
    /// completion-time analogue of accepted load.
    pub fn effective_bandwidth(&self) -> f64 {
        if self.completion_cycles == 0 {
            return 0.0;
        }
        self.delivered_phits as f64 / (self.completion_cycles as f64 * self.nodes as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(src: u32, dst: u32, deps: Vec<u32>) -> WorkloadMessage {
        WorkloadMessage { src, dst, phase: 0, deps }
    }

    #[test]
    fn validate_catches_structural_errors() {
        let ok = Workload { name: "ok".into(), nodes: 4, messages: vec![msg(0, 1, vec![]), msg(1, 2, vec![0])] };
        assert!(ok.validate().is_ok());

        let self_msg = Workload { name: "s".into(), nodes: 4, messages: vec![msg(2, 2, vec![])] };
        assert!(self_msg.validate().is_err());

        let oob = Workload { name: "o".into(), nodes: 2, messages: vec![msg(0, 5, vec![])] };
        assert!(oob.validate().is_err());

        let bad_dep = Workload { name: "d".into(), nodes: 4, messages: vec![msg(0, 1, vec![9])] };
        assert!(bad_dep.validate().is_err());
    }

    #[test]
    fn cycle_detected() {
        let cyc = Workload {
            name: "cyc".into(),
            nodes: 4,
            messages: vec![msg(0, 1, vec![1]), msg(1, 2, vec![0])],
        };
        assert!(!cyc.is_acyclic());
        assert!(cyc.validate().is_err());
        let dag = Workload {
            name: "dag".into(),
            nodes: 4,
            messages: vec![msg(0, 1, vec![]), msg(1, 2, vec![0]), msg(2, 3, vec![0, 1])],
        };
        assert!(dag.is_acyclic());
    }

    #[test]
    fn suggested_cap_scales_with_incast() {
        let spread = Workload {
            name: "spread".into(),
            nodes: 16,
            messages: (0..16u32).map(|u| msg(u, (u + 1) % 16, vec![])).collect(),
        };
        let incast = Workload {
            name: "incast".into(),
            nodes: 16,
            messages: (1..16u32).flat_map(|u| (0..16).map(move |_| msg(u, 0, vec![]))).collect(),
        };
        assert!(incast.suggested_max_cycles(16) > spread.suggested_max_cycles(16));
    }

    #[test]
    fn effective_bandwidth() {
        let o = WorkloadOutcome {
            completion_cycles: 100,
            drained: true,
            delivered_messages: 10,
            total_messages: 10,
            delivered_phits: 160,
            avg_latency: 20.0,
            p99_latency: 30.0,
            max_latency: 40,
            nodes: 4,
        };
        assert!((o.effective_bandwidth() - 0.4).abs() < 1e-12);
    }
}
