//! Packet creation and source enqueue: the open-loop Bernoulli injector,
//! the shared route-allocate-enqueue path used by both injection regimes
//! (including the virtual-channel draw — adaptive packets start on an
//! adaptive VC, never on the reserved escape lane), and the
//! route-selection policy dispatch with its escape-commitment override.

use crate::sim::policy::dor_port;
use crate::sim::rng::Rng;
use crate::sim::traffic::Traffic;

use super::state::{Fifo, Packet, State};
use super::{Simulator, MAX_DIM};

impl Simulator {
    /// Open-loop Bernoulli injection at probability `prob` per node.
    pub(super) fn inject(&self, st: &mut State, traffic: &Traffic, prob: f64, scratch: &mut [i64]) {
        if prob <= 0.0 {
            return;
        }
        let cap = self.cfg.injection_queue_packets;
        for u in 0..self.nodes {
            if !st.rng.chance(prob) {
                continue;
            }
            let Some(dest) = traffic.destination_of(u, &mut st.rng) else {
                continue;
            };
            if st.inj[u].reserved as u32 >= cap {
                st.source_dropped += 1;
                continue;
            }
            self.new_packet(st, u, dest, scratch);
            st.injected_packets += 1;
        }
    }

    /// Route, allocate and source-enqueue one packet from `u` to `dest`
    /// (shared by the open-loop Bernoulli injector and the closed-loop
    /// workload driver). The caller must ensure the source queue has room.
    pub(super) fn new_packet(
        &self,
        st: &mut State,
        u: usize,
        dest: usize,
        scratch: &mut [i64],
    ) -> u32 {
        // Difference label -> routing tie set -> random minimal record.
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = self.labels[dest * self.dim + i] - self.labels[u * self.dim + i];
        }
        self.g.reduce_in_place(scratch);
        let diff_idx = self.g.index_of(scratch);
        let ties = self.routes.ties(diff_idx);
        let record = ties[st.rng.below(ties.len())];
        // VC draw: with the escape protocol live, packets inject on a
        // uniformly random *adaptive* VC (VC 0 is reserved for escapes);
        // otherwise on any VC — one RNG draw either way, so `Dor` (and
        // any single-VC configuration) stays bit-exact with the
        // pre-escape engine at the same VC count.
        let vc = if self.escape_active() {
            (1 + st.rng.below(self.cfg.num_vcs - 1)) as u8
        } else {
            st.rng.below(self.cfg.num_vcs) as u8
        };
        let next_port = self.route_port(u, &record, vc as usize, &st.inputs, &mut st.rng);
        let pid = self.alloc_packet(
            st,
            Packet {
                record,
                vc,
                inject_time: st.now,
                head_ready: st.now,
                next_port,
            },
            dest as u32,
        );
        let icap = self.cfg.injection_queue_packets as usize;
        let base = u * icap;
        st.inj[u].push(&mut st.inj_slots[base..base + icap], pid, st.now, next_port);
        // The source now holds queued traffic: put it on the arbitration
        // worklist before this cycle's `advance` (which merges pending
        // activations first, so a packet ready at `st.now` is seen this
        // cycle — exactly when the full scan would first move it).
        st.active_nodes.insert(u);
        if st.trace.is_some() {
            let now = st.now;
            if let Some(tr) = st.trace.as_mut() {
                tr.inject(now, pid, u, dest, vc);
            }
        }
        pid
    }

    #[inline]
    pub(super) fn alloc_packet(&self, st: &mut State, p: Packet, dest: u32) -> u32 {
        if let Some(pid) = st.free_pids.pop() {
            st.packets[pid as usize] = p;
            st.dests[pid as usize] = dest;
            pid
        } else {
            st.packets.push(p);
            st.dests.push(dest);
            (st.packets.len() - 1) as u32
        }
    }

    /// Route-selection policy dispatch: the output port for a packet at
    /// `node` whose remaining record is `record`, riding virtual channel
    /// `vc`. A packet on VC 0 while the escape protocol is live is
    /// committed to the escape lane: it takes the DOR port, RNG-free,
    /// regardless of the configured policy. Otherwise the headroom
    /// closure exposes the downstream free slots behind each output port
    /// on the packet's VC (only `AdaptiveMin` calls it); `Dor` consumes
    /// no RNG, keeping the default configuration bit-exact with the
    /// pre-policy engine.
    #[inline]
    pub(super) fn route_port(
        &self,
        node: usize,
        record: &[i16; MAX_DIM],
        vc: usize,
        inputs: &[Fifo],
        rng: &mut Rng,
    ) -> u8 {
        if vc == 0 && self.escape_active() {
            return dor_port(record, self.dim, self.ports);
        }
        let cap = self.cfg.queue_packets;
        let vcc = self.cfg.num_vcs;
        self.cfg.route_policy.select_port(
            record,
            self.dim,
            self.ports,
            |p| {
                let v = self.neighbor[node * self.ports + p] as usize;
                let fifo = &inputs[(v * self.ports + p) * vcc + vc];
                cap.saturating_sub(fifo.reserved as u32)
            },
            rng,
        )
    }
}
