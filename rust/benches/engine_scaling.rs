//! Macrobench: cycle-engine throughput across (nodes × load × policy ×
//! regime × scan mode × thread count) — the perf story behind the
//! active-set refactor and the phased parallel engine (DESIGN.md
//! §Engine-performance, §Parallel-engine).
//!
//! Every case is measured under both scan modes, so one run records the
//! active-set speedup over the retained full-scan reference directly —
//! and under both a serial (`t1`) and a 4-thread (`t4`) engine, so the
//! same run records the parallel speedup (the two engines are
//! bit-identical, pinned by `tests/parallel_differential.rs`; only the
//! wall clock may differ). The interesting regimes:
//!
//! - `open@0.05`: low-load open loop — few packets in flight, the
//!   full scan burns O(nodes) per cycle on idle routers;
//! - `open@0.9`: saturation — everything is active, so active-set
//!   bookkeeping must cost ~nothing (the ≤5% regression budget), and the
//!   Phase-B shard kernels have real work to split;
//! - `chain`: a serial closed-loop relay (one message train in flight at
//!   a time) — the dependency-tail regime where per-cycle activity is a
//!   handful of nodes regardless of network size. Its `t4` twin is the
//!   parallel engine's worst case (nothing to split; the twin bounds the
//!   barrier overhead rather than showing speedup);
//! - `stencil` on T(32,32,32): a bulk-synchronous halo exchange keeping
//!   all 32k nodes busy — the closed-loop regime the 4-thread engine is
//!   *for* (the ≥2× node-cycles/s target rides this case);
//! - `open@0.9+trace`: saturation with the JSONL lifecycle trace and
//!   probes enabled — the telemetry overhead case (DESIGN.md
//!   §Telemetry). The delta against the matching `open@0.9` case is the
//!   cost of *using* the trace; the `open@0.9` cases themselves carry
//!   the always-on stall counters, so their trajectory vs the seed
//!   baseline bounds the telemetry-off overhead;
//! - `hotspot-imbalance`: T(16,16,16) under `TrafficPattern::HotSpot` —
//!   one saturated destination, everything else light. The static cut
//!   planes would leave most workers idle; the per-cycle balanced shard
//!   plan is what its `t4` twin measures (the ≥2× t4-vs-t1 target of
//!   the balancing work rides this case);
//! - `near-idle`: open loop at 0.01 — a few active nodes on 4096. Its
//!   `t4` twin measures the serial fast path: with the cutoff engaged
//!   the parallel engine must track `t1` instead of paying two barrier
//!   round-trips per near-empty cycle;
//! - `faulted@0.9`: saturation with 2% of links and 0.5% of routers
//!   failed — fault masks sit on the port-selection hot path, so the
//!   delta against the matching pristine `open@0.9` case prices
//!   degraded-mode routing (and the pristine cases pin the faults-off
//!   overhead at zero by construction: a `None` fault set skips every
//!   mask);
//! - `table_build`: routing-table construction wall time up to
//!   T(64,64,64) — the setup cost the topology-plane work attacks. Per
//!   topology three variants: `serial-hier/t1` (the legacy serial
//!   hierarchical walk compacted afterwards), `dispatch/t1` and
//!   `dispatch/t4` (the closed-form dispatch routers building the
//!   compact store directly, serial and 4-thread). Throughput is
//!   nodes/s, and every record's `extra` field carries the compact
//!   store's `route_bytes_per_node`.
//!
//! Emit machine-readable records with `--json <path>` (or `BENCH_JSON`);
//! relative paths resolve in the bench's CWD, the `rust/` package root.
//! `scripts/bench_engine.sh` regenerates the repo's committed
//! perf-trajectory baseline (`BENCH_engine.json` at the repository root,
//! budget pinned to `BENCH_BUDGET_MS=300` for comparable numbers).

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};
use lattice_networks::workload::{Workload, WorkloadMessage};

/// The serial/parallel twin pair behind every case: `t1` is the
/// reference engine, `t4` the parallel speedup (or overhead) probe.
const THREADS: [usize; 2] = [1, 4];

/// Serial neighbour relay: message `i` rides `node i -> i+1 (mod N)` and
/// depends on message `i-1`, so at most one train is ever in flight — the
/// closed-loop dependency-tail regime at its purest.
fn chain_workload(nodes: usize, len: u32) -> Workload {
    let n = nodes as u32;
    let messages = (0..len)
        .map(|i| {
            let deps = if i == 0 { vec![] } else { vec![i - 1] };
            WorkloadMessage::new(i % n, (i + 1) % n, i, deps)
        })
        .collect();
    Workload { name: format!("chain({len})"), nodes, messages }
}

fn main() {
    // `--json <path>` / `BENCH_JSON` are handled by `Bench::new`.
    let mut b = Bench::new("engine_scaling");
    b.max_iters = 20;

    let open_cfg = |policy: RoutePolicy, scan: ScanMode, threads: usize| SimConfig {
        warmup_cycles: 0,
        measure_cycles: 2_000,
        route_policy: policy,
        scan_mode: scan,
        threads,
        ..SimConfig::default()
    };

    for (name, g) in [
        ("T(8,8,8)", topology::torus(&[8, 8, 8])),
        ("T(16,16,16)", topology::torus(&[16, 16, 16])),
    ] {
        let nodes = g.order() as u64;
        let chain = chain_workload(g.order(), 256);
        for policy in [RoutePolicy::Dor, RoutePolicy::AdaptiveMin] {
            for scan in ScanMode::ALL {
                for threads in THREADS {
                    let cfg = open_cfg(policy, scan, threads);
                    let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                    let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                    // Open loop: node-cycles per second is the engine metric.
                    for load in [0.05, 0.9] {
                        b.run_throughput(
                            &format!(
                                "{name}/open@{load}/{}/{}/t{threads}",
                                policy.name(),
                                scan.name()
                            ),
                            nodes * cycles,
                            "node-cycles",
                            || {
                                black_box(sim.run(load));
                            },
                        );
                    }
                    // Saturated open loop with the lifecycle trace
                    // streaming to a scratch file: the telemetry overhead
                    // case. Only the adaptive policy (the event-heaviest:
                    // stalls and escape drains on top of hops) — the
                    // off/on delta, not policy coverage, is the point.
                    if policy == RoutePolicy::AdaptiveMin {
                        let path = std::env::temp_dir().join(format!(
                            "lattice_bench_trace_{}_{nodes}_{}_{threads}.jsonl",
                            std::process::id(),
                            scan.name()
                        ));
                        let traced = Simulator::new(
                            g.clone(),
                            TrafficPattern::Uniform,
                            SimConfig {
                                trace: Some(path.to_string_lossy().into_owned()),
                                sample_every: 100,
                                ..open_cfg(policy, scan, threads)
                            },
                        );
                        b.run_throughput(
                            &format!(
                                "{name}/open@0.9+trace/{}/{}/t{threads}",
                                policy.name(),
                                scan.name()
                            ),
                            nodes * cycles,
                            "node-cycles",
                            || {
                                black_box(traced.run(0.9));
                            },
                        );
                        std::fs::remove_file(&path).ok();
                    }
                    // Closed loop: the serial chain's cycle count is seed-
                    // deterministic, so one reference run sizes the metric.
                    let cap = chain.suggested_max_cycles_for(sim.config());
                    let seed = sim.config().seed;
                    let ref_cycles =
                        sim.run_workload_seeded(&chain, seed, cap).completion_cycles;
                    b.run_throughput(
                        &format!("{name}/chain/{}/{}/t{threads}", policy.name(), scan.name()),
                        nodes * ref_cycles,
                        "node-cycles",
                        || {
                            black_box(sim.run_workload_seeded(&chain, seed, cap));
                        },
                    );
                }
            }
        }
    }

    // Imbalance twins on T(16,16,16): the work-balanced shard planner
    // (hotspot) and the serial fast path (near-idle), each under both
    // scan modes so the gate sees active/full pairs.
    {
        let g = topology::torus(&[16, 16, 16]);
        let nodes = g.order() as u64;
        for scan in ScanMode::ALL {
            for threads in THREADS {
                // One hot destination: adaptive routing piles traffic —
                // and per-cycle work — into one corner of the node space.
                let policy = RoutePolicy::AdaptiveMin;
                let cfg = open_cfg(policy, scan, threads);
                let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                let sim = Simulator::new(g.clone(), TrafficPattern::HotSpot, cfg);
                b.run_throughput(
                    &format!(
                        "T(16,16,16)/hotspot-imbalance/{}/{}/t{threads}",
                        policy.name(),
                        scan.name()
                    ),
                    nodes * cycles,
                    "node-cycles",
                    || {
                        black_box(sim.run(0.2));
                    },
                );
                // Near-idle: 1% offered load, a handful of active nodes
                // per cycle.
                let policy = RoutePolicy::Dor;
                let cfg = open_cfg(policy, scan, threads);
                let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                b.run_throughput(
                    &format!(
                        "T(16,16,16)/near-idle/{}/{}/t{threads}",
                        policy.name(),
                        scan.name()
                    ),
                    nodes * cycles,
                    "node-cycles",
                    || {
                        black_box(sim.run(0.01));
                    },
                );
            }
        }
    }

    // Degraded-mode twins on T(16,16,16): saturated adaptive open loop
    // with 2% of links and 0.5% of routers failed. The fault masks ride
    // the port-selection hot path, so the delta against the matching
    // pristine `open@0.9` cases above prices degraded-mode routing.
    {
        let g = topology::torus(&[16, 16, 16]);
        let nodes = g.order() as u64;
        for scan in ScanMode::ALL {
            for threads in THREADS {
                let policy = RoutePolicy::AdaptiveMin;
                let cfg = SimConfig {
                    link_fault_rate: 0.02,
                    node_fault_rate: 0.005,
                    ..open_cfg(policy, scan, threads)
                };
                let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                assert!(sim.faults().is_some(), "fault rates must derive a fault set");
                b.run_throughput(
                    &format!(
                        "T(16,16,16)/faulted@0.9/{}/{}/t{threads}",
                        policy.name(),
                        scan.name()
                    ),
                    nodes * cycles,
                    "node-cycles",
                    || {
                        black_box(sim.run(0.9));
                    },
                );
            }
        }
    }

    // The parallel engine's headline case: a bulk-synchronous stencil on
    // T(32,32,32) keeps all 32k nodes exchanging halos at once, so Phase
    // B dominates the cycle and the shard kernels have maximal work to
    // split. The t4/t1 node-cycles/s ratio here is the tracked parallel
    // speedup (target ≥2× at 4 threads).
    {
        let g = topology::torus(&[32, 32, 32]);
        let nodes = g.order() as u64;
        let params = WorkloadParams { iters: 1, ..Default::default() };
        let wl = generate(WorkloadKind::Stencil, &g, &params);
        for threads in THREADS {
            let cfg = SimConfig {
                warmup_cycles: 0,
                measure_cycles: 0,
                threads,
                ..SimConfig::default()
            };
            let sim = Simulator::for_workload(g.clone(), cfg);
            let cap = wl.suggested_max_cycles_for(sim.config());
            let seed = sim.config().seed;
            let ref_cycles = sim.run_workload_seeded(&wl, seed, cap).completion_cycles;
            b.run_throughput(
                &format!("T(32,32,32)/stencil/dor/active/t{threads}"),
                nodes * ref_cycles,
                "node-cycles",
                || {
                    black_box(sim.run_workload_seeded(&wl, seed, cap));
                },
            );
        }
    }

    // Table-construction trajectory: the closed-form dispatch routers
    // building the compact store directly (serial and 4-thread) vs the
    // legacy path (serial hierarchical walk into the boxed table, then a
    // compaction pass). All three variants produce byte-identical stores
    // (pinned by `tests/routing_dispatch.rs`), so only the wall clock —
    // and the `route_bytes_per_node` carried in `extra` — differ.
    {
        use lattice_networks::routing::{CompactRoutes, RoutingTable};
        let cases: Vec<(&str, lattice_networks::lattice::LatticeGraph)> = vec![
            ("T(16,16,16)", topology::torus(&[16, 16, 16])),
            ("T(32,32,32)", topology::torus(&[32, 32, 32])),
            ("T(64,64,64)", topology::torus(&[64, 64, 64])),
            ("FCC(32)", topology::fcc(32)),
            ("BCC(16)", topology::bcc(16)),
        ];
        for (name, g) in cases {
            let nodes = g.order() as u64;
            let reference = CompactRoutes::build(&g, 1);
            let extra = format!(
                "{{\"route_bytes_per_node\":{:.3}}}",
                reference.bytes() as f64 / nodes as f64
            );
            drop(reference);
            b.run_throughput_extra(
                &format!("{name}/table_build/serial-hier/t1"),
                nodes,
                "nodes",
                &extra,
                || {
                    let table = RoutingTable::build_hierarchical(&g);
                    black_box(CompactRoutes::from_table(&table));
                },
            );
            for threads in THREADS {
                b.run_throughput_extra(
                    &format!("{name}/table_build/dispatch/t{threads}"),
                    nodes,
                    "nodes",
                    &extra,
                    || {
                        black_box(CompactRoutes::build(&g, threads));
                    },
                );
            }
        }
    }
}
