//! Bench: regenerate Table 2 (lifted/hybrid lattice graphs) and time the
//! common-lift + BFS pipeline.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::coordinator::experiments;
use lattice_networks::lattice::common_lift;
use lattice_networks::metrics::distance_distribution;
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("table2");

    let t = experiments::table2(&[2, 4]);
    print!("{}", t.render());

    for a in [2i64, 4] {
        let g = topology::fcc4d(a);
        b.run_throughput(&format!("bfs/4D-FCC({a})"), g.order() as u64, "nodes", || {
            black_box(distance_distribution(&g));
        });
        let h = topology::hybrid_pc_bcc(a);
        b.run_throughput(
            &format!("bfs/PC⊞BCC({a})"),
            h.order() as u64,
            "nodes",
            || {
                black_box(distance_distribution(&h));
            },
        );
    }

    b.run("common_lift/PC(8)⊞BCC(4)", || {
        black_box(common_lift(
            topology::pc(8).matrix(),
            topology::bcc(4).matrix(),
        ));
    });

    b.run("regenerate", || {
        black_box(experiments::table2(&[2]));
    });
}
