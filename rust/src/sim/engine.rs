//! The synchronous cycle engine: virtual cut-through routers with 3 VCs,
//! bubble flow control, DOR service over minimal routing records.
//!
//! Model (see module docs in `sim/mod.rs` for the INSEE correspondence):
//! each node has `2n` input ports (one per incoming link) with `vc_count`
//! FIFO queues each, an injection queue, and an ejection channel. One
//! packet transfer per link at a time; a transfer started at `t` holds the
//! link until `t + packet_size` (16-phit serialization), delivers the head
//! downstream at `t + 1` (cut-through), and frees the upstream buffer slot
//! at `t + packet_size` (tail departure).

use crate::lattice::LatticeGraph;
use crate::routing::{Record, RoutingTable};

use super::config::SimConfig;
use super::rng::Rng;
use super::stats::{LatencyStats, SimResult};
use super::traffic::{Traffic, TrafficPattern};

/// Max supported graph dimension (the paper uses up to 6).
pub const MAX_DIM: usize = 6;

const NO_AXIS: u8 = u8::MAX;
const FIFO_CAP: usize = 8;

/// A packet in flight.
#[derive(Clone, Copy, Debug)]
struct Packet {
    /// Remaining signed hops per dimension.
    record: [i16; MAX_DIM],
    /// Virtual channel (0..vc_count), fixed end-to-end.
    vc: u8,
    /// Axis of the last hop (`NO_AXIS` right after injection) — bubble
    /// condition: entering a new dimensional ring needs 2 free slots.
    last_axis: u8,
    /// Injection cycle (for latency).
    inject_time: u64,
    /// Cycle at which the head is present and routable at the current node.
    head_ready: u64,
    /// Cached desired output port (recomputed on every hop; `ports` value
    /// means ejection). Avoids re-deriving DOR per cycle on the hot scan.
    next_port: u8,
}

/// Fixed-capacity FIFO of packet ids with slot reservations.
///
/// `len` counts queued packets; `reserved` additionally counts slots whose
/// packet has been forwarded but whose tail has not yet fully left (VCT
/// guarantees the space stays claimed until the tail drains).
#[derive(Clone, Copy, Debug)]
struct Fifo {
    slots: [u32; FIFO_CAP],
    head: u8,
    len: u8,
    reserved: u8,
    /// Cached output port of the head packet — the arbitration scan reads
    /// only the FIFO array, never the packet arena (cache locality is the
    /// engine's top bottleneck; see EXPERIMENTS.md §Perf).
    head_port: u8,
    /// Cached `head_ready` of the head packet.
    head_ready: u64,
}

impl Fifo {
    const EMPTY: Fifo = Fifo {
        slots: [0; FIFO_CAP],
        head: 0,
        len: 0,
        reserved: 0,
        head_port: 0,
        head_ready: 0,
    };

    #[inline]
    fn push(&mut self, pid: u32, ready: u64, port: u8) {
        debug_assert!((self.len as usize) < FIFO_CAP);
        let tail = (self.head as usize + self.len as usize) % FIFO_CAP;
        self.slots[tail] = pid;
        if self.len == 0 {
            self.head_ready = ready;
            self.head_port = port;
        }
        self.len += 1;
        self.reserved += 1;
    }

    #[inline]
    fn front(&self) -> Option<u32> {
        (self.len > 0).then(|| self.slots[self.head as usize])
    }

    /// Refresh the cached head metadata after a pop.
    #[inline]
    fn refresh_head(&mut self, packets: &[Packet]) {
        if self.len > 0 {
            let pkt = &packets[self.slots[self.head as usize] as usize];
            self.head_ready = pkt.head_ready;
            self.head_port = pkt.next_port;
        }
    }

    #[inline]
    fn pop(&mut self) -> u32 {
        debug_assert!(self.len > 0);
        let pid = self.slots[self.head as usize];
        self.head = ((self.head as usize + 1) % FIFO_CAP) as u8;
        self.len -= 1;
        // `reserved` stays up; released by the tail-departure event.
        pid
    }

    #[inline]
    fn release(&mut self) {
        debug_assert!(self.reserved > 0);
        self.reserved -= 1;
    }
}

/// Deferred events, bucketed on a calendar ring (all delays equal the
/// packet serialization time, so the ring is tiny).
#[derive(Clone, Copy, Debug)]
enum Event {
    /// Tail left an input buffer: release its reservation.
    FreeInput(u32),
    /// Tail left an injection queue slot.
    FreeInj(u32),
    /// Tail fully received at the destination: complete delivery.
    Deliver(u32),
}

/// Compact routing store: tie sets of i16 records per difference index.
struct CompactRoutes {
    offsets: Vec<u32>,
    records: Vec<[i16; MAX_DIM]>,
}

impl CompactRoutes {
    fn build(table: &RoutingTable) -> Self {
        let g = table.graph();
        let n = g.order();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut records = Vec::new();
        offsets.push(0u32);
        for v in 0..n {
            // tie set for difference = label(v) (src = 0)
            for tie in table.ties_by_index(0, v) {
                records.push(compact(tie));
            }
            offsets.push(records.len() as u32);
        }
        Self { offsets, records }
    }

    #[inline]
    fn ties(&self, diff_idx: usize) -> &[[i16; MAX_DIM]] {
        &self.records[self.offsets[diff_idx] as usize..self.offsets[diff_idx + 1] as usize]
    }
}

/// DOR output port of a remaining record: lowest nonzero dimension
/// (`ports` = ejection).
#[inline]
fn port_of_record(record: &[i16; MAX_DIM], dim: usize, ports: usize) -> u8 {
    for axis in 0..dim {
        let h = record[axis];
        if h != 0 {
            return (2 * axis + usize::from(h < 0)) as u8;
        }
    }
    ports as u8
}

fn compact(r: &Record) -> [i16; MAX_DIM] {
    let mut out = [0i16; MAX_DIM];
    for (i, &x) in r.iter().enumerate() {
        out[i] = i16::try_from(x).expect("hop count exceeds i16");
    }
    out
}

/// The simulator: immutable tables + per-run mutable state.
pub struct Simulator {
    g: LatticeGraph,
    cfg: SimConfig,
    pattern: TrafficPattern,
    dim: usize,
    ports: usize,
    nodes: usize,
    /// `neighbor[u * ports + p]`: node reached from `u` via port `p`
    /// (`p = 2*axis + (sign < 0)`).
    neighbor: Vec<u32>,
    /// Flattened labels, `dim` entries per node.
    labels: Vec<i64>,
    routes: CompactRoutes,
}

/// Per-run mutable state.
struct State {
    packets: Vec<Packet>,
    free_pids: Vec<u32>,
    /// Input FIFOs: `(u * ports + p) * vc_count + vc`.
    inputs: Vec<Fifo>,
    /// Injection queue per node.
    inj: Vec<Fifo>,
    /// Per-node occupancy bitmask over the local input FIFOs
    /// (bit = p_in * vc_count + vc): lets the arbitration scan visit only
    /// non-empty queues (the dominant cost at low/mid load).
    occ: Vec<u64>,
    /// Link busy-until per `(u, p)`.
    link_busy: Vec<u64>,
    /// Ejection channel busy-until per node.
    eject_busy: Vec<u64>,
    /// Calendar ring of deferred events.
    calendar: Vec<Vec<Event>>,
    rng: Rng,
    // measurement
    now: u64,
    measure_start: u64,
    measure_end: u64,
    delivered_phits: u64,
    delivered_packets: u64,
    /// Phits transferred per dimension axis during the measurement window
    /// (the §3.4 link-utilization instrumentation).
    phits_by_axis: [u64; MAX_DIM],
    injected_packets: u64,
    source_dropped: u64,
    latency: LatencyStats,
    /// Destination node per live packet (parallel to `packets`).
    dests: Vec<u32>,
}

impl Simulator {
    /// Build a simulator with a prebuilt routing table (must belong to the
    /// same graph).
    pub fn with_table(g: LatticeGraph, table: &RoutingTable, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let dim = g.dim();
        assert!(dim <= MAX_DIM, "dimension {dim} exceeds MAX_DIM");
        assert!(cfg.queue_packets as usize <= FIFO_CAP);
        assert!(cfg.injection_queue_packets as usize <= FIFO_CAP);
        let nodes = g.order();
        let ports = 2 * dim;
        let mut neighbor = vec![0u32; nodes * ports];
        let mut labels = vec![0i64; nodes * dim];
        for u in 0..nodes {
            let label = g.label_of(u);
            labels[u * dim..(u + 1) * dim].copy_from_slice(&label);
            for axis in 0..dim {
                for (s, sign) in [(0usize, 1i64), (1, -1)] {
                    neighbor[u * ports + 2 * axis + s] = g.step(u, axis, sign) as u32;
                }
            }
        }
        let routes = CompactRoutes::build(table);
        Self { g, cfg, pattern, dim, ports, nodes, neighbor, labels, routes }
    }

    /// Build with the best available router for the graph (hierarchical —
    /// exactly minimal for any lattice graph).
    pub fn new(g: LatticeGraph, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let table = RoutingTable::build_hierarchical(&g);
        Self::with_table(g, &table, pattern, cfg)
    }

    pub fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Run one simulation at `offered_load` phits/(cycle·node).
    pub fn run(&self, offered_load: f64) -> SimResult {
        self.run_seeded(offered_load, self.cfg.seed)
    }

    /// Run with an explicit RNG seed (multi-seed averaging reuses the
    /// simulator's routing tables across runs).
    pub fn run_seeded(&self, offered_load: f64, seed: u64) -> SimResult {
        let cfg = &self.cfg;
        let ps = cfg.packet_size as u64;
        let cal_len = ps as usize + 2;
        let mut st = State {
            packets: Vec::with_capacity(4096),
            free_pids: Vec::new(),
            inputs: vec![Fifo::EMPTY; self.nodes * self.ports * cfg.vc_count],
            inj: vec![Fifo::EMPTY; self.nodes],
            occ: vec![0u64; self.nodes],
            link_busy: vec![0u64; self.nodes * self.ports],
            eject_busy: vec![0u64; self.nodes],
            calendar: vec![Vec::new(); cal_len],
            rng: Rng::new(seed ^ (offered_load.to_bits().rotate_left(17))),
            now: 0,
            measure_start: cfg.warmup_cycles,
            measure_end: cfg.warmup_cycles + cfg.measure_cycles,
            delivered_phits: 0,
            delivered_packets: 0,
            phits_by_axis: [0; MAX_DIM],
            injected_packets: 0,
            source_dropped: 0,
            latency: LatencyStats::new(),
            dests: Vec::with_capacity(4096),
        };
        let traffic = Traffic::build(self.pattern, &self.g, &mut st.rng);
        let inject_prob = offered_load / cfg.packet_size as f64;
        let total = cfg.warmup_cycles + cfg.measure_cycles + cfg.drain_cycles;

        let mut scratch = vec![0i64; self.dim];
        // Per-cycle arbitration scratch: one winner slot per output port
        // (+1 for ejection), with reservoir counts for random choice.
        let mut winners: Vec<CandSlot> = vec![CandSlot::NONE; self.ports + 1];

        for now in 0..total {
            st.now = now;
            self.apply_events(&mut st);
            self.inject(&mut st, &traffic, inject_prob, &mut scratch);
            self.advance(&mut st, &mut winners);
        }

        // Per-axis link utilization: fraction of link-cycles carrying phits
        // (2N unidirectional links per axis).
        let denom = 2.0 * self.nodes as f64 * cfg.measure_cycles as f64;
        let link_utilization: Vec<f64> = (0..self.dim)
            .map(|a| st.phits_by_axis[a] as f64 / denom)
            .collect();
        SimResult {
            offered_load,
            link_utilization,
            accepted_load: st.delivered_phits as f64
                / (cfg.measure_cycles as f64 * self.nodes as f64),
            avg_latency: st.latency.mean(),
            p99_latency: st.latency.percentile(0.99),
            max_latency: st.latency.max(),
            delivered_packets: st.delivered_packets,
            source_dropped: st.source_dropped,
            injected_packets: st.injected_packets,
            cycles: cfg.measure_cycles,
            nodes: self.nodes,
        }
    }

    #[inline]
    fn apply_events(&self, st: &mut State) {
        let ps = self.cfg.packet_size as u64;
        let slot = (st.now % (ps + 2)) as usize;
        let events = std::mem::take(&mut st.calendar[slot]);
        for ev in events {
            match ev {
                Event::FreeInput(fifo) => st.inputs[fifo as usize].release(),
                Event::FreeInj(node) => st.inj[node as usize].release(),
                Event::Deliver(pid) => {
                    let p = st.packets[pid as usize];
                    let lat = st.now - p.inject_time;
                    if st.now >= st.measure_start && st.now < st.measure_end {
                        st.delivered_phits += ps;
                        st.delivered_packets += 1;
                        st.latency.record(lat);
                    }
                    st.free_pids.push(pid);
                }
            }
        }
    }

    #[inline]
    fn schedule(&self, st: &mut State, delay: u64, ev: Event) {
        let ps = self.cfg.packet_size as u64;
        let slot = ((st.now + delay) % (ps + 2)) as usize;
        st.calendar[slot].push(ev);
    }

    fn inject(&self, st: &mut State, traffic: &Traffic, prob: f64, scratch: &mut [i64]) {
        if prob <= 0.0 {
            return;
        }
        let cap = self.cfg.injection_queue_packets;
        for u in 0..self.nodes {
            if !st.rng.chance(prob) {
                continue;
            }
            let Some(dest) = traffic.destination_of(u, &mut st.rng) else {
                continue;
            };
            if st.inj[u].reserved as u32 >= cap {
                st.source_dropped += 1;
                continue;
            }
            // Difference label -> routing tie set -> random minimal record.
            for i in 0..self.dim {
                scratch[i] = self.labels[dest * self.dim + i] - self.labels[u * self.dim + i];
            }
            self.g.reduce_in_place(scratch);
            let diff_idx = self.g.index_of(scratch);
            let ties = self.routes.ties(diff_idx);
            let record = ties[st.rng.below(ties.len())];
            let vc = st.rng.below(self.cfg.vc_count) as u8;
            let next_port = port_of_record(&record, self.dim, self.ports);
            let pid = self.alloc_packet(
                st,
                Packet {
                    record,
                    vc,
                    last_axis: NO_AXIS,
                    inject_time: st.now,
                    head_ready: st.now,
                    next_port,
                },
                dest as u32,
            );
            st.inj[u].push(pid, st.now, next_port);
            st.injected_packets += 1;
        }
    }

    #[inline]
    fn alloc_packet(&self, st: &mut State, p: Packet, dest: u32) -> u32 {
        if let Some(pid) = st.free_pids.pop() {
            st.packets[pid as usize] = p;
            st.dests[pid as usize] = dest;
            pid
        } else {
            st.packets.push(p);
            st.dests.push(dest);
            (st.packets.len() - 1) as u32
        }
    }


    /// Arbitration + transfers for every node.
    fn advance(&self, st: &mut State, winners: &mut [CandSlot]) {
        let vc_count = self.cfg.vc_count;
        let cap = self.cfg.queue_packets;
        let node_base = self.ports * vc_count;
        for u in 0..self.nodes {
            let mut mask = st.occ[u];
            let inj_head = st.inj[u].front();
            if mask == 0 && inj_head.is_none() {
                continue; // idle node: nothing can move
            }
            for w in winners.iter_mut() {
                *w = CandSlot::NONE;
            }
            // Transit candidates: heads of the non-empty input FIFOs only.
            // Everything needed (ready time, output port, VC, bubble
            // "entering" test) is derivable from the FIFO entry itself.
            while mask != 0 {
                let bit = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let fifo_idx = u * node_base + bit;
                let fifo = &st.inputs[fifo_idx];
                if fifo.head_ready > st.now {
                    continue;
                }
                let port = fifo.head_port as usize;
                let vc = bit % vc_count;
                let entering = port < self.ports && (bit / vc_count) / 2 != port / 2;
                if !self.eligible(st, u, port, entering, vc, cap) {
                    continue;
                }
                winners[port].offer(true, Cand { fifo: fifo_idx as u32, is_inj: false }, &mut st.rng);
            }
            // Injection candidate (always "entering" for the bubble rule).
            if inj_head.is_some() {
                let fifo = &st.inj[u];
                if fifo.head_ready <= st.now {
                    let port = fifo.head_port as usize;
                    let vc = st.packets[fifo.slots[fifo.head as usize] as usize].vc as usize;
                    if self.eligible(st, u, port, true, vc, cap) {
                        winners[port].offer(false, Cand { fifo: u as u32, is_inj: true }, &mut st.rng);
                    }
                }
            }
            // Fire winners.
            for port in 0..=self.ports {
                let slot = winners[port];
                let Some(cand) = slot.get() else { continue };
                self.start_transfer(st, u, port, cand);
            }
        }
    }

    /// Can the head packet move through output `port` of node `u` now?
    /// `entering` = the hop starts a new dimensional ring (bubble rule).
    #[inline]
    fn eligible(&self, st: &State, u: usize, port: usize, entering: bool, vc: usize, cap: u32) -> bool {
        if port == self.ports {
            // Ejection.
            return st.eject_busy[u] <= st.now;
        }
        if st.link_busy[u * self.ports + port] > st.now {
            return false;
        }
        let need = if self.cfg.bubble && entering { 2 } else { 1 };
        let v = self.neighbor[u * self.ports + port] as usize;
        let fifo = &st.inputs[(v * self.ports + port) * self.cfg.vc_count + vc];
        (fifo.reserved as u32) + need <= cap
    }

    /// Commit a transfer of the head packet of `cand` through `port`.
    fn start_transfer(&self, st: &mut State, u: usize, port: usize, cand: Cand) {
        let ps = self.cfg.packet_size as u64;
        let node_base = self.ports * self.cfg.vc_count;
        let pid = if cand.is_inj {
            let pid = st.inj[u].pop();
            let (inj, packets) = (&mut st.inj[u], &st.packets);
            inj.refresh_head(packets);
            self.schedule(st, ps, Event::FreeInj(u as u32));
            pid
        } else {
            let pid = st.inputs[cand.fifo as usize].pop();
            let (fifo, packets) = (&mut st.inputs[cand.fifo as usize], &st.packets);
            fifo.refresh_head(packets);
            if fifo.len == 0 {
                st.occ[u] &= !(1u64 << (cand.fifo as usize - u * node_base));
            }
            self.schedule(st, ps, Event::FreeInput(cand.fifo));
            pid
        };
        if port == self.ports {
            // Ejection: tail fully received at now + ps.
            debug_assert_eq!(st.dests[pid as usize] as usize, u, "eject at wrong node");
            st.eject_busy[u] = st.now + ps;
            self.schedule(st, ps, Event::Deliver(pid));
            return;
        }
        let axis = port / 2;
        let sign: i16 = if port % 2 == 0 { 1 } else { -1 };
        let v = self.neighbor[u * self.ports + port] as usize;
        st.link_busy[u * self.ports + port] = st.now + ps;
        if st.now >= st.measure_start && st.now < st.measure_end {
            st.phits_by_axis[axis] += ps;
        }
        let (vc, next_port) = {
            let pkt = &mut st.packets[pid as usize];
            pkt.record[axis] -= sign;
            pkt.last_axis = axis as u8;
            pkt.head_ready = st.now + 1;
            pkt.next_port = port_of_record(&pkt.record, self.dim, self.ports);
            (pkt.vc as usize, pkt.next_port)
        };
        let local = port * self.cfg.vc_count + vc;
        st.inputs[v * node_base + local].push(pid, st.now + 1, next_port);
        st.occ[v] |= 1u64 << local;
    }
}

/// A transfer candidate (which FIFO holds it).
#[derive(Clone, Copy, Debug)]
struct Cand {
    fifo: u32,
    is_inj: bool,
}

/// Reservoir-sampling winner slot per output port: random arbitration with
/// strict transit-over-injection priority.
#[derive(Clone, Copy, Debug)]
struct CandSlot {
    cand: Option<Cand>,
    transit: bool,
    count: u32,
}

impl CandSlot {
    const NONE: CandSlot = CandSlot { cand: None, transit: false, count: 0 };

    #[inline]
    fn offer(&mut self, is_transit: bool, cand: Cand, rng: &mut Rng) {
        if is_transit && !self.transit {
            // Transit preempts any injection candidate.
            *self = CandSlot { cand: Some(cand), transit: true, count: 1 };
            return;
        }
        if is_transit == self.transit {
            self.count += 1;
            if self.count == 1 || rng.below(self.count as usize) == 0 {
                self.cand = Some(cand);
            }
        }
        // injection offered while transit held: ignored.
    }

    #[inline]
    fn get(&self) -> Option<Cand> {
        self.cand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{fcc, torus};

    fn quick_cfg() -> SimConfig {
        SimConfig {
            warmup_cycles: 200,
            measure_cycles: 1000,
            ..SimConfig::default()
        }
    }

    #[test]
    fn zero_load_zero_traffic() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.0);
        assert_eq!(r.delivered_packets, 0);
        assert_eq!(r.accepted_load, 0.0);
    }

    #[test]
    fn low_load_accepted_equals_offered() {
        let sim = Simulator::new(torus(&[4, 4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.1);
        assert!(r.delivered_packets > 0);
        // At 10% load a torus is far from saturation: accepted ~ offered.
        assert!(
            (r.accepted_load - 0.1).abs() < 0.03,
            "accepted {} vs offered 0.1",
            r.accepted_load
        );
        assert_eq!(r.source_dropped, 0, "no drops far below saturation");
    }

    #[test]
    fn latency_bounded_below_by_distance() {
        // At very low load latency ~ hops + packet_size.
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(0.02);
        let ps = sim.config().packet_size as f64;
        assert!(r.avg_latency >= ps, "latency {} < packet size", r.avg_latency);
        assert!(
            r.avg_latency < ps + 30.0,
            "uncongested latency too high: {}",
            r.avg_latency
        );
    }

    #[test]
    fn saturation_accepts_less_than_offered() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(1.0);
        assert!(r.accepted_load < 0.99);
        assert!(r.source_dropped > 0);
        // but still substantial:
        assert!(r.accepted_load > 0.2, "throughput collapsed: {}", r.accepted_load);
    }

    #[test]
    fn no_deadlock_at_high_load_twisted() {
        // Twisted topology + full load; bubble must keep packets moving.
        let sim = Simulator::new(fcc(2), TrafficPattern::Uniform, quick_cfg());
        let r = sim.run(1.0);
        assert!(r.delivered_packets > 100, "only {} delivered", r.delivered_packets);
    }

    #[test]
    fn deterministic_given_seed() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let a = sim.run(0.3);
        let b = sim.run(0.3);
        assert_eq!(a.delivered_packets, b.delivered_packets);
        assert_eq!(a.avg_latency, b.avg_latency);
    }

    #[test]
    fn all_patterns_deliver() {
        for pattern in TrafficPattern::ALL {
            let sim = Simulator::new(torus(&[4, 4]), pattern, quick_cfg());
            let r = sim.run(0.2);
            assert!(r.delivered_packets > 0, "{:?}", pattern);
        }
    }

    #[test]
    fn throughput_monotone_then_saturates() {
        let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
        let lo = sim.run(0.1).accepted_load;
        let mid = sim.run(0.3).accepted_load;
        assert!(mid > lo);
    }
}
