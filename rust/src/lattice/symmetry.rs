//! Symmetry machinery: signed permutations, the `PM = MQ` automorphism
//! test (Lemma 36), the linear-symmetry criterion (Definition 37), and the
//! Theorem 12 / Theorem 47 symmetric families.

use crate::math::IMat;

use super::LatticeGraph;

/// A signed permutation of length `n` (Definition 34): `e_i ↦ s_i e_{π(i)}`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SignedPerm {
    /// Target axis for each source axis.
    pub perm: Vec<usize>,
    /// Sign for each source axis (`+1` / `-1`).
    pub signs: Vec<i64>,
}

impl SignedPerm {
    /// Identity.
    pub fn identity(n: usize) -> Self {
        Self { perm: (0..n).collect(), signs: vec![1; n] }
    }

    /// The associated matrix: column `i` is `s_i e_{π(i)}`.
    pub fn matrix(&self) -> IMat {
        let n = self.perm.len();
        let mut m = IMat::zeros(n, n);
        for i in 0..n {
            m[(self.perm[i], i)] = self.signs[i];
        }
        m
    }

    /// Apply to a vector.
    pub fn apply(&self, v: &[i64]) -> Vec<i64> {
        let n = self.perm.len();
        let mut out = vec![0i64; n];
        for i in 0..n {
            out[self.perm[i]] = self.signs[i] * v[i];
        }
        out
    }

    /// Composition `self ∘ other`.
    pub fn compose(&self, other: &SignedPerm) -> SignedPerm {
        let n = self.perm.len();
        let mut perm = vec![0usize; n];
        let mut signs = vec![0i64; n];
        for i in 0..n {
            perm[i] = self.perm[other.perm[i]];
            signs[i] = self.signs[other.perm[i]] * other.signs[i];
        }
        SignedPerm { perm, signs }
    }

    /// Multiplicative order of the signed permutation.
    pub fn order(&self) -> usize {
        let n = self.perm.len();
        let id = SignedPerm::identity(n);
        let mut cur = self.clone();
        let mut k = 1;
        while cur != id {
            cur = self.compose(&cur);
            k += 1;
            assert!(k <= 2 * (1..=n).product::<usize>(), "order runaway");
        }
        k
    }

    /// Is this the identity?
    pub fn is_identity(&self) -> bool {
        self.signs.iter().all(|&s| s == 1)
            && self.perm.iter().enumerate().all(|(i, &p)| p == i)
    }

    /// Does it only change signs (fix every axis)?
    pub fn is_sign_change(&self) -> bool {
        self.perm.iter().enumerate().all(|(i, &p)| p == i)
    }
}

/// All `n! * 2^n` signed permutations of length `n`.
pub fn signed_permutations(n: usize) -> Vec<SignedPerm> {
    let mut perms: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = (0..n).collect();
    heap_permutations(&mut cur, n, &mut perms);
    let mut out = Vec::with_capacity(perms.len() << n);
    for p in perms {
        for mask in 0..(1u32 << n) {
            let signs: Vec<i64> = (0..n)
                .map(|i| if mask & (1 << i) != 0 { -1 } else { 1 })
                .collect();
            out.push(SignedPerm { perm: p.clone(), signs });
        }
    }
    out
}

fn heap_permutations(arr: &mut Vec<usize>, k: usize, out: &mut Vec<Vec<usize>>) {
    if k == 1 {
        out.push(arr.clone());
        return;
    }
    for i in 0..k {
        heap_permutations(arr, k - 1, out);
        if k % 2 == 0 {
            arr.swap(i, k - 1);
        } else {
            arr.swap(0, k - 1);
        }
    }
}

/// Lemma 36: `φ(x) = Px` is an automorphism of `G(M)` iff `M^{-1} P M` is
/// integral.
pub fn is_automorphism(m: &IMat, p: &SignedPerm) -> bool {
    m.inverse_times_is_integral(&p.matrix().mul(m))
}

/// The stabilizer `LAut(G(M), 0)`: all signed permutations that are
/// automorphisms.
pub fn linear_stabilizer(m: &IMat) -> Vec<SignedPerm> {
    signed_permutations(m.dim())
        .into_iter()
        .filter(|p| is_automorphism(m, p))
        .collect()
}

/// Definition 37: `G(M)` is linearly symmetric iff for every axis `i` some
/// stabilizer element maps `e_1 ↦ ±e_i`.
pub fn is_linearly_symmetric(m: &IMat) -> bool {
    let n = m.dim();
    let stab = linear_stabilizer(m);
    (0..n).all(|i| stab.iter().any(|p| p.perm[0] == i))
}

impl LatticeGraph {
    /// Is this graph linearly symmetric (vertex- and edge-symmetric via
    /// linear automorphisms, the paper's working notion of "symmetric")?
    pub fn is_symmetric(&self) -> bool {
        is_linearly_symmetric(self.matrix())
    }
}

/// Theorem 12 / 47 family 1: the circulant form
/// `[[a, c, b], [b, a, c], [c, b, a]]`.
pub fn symmetric_family_circulant(a: i64, b: i64, c: i64) -> IMat {
    IMat::from_rows(&[&[a, c, b], &[b, a, c], &[c, b, a]])
}

/// Theorem 12 / 47 family 2:
/// `[[a, b, c], [a, c, -b-c], [a, -b-c, b]]`.
pub fn symmetric_family_alt(a: i64, b: i64, c: i64) -> IMat {
    IMat::from_rows(&[
        &[a, b, c],
        &[a, c, -b - c],
        &[a, -b - c, b],
    ])
}

/// Theorem 20's finite computation: enumerate all Hermite lifts
/// `[[2a,0,a,x],[0,2a,a,y],[0,0,a,z],[0,0,0,1]]` of BCC(a) (t = 1 wlog per
/// the proof) and return those that are linearly symmetric. The theorem
/// asserts the result is empty.
pub fn symmetric_bcc_lifts(a: i64) -> Vec<IMat> {
    let mut found = Vec::new();
    for x in 0..2 * a {
        for y in 0..2 * a {
            for z in 0..a {
                let l = IMat::from_rows(&[
                    &[2 * a, 0, a, x],
                    &[0, 2 * a, a, y],
                    &[0, 0, a, z],
                    &[0, 0, 0, 1],
                ]);
                if is_linearly_symmetric(&l) {
                    found.push(l);
                }
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, pc};

    #[test]
    fn count_signed_permutations() {
        // n! * 2^n; Table 4 lists the 48 for n = 3.
        assert_eq!(signed_permutations(1).len(), 2);
        assert_eq!(signed_permutations(2).len(), 8);
        assert_eq!(signed_permutations(3).len(), 48);
        assert_eq!(signed_permutations(4).len(), 384);
    }

    #[test]
    fn signed_perm_orders_table4() {
        // Lemma 42's premise: signed permutations of length 3 have orders
        // 1, 2, 3, 4 or 6 only.
        for p in signed_permutations(3) {
            let o = p.order();
            assert!([1, 2, 3, 4, 6].contains(&o), "unexpected order {o}");
        }
    }

    #[test]
    fn perm_matrix_is_unimodular() {
        for p in signed_permutations(3) {
            assert!(p.matrix().is_unimodular());
        }
    }

    #[test]
    fn apply_matches_matrix() {
        for p in signed_permutations(3).into_iter().take(20) {
            let v = [3i64, -5, 7];
            assert_eq!(p.apply(&v), p.matrix().mul_vec(&v));
        }
    }

    #[test]
    fn compose_matches_matrix_product() {
        let perms = signed_permutations(3);
        for a in perms.iter().step_by(7) {
            for b in perms.iter().step_by(11) {
                let ab = a.compose(b);
                assert_eq!(ab.matrix(), a.matrix().mul(&b.matrix()));
            }
        }
    }

    #[test]
    fn crystals_are_symmetric() {
        for a in [2i64, 3] {
            assert!(pc(a).is_symmetric(), "PC({a})");
            assert!(fcc(a).is_symmetric(), "FCC({a})");
            assert!(bcc(a).is_symmetric(), "BCC({a})");
        }
    }

    #[test]
    fn mixed_radix_torus_not_symmetric() {
        assert!(!LatticeGraph::torus(&[4, 2, 2]).is_symmetric());
        assert!(!LatticeGraph::torus(&[8, 4, 4]).is_symmetric());
    }

    #[test]
    fn theorem12_families_are_symmetric() {
        // Any member with det != 0 must pass the Definition 37 test.
        for (a, b, c) in [(3i64, 1, 0), (4, 2, 1), (2, 0, 1), (5, 1, 1)] {
            let m1 = symmetric_family_circulant(a, b, c);
            if m1.det() != 0 {
                assert!(is_linearly_symmetric(&m1), "circulant {a},{b},{c}");
            }
            let m2 = symmetric_family_alt(a, b, c);
            if m2.det() != 0 {
                assert!(is_linearly_symmetric(&m2), "alt {a},{b},{c}");
            }
        }
    }

    #[test]
    fn crystal_matrices_are_circulant_family_members() {
        // PC(a) = circulant(a, 0, 0); FCC/BCC are right-equivalent to
        // circulant members: FCC(a) = circulant(a, a, 0) rows permuted.
        assert!(is_linearly_symmetric(&symmetric_family_circulant(4, 0, 0)));
        assert!(is_linearly_symmetric(&symmetric_family_circulant(4, 4, 0)));
        assert!(is_linearly_symmetric(&symmetric_family_circulant(-4, 4, 4)));
    }

    #[test]
    fn theorem20_no_symmetric_bcc_lift() {
        for a in [1i64, 2] {
            let found = symmetric_bcc_lifts(a);
            assert!(
                found.is_empty(),
                "unexpected symmetric BCC({a}) lift: {:?}",
                found[0]
            );
        }
    }

    #[test]
    fn proposition17_4dbcc_symmetric() {
        for a in [1i64, 2, 3] {
            let m = IMat::from_rows(&[
                &[2 * a, 0, 0, a],
                &[0, 2 * a, 0, a],
                &[0, 0, 2 * a, a],
                &[0, 0, 0, a],
            ]);
            assert!(is_linearly_symmetric(&m), "4D-BCC({a})");
        }
    }

    #[test]
    fn proposition18_4dfcc_symmetric() {
        for a in [1i64, 2, 3] {
            let m = IMat::from_rows(&[
                &[2 * a, a, a, a],
                &[0, a, 0, 0],
                &[0, 0, a, 0],
                &[0, 0, 0, a],
            ]);
            assert!(is_linearly_symmetric(&m), "4D-FCC({a})");
        }
    }

    #[test]
    fn proposition19_lip_symmetric() {
        for a in [1i64, 2] {
            let m = IMat::from_rows(&[
                &[a, -a, -a, -a],
                &[a, a, -a, a],
                &[a, a, a, -a],
                &[a, -a, a, a],
            ]);
            assert!(is_linearly_symmetric(&m), "Lip({a})");
        }
    }

    #[test]
    fn stabilizer_contains_identity() {
        let stab = linear_stabilizer(pc(3).matrix());
        assert!(stab.iter().any(|p| p.is_identity()));
        // PC is fully symmetric: stabilizer is all 48 signed perms.
        assert_eq!(stab.len(), 48);
    }

    #[test]
    fn proposition17_rotation_is_automorphism() {
        // The proof's φ(e_i) = e_{i+1 mod n} on 4D-BCC.
        let a = 2;
        let m = IMat::from_rows(&[
            &[2 * a, 0, 0, a],
            &[0, 2 * a, 0, a],
            &[0, 0, 2 * a, a],
            &[0, 0, 0, a],
        ]);
        let rot = SignedPerm { perm: vec![1, 2, 3, 0], signs: vec![1, 1, 1, 1] };
        assert!(is_automorphism(&m, &rot));
    }
}
