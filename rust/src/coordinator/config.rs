//! Experiment configuration: a TOML-subset file format plus programmatic
//! defaults (the offline build carries no TOML dependency; the subset —
//! `[section]`, `key = value`, `#` comments, strings/numbers/bools/arrays
//! of numbers — covers everything the experiment drivers need).
//!
//! ```text
//! # experiments.toml
//! [sim]
//! packet_size = 16
//! num_vcs = 2
//! seeds = 5
//!
//! [sweep]
//! loads = [0.1, 0.2, 0.3]
//!
//! [experiment]
//! full = false
//! out_dir = "results"
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sim::config::{check_fault_rate, parse_fault_links, parse_fault_nodes};
use crate::sim::{RoutePolicy, ScanMode, SimConfig};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Nums(Vec<f64>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_nums(&self) -> Option<&[f64]> {
        match self {
            Value::Nums(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed config: `section.key -> value`.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    values: HashMap<String, Value>,
}

impl ExperimentConfig {
    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut values = HashMap::new();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value, got {raw:?}", no + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim()).with_context(|| format!("line {}", no + 1))?);
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// Insert/override a value (CLI overrides use this).
    pub fn set(&mut self, key: &str, v: Value) {
        self.values.insert(key.to_string(), v);
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Build a [`SimConfig`] from the `[sim]` section over Table 3 defaults.
    pub fn sim_config(&self) -> SimConfig {
        let d = SimConfig::default();
        SimConfig {
            packet_size: self.usize_or("sim.packet_size", d.packet_size as usize) as u32,
            // `vc_count` is accepted as a legacy alias for `num_vcs`.
            num_vcs: self.usize_or("sim.num_vcs", self.usize_or("sim.vc_count", d.num_vcs)),
            queue_packets: self.usize_or("sim.queue_packets", d.queue_packets as usize) as u32,
            injection_queue_packets: self
                .usize_or("sim.injection_queue_packets", d.injection_queue_packets as usize)
                as u32,
            bubble: self.bool_or("sim.bubble", d.bubble),
            warmup_cycles: self.usize_or("sim.warmup_cycles", d.warmup_cycles as usize) as u64,
            measure_cycles: self.usize_or("sim.measure_cycles", d.measure_cycles as usize) as u64,
            drain_cycles: self.usize_or("sim.drain_cycles", d.drain_cycles as usize) as u64,
            seed: self.usize_or("sim.seed", d.seed as usize) as u64,
            transit_priority: self.bool_or("sim.transit_priority", d.transit_priority),
            send_overhead: self.usize_or("sim.send_overhead", d.send_overhead as usize) as u64,
            recv_overhead: self.usize_or("sim.recv_overhead", d.recv_overhead as usize) as u64,
            packet_gap: self.usize_or("sim.packet_gap", d.packet_gap as usize) as u64,
            // Invalid values are loud, not clamped: an unknown policy
            // string panics here with the key name, and a zero latency or
            // width flows through to `Simulator::with_table`'s asserts —
            // a typo'd config must never silently run a different model.
            route_policy: match self.get("sim.route_policy").and_then(Value::as_str) {
                Some(s) => RoutePolicy::parse(s).unwrap_or_else(|| {
                    panic!("config sim.route_policy {s:?}: want dor, random or adaptive")
                }),
                None => d.route_policy,
            },
            link_latency: self.usize_or("sim.link_latency", d.link_latency as usize) as u64,
            axis_widths: self
                .get("sim.axis_widths")
                .and_then(Value::as_nums)
                .map(|v| v.iter().map(|&x| x as u32).collect())
                .unwrap_or_else(|| d.axis_widths.clone()),
            scan_mode: match self.get("sim.scan_mode").and_then(Value::as_str) {
                Some(s) => ScanMode::parse(s).unwrap_or_else(|| {
                    panic!("config sim.scan_mode {s:?}: want active or full")
                }),
                None => d.scan_mode,
            },
            trace: self
                .get("sim.trace")
                .and_then(Value::as_str)
                .map(str::to_string)
                .or(d.trace),
            sample_every: self.usize_or("sim.sample_every", d.sample_every as usize) as u64,
            threads: self.usize_or("sim.threads", d.threads),
            serial_cutoff: self.usize_or("sim.serial_cutoff", d.serial_cutoff),
            // Fault model: explicit specs use the CLI string syntax
            // (`"0-1,4-5"`, `"3,9"`); malformed specs and out-of-range
            // rates panic here with the key name — loud, like route_policy.
            fault_links: match self.get("sim.fault_links").and_then(Value::as_str) {
                Some(s) => parse_fault_links(s)
                    .unwrap_or_else(|e| panic!("config sim.fault_links {s:?}: {e}")),
                None => d.fault_links,
            },
            fault_nodes: match self.get("sim.fault_nodes").and_then(Value::as_str) {
                Some(s) => parse_fault_nodes(s)
                    .unwrap_or_else(|e| panic!("config sim.fault_nodes {s:?}: {e}")),
                None => d.fault_nodes,
            },
            link_fault_rate: {
                let r = self.f64_or("sim.link_fault_rate", d.link_fault_rate);
                check_fault_rate("sim.link_fault_rate", r).unwrap_or_else(|e| panic!("config {e}"));
                r
            },
            node_fault_rate: {
                let r = self.f64_or("sim.node_fault_rate", d.node_fault_rate);
                check_fault_rate("sim.node_fault_rate", r).unwrap_or_else(|e| panic!("config {e}"));
                r
            },
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value> {
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let nums: Result<Vec<f64>, _> = inner
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::parse::<f64>)
            .collect();
        return Ok(Value::Nums(nums.context("bad number array")?));
    }
    if let Ok(x) = v.parse::<f64>() {
        return Ok(Value::Num(x));
    }
    bail!("unparseable value {v:?}");
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
top = 1
[sim]
packet_size = 8
bubble = false
send_overhead = 12
packet_gap = 3
route_policy = "adaptive"
link_latency = 4
axis_widths = [2, 1, 1]
scan_mode = "full"
threads = 3
serial_cutoff = 32
seeds = 5        # trailing comment
[sweep]
loads = [0.1, 0.2, 0.3]
name = "uniform"
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("top", 0), 1);
        assert_eq!(c.usize_or("sim.packet_size", 16), 8);
        assert!(!c.bool_or("sim.bubble", true));
        assert_eq!(c.get("sweep.loads").unwrap().as_nums().unwrap(), &[0.1, 0.2, 0.3]);
        assert_eq!(c.str_or("sweep.name", "x"), "uniform");
    }

    #[test]
    fn sim_config_overrides_defaults() {
        let c = ExperimentConfig::parse(SAMPLE).unwrap();
        let sc = c.sim_config();
        assert_eq!(sc.packet_size, 8);
        assert!(!sc.bubble);
        assert_eq!(sc.num_vcs, 2); // untouched default
        assert_eq!(sc.send_overhead, 12);
        assert_eq!(sc.packet_gap, 3);
        assert_eq!(sc.recv_overhead, 0); // untouched default
        assert_eq!(sc.route_policy, RoutePolicy::AdaptiveMin);
        assert_eq!(sc.link_latency, 4);
        assert_eq!(sc.axis_widths, vec![2, 1, 1]);
        assert_eq!(sc.scan_mode, ScanMode::FullScan);
        assert_eq!(sc.threads, 3);
        assert_eq!(sc.serial_cutoff, 32);
        // Untouched default: the activity-proportional scan.
        assert_eq!(ExperimentConfig::default().sim_config().scan_mode, ScanMode::ActiveSet);
        // Untouched default: the serial engine.
        assert_eq!(ExperimentConfig::default().sim_config().threads, 1);
    }

    #[test]
    fn defaults_when_missing() {
        let c = ExperimentConfig::default();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert_eq!(c.sim_config(), SimConfig::default());
    }

    #[test]
    fn bad_lines_error() {
        assert!(ExperimentConfig::parse("key value\n").is_err());
        assert!(ExperimentConfig::parse("k = [1, two]\n").is_err());
        assert!(ExperimentConfig::parse("k = what\n").is_err());
    }

    #[test]
    fn num_vcs_key_and_legacy_alias() {
        let c = ExperimentConfig::parse("[sim]\nnum_vcs = 4\n").unwrap();
        assert_eq!(c.sim_config().num_vcs, 4);
        // Pre-escape configs wrote `vc_count`; it must keep working.
        let legacy = ExperimentConfig::parse("[sim]\nvc_count = 3\n").unwrap();
        assert_eq!(legacy.sim_config().num_vcs, 3);
        // The new key wins when both are present.
        let both = ExperimentConfig::parse("[sim]\nvc_count = 3\nnum_vcs = 1\n").unwrap();
        assert_eq!(both.sim_config().num_vcs, 1);
    }

    #[test]
    fn telemetry_keys() {
        let c =
            ExperimentConfig::parse("[sim]\ntrace = \"/tmp/t.jsonl\"\nsample_every = 250\n")
                .unwrap();
        let sc = c.sim_config();
        assert_eq!(sc.trace.as_deref(), Some("/tmp/t.jsonl"));
        assert_eq!(sc.sample_every, 250);
        // Telemetry defaults off.
        let d = ExperimentConfig::default().sim_config();
        assert_eq!(d.trace, None);
        assert_eq!(d.sample_every, 0);
    }

    #[test]
    fn cli_override() {
        let mut c = ExperimentConfig::parse(SAMPLE).unwrap();
        c.set("sim.packet_size", Value::Num(32.0));
        assert_eq!(c.sim_config().packet_size, 32);
    }

    #[test]
    fn fault_keys() {
        let c = ExperimentConfig::parse(
            "[sim]\nfault_links = \"0-1,4-5\"\nfault_nodes = \"3,9\"\n\
             link_fault_rate = 0.05\nnode_fault_rate = 0.01\n",
        )
        .unwrap();
        let sc = c.sim_config();
        assert_eq!(sc.fault_links, vec![(0, 1), (4, 5)]);
        assert_eq!(sc.fault_nodes, vec![3, 9]);
        assert_eq!(sc.link_fault_rate, 0.05);
        assert_eq!(sc.node_fault_rate, 0.01);
        assert!(sc.has_faults());
        // Faults default off (and `defaults_when_missing` pins the whole
        // default SimConfig, so the pristine fast path stays the default).
        assert!(!ExperimentConfig::default().sim_config().has_faults());
    }

    #[test]
    #[should_panic(expected = "sim.fault_links")]
    fn bad_fault_links_string_is_loud() {
        // A malformed spec must not silently run a pristine network.
        let c = ExperimentConfig::parse("[sim]\nfault_links = \"0+1\"\n").unwrap();
        let _ = c.sim_config();
    }

    #[test]
    #[should_panic(expected = "sim.link_fault_rate")]
    fn out_of_range_fault_rate_is_loud() {
        let c = ExperimentConfig::parse("[sim]\nlink_fault_rate = 1.5\n").unwrap();
        let _ = c.sim_config();
    }

    #[test]
    #[should_panic(expected = "sim.route_policy")]
    fn bad_route_policy_string_is_loud() {
        // A typo'd policy must not silently fall back to DOR.
        let c = ExperimentConfig::parse("[sim]\nroute_policy = \"adaptiv\"\n").unwrap();
        let _ = c.sim_config();
    }
}
