//! Microbench: the simulator cycle engine — router-cycle throughput, the
//! §Perf L3 target (see EXPERIMENTS.md §Perf).

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::sim::{SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("sim_engine");
    b.max_iters = 20;

    for (name, g) in [
        ("T(8,8,8)", topology::torus(&[8, 8, 8])),
        ("FCC(8)", topology::fcc(8)),
        ("4D-FCC(4)", topology::fcc4d(4)),
        ("4D-BCC(2)", topology::bcc4d(2)),
    ] {
        let cfg = SimConfig { warmup_cycles: 0, measure_cycles: 2_000, ..SimConfig::default() };
        let cycles = cfg.warmup_cycles + cfg.measure_cycles;
        let nodes = g.order() as u64;
        let sim = Simulator::new(g, TrafficPattern::Uniform, cfg);
        // node-cycles per second is the engine's primary metric.
        for load in [0.3, 0.9] {
            b.run_throughput(
                &format!("{name}@{load}"),
                nodes * cycles,
                "node-cycles",
                || {
                    black_box(sim.run(load));
                },
            );
        }
    }
}
