//! Remark 33: closed-form routing for the n-dimensional crystal families.
//!
//! - `nD-PC` routes with `n` independent ring comparisons (the torus
//!   router).
//! - `nD-BCC(a)` (Hermite `[[2aI, a·1],[0, a]]`) routes with **2 calls**
//!   to `(n-1)D-PC` ring routing — the cycle `<e_n>` has order `2a` and
//!   meets the destination copy at offsets `0` and `(a, ..., a)`.
//! - `nD-FCC(a)` (Hermite `[[2a, a...a],[0, aI]]`) recurses: 2 calls to
//!   `(n-1)D-FCC`, bottoming out at `RTT = 2D-FCC` (Algorithm 3), i.e.
//!   `2^{n-2}` RTT evaluations total, exactly as the paper counts.
//!
//! Both are validated exactly-minimal against the BFS oracle in tests and
//! against the generic hierarchical router.

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;
use crate::topology::{bcc_nd, fcc_nd};

use super::rtt::RttRouter;
use super::torus::TorusRouter;
use super::{norm, Record, Router};

/// Cartesian product of per-dimension ring tie sets in the hierarchical
/// router's emission order (dimension 0 varies fastest). `off` shifts
/// every dimension's difference by the cycle intersection's drag.
fn ring_product_ties(diff: &[i64], off: i64, side: i64) -> Vec<Record> {
    let mut out: Vec<Record> = vec![Vec::new()];
    for &x in diff {
        let opts = TorusRouter::ring_route_ties(x - off, side);
        let mut next = Vec::with_capacity(out.len() * opts.len());
        for &o in &opts {
            for partial in &out {
                let mut r = partial.clone();
                r.push(o);
                next.push(r);
            }
        }
        out = next;
    }
    out
}

/// Merge the tie candidates of the two cycle intersections exactly the
/// way `HierarchicalRouter::route_impl` does: intersections in ascending
/// cycle position `k`, the forward step `k` before the wrapped step
/// `k - ord` (only `0` when `k == 0`), projection ties innermost, global
/// minimum retained with clear-on-better and a membership dedup. Every
/// projection set is a minimal tie set, so its records share one norm.
///
/// The emitted order is RNG-stream-load-bearing: the engine draws
/// `rng.below(ties.len())` into the table rows built from this, so both
/// the count and the order must equal the hierarchical builder's
/// record-for-record (pinned by `tests/routing_dispatch.rs`).
fn merge_intersections(branches: [(i64, Vec<Record>); 2], ord: i64) -> Vec<Record> {
    let mut best: Vec<Record> = Vec::new();
    let mut best_norm = i64::MAX;
    for (k, proj) in branches {
        let m = norm(&proj[0]);
        let opts = [k, k - ord];
        let opts = if k == 0 { &opts[..1] } else { &opts[..] };
        for &steps in opts {
            let total = m + steps.abs();
            if total < best_norm {
                best_norm = total;
                best.clear();
            }
            if total == best_norm {
                for pr in &proj {
                    let mut r = pr.clone();
                    r.push(steps);
                    if !best.contains(&r) {
                        best.push(r);
                    }
                }
            }
        }
    }
    best
}

/// Closed-form minimal router for `nD-BCC(a)`.
pub struct BccNdRouter {
    g: LatticeGraph,
    n: usize,
    a: i64,
}

impl BccNdRouter {
    pub fn new(n: usize, a: i64) -> Self {
        assert!(n >= 2);
        Self { g: bcc_nd(n, a), n, a }
    }

    /// Route a difference vector (first `n-1` comps in `(-2a, 2a)`, last in
    /// `(-a, a)`).
    pub fn route_diff(&self, diff: &[i64]) -> Record {
        let (n, a) = (self.n, self.a);
        let z = diff[n - 1];
        // Lifting z by +a drags every leading coordinate by +a (the last
        // Hermite column is (a, ..., a, a)).
        let lift = i64::from(z < 0);
        let zp = z + a * lift;
        let xs: Vec<i64> = (0..n - 1)
            .map(|i| rem_euclid(diff[i] + a * lift, 2 * a))
            .collect();
        // Intersection 1: offset 0, zp cycle hops; 2: offset a, zp - a.
        let mut r1: Record = xs.iter().map(|&x| TorusRouter::ring_route(x, 2 * a)).collect();
        r1.push(zp);
        let mut r2: Record = xs
            .iter()
            .map(|&x| TorusRouter::ring_route(x - a, 2 * a))
            .collect();
        r2.push(zp - a);
        if norm(&r1) <= norm(&r2) {
            r1
        } else {
            r2
        }
    }
}

impl Router for BccNdRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        self.route_diff(&diff)
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        let (n, a) = (self.n, self.a);
        let mut diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        self.g.reduce_in_place(&mut diff);
        // Canonical difference: diff[i] in [0, 2a) for i < n-1, the last
        // in [0, a). The cycle `<e_n>` (order 2a) meets the destination
        // copy of the `(n-1)`-torus at positions k = y_n and k = y_n + a;
        // the second lifts every leading coordinate by +a (the last
        // Hermite column is (a, ..., a, a)).
        let yl = diff[n - 1];
        let proj = |off: i64| ring_product_ties(&diff[..n - 1], off, 2 * a);
        merge_intersections([(yl, proj(0)), (yl + a, proj(a))], 2 * a)
    }
}

/// Closed-form minimal router for `nD-FCC(a)` (recursive; `2^{n-2}` RTT
/// evaluations at the leaves).
pub struct FccNdRouter {
    g: LatticeGraph,
    n: usize,
    a: i64,
}

impl FccNdRouter {
    pub fn new(n: usize, a: i64) -> Self {
        assert!(n >= 2);
        Self { g: fcc_nd(n, a), n, a }
    }

    /// Recursive difference routing. `diff` has the x component first then
    /// `n-1` components in `(-a, a)`.
    fn route_diff_rec(a: i64, n: usize, diff: &[i64]) -> Record {
        if n == 2 {
            let (x, y) = RttRouter::route_diff_min(a, diff[0], diff[1]);
            return vec![x, y];
        }
        let z = diff[n - 1];
        let lift = i64::from(z < 0);
        let zp = z + a * lift;
        // Lifting z by +a drags x (row 0 of the Hermite column) by +a.
        let x = rem_euclid(diff[0] + a * lift, 2 * a);
        let mut head: Vec<i64> = Vec::with_capacity(n - 1);
        head.push(x);
        head.extend_from_slice(&diff[1..n - 1]);
        // Intersection 1: offset 0, zp hops; 2: x offset a, zp - a hops.
        let mut r1 = Self::route_diff_rec(a, n - 1, &head);
        r1.push(zp);
        head[0] = x - a;
        let mut r2 = Self::route_diff_rec(a, n - 1, &head);
        r2.push(zp - a);
        if norm(&r1) <= norm(&r2) {
            r1
        } else {
            r2
        }
    }

    /// Recursive tie-set emission over the canonical difference of the
    /// level-`l` box (`y[0]` in `[0, 2a)`, the rest in `[0, a)`), in the
    /// hierarchical router's order: the level-`l` cycle (order `2a`)
    /// meets the destination copy at k = y_l and k = y_l + a, the second
    /// dragging the `x` coordinate by +a (Hermite column `a*e_0 + a*e_l`).
    fn ties_rec(a: i64, l: usize, y: &[i64]) -> Vec<Record> {
        if l == 1 {
            return TorusRouter::ring_route_ties(y[0], 2 * a)
                .into_iter()
                .map(|r| vec![r])
                .collect();
        }
        let yl = y[l - 1];
        let branch = |off: i64| {
            let mut head = y[..l - 1].to_vec();
            head[0] = rem_euclid(head[0] - off, 2 * a);
            Self::ties_rec(a, l - 1, &head)
        };
        merge_intersections([(yl, branch(0)), (yl + a, branch(a))], 2 * a)
    }
}

impl Router for FccNdRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let mut diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        // Normalize the trailing box coordinates into (-a, a) by moving
        // their lifts into x (each Hermite column j >= 1 is a*e_0 + a*e_j).
        let a = self.a;
        for i in 1..self.n {
            let lift = i64::from(diff[i] < 0) - i64::from(diff[i] >= a);
            diff[i] += a * lift;
            diff[0] += a * lift;
        }
        Self::route_diff_rec(a, self.n, &diff)
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        let mut diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        self.g.reduce_in_place(&mut diff);
        Self::ties_rec(self.a, self.n, &diff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::bfs_distances;
    use crate::routing::is_valid_record;

    fn check_minimal<R: Router>(router: &R, tag: &str) {
        let g = router.graph().clone();
        let dist = bfs_distances(&g, 0);
        let src = vec![0i64; g.dim()];
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let r = router.route(&src, &dst);
            assert!(is_valid_record(&g, &src, &dst, &r), "{tag} dst={dst:?} r={r:?}");
            assert_eq!(norm(&r), dist[v] as i64, "{tag} dst={dst:?} r={r:?}");
        }
    }

    #[test]
    fn bcc_nd_matches_3d_algorithm() {
        for a in 1..5i64 {
            check_minimal(&BccNdRouter::new(3, a), &format!("3D-BCC({a})"));
        }
    }

    #[test]
    fn bcc_4d_minimal() {
        for a in 1..4i64 {
            check_minimal(&BccNdRouter::new(4, a), &format!("4D-BCC({a})"));
        }
    }

    #[test]
    fn bcc_5d_minimal() {
        check_minimal(&BccNdRouter::new(5, 1), "5D-BCC(1)");
        check_minimal(&BccNdRouter::new(5, 2), "5D-BCC(2)");
    }

    #[test]
    fn fcc_nd_matches_3d_algorithm() {
        for a in 1..5i64 {
            check_minimal(&FccNdRouter::new(3, a), &format!("3D-FCC({a})"));
        }
    }

    #[test]
    fn fcc_4d_minimal() {
        for a in 1..4i64 {
            check_minimal(&FccNdRouter::new(4, a), &format!("4D-FCC({a})"));
        }
    }

    #[test]
    fn fcc_5d_minimal() {
        check_minimal(&FccNdRouter::new(5, 2), "5D-FCC(2)");
    }

    #[test]
    fn rtt_base_case() {
        check_minimal(&FccNdRouter::new(2, 5), "2D-FCC(5)=RTT(5)");
    }

    #[test]
    fn bcc_nd_ties_minimal() {
        let router = BccNdRouter::new(4, 2);
        let g = router.graph().clone();
        let dist = bfs_distances(&g, 0);
        for v in (0..g.order()).step_by(3) {
            let dst = g.label_of(v);
            for t in router.route_ties(&vec![0; 4], &dst) {
                assert!(is_valid_record(&g, &vec![0; 4], &dst, &t));
                assert_eq!(norm(&t), dist[v] as i64);
            }
        }
    }

    #[test]
    fn nonzero_sources() {
        let router = FccNdRouter::new(4, 2);
        let g = router.graph().clone();
        for s in [5usize, 17, 29] {
            let src = g.label_of(s);
            let dist = bfs_distances(&g, s);
            for v in (0..g.order()).step_by(2) {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r));
                assert_eq!(norm(&r), dist[v] as i64, "src={src:?} dst={dst:?}");
            }
        }
    }
}
