//! # lattice-networks
//!
//! Production-grade reproduction of *"Symmetric Interconnection Networks
//! from Cubic Crystal Lattices"* (Camarero, Martínez, Beivide — CS.DC
//! 2013): lattice graphs over integral matrices, the cubic crystal
//! topologies (PC / FCC / BCC) and their higher-dimensional lifts, minimal
//! routing, a cycle-accurate interconnection-network simulator, and a
//! PJRT-backed APSP runtime executing JAX/Pallas AOT artifacts.
//!
//! ## Layer map (see DESIGN.md)
//!
//! - [`math`] — exact integer matrix algebra (HNF, adjugate, unimodular).
//! - [`lattice`] — `G(M)` graphs: labelling, projections/lifts, `⊞`,
//!   symmetry (paper §2, §4, Appendix A).
//! - [`topology`] — named constructors + catalog parser (paper §3, §4).
//! - [`metrics`] — BFS distance structure, closed forms, throughput
//!   bounds (paper §3.4).
//! - [`routing`] — minimal routing records: Algorithms 1–4 + DOR + oracle
//!   (paper §5).
//! - [`sim`] — INSEE-equivalent cycle-accurate simulator (paper §6.2),
//!   with open-loop (steady-state) and closed-loop (finite workload)
//!   injection modes.
//! - [`workload`] — dependency-ordered application workloads (halo
//!   exchange, all-to-all, all-reduce, permutation, hotspot) measured by
//!   completion time on the cycle engine.
//! - [`coordinator`] — experiment drivers for every paper table/figure,
//!   config system, parallel sweeps.
//! - [`runtime`] — PJRT CPU client running the AOT APSP artifacts (behind
//!   the `pjrt` cargo feature).
//!
//! ## Quickstart
//!
//! ```no_run
//! use lattice_networks::topology;
//! use lattice_networks::metrics::distance_distribution;
//!
//! let g = topology::bcc(4);               // 256-node body-centered cubic
//! let stats = distance_distribution(&g);
//! assert_eq!(stats.diameter, 6);          // Table 1: floor(3a/2)
//! ```

pub mod benchkit;
pub mod coordinator;
pub mod lattice;
pub mod math;
pub mod metrics;
pub mod routing;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod util;
pub mod workload;
