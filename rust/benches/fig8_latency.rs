//! Bench: regenerate Figure 8 — packet latency vs offered load for
//! T(8,8,8,4) vs 4D-BCC(4). Scaled by default; `LATTICE_FULL=1` for the
//! paper configuration.

use lattice_networks::coordinator::experiments as exp;
use lattice_networks::sim::TrafficPattern;

fn main() {
    let full = std::env::var_os("LATTICE_FULL").is_some();
    let spec = exp::fig6_spec(full); // fig8 shares fig6's networks
    let (cfg, seeds) = exp::fig_sim_config(full);
    let loads: Vec<f64> = if full {
        exp::default_loads()
    } else {
        vec![0.1, 0.3, 0.5, 0.7, 0.9]
    };
    let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &loads, seeds, cfg)
        .expect("figure run");
    print!("{}", exp::curve_table(&fig).render());
}
