//! Offered-load sweeps: the workhorse behind Figures 5–8.
//!
//! A sweep runs the simulator at each offered load for several seeds and
//! averages accepted throughput and latency (the paper averages >= 5
//! simulations per point). Points are distributed over
//! [`crate::util::pool::par_map`], which returns results in job order —
//! so the per-point f64 accumulation sums seeds in a fixed sequence and
//! the averaged sweep is bit-identical for every worker count (a racing
//! collection vector would reorder the non-associative float sums). The
//! `Simulator` is shared immutably (per-run state is local), so every
//! point and seed reuses one [`crate::sim::TopologyArtifacts`] bundle.

use crate::lattice::LatticeGraph;
use crate::sim::{SimConfig, Simulator, TrafficPattern};
use crate::util::pool::par_map;

/// One averaged sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub offered_load: f64,
    pub accepted_load: f64,
    pub avg_latency: f64,
    pub p99_latency: f64,
    pub seeds: usize,
}

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct LoadSweep {
    /// Offered loads to visit (phits/cycle/node).
    pub loads: Vec<f64>,
    /// Seeds averaged per point.
    pub seeds: usize,
    /// Simulator parameters.
    pub sim: SimConfig,
    /// Worker threads (0 = auto).
    pub workers: usize,
}

impl LoadSweep {
    /// `from..=to` in steps of `step`.
    pub fn linspace(from: f64, to: f64, step: f64, seeds: usize, sim: SimConfig) -> Self {
        assert!(step > 0.0 && to >= from);
        let mut loads = Vec::new();
        let mut l = from;
        while l <= to + 1e-9 {
            loads.push((l * 1e9).round() / 1e9);
            l += step;
        }
        Self { loads, seeds, sim, workers: 0 }
    }

    /// Run the sweep for one topology + pattern.
    pub fn run(&self, g: &LatticeGraph, pattern: TrafficPattern) -> Vec<SweepPoint> {
        let sim = Simulator::new(g.clone(), pattern, self.sim.clone());
        self.run_with(&sim)
    }

    /// Run over a prebuilt simulator (reuses its routing tables).
    pub fn run_with(&self, sim: &Simulator) -> Vec<SweepPoint> {
        // Work items: (load index, seed).
        let jobs: Vec<(usize, u64)> = self
            .loads
            .iter()
            .enumerate()
            .flat_map(|(i, _)| (0..self.seeds as u64).map(move |s| (i, s)))
            .collect();
        // Ordered fan-out: results come back in job order regardless of
        // worker count, so the f64 accumulation below is deterministic.
        let results = par_map(jobs.len(), self.workers, |k| {
            let (i, seed) = jobs[k];
            run_one(sim, &self.sim, self.loads[i], seed)
        });

        // Average per load point (jobs are grouped by point, seeds in
        // ascending order, so each point's sum has a fixed sequence).
        let mut acc = vec![(0.0f64, 0.0f64, 0.0f64, 0usize); self.loads.len()];
        for (&(i, _), r) in jobs.iter().zip(results) {
            acc[i].0 += r.accepted_load;
            acc[i].1 += r.avg_latency;
            acc[i].2 += r.p99_latency;
            acc[i].3 += 1;
        }
        self.loads
            .iter()
            .zip(acc)
            .map(|(&load, (a, l, p, n))| SweepPoint {
                offered_load: load,
                accepted_load: a / n as f64,
                avg_latency: l / n as f64,
                p99_latency: p / n as f64,
                seeds: n,
            })
            .collect()
    }
}

fn run_one(sim: &Simulator, base: &SimConfig, load: f64, seed: u64) -> crate::sim::SimResult {
    // Each seed perturbs the base seed; run_seeded reuses the simulator's
    // routing tables, so per-seed cost is the cycle loop only.
    let s = base.seed.wrapping_add(seed.wrapping_mul(0x9e3779b97f4a7c15));
    sim.run_seeded(load, s)
}

/// Peak accepted throughput of a sweep.
pub fn peak_throughput(points: &[SweepPoint]) -> f64 {
    points.iter().map(|p| p.accepted_load).fold(0.0, f64::max)
}

/// Latency at the lowest load (the base-latency estimate for Figs 7–8).
pub fn base_latency(points: &[SweepPoint]) -> f64 {
    points.first().map_or(0.0, |p| p.avg_latency)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::torus;

    #[test]
    fn linspace_inclusive() {
        let s = LoadSweep::linspace(0.1, 0.5, 0.2, 1, SimConfig::fast());
        assert_eq!(s.loads, vec![0.1, 0.3, 0.5]);
    }

    #[test]
    fn sweep_runs_and_averages() {
        let mut cfg = SimConfig::fast();
        cfg.warmup_cycles = 100;
        cfg.measure_cycles = 400;
        let sweep = LoadSweep { loads: vec![0.1, 0.6], seeds: 2, sim: cfg, workers: 1 };
        let pts = sweep.run(&torus(&[4, 4]), TrafficPattern::Uniform);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].seeds, 2);
        assert!(pts[0].accepted_load > 0.0);
        assert!(pts[1].accepted_load >= pts[0].accepted_load * 0.8);
    }

    #[test]
    fn sweep_is_worker_count_invariant() {
        // The averaged f64s must be bit-identical for every worker
        // count: par_map returns results in job order, so each point's
        // non-associative float sum runs in a fixed sequence.
        let mut cfg = SimConfig::fast();
        cfg.warmup_cycles = 50;
        cfg.measure_cycles = 200;
        let g = torus(&[4, 4]);
        let base = LoadSweep { loads: vec![0.1, 0.4], seeds: 3, sim: cfg, workers: 1 };
        let p1 = base.run(&g, TrafficPattern::Uniform);
        for workers in [2usize, 4, 8] {
            let sweep = LoadSweep { workers, ..base.clone() };
            let pw = sweep.run(&g, TrafficPattern::Uniform);
            assert_eq!(p1.len(), pw.len());
            for (a, b) in p1.iter().zip(&pw) {
                assert_eq!(a.accepted_load.to_bits(), b.accepted_load.to_bits(), "workers={workers}");
                assert_eq!(a.avg_latency.to_bits(), b.avg_latency.to_bits(), "workers={workers}");
                assert_eq!(a.p99_latency.to_bits(), b.p99_latency.to_bits(), "workers={workers}");
                assert_eq!(a.seeds, b.seeds);
            }
        }
    }

    #[test]
    fn peak_and_base() {
        let pts = vec![
            SweepPoint { offered_load: 0.1, accepted_load: 0.1, avg_latency: 20.0, p99_latency: 30.0, seeds: 1 },
            SweepPoint { offered_load: 0.9, accepted_load: 0.5, avg_latency: 90.0, p99_latency: 300.0, seeds: 1 },
        ];
        assert_eq!(peak_throughput(&pts), 0.5);
        assert_eq!(base_latency(&pts), 20.0);
    }
}
