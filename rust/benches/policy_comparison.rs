//! Microbench: route-selection policies at saturation — engine speed per
//! (policy × VC count) (node-cycles/s; the adaptive policies pay a
//! per-hop headroom scan + RNG draw, and the escape protocol adds the
//! blocked-head re-selection path) and the accepted-throughput /
//! link-balance / escape-usage comparison the policy and VC layers exist
//! for, on the edge-asymmetric mixed-radix torus vs the matched crystal.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::routing::RoutingTable;
use lattice_networks::sim::{RoutePolicy, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("policy_comparison");
    b.max_iters = 20;

    for (name, g) in [
        ("T(8,4,4)", topology::torus(&[8, 4, 4])),
        ("FCC(4)", topology::fcc(4)),
    ] {
        // One routing table per network, shared by the per-policy sims.
        let table = RoutingTable::build_hierarchical(&g);
        let nodes = g.order() as u64;
        for policy in RoutePolicy::ALL {
            // 1 VC = the unprotected pre-escape engine; 2 VCs = the
            // default escape configuration (VC 0 pinned to DOR).
            for num_vcs in [1usize, 2] {
                let cfg = SimConfig {
                    warmup_cycles: 500,
                    measure_cycles: 2_000,
                    route_policy: policy,
                    num_vcs,
                    ..SimConfig::default()
                };
                let cycles = cfg.warmup_cycles + cfg.measure_cycles;
                let sim = Simulator::with_table(g.clone(), &table, TrafficPattern::Uniform, cfg);
                b.run_throughput(
                    &format!("{name}/{}x{num_vcs}vc@0.9", policy.name()),
                    nodes * cycles,
                    "node-cycles",
                    || {
                        black_box(sim.run(0.9));
                    },
                );
                // The headline numbers the policies are judged by:
                // accepted throughput at 90% offered load, the per-link
                // balance, and how much traffic the escape lane carried.
                // VC 0 is an escape lane only under the adaptive policies
                // with >= 2 VCs; elsewhere its share is meaningless.
                let r = sim.run(0.9);
                let esc = if sim.escape_active() {
                    format!("{:.3}", r.escape_share())
                } else {
                    "-".into()
                };
                println!(
                    "policy_comparison/{name}/{:<8} vcs {num_vcs}  accepted {:.4} \
                     phits/cycle/node  spread {:.2}  p99 {:.0}  esc {esc}",
                    policy.name(),
                    r.accepted_load,
                    r.link_util_spread,
                    r.p99_latency,
                );
            }
        }
    }
}
