//! The four synthetic traffic patterns of §6.2.
//!
//! - `Uniform`: destination drawn uniformly among the other nodes, fresh
//!   per packet.
//! - `Antipodal`: every node sends to (one of) its most distant nodes.
//!   By vertex transitivity the translation `v ↦ v + anti(0)` is
//!   max-distance for every `v`, so one BFS suffices.
//! - `CentralSymmetric`: with the center fixed at the origin of the
//!   label box, `v ↦ -v (mod M)`.
//! - `RandomPairings`: a random perfect matching fixed for the whole run;
//!   partners send to each other.
//!
//! Plus one post-paper adversarial pattern:
//!
//! - `HotSpot`: uniform traffic with a fixed hot destination drawing an
//!   extra [`HOTSPOT_SHARE`]-th of all packets — the classic
//!   congested-server scenario, and the engine's shard-imbalance
//!   stressor. Not part of [`TrafficPattern::ALL`] (the figure
//!   experiments sweep exactly the paper's four §6.2 patterns);
//!   selectable by name (`--traffic hotspot`).

use crate::lattice::LatticeGraph;
use crate::metrics::bfs_distances;

use super::rng::{Draw, Rng};

/// Traffic pattern selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TrafficPattern {
    Uniform,
    Antipodal,
    CentralSymmetric,
    RandomPairings,
    /// Uniform plus a fixed hot destination (see the module docs).
    HotSpot,
}

/// One packet in `HOTSPOT_SHARE` targets the hot node under
/// [`TrafficPattern::HotSpot`]; the rest are uniform.
pub const HOTSPOT_SHARE: usize = 8;

impl TrafficPattern {
    /// The paper's four §6.2 patterns — exactly what the figure
    /// experiments sweep. `HotSpot` is deliberately excluded; select it
    /// by name.
    pub const ALL: [TrafficPattern; 4] = [
        TrafficPattern::Uniform,
        TrafficPattern::Antipodal,
        TrafficPattern::CentralSymmetric,
        TrafficPattern::RandomPairings,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TrafficPattern::Uniform => "uniform",
            TrafficPattern::Antipodal => "antipodal",
            TrafficPattern::CentralSymmetric => "centralsymmetric",
            TrafficPattern::RandomPairings => "randompairings",
            TrafficPattern::HotSpot => "hotspot",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "uniform" => Some(TrafficPattern::Uniform),
            "antipodal" => Some(TrafficPattern::Antipodal),
            "centralsymmetric" | "central" => Some(TrafficPattern::CentralSymmetric),
            "randompairings" | "pairs" => Some(TrafficPattern::RandomPairings),
            "hotspot" | "hot" => Some(TrafficPattern::HotSpot),
            _ => None,
        }
    }
}

/// Materialized destination generator for a run.
pub enum Traffic {
    /// Fresh uniform destination per packet.
    Uniform { order: usize },
    /// Fixed destination per source.
    Fixed { dest: Vec<u32> },
    /// Uniform with a fixed hot destination taking one packet in
    /// [`HOTSPOT_SHARE`].
    HotSpot { order: usize, hot: usize },
}

impl Traffic {
    /// Build the generator for a pattern on a graph.
    pub fn build(pattern: TrafficPattern, g: &LatticeGraph, rng: &mut Rng) -> Traffic {
        let n = g.order();
        match pattern {
            TrafficPattern::Uniform => Traffic::Uniform { order: n },
            TrafficPattern::Antipodal => {
                // anti(0) via BFS; translate by group structure.
                let dist = bfs_distances(g, 0);
                let max = dist.iter().max().copied().unwrap();
                let anti0 = dist.iter().position(|&d| d == max).unwrap();
                let anti_label = g.label_of(anti0);
                let dim = g.dim();
                let mut dest = vec![0u32; n];
                let mut tmp = vec![0i64; dim];
                for v in 0..n {
                    let label = g.label_of(v);
                    for i in 0..dim {
                        tmp[i] = label[i] + anti_label[i];
                    }
                    g.reduce_in_place(&mut tmp);
                    dest[v] = g.index_of(&tmp) as u32;
                }
                Traffic::Fixed { dest }
            }
            TrafficPattern::CentralSymmetric => {
                let dim = g.dim();
                let mut dest = vec![0u32; n];
                let mut tmp = vec![0i64; dim];
                for v in 0..n {
                    let label = g.label_of(v);
                    for i in 0..dim {
                        tmp[i] = -label[i];
                    }
                    g.reduce_in_place(&mut tmp);
                    dest[v] = g.index_of(&tmp) as u32;
                }
                Traffic::Fixed { dest }
            }
            TrafficPattern::RandomPairings => {
                let mut perm: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut perm);
                let mut dest = vec![0u32; n];
                for pair in perm.chunks(2) {
                    if let [a, b] = *pair {
                        dest[a as usize] = b;
                        dest[b as usize] = a;
                    } else {
                        // odd order: the leftover talks to itself (never
                        // injected; see destination_of).
                        dest[pair[0] as usize] = pair[0];
                    }
                }
                Traffic::Fixed { dest }
            }
            // The hot node is topology-determined (the center of the
            // index space), not drawn: every seed hammers the same spot.
            TrafficPattern::HotSpot => Traffic::HotSpot { order: n, hot: n / 2 },
        }
    }

    /// [`build`](Self::build), then degrade for a fault set: a `HotSpot`
    /// pattern whose topology-determined hot node is dead re-homes to the
    /// next live node id (wrapping), consuming no RNG — so every seed,
    /// scan mode and thread count hammers the same replacement spot, and
    /// an open-loop sweep with a dead hotspot keeps its congestion
    /// character instead of drawing undeliverable destinations forever.
    /// Every other pattern is returned untouched; its dead endpoints are
    /// filtered per-arrival (open loop) or masked out of the workload
    /// (closed loop).
    pub fn build_with_faults(
        pattern: TrafficPattern,
        g: &LatticeGraph,
        rng: &mut Rng,
        node_dead: Option<&[bool]>,
    ) -> Traffic {
        let mut t = Traffic::build(pattern, g, rng);
        if let (Traffic::HotSpot { order, hot }, Some(dead)) = (&mut t, node_dead) {
            if dead[*hot] {
                // All-dead networks keep the original hot node; no
                // arrival can be injected from or to a dead node anyway.
                *hot = (*hot + 1..*order).chain(0..*hot).find(|&v| !dead[v]).unwrap_or(*hot);
            }
        }
        t
    }

    /// Destination for a packet from `src` (None = no traffic, e.g. the
    /// odd node out in a pairing, or a self-destination). Generic over
    /// the draw source ([`Draw`]): the engine passes the source node's
    /// injection stream.
    #[inline]
    pub fn destination_of(&self, src: usize, rng: &mut impl Draw) -> Option<usize> {
        match self {
            Traffic::Uniform { order } => {
                // uniform over the other N-1 nodes
                let d = rng.below(*order - 1);
                Some(if d >= src { d + 1 } else { d })
            }
            Traffic::Fixed { dest } => {
                let d = dest[src] as usize;
                (d != src).then_some(d)
            }
            Traffic::HotSpot { order, hot } => {
                // Every packet flips the hot coin first (one extra draw,
                // same law at every source), then falls back to uniform
                // over the other N-1 nodes. The hot node's own hot-coin
                // packets are dropped (self-destination), like the odd
                // node out of a pairing.
                let d = if rng.below(HOTSPOT_SHARE) == 0 {
                    *hot
                } else {
                    let d = rng.below(*order - 1);
                    if d >= src {
                        d + 1
                    } else {
                        d
                    }
                };
                (d != src).then_some(d)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, torus};

    #[test]
    fn uniform_never_self() {
        let g = torus(&[4, 4]);
        let t = Traffic::build(TrafficPattern::Uniform, &g, &mut Rng::new(1));
        let mut rng = Rng::new(2);
        for src in 0..g.order() {
            for _ in 0..50 {
                let d = t.destination_of(src, &mut rng).unwrap();
                assert_ne!(d, src);
                assert!(d < g.order());
            }
        }
    }

    #[test]
    fn antipodal_hits_diameter() {
        let g = fcc(2);
        let t = Traffic::build(TrafficPattern::Antipodal, &g, &mut Rng::new(1));
        let stats = crate::metrics::distance_distribution(&g);
        let mut rng = Rng::new(2);
        for src in 0..g.order() {
            let d = t.destination_of(src, &mut rng).unwrap();
            let dist = bfs_distances(&g, src);
            assert_eq!(dist[d] as usize, stats.diameter, "src={src}");
        }
    }

    #[test]
    fn central_symmetric_is_involution() {
        let g = bcc(2);
        let t = Traffic::build(TrafficPattern::CentralSymmetric, &g, &mut Rng::new(1));
        let mut rng = Rng::new(2);
        for src in 0..g.order() {
            if let Some(d) = t.destination_of(src, &mut rng) {
                let dd = t.destination_of(d, &mut rng).unwrap();
                assert_eq!(dd, src, "not an involution at {src}");
            }
        }
    }

    #[test]
    fn pairings_are_a_matching() {
        let g = torus(&[4, 4, 4]);
        let t = Traffic::build(TrafficPattern::RandomPairings, &g, &mut Rng::new(5));
        let mut rng = Rng::new(2);
        let mut seen = vec![false; g.order()];
        for src in 0..g.order() {
            let d = t.destination_of(src, &mut rng).unwrap();
            assert_ne!(d, src);
            let back = t.destination_of(d, &mut rng).unwrap();
            assert_eq!(back, src);
            assert!(!seen[src]);
            seen[src] = true;
        }
    }

    #[test]
    fn pattern_parse() {
        assert_eq!(TrafficPattern::parse("uniform"), Some(TrafficPattern::Uniform));
        assert_eq!(TrafficPattern::parse("PAIRS"), Some(TrafficPattern::RandomPairings));
        assert_eq!(TrafficPattern::parse("central"), Some(TrafficPattern::CentralSymmetric));
        assert_eq!(TrafficPattern::parse("hotspot"), Some(TrafficPattern::HotSpot));
        assert_eq!(TrafficPattern::parse("nope"), None);
        // Hotspot is selectable but stays out of the figure sweep.
        assert!(!TrafficPattern::ALL.contains(&TrafficPattern::HotSpot));
    }

    #[test]
    fn hotspot_rehomes_off_a_dead_hot_node() {
        let g = torus(&[8, 8]);
        let n = g.order();
        let mut dead = vec![false; n];
        dead[n / 2] = true;
        dead[n / 2 + 1] = true;
        let t =
            Traffic::build_with_faults(TrafficPattern::HotSpot, &g, &mut Rng::new(1), Some(&dead));
        match t {
            Traffic::HotSpot { hot, .. } => assert_eq!(hot, n / 2 + 2, "skip both dead nodes"),
            _ => panic!("hotspot pattern expected"),
        }
        // The search wraps past the top of the id space.
        let mut dead = vec![true; n];
        dead[1] = false;
        let t =
            Traffic::build_with_faults(TrafficPattern::HotSpot, &g, &mut Rng::new(1), Some(&dead));
        match t {
            Traffic::HotSpot { hot, .. } => assert_eq!(hot, 1, "wrap to the only live node"),
            _ => panic!("hotspot pattern expected"),
        }
        // No fault set: identical to the plain build.
        let t = Traffic::build_with_faults(TrafficPattern::HotSpot, &g, &mut Rng::new(1), None);
        match t {
            Traffic::HotSpot { hot, .. } => assert_eq!(hot, n / 2),
            _ => panic!("hotspot pattern expected"),
        }
    }

    #[test]
    fn hotspot_concentrates_on_one_destination() {
        let g = torus(&[8, 8]);
        let t = Traffic::build(TrafficPattern::HotSpot, &g, &mut Rng::new(1));
        let hot = g.order() / 2;
        let mut rng = Rng::new(2);
        let (mut hits, mut total) = (0usize, 0usize);
        for src in 0..g.order() {
            for _ in 0..500 {
                if let Some(d) = t.destination_of(src, &mut rng) {
                    assert_ne!(d, src);
                    assert!(d < g.order());
                    total += 1;
                    if d == hot {
                        hits += 1;
                    }
                }
            }
        }
        // Expected share ≈ 1/8 + (7/8)·1/(N-1) ≈ 0.139 on N = 64.
        let share = hits as f64 / total as f64;
        assert!((0.10..0.18).contains(&share), "hot share {share}");
    }
}
