//! PJRT CPU client wrapper with a compiled-executable cache.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A PJRT client plus a cache of compiled executables keyed by HLO path.
///
/// Compilation is the expensive step (tens to hundreds of ms); executing a
/// cached executable is micro/milliseconds. The cache is behind a mutex so
/// one runtime can serve concurrent experiment threads.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string (e.g. "cpu") — handy for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it (cached).
    pub fn load_hlo(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute a compiled module on literals, returning the decomposed
    /// output tuple (aot.py always lowers with `return_tuple=True`).
    pub fn execute_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .context("executing PJRT module")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }
}
