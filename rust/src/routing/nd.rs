//! Remark 33: closed-form routing for the n-dimensional crystal families.
//!
//! - `nD-PC` routes with `n` independent ring comparisons (the torus
//!   router).
//! - `nD-BCC(a)` (Hermite `[[2aI, a·1],[0, a]]`) routes with **2 calls**
//!   to `(n-1)D-PC` ring routing — the cycle `<e_n>` has order `2a` and
//!   meets the destination copy at offsets `0` and `(a, ..., a)`.
//! - `nD-FCC(a)` (Hermite `[[2a, a...a],[0, aI]]`) recurses: 2 calls to
//!   `(n-1)D-FCC`, bottoming out at `RTT = 2D-FCC` (Algorithm 3), i.e.
//!   `2^{n-2}` RTT evaluations total, exactly as the paper counts.
//!
//! Both are validated exactly-minimal against the BFS oracle in tests and
//! against the generic hierarchical router.

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;
use crate::topology::{bcc_nd, fcc_nd};

use super::rtt::RttRouter;
use super::torus::TorusRouter;
use super::{norm, Record, Router};

/// Closed-form minimal router for `nD-BCC(a)`.
pub struct BccNdRouter {
    g: LatticeGraph,
    n: usize,
    a: i64,
}

impl BccNdRouter {
    pub fn new(n: usize, a: i64) -> Self {
        assert!(n >= 2);
        Self { g: bcc_nd(n, a), n, a }
    }

    /// Route a difference vector (first `n-1` comps in `(-2a, 2a)`, last in
    /// `(-a, a)`).
    pub fn route_diff(&self, diff: &[i64]) -> Record {
        let (n, a) = (self.n, self.a);
        let z = diff[n - 1];
        // Lifting z by +a drags every leading coordinate by +a (the last
        // Hermite column is (a, ..., a, a)).
        let lift = i64::from(z < 0);
        let zp = z + a * lift;
        let xs: Vec<i64> = (0..n - 1)
            .map(|i| rem_euclid(diff[i] + a * lift, 2 * a))
            .collect();
        // Intersection 1: offset 0, zp cycle hops; 2: offset a, zp - a.
        let mut r1: Record = xs.iter().map(|&x| TorusRouter::ring_route(x, 2 * a)).collect();
        r1.push(zp);
        let mut r2: Record = xs
            .iter()
            .map(|&x| TorusRouter::ring_route(x - a, 2 * a))
            .collect();
        r2.push(zp - a);
        if norm(&r1) <= norm(&r2) {
            r1
        } else {
            r2
        }
    }
}

impl Router for BccNdRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        self.route_diff(&diff)
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        let (n, a) = (self.n, self.a);
        let diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        let z = diff[n - 1];
        let lift = i64::from(z < 0);
        let zp = z + a * lift;
        let xs: Vec<i64> = (0..n - 1)
            .map(|i| rem_euclid(diff[i] + a * lift, 2 * a))
            .collect();
        let mut out: Vec<Record> = Vec::new();
        for (off, dz) in [(0i64, zp), (a, zp - a)] {
            // Cartesian product of per-dimension ring ties.
            let mut partial: Vec<Record> = vec![Vec::new()];
            for &x in &xs {
                let opts = TorusRouter::ring_route_ties(x - off, 2 * a);
                let mut next = Vec::with_capacity(partial.len() * opts.len());
                for p in &partial {
                    for &o in &opts {
                        let mut q = p.clone();
                        q.push(o);
                        next.push(q);
                    }
                }
                partial = next;
            }
            for mut p in partial {
                p.push(dz);
                out.push(p);
            }
        }
        let best = out.iter().map(|r| norm(r)).min().unwrap();
        out.retain(|r| norm(r) == best);
        out.dedup();
        out
    }
}

/// Closed-form minimal router for `nD-FCC(a)` (recursive; `2^{n-2}` RTT
/// evaluations at the leaves).
pub struct FccNdRouter {
    g: LatticeGraph,
    n: usize,
    a: i64,
}

impl FccNdRouter {
    pub fn new(n: usize, a: i64) -> Self {
        assert!(n >= 2);
        Self { g: fcc_nd(n, a), n, a }
    }

    /// Recursive difference routing. `diff` has the x component first then
    /// `n-1` components in `(-a, a)`.
    fn route_diff_rec(a: i64, n: usize, diff: &[i64]) -> Record {
        if n == 2 {
            let (x, y) = RttRouter::route_diff_min(a, diff[0], diff[1]);
            return vec![x, y];
        }
        let z = diff[n - 1];
        let lift = i64::from(z < 0);
        let zp = z + a * lift;
        // Lifting z by +a drags x (row 0 of the Hermite column) by +a.
        let x = rem_euclid(diff[0] + a * lift, 2 * a);
        let mut head: Vec<i64> = Vec::with_capacity(n - 1);
        head.push(x);
        head.extend_from_slice(&diff[1..n - 1]);
        // Intersection 1: offset 0, zp hops; 2: x offset a, zp - a hops.
        let mut r1 = Self::route_diff_rec(a, n - 1, &head);
        r1.push(zp);
        head[0] = x - a;
        let mut r2 = Self::route_diff_rec(a, n - 1, &head);
        r2.push(zp - a);
        if norm(&r1) <= norm(&r2) {
            r1
        } else {
            r2
        }
    }
}

impl Router for FccNdRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        let mut diff: Vec<i64> = dst.iter().zip(src).map(|(d, s)| d - s).collect();
        // Normalize the trailing box coordinates into (-a, a) by moving
        // their lifts into x (each Hermite column j >= 1 is a*e_0 + a*e_j).
        let a = self.a;
        for i in 1..self.n {
            let lift = i64::from(diff[i] < 0) - i64::from(diff[i] >= a);
            diff[i] += a * lift;
            diff[0] += a * lift;
        }
        Self::route_diff_rec(a, self.n, &diff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::bfs_distances;
    use crate::routing::is_valid_record;

    fn check_minimal<R: Router>(router: &R, tag: &str) {
        let g = router.graph().clone();
        let dist = bfs_distances(&g, 0);
        let src = vec![0i64; g.dim()];
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let r = router.route(&src, &dst);
            assert!(is_valid_record(&g, &src, &dst, &r), "{tag} dst={dst:?} r={r:?}");
            assert_eq!(norm(&r), dist[v] as i64, "{tag} dst={dst:?} r={r:?}");
        }
    }

    #[test]
    fn bcc_nd_matches_3d_algorithm() {
        for a in 1..5i64 {
            check_minimal(&BccNdRouter::new(3, a), &format!("3D-BCC({a})"));
        }
    }

    #[test]
    fn bcc_4d_minimal() {
        for a in 1..4i64 {
            check_minimal(&BccNdRouter::new(4, a), &format!("4D-BCC({a})"));
        }
    }

    #[test]
    fn bcc_5d_minimal() {
        check_minimal(&BccNdRouter::new(5, 1), "5D-BCC(1)");
        check_minimal(&BccNdRouter::new(5, 2), "5D-BCC(2)");
    }

    #[test]
    fn fcc_nd_matches_3d_algorithm() {
        for a in 1..5i64 {
            check_minimal(&FccNdRouter::new(3, a), &format!("3D-FCC({a})"));
        }
    }

    #[test]
    fn fcc_4d_minimal() {
        for a in 1..4i64 {
            check_minimal(&FccNdRouter::new(4, a), &format!("4D-FCC({a})"));
        }
    }

    #[test]
    fn fcc_5d_minimal() {
        check_minimal(&FccNdRouter::new(5, 2), "5D-FCC(2)");
    }

    #[test]
    fn rtt_base_case() {
        check_minimal(&FccNdRouter::new(2, 5), "2D-FCC(5)=RTT(5)");
    }

    #[test]
    fn bcc_nd_ties_minimal() {
        let router = BccNdRouter::new(4, 2);
        let g = router.graph().clone();
        let dist = bfs_distances(&g, 0);
        for v in (0..g.order()).step_by(3) {
            let dst = g.label_of(v);
            for t in router.route_ties(&vec![0; 4], &dst) {
                assert!(is_valid_record(&g, &vec![0; 4], &dst, &t));
                assert_eq!(norm(&t), dist[v] as i64);
            }
        }
    }

    #[test]
    fn nonzero_sources() {
        let router = FccNdRouter::new(4, 2);
        let g = router.graph().clone();
        for s in [5usize, 17, 29] {
            let src = g.label_of(s);
            let dist = bfs_distances(&g, s);
            for v in (0..g.order()).step_by(2) {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r));
                assert_eq!(norm(&r), dist[v] as i64, "src={src:?} dst={dst:?}");
            }
        }
    }
}
