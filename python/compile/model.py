"""L2: the JAX compute graph lowered to the AOT artifacts.

The paper's repeatable dense compute is all-pairs-shortest-path distance
analysis of candidate topologies (it backs Table 1, Table 2 and the
"checked computationally up to 40,000 nodes" claim for the average-distance
closed forms). Two interchangeable models are provided, both calling the
L1 Pallas kernels:

- ``apsp_minplus``: ceil(log2 N) min-plus squarings (VPU kernel).
- ``apsp_gemm``:    T reachability expansions as real GEMMs (MXU kernel).

Both take a *padded* N x N input plus the real topology order ``n_real`` so
a single compiled artifact serves every topology of order <= N:

- padding protocol (minplus): adj[i,j] = 0 on diag, 1 for edges, INF
  elsewhere *including* all padded rows/cols. Padded nodes are isolated at
  distance INF and never affect real entries (INF + x >= INF/2 stays
  filtered by ``distance_stats``).
- padding protocol (gemm): 0/1 adjacency, padded rows/cols all-zero.
  Padded nodes stay unreached; their dist saturates at T and is masked by
  ``n_real`` in the stats epilogue.

Outputs are ``(dist, sum_of_distances, max_distance)`` — enough for the
Rust side to derive average distance and diameter without shipping the
matrix back through more artifacts.

This module is build-time only; it is lowered once by aot.py and never
imported at runtime.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .kernels import bfs_gemm, minplus
from .kernels.ref import INF, distance_stats_ref


def apsp_minplus(adj: jax.Array, n_real: jax.Array, *, iters: int, block: int):
    """APSP via repeated min-plus squaring of the one-hop cost matrix.

    ``iters`` squarings cover all shortest paths of length <= 2**iters; the
    caller (aot.py) picks iters = ceil(log2(N)), always sufficient since any
    shortest path in a connected N-node graph has < N hops.
    """

    def body(_, d):
        return minplus.minplus(d, d, block=block)

    dist = jax.lax.fori_loop(0, iters, body, adj)
    s, mx = distance_stats_ref(dist, n_real)
    return dist, s, mx


def apsp_gemm(adj01: jax.Array, n_real: jax.Array, *, steps: int, block: int):
    """APSP via ``steps`` BFS-GEMM frontier expansions.

    ``steps`` must be >= the graph diameter; aot.py bakes steps = the
    largest diameter any topology of order <= N can present to us in
    practice (we use N/2 + 1, the ring worst case, the loosest of all
    lattice graphs of degree >= 4; torus/crystal diameters are far smaller).
    """
    n = adj01.shape[0]
    m = jnp.minimum(adj01 + jnp.eye(n, dtype=adj01.dtype), 1.0)

    def body(_, state):
        # Accumulate BEFORE expanding: a pair first reached at hop k is
        # unreached for t = 0..k-1, contributing exactly k.
        reach, dist = state
        dist = dist + (reach == 0.0).astype(jnp.float32)
        reach = bfs_gemm.expand_frontier(reach, m, block=block)
        return reach, dist

    reach0 = jnp.eye(n, dtype=jnp.float32)
    dist0 = jnp.zeros((n, n), jnp.float32)
    _, dist = jax.lax.fori_loop(0, steps, body, (reach0, dist0))
    # Pairs never reached (padding or disconnection) sit at ``steps``;
    # promote them to INF so the stats epilogue filters them out.
    dist = jnp.where(dist >= steps, INF, dist)
    s, mx = distance_stats_ref(dist, n_real)
    return dist, s, mx


def minplus_iters_for(n: int) -> int:
    """Squarings needed to cover any shortest path in an n-node graph."""
    return max(1, math.ceil(math.log2(n)))


def gemm_steps_for(n: int) -> int:
    """Expansion steps: ring worst case (diameter n/2), degree-4+ graphs are
    far below this. Kept modest because each step is a full GEMM."""
    return n // 2 + 1
