//! Simulation parameters — defaults are exactly the paper's Table 3, plus
//! a LogGP-style software overhead model for the closed-loop workload mode
//! (all overheads default to zero, i.e. the pure Table 3 hardware model).

/// Simulator configuration (Table 3 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Packet size in phits (Table 3: 16).
    pub packet_size: u32,
    /// Virtual channels per physical link (Table 3: 3).
    pub vc_count: usize,
    /// Input queue capacity in packets per VC (Table 3: 4).
    pub queue_packets: u32,
    /// Injection queue capacity in packets (Table 3: "Injectors 6" — INSEE
    /// models six independent injectors; we model the aggregate as a
    /// 6-packet source queue, the arrangement that affects behaviour at
    /// and past saturation).
    pub injection_queue_packets: u32,
    /// Bubble deadlock avoidance on dimensional rings (Table 3: Bubble).
    pub bubble: bool,
    /// Warmup cycles before statistics.
    pub warmup_cycles: u64,
    /// Measured cycles (paper: 10 000).
    pub measure_cycles: u64,
    /// Drain cycles after measurement window (latency stragglers).
    pub drain_cycles: u64,
    /// RNG seed.
    pub seed: u64,
    /// In-transit priority over injection (BG/Q congestion control, §6.2).
    pub transit_priority: bool,
    /// LogGP `o_send`: per-message software overhead (cycles) between a
    /// message's dependencies completing and its first packet becoming
    /// eligible for injection. Closed-loop workload mode only.
    pub send_overhead: u64,
    /// LogGP `o_recv`: per-message software overhead (cycles) between the
    /// last packet of a message draining at its destination and the message
    /// counting as complete (releasing its dependents). Closed-loop
    /// workload mode only.
    pub recv_overhead: u64,
    /// LogGP `g`: minimum cycles between successive packet injections
    /// from one NIC (injection gap) — within a message's train and across
    /// consecutive messages from the same source. Values at or below the
    /// wire serialization time `packet_size` are absorbed by link
    /// serialization. Closed-loop workload mode only.
    pub packet_gap: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            packet_size: 16,
            vc_count: 3,
            queue_packets: 4,
            injection_queue_packets: 6,
            bubble: true,
            warmup_cycles: 2_000,
            measure_cycles: 10_000,
            drain_cycles: 0,
            seed: 0x1ce_b00da,
            transit_priority: true,
            send_overhead: 0,
            recv_overhead: 0,
            packet_gap: 0,
        }
    }
}

impl SimConfig {
    /// A fast configuration for unit tests and CI benches.
    ///
    /// Carries a small nonzero drain so packets injected near the end of
    /// the short measurement window still get their latencies recorded
    /// (with `drain_cycles: 0` the latency tail is silently truncated —
    /// see the `drain_records_straggler_latencies` engine test).
    pub fn fast() -> Self {
        Self {
            warmup_cycles: 300,
            measure_cycles: 1_500,
            drain_cycles: 200,
            ..Self::default()
        }
    }

    /// Buffer capacity in phits per VC queue.
    pub fn queue_phits(&self) -> u32 {
        self.queue_packets * self.packet_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_defaults() {
        let c = SimConfig::default();
        assert_eq!(c.packet_size, 16);
        assert_eq!(c.vc_count, 3);
        assert_eq!(c.queue_packets, 4);
        assert_eq!(c.injection_queue_packets, 6);
        assert!(c.bubble);
        assert!(c.transit_priority);
        assert_eq!(c.measure_cycles, 10_000);
        // Software overheads default off: the pure Table 3 hardware model.
        assert_eq!(c.send_overhead, 0);
        assert_eq!(c.recv_overhead, 0);
        assert_eq!(c.packet_gap, 0);
    }

    #[test]
    fn queue_phits() {
        assert_eq!(SimConfig::default().queue_phits(), 64);
    }
}
