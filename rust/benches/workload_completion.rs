//! Microbench: closed-loop workload completion — the engine's finite
//! injection mode end-to-end (generation excluded; routing tables built
//! once per network).

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::sim::{SimConfig, Simulator};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};

fn main() {
    let mut b = Bench::new("workload_completion");
    b.max_iters = 20;

    let cfg = SimConfig::default();
    for (name, g) in [
        ("T(8,4,4)", topology::torus(&[8, 4, 4])),
        ("FCC(4)", topology::fcc(4)),
        ("BCC(2)", topology::bcc(2)),
    ] {
        let sim = Simulator::for_workload(g.clone(), cfg.clone());
        let params = WorkloadParams { iters: 8, ..Default::default() };
        for kind in [
            WorkloadKind::Stencil,
            WorkloadKind::AllToAll,
            WorkloadKind::RingAllReduce,
        ] {
            let wl = generate(kind, &g, &params);
            let cap = wl.suggested_max_cycles(cfg.packet_size);
            // Messages drained per second is the closed-loop metric.
            b.run_throughput(
                &format!("{name}/{}", kind.name()),
                wl.len() as u64,
                "messages",
                || {
                    black_box(sim.run_workload_seeded(&wl, cfg.seed, cap));
                },
            );
        }
    }
}
