//! Deterministic PRNGs for the simulator.
//!
//! Two generators, two jobs:
//!
//! - [`Rng`] — xoshiro256++, a sequential stream seeded once per run.
//!   Used only for run *setup* (traffic-pattern construction, e.g. the
//!   random-pairings shuffle), where draws happen on one thread in a
//!   fixed order.
//! - [`NodeRng`] — a counter-based (SplitMix64-finalized) stream keyed by
//!   `(seed, node, stream, draw_index)`. Used for every in-run draw
//!   (arbitration tie-breaks, route tie-breaks, VC picks, injection
//!   destinations and inter-arrival gaps). Because the value of draw `i`
//!   is a pure hash of the key tuple, a node's draw sequence is
//!   independent of *when* the node is visited relative to other nodes —
//!   which makes the parallel engine's draws bit-identical to the serial
//!   engine's for any thread count (DESIGN.md §Parallel-engine), and lets
//!   an idle node consume zero RNG state (no stream to keep aligned).
//!
//! Both are hand-rolled (this environment builds offline; see DESIGN.md
//! §Substitutions). xoshiro256++ passes BigCrush and is the default
//! generator of several stdlibs; SplitMix64's finalizer is the standard
//! avalanche mix used to seed it, applied here counter-mode per key.

/// SplitMix64 finalizer: the avalanche mix at the heart of both
/// generators. Bijective on `u64`, so distinct keys never collide. Also
/// used by the engine to fold the per-node draw accumulators into
/// `rng_digest`.
#[inline]
pub(crate) fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Uniform-draw interface shared by [`Rng`] and [`NodeRng`], so the
/// policy and traffic layers can be generic over the source of
/// randomness (setup code draws from the sequential stream, engine code
/// from per-node counter streams).
pub trait Draw {
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, n)` (Lemire multiply-shift; the rejection-free
    /// bias is negligible for simulator n's).
    #[inline]
    fn below(&mut self, n: usize) -> usize {
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// xoshiro256++ PRNG (sequential stream; run setup only).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (see [`Draw::below`]).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Order-sensitive digest of the generator state — a determinism
    /// fingerprint for the *setup* stream: two runs that consumed the
    /// identical draw sequence from the same seed end with equal digests.
    /// The engine combines this with the commutative per-node draw
    /// accumulator to form the `rng_digest` fields of `SimResult` /
    /// `WorkloadOutcome`.
    pub fn state_digest(&self) -> u64 {
        self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

impl Draw for Rng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        Rng::next_u64(self)
    }
}

/// Injection stream selector for [`NodeRng::new`]. Arbitration streams
/// are keyed by the cycle number, which is always `< u64::MAX`, so the
/// two stream families can never collide on a node.
pub const STREAM_INJECT: u64 = u64::MAX;

/// Counter-based per-node RNG stream: draw `i` of stream `(seed, node,
/// stream)` is `splitmix64(key + i)` where `key` mixes the tuple through
/// two finalizer rounds. Stateless apart from the counter — the draw
/// sequence is a pure function of the key, independent of every other
/// node's draws, of thread count, and of visit order.
///
/// The generator also accumulates a `(digest, draws)` fingerprint of
/// what it produced: `digest` is the wrapping sum of drawn values,
/// `draws` the count. Both are *commutative* across nodes, so the engine
/// can merge per-shard accumulators in any grouping and still equal the
/// serial reference — the property `rng_digest` relies on
/// (DESIGN.md §Parallel-engine).
#[derive(Clone, Debug)]
pub struct NodeRng {
    key: u64,
    counter: u64,
    /// Wrapping sum of every value drawn so far (commutative fingerprint).
    pub digest: u64,
    /// Number of draws so far.
    pub draws: u64,
}

impl NodeRng {
    /// Stream for `node` under `seed`. `stream` distinguishes draw
    /// families on the same node: the engine uses the cycle number for
    /// arbitration/routing visits and [`STREAM_INJECT`] for the
    /// open-loop injection process.
    #[inline]
    pub fn new(seed: u64, node: u32, stream: u64) -> Self {
        // Two finalizer rounds over the mixed tuple: one round would make
        // nearby (node, stream) keys differ by small deltas pre-mix;
        // cascading twice decorrelates the per-draw counters too.
        let key = splitmix64(splitmix64(seed ^ (node as u64).rotate_left(32)) ^ stream);
        Self { key, counter: 0, digest: 0, draws: 0 }
    }
}

impl Draw for NodeRng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let v = splitmix64(self.key.wrapping_add(self.counter));
        self.counter += 1;
        self.digest = self.digest.wrapping_add(v);
        self.draws += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(1);
        let mean: f64 = (0..10_000).map(|_| rng.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn node_streams_are_pure_functions_of_the_key() {
        let mut a = NodeRng::new(42, 7, 1000);
        let mut b = NodeRng::new(42, 7, 1000);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.draws, 64);
    }

    #[test]
    fn node_streams_decorrelate_across_key_components() {
        // Distinct (seed, node, stream) keys must give distinct first
        // draws (bijective finalizer makes collisions astronomically
        // unlikely) — including the adjacent keys a lattice produces.
        let mut firsts = std::collections::HashSet::new();
        for seed in [1u64, 2] {
            for node in 0..16u32 {
                for stream in [0u64, 1, 2, STREAM_INJECT] {
                    firsts.insert(NodeRng::new(seed, node, stream).next_u64());
                }
            }
        }
        assert_eq!(firsts.len(), 2 * 16 * 4);
    }

    #[test]
    fn node_stream_statistics_are_uniform() {
        // The counter stream must be usable as a uniform source: mean of
        // f64 draws near 1/2, below(n) covering all residues.
        let mut rng = NodeRng::new(9, 3, STREAM_INJECT);
        let mean: f64 = (0..10_000).map(|_| rng.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn digest_accumulator_is_commutative_across_streams() {
        // Summing two nodes' fingerprints in either order gives the same
        // totals — the property the parallel shard merge relies on.
        let drain = |node: u32, n: u64| {
            let mut r = NodeRng::new(5, node, 17);
            for _ in 0..n {
                r.next_u64();
            }
            (r.digest, r.draws)
        };
        let (d0, n0) = drain(0, 10);
        let (d1, n1) = drain(1, 3);
        assert_eq!(d0.wrapping_add(d1), d1.wrapping_add(d0));
        assert_eq!(n0 + n1, 13);
    }
}
