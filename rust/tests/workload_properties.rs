//! Workload subsystem integration: generator structure (deterministic,
//! acyclic, counted), closed-loop execution on the cycle engine, the
//! paper's qualitative claim that near-neighbor traffic completes far
//! faster than global traffic at equal message volume on a torus, and the
//! packetization invariants of the multi-packet message model (phit
//! conservation, dependency gating on the *last* packet, and exact
//! single-packet equivalence with the original model).

use lattice_networks::sim::{SimConfig, Simulator};
use lattice_networks::topology;
use lattice_networks::workload::{
    generate, Workload, WorkloadKind, WorkloadMessage, WorkloadParams, WorkloadRunner,
};

fn cfg() -> SimConfig {
    SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() }
}

#[test]
fn generators_are_deterministic_counted_and_acyclic() {
    let g = topology::torus(&[4, 4, 4]); // n = 64, dim 3
    let p = WorkloadParams { iters: 5, ..Default::default() };
    for kind in WorkloadKind::ALL {
        let a = generate(kind, &g, &p);
        let b = generate(kind, &g, &p);
        assert_eq!(a, b, "{} must be deterministic for a fixed seed", a.name);
        assert!(a.validate().is_ok(), "{}: {:?}", a.name, a.validate());
        assert!(a.is_acyclic(), "{}", a.name);
        assert!(!a.is_empty(), "{}", a.name);
    }
    // Exact counts on n = 64, degree 6:
    assert_eq!(generate(WorkloadKind::Stencil, &g, &p).len(), 5 * 64 * 6);
    assert_eq!(generate(WorkloadKind::AllToAll, &g, &p).len(), 64 * 63);
    assert_eq!(generate(WorkloadKind::RingAllReduce, &g, &p).len(), 2 * 63 * 64);
    assert_eq!(generate(WorkloadKind::RecursiveDoubling, &g, &p).len(), 64 * 6);
    assert_eq!(generate(WorkloadKind::Permutation, &g, &p).len(), 5 * 64);
    assert_eq!(generate(WorkloadKind::Hotspot, &g, &p).len(), 5 * 63);
}

#[test]
fn every_workload_drains_on_crystals_and_tori() {
    let p = WorkloadParams { iters: 2, ..Default::default() };
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    for (name, g) in [
        ("FCC(2)", topology::fcc(2)),
        ("BCC(2)", topology::bcc(2)),
        ("T(4,4)", topology::torus(&[4, 4])),
    ] {
        for kind in WorkloadKind::ALL {
            let wl = generate(kind, &g, &p);
            let point = runner.run(name, &g, &wl);
            assert!(point.drained, "{name}/{}: undrained", wl.name);
            assert!(point.completion_cycles > 0.0);
            assert!(point.effective_bandwidth > 0.0);
        }
    }
}

#[test]
fn halo_exchange_beats_alltoall_at_equal_volume_on_torus() {
    // The paper's qualitative near-neighbor vs global ordering, measured
    // at the application level: on a 3D torus, ~10 rounds of halo
    // exchange (3840 messages) complete far faster than one personalized
    // all-to-all (4032 messages) of the same total volume.
    let g = topology::torus(&[4, 4, 4]);
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    let halo = generate(
        WorkloadKind::Stencil,
        &g,
        &WorkloadParams { iters: 10, ..Default::default() },
    );
    let a2a = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    // Equal volume within ~5%.
    let ratio = halo.len() as f64 / a2a.len() as f64;
    assert!((0.9..=1.1).contains(&ratio), "volume ratio {ratio}");
    let halo_pt = runner.run("T(4,4,4)", &g, &halo);
    let a2a_pt = runner.run("T(4,4,4)", &g, &a2a);
    assert!(halo_pt.drained && a2a_pt.drained);
    assert!(
        halo_pt.completion_cycles < a2a_pt.completion_cycles,
        "halo {} should beat all-to-all {}",
        halo_pt.completion_cycles,
        a2a_pt.completion_cycles
    );
}

#[test]
fn hotspot_is_ejection_bound() {
    // N-1 senders x iters messages into one ejection channel: completion
    // is at least (messages x packet_size) at the hot node.
    let g = topology::torus(&[4, 4]);
    let iters = 4;
    let wl = generate(WorkloadKind::Hotspot, &g, &WorkloadParams { iters, ..Default::default() });
    let runner = WorkloadRunner { sim: cfg(), ..Default::default() };
    let p = runner.run("T(4,4)", &g, &wl);
    assert!(p.drained);
    let floor = (wl.len() as u64 * 16) as f64;
    assert!(
        p.completion_cycles >= floor,
        "completion {} below the serialization floor {floor}",
        p.completion_cycles
    );
}

#[test]
fn crystal_completes_alltoall_no_slower_than_matched_torus() {
    // The tentpole claim at small scale: FCC(3) (54 nodes) vs T(6,3,3).
    let fcc = topology::fcc(3);
    let torus = topology::torus(&[6, 3, 3]);
    assert_eq!(fcc.order(), torus.order());
    let runner = WorkloadRunner { sim: cfg(), seeds: 2, ..Default::default() };
    let wl_f = generate(WorkloadKind::AllToAll, &fcc, &WorkloadParams::default());
    let wl_t = generate(WorkloadKind::AllToAll, &torus, &WorkloadParams::default());
    let pf = runner.run("FCC(3)", &fcc, &wl_f);
    let pt = runner.run("T(6,3,3)", &torus, &wl_t);
    assert!(pf.drained && pt.drained);
    assert!(
        pf.completion_cycles <= pt.completion_cycles * 1.05,
        "FCC {} vs torus {}",
        pf.completion_cycles,
        pt.completion_cycles
    );
}

#[test]
fn engine_workload_mode_matches_runner() {
    // The runner's single-seed numbers are exactly the engine's.
    let g = topology::fcc(2);
    let wl = generate(WorkloadKind::RingAllReduce, &g, &WorkloadParams::default());
    let sim = Simulator::for_workload(g.clone(), cfg());
    let direct = sim.run_workload_seeded(&wl, cfg().seed, wl.suggested_max_cycles(16));
    let runner = WorkloadRunner { sim: cfg(), seeds: 1, ..Default::default() };
    let point = runner.run_with(&sim, "FCC(2)", &wl);
    assert_eq!(point.completion_cycles, direct.completion_cycles as f64);
    assert_eq!(point.avg_latency, direct.avg_latency);
}

// ---------------------------------------------------------------------------
// Packetization invariants (the multi-packet message model).
// ---------------------------------------------------------------------------

const PS: u64 = 16; // default packet_size

/// Phit conservation: across every family and payload size — including
/// payloads that are not a multiple of the packet size — the engine
/// delivers exactly the sum of the message sizes, in exactly
/// `ceil(size/packet_size)` packets per message.
#[test]
fn delivered_phits_equal_sum_of_message_sizes() {
    for g in [topology::torus(&[4, 4]), topology::fcc(2)] {
        let sim = Simulator::for_workload(g.clone(), cfg());
        for kind in WorkloadKind::ALL {
            for phits in [16u32, 100, 272] {
                let p = WorkloadParams { iters: 2, payload_phits: phits, ..Default::default() };
                let wl = generate(kind, &g, &p);
                let out = sim.run_workload(&wl);
                assert!(out.drained, "{} @ {phits} phits undrained", wl.name);
                assert_eq!(out.delivered_messages, wl.len() as u64, "{}", wl.name);
                assert_eq!(
                    out.delivered_phits,
                    wl.total_phits(),
                    "{} @ {phits} phits: delivered phits must equal the payload sum",
                    wl.name
                );
                assert_eq!(
                    out.delivered_packets,
                    wl.total_packets(16),
                    "{} @ {phits} phits: ceil-packetization packet count",
                    wl.name
                );
            }
        }
    }
}

/// A single multi-packet message on a unique minimal path: the source link
/// serializes the train, so completion is exactly `packets·ps + hops`, and
/// a super-serialization inter-packet gap stretches it to
/// `(packets−1)·gap + ps + hops`.
#[test]
fn train_serialization_is_exact() {
    // Node 1 of T(4,4) is one hop from node 0 with a unique minimal
    // record, so no RNG tie choice can perturb the path.
    let g = topology::torus(&[4, 4]);
    let train = |pkts: u64| Workload {
        name: format!("train{pkts}"),
        nodes: g.order(),
        messages: vec![WorkloadMessage {
            size_phits: (pkts * PS) as u32,
            ..WorkloadMessage::new(0, 1, 0, vec![])
        }],
    };
    let sim = Simulator::for_workload(g.clone(), cfg());
    for pkts in [1u64, 2, 5, 9] {
        let out = sim.run_workload(&train(pkts));
        assert!(out.drained);
        assert_eq!(out.completion_cycles, pkts * PS + 1, "{pkts}-packet train");
    }
    // gap > ps dominates the wire serialization exactly.
    let gap = PS + 4;
    let gapped = Simulator::for_workload(g.clone(), SimConfig { packet_gap: gap, ..cfg() });
    let out = gapped.run_workload(&train(5));
    assert!(out.drained);
    assert_eq!(out.completion_cycles, 4 * gap + PS + 1);
}

/// The inter-packet gap paces the NIC across message boundaries too: two
/// independent single-packet messages from one node behave like a 2-packet
/// train, so a super-serialization gap delays the second message's packet
/// exactly as it would a second train packet.
#[test]
fn gap_spaces_consecutive_messages_from_one_nic() {
    let g = topology::torus(&[4, 4]);
    let wl = Workload {
        name: "back-to-back".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage::new(0, 1, 0, vec![]), WorkloadMessage::new(0, 1, 1, vec![])],
    };
    // Ungapped: the source link serializes the two packets back to back.
    let base = Simulator::for_workload(g.clone(), cfg()).run_workload(&wl);
    assert!(base.drained);
    assert_eq!(base.completion_cycles, 2 * PS + 1);
    // gap > ps: the second message's packet waits out the gap from the
    // first message's injection, so --packet-gap is not a no-op even on
    // single-packet workloads.
    let gap = PS + 4;
    let out = Simulator::for_workload(g, SimConfig { packet_gap: gap, ..cfg() }).run_workload(&wl);
    assert!(out.drained);
    assert_eq!(out.completion_cycles, gap + PS + 1);
}

/// Dependency gating: a dependent message never injects before its
/// parent's *last* packet drains (plus overheads). On a unique minimal
/// path the whole chain is exact: each link contributes
/// `o_send + packets·ps + hops + o_recv`.
#[test]
fn dependent_waits_for_parents_last_packet() {
    let g = topology::torus(&[4, 4]);
    let chain = |parent_pkts: u64| Workload {
        name: format!("chain{parent_pkts}"),
        nodes: g.order(),
        messages: vec![
            WorkloadMessage {
                size_phits: (parent_pkts * PS) as u32,
                ..WorkloadMessage::new(0, 1, 0, vec![])
            },
            WorkloadMessage::new(1, 0, 1, vec![0]),
        ],
    };
    // No overheads: completion = (P·ps + 1) + (ps + 1), growing by exactly
    // ps per extra parent packet — the child cannot start early.
    let sim = Simulator::for_workload(g.clone(), cfg());
    for pkts in [1u64, 2, 8] {
        let out = sim.run_workload(&chain(pkts));
        assert!(out.drained);
        assert_eq!(out.completion_cycles, (pkts * PS + 1) + (PS + 1), "parent {pkts} packets");
    }
    // With LogGP overheads each chain link pays o_send + o_recv too.
    let (o_s, o_r) = (7u64, 9u64);
    let loaded = Simulator::for_workload(
        g.clone(),
        SimConfig { send_overhead: o_s, recv_overhead: o_r, ..cfg() },
    );
    let out = loaded.run_workload(&chain(4));
    assert!(out.drained);
    assert_eq!(
        out.completion_cycles,
        (o_s + 4 * PS + 1 + o_r) + (o_s + PS + 1 + o_r),
        "overheads accrue per chain link"
    );
    // Same-source chaining gates on delivery, not on NIC availability.
    let same_src = Workload {
        name: "same-src".into(),
        nodes: g.order(),
        messages: vec![
            WorkloadMessage { size_phits: (3 * PS) as u32, ..WorkloadMessage::new(0, 1, 0, vec![]) },
            WorkloadMessage::new(0, 1, 1, vec![0]),
        ],
    };
    let out = sim.run_workload(&same_src);
    assert!(out.drained);
    assert_eq!(out.completion_cycles, (3 * PS + 1) + (PS + 1));
}

/// `size_phits = packet_size` reproduces the original single-packet
/// model's dynamics exactly: shrinking every payload within one packet
/// changes delivered phits but not one cycle of the wire behaviour (same
/// completion, same latencies, same packet count — same RNG stream).
#[test]
fn single_packet_payloads_reproduce_single_packet_dynamics() {
    for g in [topology::torus(&[4, 4, 4]), topology::fcc(2)] {
        let sim = Simulator::for_workload(g.clone(), cfg());
        for kind in WorkloadKind::ALL {
            let p = WorkloadParams { iters: 3, ..Default::default() };
            let wl = generate(kind, &g, &p);
            assert!(wl.messages.iter().all(|m| m.size_phits as u64 <= PS), "{}", wl.name);
            // The same message set with every payload shrunk to one phit:
            // still one packet per message, so the wire dynamics — and the
            // RNG stream — must be bit-identical.
            let shrunk = Workload {
                name: wl.name.clone(),
                nodes: wl.nodes,
                messages: wl
                    .messages
                    .iter()
                    .map(|m| WorkloadMessage { size_phits: 1, ..m.clone() })
                    .collect(),
            };
            let cap = wl.suggested_max_cycles(16);
            let a = sim.run_workload_seeded(&wl, 11, cap);
            let b = sim.run_workload_seeded(&shrunk, 11, cap);
            assert!(a.drained && b.drained, "{}", wl.name);
            assert_eq!(a.completion_cycles, b.completion_cycles, "{}", wl.name);
            assert_eq!(a.avg_latency, b.avg_latency, "{}", wl.name);
            assert_eq!(a.p99_latency, b.p99_latency, "{}", wl.name);
            assert_eq!(a.max_latency, b.max_latency, "{}", wl.name);
            assert_eq!(a.delivered_packets, b.delivered_packets, "{}", wl.name);
            assert_eq!(a.delivered_phits, wl.total_phits(), "{}", wl.name);
            assert_eq!(b.delivered_phits, shrunk.total_phits(), "{}", wl.name);
        }
    }
}

/// Chained generated patterns pay at least the analytic LogGP floor:
/// every phase of the critical path costs `o_send + wire + o_recv`, and a
/// super-serialization gap adds `(packets−1)·gap` per phase.
#[test]
fn overheads_and_gap_bound_generated_patterns() {
    let g = topology::torus(&[4, 4]); // n = 16
    let (o_s, o_r) = (10u64, 10u64);
    let p = WorkloadParams { payload_phits: 64, ..Default::default() }; // 4 packets/msg
    let wl = generate(WorkloadKind::AllToAll, &g, &p);
    let phases = wl.phases() as u64; // 15 chained phases per source

    // Per chain link the last packet cannot drain before the first-packet
    // eligibility plus 3 injection-queue services, one hop, and one tail
    // serialization (packets of one train may fan out over different
    // output ports when routing ties allow, so the floor is NIC-side, not
    // per-link).
    let link_floor = 3 + 1 + PS;
    let base = Simulator::for_workload(g.clone(), cfg()).run_workload(&wl);
    assert!(base.drained);
    assert!(
        base.completion_cycles >= phases * link_floor,
        "wire serialization floor: {}",
        base.completion_cycles
    );

    let loaded = Simulator::for_workload(
        g.clone(),
        SimConfig { send_overhead: o_s, recv_overhead: o_r, ..cfg() },
    )
    .run_workload(&wl);
    assert!(loaded.drained);
    assert!(
        loaded.completion_cycles >= phases * (o_s + link_floor + o_r),
        "LogGP floor: {}",
        loaded.completion_cycles
    );
    assert!(
        loaded.completion_cycles >= base.completion_cycles + phases * (o_s + o_r) / 2,
        "overheads must show up in completion: {} vs {}",
        loaded.completion_cycles,
        base.completion_cycles
    );

    let gap = 2 * PS;
    let gapped = Simulator::for_workload(g, SimConfig { packet_gap: gap, ..cfg() })
        .run_workload(&wl);
    assert!(gapped.drained);
    assert!(
        gapped.completion_cycles >= phases * (3 * gap + PS + 1),
        "gap floor: {}",
        gapped.completion_cycles
    );
    assert!(
        gapped.completion_cycles > base.completion_cycles,
        "a 2·ps gap must slow the train down: {} vs {}",
        gapped.completion_cycles,
        base.completion_cycles
    );
}
