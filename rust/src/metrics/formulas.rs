//! Closed-form distance models from §3.4 (Table 1, Table 2).
//!
//! The paper's exact average-distance expressions for the three crystals
//! (split by parity of `a`), the Table 1 approximations, and the Table 2
//! constants. Each is validated against exact BFS in tests and by the
//! `experiment formulas` driver up to 40 000+ nodes (the paper's own
//! computational check).

/// Exact average distance of `PC(a)` (§3.4).
pub fn avg_distance_pc(a: i64) -> f64 {
    let af = a as f64;
    if a % 2 == 0 {
        3.0 * af.powi(4) / (4.0 * (af.powi(3) - 1.0))
    } else {
        (3.0 * af.powi(4) - 3.0 * af * af) / (4.0 * (af.powi(3) - 1.0))
    }
}

/// Exact average distance of `FCC(a)` (§3.4).
pub fn avg_distance_fcc(a: i64) -> f64 {
    let af = a as f64;
    if a % 2 == 0 {
        (7.0 * af.powi(4) - 2.0 * af * af) / (4.0 * (2.0 * af.powi(3) - 1.0))
    } else {
        (7.0 * af.powi(4) - 2.0 * af * af - 1.0) / (4.0 * (2.0 * af.powi(3) - 1.0))
    }
}

/// Exact average distance of `BCC(a)` (§3.4).
///
/// **Erratum**: the paper prints the odd-`a` numerator as
/// `35a^4 - 14a^2 + 30`; the printed constant cannot be right (it makes the
/// distance sum non-integral). Exact BFS sums for a = 1, 3, 5, 7 fit
/// `35a^4 - 14a^2 + 3` exactly — a `+30` / `+3` typo. See EXPERIMENTS.md.
pub fn avg_distance_bcc(a: i64) -> f64 {
    let af = a as f64;
    if a % 2 == 0 {
        (35.0 * af.powi(4) - 8.0 * af * af) / (8.0 * (4.0 * af.powi(3) - 1.0))
    } else {
        (35.0 * af.powi(4) - 14.0 * af * af + 3.0) / (8.0 * (4.0 * af.powi(3) - 1.0))
    }
}

/// Exact average distance of the torus `T(a_1, ..., a_n)`: per-dimension
/// ring averages add (distances are L1-separable), with the paper's
/// `sum / (N - 1)` normalization.
pub fn avg_distance_torus(sides: &[i64]) -> f64 {
    let n: i64 = sides.iter().product();
    let mut sum_per_node = 0.0f64;
    for &a in sides {
        // Sum of ring distances from 0: even a: a^2/4; odd a: (a^2-1)/4.
        let ring_sum = if a % 2 == 0 { a * a / 4 } else { (a * a - 1) / 4 };
        // Each other dimension multiplies the count of pairs.
        sum_per_node += (ring_sum as f64) * (n / a) as f64;
    }
    sum_per_node * n as f64 / (n as f64 * (n as f64 - 1.0))
}

/// Table 1 diameter models.
pub fn diameter_pc(a: i64) -> i64 {
    3 * (a / 2)
}
pub fn diameter_fcc(a: i64) -> i64 {
    3 * a / 2
}
pub fn diameter_bcc(a: i64) -> i64 {
    3 * a / 2
}
pub fn diameter_torus(sides: &[i64]) -> i64 {
    sides.iter().map(|&a| a / 2).sum()
}

/// Table 1 asymptotic average-distance coefficients (`k̄ ≈ coeff * a`).
pub const TABLE1_COEFF_PC: f64 = 0.75;
pub const TABLE1_COEFF_T2AAA: f64 = 1.0;
pub const TABLE1_COEFF_FCC: f64 = 0.875;
pub const TABLE1_COEFF_T2A2AA: f64 = 1.25;
pub const TABLE1_COEFF_BCC: f64 = 35.0 / 32.0; // 1.09375

/// Table 2 rows: `(name, dimension, order(a), projection, diameter(a),
/// avg-distance coefficient)` — the paper's reported models for the
/// lifted/hybrid graphs.
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    pub name: &'static str,
    pub dim: usize,
    /// Diameter model as a multiple of `a`.
    pub diameter_coeff: f64,
    /// Average distance `≈ coeff * a`.
    pub avg_coeff: f64,
}

/// The Table 2 constants as printed in the paper.
pub const TABLE2: &[Table2Row] = &[
    Table2Row { name: "T(2a,2a)⊞RTT(a)", dim: 3, diameter_coeff: 2.0, avg_coeff: 1.14877 },
    Table2Row { name: "4D-FCC(a)", dim: 4, diameter_coeff: 2.0, avg_coeff: 1.10396 },
    Table2Row { name: "4D-BCC(a)", dim: 4, diameter_coeff: 2.0, avg_coeff: 1.5379 },
    Table2Row { name: "Lip(a)", dim: 4, diameter_coeff: 3.0, avg_coeff: 1.815 },
    Table2Row { name: "PC(2a)⊞BCC(a)", dim: 4, diameter_coeff: 2.5, avg_coeff: 1.59715 },
    Table2Row { name: "PC(2a)⊞FCC(a)", dim: 5, diameter_coeff: 3.5, avg_coeff: 1.87856 },
    Table2Row { name: "BCC(a)⊞FCC(a)", dim: 5, diameter_coeff: 2.5, avg_coeff: 1.52522 },
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::distance_distribution;
    use crate::topology::{bcc, fcc, pc, torus};

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn pc_formula_matches_bfs() {
        for a in 2..9i64 {
            let exact = distance_distribution(&pc(a)).avg_distance;
            assert!(
                close(avg_distance_pc(a), exact, 1e-9),
                "PC({a}): formula {} vs bfs {exact}",
                avg_distance_pc(a)
            );
        }
    }

    #[test]
    fn fcc_formula_matches_bfs() {
        for a in 2..9i64 {
            let exact = distance_distribution(&fcc(a)).avg_distance;
            assert!(
                close(avg_distance_fcc(a), exact, 1e-9),
                "FCC({a}): formula {} vs bfs {exact}",
                avg_distance_fcc(a)
            );
        }
    }

    #[test]
    fn bcc_formula_matches_bfs() {
        // NOTE: the odd case is checked loosely first; see
        // experiment `formulas` for the full sweep report.
        for a in 2..9i64 {
            let exact = distance_distribution(&bcc(a)).avg_distance;
            let formula = avg_distance_bcc(a);
            assert!(
                close(formula, exact, 1e-9),
                "BCC({a}): formula {formula} vs bfs {exact}"
            );
        }
    }

    #[test]
    fn torus_formula_matches_bfs() {
        for sides in [vec![4i64, 4], vec![8, 4, 4], vec![5, 3, 2], vec![6, 6, 3]] {
            let exact = distance_distribution(&torus(&sides)).avg_distance;
            let formula = avg_distance_torus(&sides);
            assert!(
                close(formula, exact, 1e-9),
                "{sides:?}: formula {formula} vs bfs {exact}"
            );
        }
    }

    #[test]
    fn table1_asymptotics() {
        // The asymptotic coefficients should be approached by a = 16.
        let a = 16i64;
        assert!(close(avg_distance_pc(a) / a as f64, TABLE1_COEFF_PC, 0.01));
        assert!(close(avg_distance_fcc(a) / a as f64, TABLE1_COEFF_FCC, 0.01));
        assert!(close(avg_distance_bcc(a) / a as f64, TABLE1_COEFF_BCC, 0.01));
        assert!(close(
            avg_distance_torus(&[2 * a, a, a]) / a as f64,
            TABLE1_COEFF_T2AAA,
            0.02
        ));
        assert!(close(
            avg_distance_torus(&[2 * a, 2 * a, a]) / a as f64,
            TABLE1_COEFF_T2A2AA,
            0.02
        ));
    }

    #[test]
    fn crystals_beat_equal_order_tori() {
        // The Table 1 story: FCC(a) beats T(2a,a,a); BCC(a) beats T(2a,2a,a).
        for a in [4i64, 8] {
            assert!(avg_distance_fcc(a) < avg_distance_torus(&[2 * a, a, a]));
            assert!(
                avg_distance_bcc(a) < avg_distance_torus(&[2 * a, 2 * a, a])
            );
            assert!(diameter_fcc(a) < diameter_torus(&[2 * a, a, a]));
            assert!(diameter_bcc(a) < diameter_torus(&[2 * a, 2 * a, a]));
        }
    }
}
