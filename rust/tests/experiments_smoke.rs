//! End-to-end smoke of every experiment driver (the `experiment all`
//! surface) at test-sized parameters, plus the paper's qualitative claims.

use lattice_networks::coordinator::experiments as exp;
use lattice_networks::coordinator::sweep::peak_throughput;
use lattice_networks::sim::{SimConfig, TrafficPattern};

#[test]
fn table1_diameter_models_hold_to_a16() {
    // The driver asserts the diameter models internally.
    let t = exp::table1(&[2, 3, 4, 5, 8, 16]);
    assert_eq!(t.rows.len(), 6 * 5);
}

#[test]
fn formulas_hold_to_5000_nodes() {
    let t = exp::formulas_check(5_000);
    // PC to a=17 (4913), FCC to a=13 (4394), BCC to a=10 (4000)
    assert!(t.rows.len() >= 16 + 12 + 9, "rows: {}", t.rows.len());
}

#[test]
fn table2_matches_paper_constants_loosely() {
    // avg-distance coefficients approach the paper's constants with a；
    // at a=4 they should be within ~15%.
    let t = exp::table2(&[4]);
    for row in &t.rows {
        let measured: f64 = row[6].parse().unwrap();
        let model: f64 = row[7].parse().unwrap();
        let rel = (measured - model).abs() / model;
        assert!(rel < 0.15, "{}: measured {measured} vs model {model}", row[0]);
    }
}

#[test]
fn tree_contains_both_branches() {
    let s = exp::tree(4);
    assert!(s.contains("cycle"));
    assert!(s.contains("RTT"));
    assert!(s.contains("3D-PC"));
    assert!(s.contains("3D-FCC"));
    assert!(s.contains("3D-BCC"));
    assert!(s.contains("4D-BCC"));
    assert!(s.contains("4D-FCC"));
}

#[test]
fn fig6_scaled_shape_holds() {
    // The paper's qualitative result at reduced scale: the lattice network
    // sustains at least as much uniform traffic as the mixed-radix torus.
    let spec = exp::fig6_spec(false);
    let cfg = SimConfig { warmup_cycles: 400, measure_cycles: 2500, ..SimConfig::default() };
    let fig = exp::run_figure(
        &spec,
        &[TrafficPattern::Uniform],
        &[0.4, 0.6, 0.8, 1.0],
        2,
        cfg,
    )
    .unwrap();
    let torus = peak_throughput(&fig.curves[0].2);
    let lattice = peak_throughput(&fig.curves[1].2);
    assert!(
        lattice > torus,
        "4D-BCC peak {lattice:.3} should beat torus {torus:.3}"
    );
}

#[test]
fn gain_table_has_all_patterns() {
    let spec = exp::fig6_spec(false);
    let cfg = SimConfig { warmup_cycles: 200, measure_cycles: 800, ..SimConfig::default() };
    let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &[0.5], 1, cfg).unwrap();
    let t = exp::gain_table(&fig);
    assert_eq!(t.rows.len(), 4);
    let curves = exp::curve_table(&fig);
    assert_eq!(curves.rows.len(), 8); // 2 networks x 4 patterns x 1 load
}

#[test]
fn thm20_and_appendix() {
    assert_eq!(exp::thm20(&[1, 2]).rows.len(), 2);
    assert_eq!(exp::appendix().rows.len(), 48);
    assert!(exp::cycles().contains("RTT(4)"));
    assert_eq!(exp::crystals(4).rows.len(), 3);
}

#[test]
fn csv_output_works() {
    let t = exp::bounds(&[8]);
    let dir = std::env::temp_dir().join("lattice_networks_expsmoke");
    let path = t.write_csv(&dir, "bounds").unwrap();
    let body = std::fs::read_to_string(path).unwrap();
    assert!(body.lines().count() >= 2);
}
