//! Serial-vs-parallel differential pins (DESIGN.md §Parallel-engine).
//!
//! The phased multi-threaded cycle engine must be **bit-reproducible**
//! across thread counts: `threads = k` produces the same `SimResult` /
//! `WorkloadOutcome` — every counter, every latency statistic, and the
//! RNG end-state (`rng_digest`) — as the serial `threads = 1` reference,
//! for every k. The per-node counter RNG streams make that possible (no
//! draw depends on visit order), and the deterministic shard merge makes
//! it hold for the packet schedule too; these tests are the contract's
//! teeth, swept across policies, VC counts, loads, regimes, both scan
//! modes, the adversarial escape-protocol workload, and faulted
//! (degraded-mode) networks.
//!
//! CI runs this file twice over: once directly (the explicit thread
//! matrix below) and once per `LATTICE_THREADS` value in the
//! `parallel-differential` job's matrix, which additionally re-runs the
//! scan-mode and telemetry differentials at that thread count.
//!
//! The second half pins the injection-model refactor that enables the
//! parallelism: geometric inter-arrival gaps must reproduce the exact
//! per-cycle Bernoulli law, and idle nodes must consume zero RNG state.

use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};
use lattice_networks::workload::{Workload, WorkloadMessage};

/// Thread counts checked against the serial reference: an even split, a
/// split exceeding the shard-size remainder boundary, and a prime count
/// that divides nothing (every shard boundary lands mid-ring). CI's
/// `LATTICE_THREADS` value joins the matrix when set.
fn thread_matrix() -> Vec<usize> {
    let mut m = vec![2, 4, 7];
    if let Some(t) = std::env::var("LATTICE_THREADS").ok().and_then(|v| v.parse().ok()) {
        if t > 1 && !m.contains(&t) {
            m.push(t);
        }
    }
    m
}

/// Quick windows with a drain tail (the `engine_differential.rs` shape).
///
/// `serial_cutoff: 0` pins the *sharded* path: these networks are small
/// enough that the default fast-path cutoff would run every cycle on the
/// calling thread, and the point of this suite is to exercise shard
/// boundaries, barriers, and the merge at every thread count. (The
/// fast-path/sharded equivalence has its own pins below.)
fn base_cfg(policy: RoutePolicy, num_vcs: usize, threads: usize) -> SimConfig {
    SimConfig {
        warmup_cycles: 100,
        measure_cycles: 500,
        drain_cycles: 150,
        route_policy: policy,
        num_vcs,
        threads,
        serial_cutoff: 0,
        ..SimConfig::default()
    }
}

#[test]
fn open_loop_parallel_matches_serial_across_policy_vc_load() {
    // T(8,4) has DOR-visible asymmetry and tie-heavy half-ring records;
    // FCC(2) is a twisted (non-torus) lattice whose wrap edges cross
    // every shard cut.
    for g in [topology::torus(&[8, 4]), topology::fcc(2)] {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2] {
                for load in [0.1, 0.9] {
                    let run = |threads: usize| {
                        let sim = Simulator::new(
                            g.clone(),
                            TrafficPattern::Uniform,
                            base_cfg(policy, num_vcs, threads),
                        );
                        sim.run_seeded(load, 0xdead_beef)
                    };
                    let serial = run(1);
                    for threads in thread_matrix() {
                        let par = run(threads);
                        assert_eq!(
                            serial.rng_digest,
                            par.rng_digest,
                            "RNG stream diverged at {threads} threads: {} vcs={num_vcs} load={load}",
                            policy.name()
                        );
                        assert_eq!(
                            format!("{serial:?}"),
                            format!("{par:?}"),
                            "result diverged at {threads} threads: {} vcs={num_vcs} load={load}",
                            policy.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn closed_loop_parallel_matches_serial_across_policy_vc() {
    let g = topology::torus(&[4, 4]);
    // A contended collective plus a dependency-chained stencil: between
    // them they exercise NIC serialization, dependency release,
    // head-of-line blocking and the drain tail.
    let alltoall = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    let stencil =
        generate(WorkloadKind::Stencil, &g, &WorkloadParams { iters: 3, ..Default::default() });
    for wl in [&alltoall, &stencil] {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2] {
                let run = |threads: usize| {
                    let cfg = base_cfg(policy, num_vcs, threads);
                    let cap = wl.suggested_max_cycles_for(&cfg);
                    Simulator::for_workload(g.clone(), cfg).run_workload_seeded(wl, 7, cap)
                };
                let serial = run(1);
                assert!(serial.drained, "{} {} vcs={num_vcs}", wl.name, policy.name());
                for threads in thread_matrix() {
                    let par = run(threads);
                    assert_eq!(
                        serial.rng_digest,
                        par.rng_digest,
                        "RNG stream diverged at {threads} threads: {} {} vcs={num_vcs}",
                        wl.name,
                        policy.name()
                    );
                    assert_eq!(
                        format!("{serial:?}"),
                        format!("{par:?}"),
                        "outcome diverged at {threads} threads: {} {} vcs={num_vcs}",
                        wl.name,
                        policy.name()
                    );
                }
            }
        }
    }
}

/// Both scan modes must agree with their own serial reference *and* with
/// each other at every thread count — the active-set worklist is
/// maintained by shard-owned flags plus a serial compaction, the most
/// thread-sensitive structure in the engine.
#[test]
fn scan_modes_agree_at_every_thread_count() {
    let g = topology::torus(&[8, 4]);
    let run = |scan: ScanMode, threads: usize| {
        let cfg = SimConfig { scan_mode: scan, ..base_cfg(RoutePolicy::AdaptiveMin, 2, threads) };
        Simulator::new(g.clone(), TrafficPattern::Uniform, cfg).run_seeded(0.7, 99)
    };
    let reference = run(ScanMode::ActiveSet, 1);
    for threads in thread_matrix() {
        for scan in [ScanMode::ActiveSet, ScanMode::FullScan] {
            let r = run(scan, threads);
            assert_eq!(
                format!("{reference:?}"),
                format!("{r:?}"),
                "{scan:?} at {threads} threads diverged from the serial active-set run"
            );
        }
    }
}

/// The adversarial turn-cycle workload from `policy_properties.rs`: every
/// node floods `(+2, +2)` message trains through tight 2-packet queues
/// under `AdaptiveMin`, forcing heavy escape-lane traffic. The escape
/// drain decision reads cross-shard credit state, so this is the pattern
/// most likely to expose a phase-ordering bug — the whole outcome
/// (including the stall attribution and escape counters) must be
/// bit-identical at every thread count, and must still drain.
#[test]
fn escape_turn_cycle_drains_identically_at_every_thread_count() {
    let g = topology::torus(&[4, 4]);
    let n = g.order() as u32;
    let mut messages = Vec::new();
    for round in 0..12u32 {
        for u in 0..n {
            let label = g.label_of(u as usize);
            let dst = g.index_of_vec(&[label[0] + 2, label[1] + 2]) as u32;
            messages.push(WorkloadMessage::new(u, dst, round, vec![]));
        }
    }
    let wl = Workload { name: "turn-cycle".into(), nodes: g.order(), messages };
    let run = |threads: usize, seed: u64| {
        let cfg = SimConfig {
            num_vcs: 2,
            queue_packets: 2,
            route_policy: RoutePolicy::AdaptiveMin,
            warmup_cycles: 0,
            measure_cycles: 0,
            threads,
            // 16 nodes: force the sharded path (see `base_cfg`).
            serial_cutoff: 0,
            ..SimConfig::default()
        };
        Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, seed, 200_000)
    };
    for seed in [1u64, 2, 3] {
        let serial = run(1, seed);
        assert!(serial.drained, "serial escape run wedged at seed {seed}");
        assert!(serial.stalls.escape_drains > 0, "no escape traffic at seed {seed}");
        for threads in thread_matrix() {
            let par = run(threads, seed);
            assert_eq!(
                format!("{serial:?}"),
                format!("{par:?}"),
                "escape run diverged at {threads} threads, seed {seed}"
            );
        }
    }
}

/// Requesting more threads than nodes must clamp, not panic or wedge —
/// and still reproduce the serial run exactly.
#[test]
fn oversubscribed_thread_count_clamps_and_matches_serial() {
    let g = topology::torus(&[4, 4]); // 16 nodes
    let run = |threads: usize| {
        Simulator::new(g.clone(), TrafficPattern::Uniform, base_cfg(RoutePolicy::Dor, 2, threads))
            .run_seeded(0.5, 5)
    };
    let serial = run(1);
    let over = run(999);
    assert_eq!(format!("{serial:?}"), format!("{over:?}"));
}

// ---------------------------------------------------------------------------
// Skewed-work pins: the balanced shard planner and the serial fast path
// must be invisible to results under maximally uneven traffic.
// ---------------------------------------------------------------------------

/// Hotspot traffic (one saturated destination, everything else light) is
/// the balanced planner's reason to exist: the static cut planes leave
/// all but one worker idle. Pinned at both cutoff settings so the
/// forced-sharded and fast-path-eligible engines are each compared
/// against serial.
#[test]
fn open_loop_hotspot_traffic_matches_serial_at_every_thread_count() {
    let g = topology::torus(&[8, 4]);
    for scan in ScanMode::ALL {
        for cutoff in [0usize, 64] {
            let run = |threads: usize| {
                let cfg = SimConfig {
                    scan_mode: scan,
                    serial_cutoff: cutoff,
                    ..base_cfg(RoutePolicy::AdaptiveMin, 2, threads)
                };
                Simulator::new(g.clone(), TrafficPattern::HotSpot, cfg).run_seeded(0.5, 0x407)
            };
            let serial = run(1);
            for threads in thread_matrix() {
                let par = run(threads);
                assert_eq!(
                    serial.rng_digest, par.rng_digest,
                    "hotspot RNG diverged at {threads} threads ({scan:?}, cutoff {cutoff})"
                );
                assert_eq!(
                    format!("{serial:?}"),
                    format!("{par:?}"),
                    "hotspot result diverged at {threads} threads ({scan:?}, cutoff {cutoff})"
                );
            }
        }
    }
}

/// One hot quadrant: all traffic lives on the 16 lowest-index nodes of a
/// 64-node torus, so every static shard but the first is empty while the
/// balanced planner splits the quadrant across all workers. Dependency
/// chains keep the quadrant busy for many cycles. The whole outcome must
/// be identical across thread counts, scan modes, and both cutoff
/// settings — the cutoff grid also pins that a fast-path run (16 active
/// nodes is under every nonzero threshold) equals a forced-sharded one.
#[test]
fn hot_quadrant_workload_matches_serial_at_every_thread_count() {
    let g = topology::torus(&[8, 8]);
    let q = 16u32;
    let rounds = 6u32;
    let mut messages = Vec::new();
    for round in 0..rounds {
        for u in 0..q {
            // (u + 1 + round) % q == u would need round == q - 1; rounds
            // stay below that, so no self-messages and the message index
            // is exactly round * q + u — which makes the chain deps
            // trivial to name.
            let dst = (u + 1 + round) % q;
            let deps = if round == 0 { vec![] } else { vec![(round - 1) * q + u] };
            messages.push(WorkloadMessage::new(u, dst, round, deps));
        }
    }
    let wl = Workload { name: "hot-quadrant".into(), nodes: g.order(), messages };
    let mut reference: Option<String> = None;
    for scan in ScanMode::ALL {
        for cutoff in [0usize, 64] {
            let run = |threads: usize| {
                let cfg = SimConfig {
                    scan_mode: scan,
                    serial_cutoff: cutoff,
                    ..base_cfg(RoutePolicy::AdaptiveMin, 2, threads)
                };
                let cap = wl.suggested_max_cycles_for(&cfg);
                Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, 11, cap)
            };
            let serial = run(1);
            assert!(serial.drained, "hot quadrant wedged ({scan:?}, cutoff {cutoff})");
            let serial_dbg = format!("{serial:?}");
            // Scan mode, cutoff, and thread count are all perf knobs:
            // one global reference outcome covers the whole grid.
            match &reference {
                None => reference = Some(serial_dbg.clone()),
                Some(r) => assert_eq!(
                    r, &serial_dbg,
                    "serial outcome varies across ({scan:?}, cutoff {cutoff})"
                ),
            }
            for threads in thread_matrix() {
                let par = run(threads);
                assert_eq!(serial.rng_digest, par.rng_digest);
                assert_eq!(
                    serial_dbg,
                    format!("{par:?}"),
                    "hot quadrant diverged at {threads} threads ({scan:?}, cutoff {cutoff})"
                );
            }
        }
    }
}

/// A nearly idle network (512 nodes, 1% load) must (a) stay bit-identical
/// at every thread count and (b) actually take the serial fast path at
/// the default cutoff — a handful of active nodes can never amortize a
/// barrier round-trip.
#[test]
fn near_idle_network_matches_serial_and_takes_the_fast_path() {
    let g = topology::torus(&[8, 8, 8]);
    let run = |scan: ScanMode, threads: usize| {
        let cfg = SimConfig {
            scan_mode: scan,
            serial_cutoff: SimConfig::default().serial_cutoff,
            ..base_cfg(RoutePolicy::Dor, 2, threads)
        };
        Simulator::new(g.clone(), TrafficPattern::Uniform, cfg).run_seeded(0.01, 2024)
    };
    for scan in ScanMode::ALL {
        let serial = run(scan, 1);
        assert!(serial.injected_packets > 0);
        for threads in thread_matrix() {
            let par = run(scan, threads);
            assert_eq!(serial.rng_digest, par.rng_digest, "{scan:?} at {threads} threads");
            assert_eq!(
                format!("{serial:?}"),
                format!("{par:?}"),
                "near-idle run diverged at {threads} threads ({scan:?})"
            );
        }
    }
    // Under the active scan at 4 threads the work estimate (~a few
    // active nodes) sits far below 4 × 64, so effectively every cycle
    // must have run serial. The full scan estimates nodes = 512 ≥ 256
    // and must have sharded every cycle instead.
    let active = run(ScanMode::ActiveSet, 4);
    assert!(active.engine.parallel_cycles == 0 && active.engine.serial_cycles > 0,
        "near-idle active scan should be all fast-path (serial {}, parallel {})",
        active.engine.serial_cycles, active.engine.parallel_cycles);
    let full = run(ScanMode::FullScan, 4);
    assert!(full.engine.serial_cycles == 0 && full.engine.parallel_cycles > 0,
        "full scan's work estimate is the node count; it must shard every cycle");
}

/// Burst-then-tail: a serial dependency chain (a couple of active nodes,
/// below the fast-path threshold) gates a 512-node burst (far above it),
/// which drains back into another chain — so a 4-thread run crosses the
/// threshold in both directions mid-run, and both transitions must be
/// seamless: bit-identical outcome, and a profile showing both paths ran.
#[test]
fn fast_path_threshold_crossings_stay_bit_identical() {
    let g = topology::torus(&[8, 8, 8]);
    let n = g.order() as u32; // 512
    let chain = 40u32;
    let mut messages = Vec::new();
    // Lead-in chain: message i from node i to node i+1, each gated on
    // the previous hop.
    for i in 0..chain {
        let deps = if i == 0 { vec![] } else { vec![i - 1] };
        messages.push(WorkloadMessage::new(i % n, (i + 1) % n, 0, deps));
    }
    // Burst: once the chain completes, every node sends to its antipode
    // in the same cycle.
    let burst_base = chain;
    for u in 0..n {
        messages.push(WorkloadMessage::new(u, (u + n / 2) % n, 1, vec![chain - 1]));
    }
    // Tail chain, gated on one burst message: outlives the burst drain,
    // pulling the engine back under the threshold while it runs.
    let tail_base = burst_base + n;
    for i in 0..chain {
        let deps = if i == 0 { vec![burst_base] } else { vec![tail_base + i - 1] };
        messages.push(WorkloadMessage::new((i + 7) % n, (i + 8) % n, 2, deps));
    }
    let wl = Workload { name: "burst-tail".into(), nodes: g.order(), messages };
    for scan in ScanMode::ALL {
        let run = |threads: usize| {
            let cfg = SimConfig {
                scan_mode: scan,
                serial_cutoff: SimConfig::default().serial_cutoff,
                ..base_cfg(RoutePolicy::AdaptiveMin, 2, threads)
            };
            let cap = wl.suggested_max_cycles_for(&cfg);
            Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, 3, cap)
        };
        let serial = run(1);
        assert!(serial.drained, "burst-tail wedged ({scan:?})");
        for threads in thread_matrix() {
            let par = run(threads);
            assert_eq!(serial.rng_digest, par.rng_digest, "{scan:?} at {threads} threads");
            assert_eq!(
                format!("{serial:?}"),
                format!("{par:?}"),
                "burst-tail diverged at {threads} threads ({scan:?})"
            );
        }
        if scan == ScanMode::ActiveSet {
            // At 4 threads the chains run under 4 × 64 = 256 active and
            // the 512-node burst above it: the profile must show the
            // engine crossed the threshold (both counters nonzero).
            let r = run(4);
            assert!(
                r.engine.serial_cycles > 0 && r.engine.parallel_cycles > 0,
                "expected both paths: serial {} parallel {}",
                r.engine.serial_cycles,
                r.engine.parallel_cycles
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Faulted-network pins: degraded-mode routing must be thread-invariant.
// ---------------------------------------------------------------------------

/// Degraded-mode routing adds fault masks to port selection, a
/// reachability gate to injection, and an extra admission check to the
/// escape drain — all keyed off shared read-only fault state. No shard
/// may ever see a different fault set or consume RNG in a different
/// order because of one. Swept over both scan modes and both lattice
/// families at rates heavy enough that dead hardware lands in every
/// shard of the thread matrix.
#[test]
fn faulted_open_loop_matches_serial_at_every_thread_count() {
    for g in [topology::torus(&[8, 4]), topology::fcc(2)] {
        for scan in ScanMode::ALL {
            let run = |threads: usize| {
                let cfg = SimConfig {
                    scan_mode: scan,
                    link_fault_rate: 0.1,
                    node_fault_rate: 0.05,
                    ..base_cfg(RoutePolicy::AdaptiveMin, 2, threads)
                };
                let sim = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg);
                assert!(sim.faults().is_some(), "fault rates must derive a fault set");
                sim.run_seeded(0.4, 0xfa11)
            };
            let serial = run(1);
            assert!(serial.delivered_packets > 0, "faulted serial run moved no traffic");
            for threads in thread_matrix() {
                let par = run(threads);
                assert_eq!(
                    serial.rng_digest, par.rng_digest,
                    "faulted RNG diverged at {threads} threads ({scan:?})"
                );
                assert_eq!(
                    format!("{serial:?}"),
                    format!("{par:?}"),
                    "faulted result diverged at {threads} threads ({scan:?})"
                );
            }
        }
    }
}

/// A masked faulted workload must drain to the same outcome at every
/// thread count. Every drained run here also executes the dead-hardware
/// quiescence checks (`assert_quiescent`: dead links carried zero phits,
/// dead routers hold nothing), so the sweep itself verifies that no
/// shard ever drove faulted hardware.
#[test]
fn faulted_closed_loop_drains_identically_at_every_thread_count() {
    let g = topology::torus(&[8, 4]);
    let wl = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    for policy in [RoutePolicy::Dor, RoutePolicy::AdaptiveMin] {
        let faulted = |threads: usize| SimConfig {
            link_fault_rate: 0.1,
            node_fault_rate: 0.05,
            ..base_cfg(policy, 2, threads)
        };
        // Fault draws are a pure function of the config (not the run
        // seed), so a probe simulator sees the same dead set every run
        // below does.
        let probe = Simulator::for_workload(g.clone(), faulted(1));
        let f = probe.faults().expect("fault rates must derive a fault set");
        assert!(f.dead_links() > 0, "rate 0.1 on 64 links must kill hardware");
        let run = |threads: usize| {
            let cfg = faulted(threads);
            let cap = wl.suggested_max_cycles_for(&cfg);
            Simulator::for_workload(g.clone(), cfg).run_workload_seeded(&wl, 13, cap)
        };
        let serial = run(1);
        assert!(serial.drained, "faulted {} workload wedged", policy.name());
        assert!(serial.delivered_messages > 0, "masked workload delivered nothing");
        for threads in thread_matrix() {
            let par = run(threads);
            assert_eq!(
                serial.rng_digest,
                par.rng_digest,
                "faulted {} RNG diverged at {threads} threads",
                policy.name()
            );
            assert_eq!(
                format!("{serial:?}"),
                format!("{par:?}"),
                "faulted {} outcome diverged at {threads} threads",
                policy.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Injection-model properties: the geometric arrival calendar vs the
// per-cycle Bernoulli trial loop it replaced.
// ---------------------------------------------------------------------------

/// Law equality, end to end: the arrival calendar must offer packets at
/// the exact Bernoulli rate `load / packet_size` per node per cycle.
/// `injected + source_dropped` counts every arrival in the injection
/// window, so it is a Binomial(nodes · window, prob) sample — pinned to
/// the mean within a generous multiple of its standard deviation.
#[test]
fn geometric_calendar_matches_bernoulli_acceptance_rate() {
    let g = topology::torus(&[8, 8]);
    let nodes = g.order() as f64;
    for load in [0.1, 0.3, 0.6] {
        let cfg = SimConfig {
            warmup_cycles: 0,
            measure_cycles: 4000,
            drain_cycles: 0,
            ..SimConfig::default()
        };
        let prob = load / cfg.packet_size as f64;
        let window = cfg.measure_cycles as f64;
        let r = Simulator::new(g.clone(), TrafficPattern::Uniform, cfg).run_seeded(load, 1234);
        let arrivals = (r.injected_packets + r.source_dropped) as f64;
        let mean = nodes * window * prob;
        let sd = (mean * (1.0 - prob)).sqrt();
        assert!(
            (arrivals - mean).abs() < 6.0 * sd,
            "load {load}: {arrivals} arrivals vs Bernoulli mean {mean:.0} (sd {sd:.1})"
        );
    }
}

/// A zero-load network consumes zero per-node RNG state: no injection
/// draws (the calendar never fires) and no arbitration draws (no node is
/// ever visited with traffic). The engine-wide setup stream is excluded
/// from `rng_draws` by construction.
#[test]
fn idle_network_consumes_zero_node_rng_state() {
    let r = Simulator::new(
        topology::torus(&[8, 8]),
        TrafficPattern::Uniform,
        SimConfig { warmup_cycles: 100, measure_cycles: 1000, ..SimConfig::default() },
    )
    .run(0.0);
    assert_eq!(r.injected_packets, 0);
    assert_eq!(r.rng_draws, 0, "idle nodes drew RNG state");
}

/// Activity-proportional RNG cost: at light load the draw count must be
/// far below the one-draw-per-node-per-cycle floor of the retired
/// Bernoulli trial loop — that floor was the reason the injector blocked
/// the active-set engine's cost model (ROADMAP follow-up, now closed).
#[test]
fn light_load_draw_count_is_far_below_per_cycle_floor() {
    let g = topology::torus(&[8, 8]);
    let cfg = SimConfig {
        warmup_cycles: 0,
        measure_cycles: 2000,
        drain_cycles: 100,
        ..SimConfig::default()
    };
    let floor = g.order() as u64 * cfg.measure_cycles; // retired injector's draws
    let r = Simulator::new(g, TrafficPattern::Uniform, cfg).run_seeded(0.05, 77);
    assert!(r.injected_packets > 0, "no traffic at 5% load");
    assert!(r.rng_draws > 0);
    assert!(
        r.rng_draws < floor / 8,
        "draw count {} not activity-proportional (per-cycle floor {floor})",
        r.rng_draws
    );
}
