//! Route-selection policies: the per-hop output-port decision layer.
//!
//! The cycle engine walks each packet's remaining signed routing record
//! (the tie sets of Remark 30 fix *which* record a packet carries; the
//! *order* in which its nonzero components are consumed is this layer's
//! choice). Every policy is minimal — it only ever moves along a
//! productive axis, i.e. a nonzero component of the remaining record, so
//! the hop count is always the record's L1 norm — but the choice of which
//! productive axis to take next decides which physically distinct
//! intermediate links carry the packet, and therefore how load spreads
//! under global traffic:
//!
//! - [`RoutePolicy::Dor`]: deterministic dimension order, lowest nonzero
//!   axis first — bit-exact with the engine's historical behaviour (it
//!   consumes no RNG), and deadlock-free together with bubble flow
//!   control.
//! - [`RoutePolicy::RandomOrder`]: a uniformly random productive axis per
//!   hop, drawn from the simulator RNG — the oblivious balancing baseline.
//! - [`RoutePolicy::AdaptiveMin`]: the productive port with the most
//!   downstream buffer headroom (credits), RNG tie-break —
//!   congestion-aware minimal adaptive routing.
//!
//! The non-DOR policies choose the *preferred* hop; deadlock freedom
//! comes from the engine's escape protocol (`SimConfig::num_vcs >= 2`):
//! VC 0 is pinned to DOR, and a blocked adaptive packet drains into it —
//! a packet on the escape lane bypasses this layer's dispatch entirely
//! and takes [`dor_port`] RNG-free. With a single VC the adaptive
//! policies run unprotected and can genuinely deadlock at saturation
//! (demonstrated by the adversarial regression in
//! `rust/tests/policy_properties.rs`).
//!
//! See DESIGN.md §Route-policy for the semantics and determinism
//! guarantees, and DESIGN.md §Virtual-channels for the escape protocol
//! and the deadlock-freedom argument.
//!
//! Diagnosing a policy's behaviour under load is the telemetry layer's
//! job ([`crate::sim::telemetry`]): a head this layer routed but the
//! engine could not move is attributed a stall cause (credit-starved /
//! link-busy / bubble-blocked), and each drain into the escape lane is
//! counted — so "adaptivity is stalling on credits and living in the
//! escape channel" is readable off `SimResult::stalls` instead of
//! guessed from throughput curves.

use super::engine::MAX_DIM;
use super::rng::Draw;

/// Per-hop output-port selection policy (`SimConfig::route_policy`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum RoutePolicy {
    /// Dimension order: lowest nonzero axis first (the historical engine).
    #[default]
    Dor,
    /// Uniformly random productive axis per hop.
    RandomOrder,
    /// Most downstream headroom among productive ports, RNG tie-break.
    AdaptiveMin,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::Dor, RoutePolicy::RandomOrder, RoutePolicy::AdaptiveMin];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Dor => "dor",
            RoutePolicy::RandomOrder => "random",
            RoutePolicy::AdaptiveMin => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_lowercase().as_str() {
            "dor" => Some(RoutePolicy::Dor),
            "random" | "random-order" | "randomorder" => Some(RoutePolicy::RandomOrder),
            "adaptive" | "adaptive-min" | "adaptivemin" => Some(RoutePolicy::AdaptiveMin),
            _ => None,
        }
    }

    /// Choose the output port for a packet whose remaining record is
    /// `record` (`ports` is returned for ejection once the record is
    /// exhausted). `headroom(p)` reports the free downstream packet slots
    /// behind output port `p` on the packet's virtual channel; only
    /// [`AdaptiveMin`](RoutePolicy::AdaptiveMin) consults it, and only
    /// [`Dor`](RoutePolicy::Dor) is RNG-free. Generic over the draw
    /// source ([`Draw`]): the engine passes per-node counter streams,
    /// unit tests may pass the sequential [`Rng`](super::rng::Rng).
    #[inline]
    pub fn select_port(
        &self,
        record: &[i16; MAX_DIM],
        dim: usize,
        ports: usize,
        mut headroom: impl FnMut(usize) -> u32,
        rng: &mut impl Draw,
    ) -> u8 {
        match self {
            RoutePolicy::Dor => dor_port(record, dim, ports),
            RoutePolicy::RandomOrder => {
                let k = record.iter().take(dim).filter(|&&h| h != 0).count();
                if k == 0 {
                    return ports as u8;
                }
                let mut pick = if k > 1 { rng.below(k) } else { 0 };
                for (axis, &h) in record.iter().enumerate().take(dim) {
                    if h != 0 {
                        if pick == 0 {
                            return port_of(axis, h);
                        }
                        pick -= 1;
                    }
                }
                unreachable!("productive-axis count mismatch")
            }
            RoutePolicy::AdaptiveMin => {
                // Single pass, reservoir tie-break: best headroom wins;
                // equals replace the incumbent with probability 1/ties.
                let mut best: Option<u8> = None;
                let mut best_room = 0u32;
                let mut ties = 0usize;
                for (axis, &h) in record.iter().enumerate().take(dim) {
                    if h == 0 {
                        continue;
                    }
                    let port = port_of(axis, h);
                    let room = headroom(port as usize);
                    if best.is_none() || room > best_room {
                        best = Some(port);
                        best_room = room;
                        ties = 1;
                    } else if room == best_room {
                        ties += 1;
                        if rng.below(ties) == 0 {
                            best = Some(port);
                        }
                    }
                }
                best.unwrap_or(ports as u8)
            }
        }
    }
}

impl RoutePolicy {
    /// [`select_port`](Self::select_port) with the productive set masked
    /// by `allowed(axis)` — the degraded-mode dispatch (DESIGN.md
    /// §Fault-model). The engine passes "the hop's link is live and the
    /// post-hop state keeps a live DOR completion", so the adaptive
    /// policies exclude faulted ports from their productive sets and
    /// `Dor` detours to the lowest *surviving* productive axis (in any
    /// reachable in-network state the true DOR port is allowed, so the
    /// detour only ever fires on the injection-time first hop).
    ///
    /// Returns `None` when the record is productive but every productive
    /// axis is masked out (the caller decides whether that is an
    /// admission failure or an invariant violation), `Some(ports)` for
    /// an exhausted record (ejection). Draws are over the *masked* set,
    /// so the stream differs from the unfaulted dispatch — which is why
    /// the engine only calls this when a fault set exists.
    #[inline]
    pub fn select_port_masked(
        &self,
        record: &[i16; MAX_DIM],
        dim: usize,
        ports: usize,
        mut allowed: impl FnMut(usize) -> bool,
        mut headroom: impl FnMut(usize) -> u32,
        rng: &mut impl Draw,
    ) -> Option<u8> {
        if record.iter().take(dim).all(|&h| h == 0) {
            return Some(ports as u8);
        }
        let mut live = |axis: usize, h: i16| h != 0 && allowed(axis);
        match self {
            RoutePolicy::Dor => (0..dim)
                .find(|&axis| live(axis, record[axis]))
                .map(|axis| port_of(axis, record[axis])),
            RoutePolicy::RandomOrder => {
                let k = (0..dim).filter(|&axis| live(axis, record[axis])).count();
                if k == 0 {
                    return None;
                }
                let mut pick = if k > 1 { rng.below(k) } else { 0 };
                for axis in 0..dim {
                    if live(axis, record[axis]) {
                        if pick == 0 {
                            return Some(port_of(axis, record[axis]));
                        }
                        pick -= 1;
                    }
                }
                unreachable!("masked productive-axis count mismatch")
            }
            RoutePolicy::AdaptiveMin => {
                let mut best: Option<u8> = None;
                let mut best_room = 0u32;
                let mut ties = 0usize;
                for axis in 0..dim {
                    if !live(axis, record[axis]) {
                        continue;
                    }
                    let port = port_of(axis, record[axis]);
                    let room = headroom(port as usize);
                    if best.is_none() || room > best_room {
                        best = Some(port);
                        best_room = room;
                        ties = 1;
                    } else if room == best_room {
                        ties += 1;
                        if rng.below(ties) == 0 {
                            best = Some(port);
                        }
                    }
                }
                best
            }
        }
    }
}

/// DOR output port of a remaining record: lowest nonzero dimension
/// (`ports` = ejection). A free function so the engine's hot path and the
/// tests can call it without going through the policy dispatch.
#[inline]
pub fn dor_port(record: &[i16; MAX_DIM], dim: usize, ports: usize) -> u8 {
    for (axis, &h) in record.iter().enumerate().take(dim) {
        if h != 0 {
            return port_of(axis, h);
        }
    }
    ports as u8
}

/// Directed port of a signed hop on `axis`: `2*axis` for `+`, `2*axis+1`
/// for `-` (the simulator's port numbering; also used by the engine's
/// escape re-selection scan).
#[inline]
pub(crate) fn port_of(axis: usize, h: i16) -> u8 {
    (2 * axis + usize::from(h < 0)) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    fn rec(xs: &[i16]) -> [i16; MAX_DIM] {
        let mut out = [0i16; MAX_DIM];
        out[..xs.len()].copy_from_slice(xs);
        out
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("ADAPTIVE-MIN"), Some(RoutePolicy::AdaptiveMin));
        assert_eq!(RoutePolicy::parse("random-order"), Some(RoutePolicy::RandomOrder));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert_eq!(RoutePolicy::default(), RoutePolicy::Dor);
    }

    #[test]
    fn dor_picks_lowest_nonzero_axis() {
        assert_eq!(dor_port(&rec(&[2, -1, 3]), 3, 6), 0);
        assert_eq!(dor_port(&rec(&[0, -1, 3]), 3, 6), 3);
        assert_eq!(dor_port(&rec(&[0, 0, 3]), 3, 6), 4);
        assert_eq!(dor_port(&rec(&[0, 0, 0]), 3, 6), 6, "exhausted record ejects");
    }

    #[test]
    fn dor_policy_is_rng_free() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let r = rec(&[1, -2, 0]);
        let port = RoutePolicy::Dor.select_port(&r, 3, 6, |_| 0, &mut a);
        assert_eq!(port, 0);
        // The stream was not consumed.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn random_order_covers_every_productive_axis() {
        let mut rng = Rng::new(3);
        let r = rec(&[1, -1, 2]);
        let mut seen = [false; 6];
        for _ in 0..200 {
            let p = RoutePolicy::RandomOrder.select_port(&r, 3, 6, |_| 0, &mut rng);
            seen[p as usize] = true;
        }
        // +x, -y, +z reachable; their opposites and ejection never.
        assert!(seen[0] && seen[3] && seen[4], "{seen:?}");
        assert!(!seen[1] && !seen[2] && !seen[5], "{seen:?}");
        // Exhausted record ejects without touching the RNG state mid-pick.
        assert_eq!(RoutePolicy::RandomOrder.select_port(&rec(&[]), 3, 6, |_| 0, &mut rng), 6);
    }

    #[test]
    fn adaptive_min_prefers_headroom_and_tiebreaks_uniformly() {
        let mut rng = Rng::new(11);
        let r = rec(&[1, 1, 0]);
        // +y (port 2) has strictly more room: always chosen.
        for _ in 0..50 {
            let p = RoutePolicy::AdaptiveMin
                .select_port(&r, 3, 6, |p| if p == 2 { 4 } else { 1 }, &mut rng);
            assert_eq!(p, 2);
        }
        // Equal room: both productive ports must appear.
        let mut seen = [false; 6];
        for _ in 0..200 {
            let p = RoutePolicy::AdaptiveMin.select_port(&r, 3, 6, |_| 2, &mut rng);
            seen[p as usize] = true;
        }
        assert!(seen[0] && seen[2], "{seen:?}");
        assert!(!seen[1] && !seen[3], "{seen:?}");
        // Exhausted record ejects.
        assert_eq!(RoutePolicy::AdaptiveMin.select_port(&rec(&[]), 3, 6, |_| 0, &mut rng), 6);
    }

    #[test]
    fn masked_dor_detours_to_lowest_surviving_axis() {
        let mut rng = Rng::new(1);
        let r = rec(&[2, -1, 3]);
        // Unmasked: axis 0. Axis 0 masked out: detour to axis 1, RNG-free.
        let before = rng.clone().next_u64();
        let p = RoutePolicy::Dor.select_port_masked(&r, 3, 6, |a| a != 0, |_| 0, &mut rng);
        assert_eq!(p, Some(3), "-y after masking +x");
        assert_eq!(rng.next_u64(), before, "Dor draws nothing, masked or not");
        // Everything masked: None, not a bogus port.
        let mut rng = Rng::new(1);
        assert_eq!(RoutePolicy::Dor.select_port_masked(&r, 3, 6, |_| false, |_| 0, &mut rng), None);
        // Exhausted record ejects regardless of the mask.
        assert_eq!(
            RoutePolicy::Dor.select_port_masked(&rec(&[]), 3, 6, |_| false, |_| 0, &mut rng),
            Some(6)
        );
    }

    #[test]
    fn masked_random_order_excludes_dead_axes() {
        let mut rng = Rng::new(3);
        let r = rec(&[1, -1, 2]);
        let mut seen = [false; 6];
        for _ in 0..200 {
            let p = RoutePolicy::RandomOrder
                .select_port_masked(&r, 3, 6, |a| a != 1, |_| 0, &mut rng)
                .unwrap();
            seen[p as usize] = true;
        }
        assert!(seen[0] && seen[4], "surviving productive axes covered: {seen:?}");
        assert!(!seen[3], "masked -y never chosen: {seen:?}");
        assert_eq!(
            RoutePolicy::RandomOrder.select_port_masked(&r, 3, 6, |_| false, |_| 0, &mut rng),
            None
        );
    }

    #[test]
    fn masked_adaptive_min_ignores_headroom_behind_dead_ports() {
        let mut rng = Rng::new(11);
        let r = rec(&[1, 1, 0]);
        // +y (port 2) has the most room but its axis is masked: +x wins.
        for _ in 0..50 {
            let p = RoutePolicy::AdaptiveMin
                .select_port_masked(&r, 3, 6, |a| a != 1, |p| if p == 2 { 9 } else { 1 }, &mut rng)
                .unwrap();
            assert_eq!(p, 0);
        }
        assert_eq!(
            RoutePolicy::AdaptiveMin.select_port_masked(&r, 3, 6, |_| false, |_| 9, &mut rng),
            None
        );
    }
}
