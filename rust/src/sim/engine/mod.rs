//! The synchronous cycle engine: virtual cut-through routers with
//! `num_vcs` virtual channels per link, bubble flow control, pluggable
//! per-hop route selection over minimal routing records, and a
//! Duato-style escape channel that makes the adaptive policies
//! deadlock-free.
//!
//! Model (see module docs in `sim/mod.rs` for the INSEE correspondence):
//! each node has `2n` input ports (one per incoming link) with `num_vcs`
//! FIFO queues each, an injection queue, and an ejection channel. One
//! packet transfer per link at a time; a transfer started at `t` holds the
//! link for the axis's serialization time (`ceil(packet_size /
//! axis_width)` cycles — 16 on a symmetric Table 3 link), delivers the
//! head downstream at `t + link_latency` (cut-through; the LogGP `L`
//! term), and frees the upstream buffer slot when the tail departs.
//!
//! Per-hop output ports come from the route-selection policy layer
//! ([`crate::sim::policy`]): packets carry their **remaining** signed
//! record, and the configured policy consumes one productive axis per hop
//! — deterministic dimension order (`Dor`, the historical engine, bit-
//! exact), a uniformly random productive axis (`RandomOrder`), or the
//! port with the most downstream headroom (`AdaptiveMin`). Every policy
//! is minimal: hop count always equals the record's L1 norm.
//!
//! **Virtual channels and the escape protocol** (DESIGN.md
//! §Virtual-channels): under `Dor` every VC is a plain parallel lane —
//! packets draw a VC at injection and keep it end-to-end, and DOR order
//! plus the bubble rule keeps each lane deadlock-free on its own. Under
//! the adaptive policies with `num_vcs >= 2`, VC 0 becomes the **escape
//! channel**: packets inject on an adaptive VC (`1..num_vcs`), and a
//! blocked adaptive head first retries the other productive ports on its
//! own VC, then drains into VC 0 on the DOR port (a ring-entering hop:
//! the full 2-slot bubble is required). Once on VC 0 a packet is
//! committed — it follows DOR on the escape lane to its destination —
//! so the escape subnetwork is exactly the provably deadlock-free
//! DOR+bubble network, and every blocked adaptive packet can always
//! eventually fall into it: adaptivity becomes safe at saturation.
//!
//! Two injection regimes share the router core:
//!
//! - **open loop** ([`Simulator::run`], `open_loop`): Bernoulli injection
//!   at a fixed offered load with a warmup/measure/drain window — the
//!   steady-state regime behind the paper's Figures 5–8;
//! - **closed loop** ([`Simulator::run_workload`], `closed_loop`): a
//!   finite, dependency-ordered message set (a
//!   [`Workload`](crate::workload::Workload)) is injected as its
//!   dependencies complete and the run lasts until the network drains,
//!   measuring **completion time** — the application-level regime behind
//!   the collective workload experiments.
//!
//! **Scan strategy** ([`SimConfig::scan_mode`], DESIGN.md
//! §Engine-performance): per-cycle work is proportional to *activity*,
//! not network size. The arbitration scan and the closed-loop NIC
//! packetizer visit maintained worklists — nodes with queued packets,
//! NICs with eligible messages — in ascending node order; every draw
//! comes from a per-node counter stream ([`crate::sim::rng::NodeRng`]),
//! so the results are bit-identical to the retained full-network
//! reference scan ([`ScanMode::FullScan`](crate::sim::ScanMode)), and
//! the open-loop Bernoulli injector samples geometric inter-arrival gaps
//! instead of drawing per node per cycle. Drain windows, closed-loop
//! dependency tails and low-load sweeps thus cost near-zero per idle
//! cycle; the `engine_scaling` bench records the speedup.
//!
//! **Parallel execution** ([`SimConfig::threads`], `parallel`, DESIGN.md
//! §Parallel-engine): every cycle runs a serial Phase A (events,
//! injection), a sharded Phase B (arbitration over contiguous node
//! ranges) and a serial Phase C (deferred cross-node effects merged in
//! node order). One code path serves every thread count, and per-node
//! counter streams make `threads = k` bit-identical to `threads = 1`
//! (pinned by `tests/parallel_differential.rs` and the CI thread matrix).
//!
//! **Telemetry** ([`crate::sim::telemetry`], DESIGN.md §Telemetry): the
//! engine carries observation-only hooks — always-on stall-cause counters
//! (`note_stall` in `arbitration`, NIC backlog in `closed_loop`) and, when
//! [`SimConfig::trace`] is set, packet-lifecycle JSONL events plus
//! periodic occupancy probes. The hooks draw no RNG and mutate no router
//! state, so results and `rng_digest` are bit-identical with tracing on
//! or off (pinned by `tests/telemetry_differential.rs`).
//!
//! File map: `state` holds the packet/FIFO/event arenas, the per-run
//! mutable state and the `ActiveSet` worklist; `arbitration` the
//! per-node output arbitration and link transfers (both scan flavours);
//! `parallel` the phased multi-threaded cycle driver and shard merge;
//! `injection` packet creation and source enqueue; `open_loop` /
//! `closed_loop` the two run regimes.

mod arbitration;
mod closed_loop;
mod injection;
mod open_loop;
mod parallel;
mod state;
#[cfg(test)]
mod tests;

use crate::lattice::LatticeGraph;
use crate::routing::RoutingTable;

use super::config::SimConfig;
use super::traffic::TrafficPattern;

use self::state::CompactRoutes;

/// Max supported graph dimension (the paper uses up to 6).
pub const MAX_DIM: usize = 6;

/// The simulator: immutable tables + per-run mutable state.
pub struct Simulator {
    g: LatticeGraph,
    cfg: SimConfig,
    pattern: TrafficPattern,
    dim: usize,
    ports: usize,
    nodes: usize,
    /// `neighbor[u * ports + p]`: node reached from `u` via port `p`
    /// (`p = 2*axis + (sign < 0)`).
    neighbor: Vec<u32>,
    /// Flattened labels, `dim` entries per node.
    labels: Vec<i64>,
    routes: CompactRoutes,
    /// Per-port link serialization time in cycles
    /// (`SimConfig::serialization_cycles` of the port's axis; both
    /// directions of an axis share a physical width).
    ser: Vec<u64>,
}

impl Simulator {
    /// Build a simulator with a prebuilt routing table (must belong to the
    /// same graph).
    pub fn with_table(
        g: LatticeGraph,
        table: &RoutingTable,
        pattern: TrafficPattern,
        cfg: SimConfig,
    ) -> Self {
        let dim = g.dim();
        assert!(dim <= MAX_DIM, "dimension {dim} exceeds MAX_DIM");
        assert!(
            cfg.queue_packets >= 1 && cfg.injection_queue_packets >= 1,
            "queue capacities must be at least one packet"
        );
        assert!(
            cfg.queue_packets <= u16::MAX as u32 && cfg.injection_queue_packets <= u16::MAX as u32,
            "queue capacities exceed u16 bookkeeping"
        );
        assert!(cfg.num_vcs >= 1, "at least one virtual channel is required");
        assert!(
            cfg.num_vcs <= SimConfig::max_vcs(dim),
            "occupancy bitmask supports at most 64 VC queues per node"
        );
        assert!(cfg.link_latency >= 1, "link_latency must be at least one cycle");
        assert!(cfg.threads >= 1, "at least one engine thread is required");
        assert!(
            cfg.axis_widths.iter().all(|&w| w >= 1),
            "axis widths must be at least 1"
        );
        let nodes = g.order();
        let ports = 2 * dim;
        let mut neighbor = vec![0u32; nodes * ports];
        let mut labels = vec![0i64; nodes * dim];
        for u in 0..nodes {
            let label = g.label_of(u);
            labels[u * dim..(u + 1) * dim].copy_from_slice(&label);
            for axis in 0..dim {
                for (s, sign) in [(0usize, 1i64), (1, -1)] {
                    neighbor[u * ports + 2 * axis + s] = g.step(u, axis, sign) as u32;
                }
            }
        }
        let routes = CompactRoutes::build(table);
        let ser: Vec<u64> = (0..ports).map(|p| cfg.serialization_cycles(p / 2)).collect();
        Self { g, cfg, pattern, dim, ports, nodes, neighbor, labels, routes, ser }
    }

    /// Build with the best available router for the graph (hierarchical —
    /// exactly minimal for any lattice graph).
    pub fn new(g: LatticeGraph, pattern: TrafficPattern, cfg: SimConfig) -> Self {
        let table = RoutingTable::build_hierarchical(&g);
        Self::with_table(g, &table, pattern, cfg)
    }

    /// Build for closed-loop workload runs (no synthetic traffic pattern is
    /// consulted in that mode).
    pub fn for_workload(g: LatticeGraph, cfg: SimConfig) -> Self {
        Self::new(g, TrafficPattern::Uniform, cfg)
    }

    pub fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    /// Is the Duato escape protocol live? VC 0 is pinned to DOR (the
    /// escape channel) exactly when an adaptive policy runs with at least
    /// one free VC beside the escape lane; under `Dor` — or with a single
    /// VC — every VC is a plain lane and the engine is bit-exact with the
    /// pre-escape code. Consumers of the per-VC statistics
    /// ([`SimResult::vc_phits`](crate::sim::SimResult) and friends)
    /// should gate escape-share reporting on this predicate.
    #[inline]
    pub fn escape_active(&self) -> bool {
        self.cfg.num_vcs >= 2 && self.cfg.route_policy != super::policy::RoutePolicy::Dor
    }
}
