//! Table and CSV rendering for experiment output.

use std::fmt::Write as _;
use std::path::Path;

/// A simple right-aligned text table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let _ = write!(line, "{:>width$}", cells[i], width = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV form to `<dir>/<name>.csv` (creates `dir`).
    pub fn write_csv(&self, dir: &Path, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float to a compact fixed precision.
pub fn f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format an event count with `_` thousands separators (Rust-literal
/// style — unlike commas it needs no CSV escaping). The stall-cause
/// tables report raw cycle counts that routinely reach 7-8 digits.
pub fn count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    for (i, ch) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        // header and rows aligned on the same column widths
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn count_groups_thousands() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1_000");
        assert_eq!(count(1234567), "1_234_567");
        assert_eq!(count(u64::MAX), "18_446_744_073_709_551_615");
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"t".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"t\""));
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_row_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn write_csv_roundtrip() {
        let mut t = Table::new("", &["x"]);
        t.row(vec!["1".into()]);
        let dir = std::env::temp_dir().join("lattice_networks_test_csv");
        let path = t.write_csv(&dir, "t").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "x\n1\n");
    }
}
