"""L2 model tests: APSP models vs known graphs and vs each other.

Graph constructors here are tiny numpy mirrors of the Rust topology layer;
exact distance values for rings/tori are textbook, so both APSP models are
validated end-to-end against ground truth and against each other.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from compile import model
from compile.kernels.ref import INF

jax.config.update("jax_platform_name", "cpu")


def ring_adj(n, pad):
    adj = np.full((pad, pad), float(INF), np.float32)
    for i in range(n):
        adj[i, i] = 0.0
        adj[i, (i + 1) % n] = 1.0
        adj[i, (i - 1) % n] = 1.0
    return adj


def torus2d_adj(a, b, pad):
    n = a * b
    adj = np.full((pad, pad), float(INF), np.float32)
    for x in range(a):
        for y in range(b):
            i = x * b + y
            adj[i, i] = 0.0
            for dx, dy in [(1, 0), (-1, 0), (0, 1), (0, -1)]:
                j = ((x + dx) % a) * b + (y + dy) % b
                adj[i, j] = 1.0
    return adj


def run_minplus(adj, n_real, block=8):
    pad = adj.shape[0]
    fn = functools.partial(
        model.apsp_minplus, iters=model.minplus_iters_for(pad), block=block
    )
    return jax.jit(fn)(jnp.array(adj), jnp.float32(n_real))


def run_gemm(adj, n_real, block=8):
    pad = adj.shape[0]
    adj01 = (adj == 1.0).astype(np.float32)
    fn = functools.partial(
        model.apsp_gemm, steps=model.gemm_steps_for(pad), block=block
    )
    return jax.jit(fn)(jnp.array(adj01), jnp.float32(n_real))


def ring_distance_sum(n):
    return n * sum(min(k, n - k) for k in range(n))


@pytest.mark.parametrize("n,pad", [(8, 8), (12, 16), (10, 16), (16, 16)])
def test_ring_minplus(n, pad):
    _, s, mx = run_minplus(ring_adj(n, pad), n)
    assert float(s) == ring_distance_sum(n)
    assert float(mx) == n // 2


@pytest.mark.parametrize("n,pad", [(8, 8), (12, 16), (10, 16)])
def test_ring_gemm(n, pad):
    _, s, mx = run_gemm(ring_adj(n, pad), n)
    assert float(s) == ring_distance_sum(n)
    assert float(mx) == n // 2


@pytest.mark.parametrize("a,b,pad", [(4, 4, 16), (4, 3, 16), (5, 5, 32)])
def test_torus2d_both_models_agree(a, b, pad):
    adj = torus2d_adj(a, b, pad)
    n = a * b
    d1, s1, m1 = run_minplus(adj, n)
    d2, s2, m2 = run_gemm(adj, n)
    assert float(s1) == float(s2)
    assert float(m1) == float(m2)
    # torus diameter = floor(a/2) + floor(b/2)
    assert float(m1) == a // 2 + b // 2
    npt.assert_allclose(
        np.asarray(d1)[:n, :n], np.asarray(d2)[:n, :n]
    )


def test_torus_known_values():
    # T(4,4): per-node distance distribution 1x0 4x1 6x2 4x3 1x4 = sum 32? no:
    # distances in a 4-ring: 0,1,2,1 per axis; 2D sums convolve.
    adj = torus2d_adj(4, 4, 16)
    _, s, mx = run_minplus(adj, 16)
    per_node = sum(
        (min(dx, 4 - dx) + min(dy, 4 - dy)) for dx in range(4) for dy in range(4)
    )
    assert float(s) == 16 * per_node
    assert float(mx) == 4


def test_padding_is_inert():
    """Same graph under two pad sizes gives identical stats."""
    n = 10
    _, s1, m1 = run_minplus(ring_adj(n, 16), n)
    _, s2, m2 = run_minplus(ring_adj(n, 32), n)
    assert float(s1) == float(s2) and float(m1) == float(m2)
    _, s3, m3 = run_gemm(ring_adj(n, 16), n)
    _, s4, m4 = run_gemm(ring_adj(n, 32), n)
    assert float(s3) == float(s4) and float(m3) == float(m4)


def test_disconnected_pairs_filtered():
    """Two disjoint 4-rings: cross distances must not pollute the stats."""
    pad = 16
    adj = np.full((pad, pad), float(INF), np.float32)
    for base in (0, 4):
        for i in range(4):
            adj[base + i, base + i] = 0.0
            adj[base + i, base + (i + 1) % 4] = 1.0
            adj[base + i, base + (i - 1) % 4] = 1.0
    _, s, mx = run_minplus(adj, 8)
    assert float(s) == 2 * ring_distance_sum(4)
    assert float(mx) == 2
    _, s2, mx2 = run_gemm(adj, 8)
    assert float(s2) == float(s) and float(mx2) == float(mx)
