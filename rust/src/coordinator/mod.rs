//! The experiment coordinator: the L3 leader that turns the paper's
//! evaluation section into runnable drivers.
//!
//! - [`sweep`]: offered-load sweeps over the simulator, multi-seed
//!   averaged, parallelized across worker threads.
//! - [`report`]: fixed-width table + CSV rendering shared by the CLI,
//!   experiments and benches.
//! - [`experiments`]: one driver per paper table/figure (see DESIGN.md §3
//!   for the index) — `table1`, `table2`, `formulas`, `bounds`, `tree`,
//!   `thm20`, `cycles`, `crystals`, `appendix`, `fig5`–`fig8`, `apsp` —
//!   plus `collectives`, the closed-loop workload comparison of the
//!   crystals vs matched-order tori.
//! - [`config`]: the experiment configuration system (offline-friendly
//!   INI/TOML-subset file format + CLI overrides).
//! - [`cli`]: the hand-rolled argument parser used by `main.rs` (offline
//!   build — no clap; see DESIGN.md §Substitutions).

pub mod cli;
pub mod config;
pub mod experiments;
pub mod report;
pub mod sweep;

pub use config::ExperimentConfig;
pub use report::Table;
pub use sweep::{LoadSweep, SweepPoint};
