//! Deterministic multi-threaded cycle driver (DESIGN.md
//! §Parallel-engine).
//!
//! Every cycle runs in three phases:
//!
//! - **Phase A (serial)**: the regime-specific closure — probes, calendar
//!   events, injection/packetization, closed-loop completions — followed
//!   by the active-set merge and the cycle's *shard plan*. Runs on the
//!   calling thread with exclusive access to [`State`].
//! - **Phase B (parallel)**: the arbitration kernel over the planned
//!   shards. Under `scan_mode=full` the plan is the static contiguous
//!   node ranges (the lattice's natural cut planes); under
//!   `scan_mode=active` it is re-carved every cycle from the merged
//!   active list, balanced by queued work (see [`plan_active_shards`]),
//!   so per-cycle cost tracks traffic, not network size. Each worker
//!   mutates only state owned by its shard's nodes (their FIFOs,
//!   occupancy bits, link/eject timers, per-link phit counters, popped
//!   packets) and *defers* every cross-node or global effect —
//!   downstream FIFO pushes, calendar events, stall counters, per-VC
//!   phits, trace events, RNG fingerprints — into its private
//!   [`ShardBuf`].
//! - **Phase C (serial)**: the buffers are merged in shard order, which
//!   is ascending producer-node order — exactly the order the serial
//!   scan produces its side effects in — so every thread count yields a
//!   bit-identical run.
//!
//! Determinism rests on two properties. First, per-node draws come from
//! counter-based streams keyed `(seed, node, cycle)`
//! ([`crate::sim::rng::NodeRng`]), so a node's draw sequence is a pure
//! function of the key — independent of which thread visits it and of
//! what other nodes did. Second, the Phase-B kernel is *pure per node*
//! given the Phase-A state snapshot: the cross-shard values it reads
//! (downstream `reserved` counts for eligibility and adaptive headroom)
//! are constant during Phase B, because pushes are deferred to Phase C
//! and releases happen only in Phase A's calendar drain. Together these
//! make the per-cycle shard boundaries — and whether the cycle is
//! sharded at all — invisible to results: the merge replays outboxes in
//! ascending-node order regardless of which worker produced them, which
//! is also exactly what a whole-range serial scan emits. That freedom
//! buys the two throughput levers here: per-cycle *balanced* shard
//! plans, and a *serial fast path* that runs a light cycle's Phase B on
//! the calling thread (active work below `threads × serial_cutoff`),
//! skipping the barrier round-trip entirely.
//!
//! The workers synchronize through two [`SpinBarrier`]s per cycle
//! (sense-reversing spin-then-park — `std::sync::Barrier`'s
//! mutex+condvar crossing costs more than a light Phase B); each
//! worker's scratch lives in an [`UnsafeCell`] slot whose exclusive
//! owner alternates between that worker (Phase B) and the main thread
//! (elsewhere), with the barrier generations establishing the
//! happens-before — see [`CtxCell`]. The exchange is
//! ThreadSanitizer-clean: all shared mutation is ordered through the
//! barrier's acquire/release atomics and park/unpark.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::sim::config::ScanMode;
use crate::sim::telemetry::{StallCause, StallCounters};
use crate::util::{with_helpers, SpinBarrier};

use super::arbitration::ArbScratch;
use super::state::{Event, State};
use super::Simulator;

/// A cross-node FIFO push deferred out of Phase B: packet `pid` lands in
/// input FIFO `fi` (global index). The packet's `head_ready` /
/// `next_port` were already written into the arena by the producing
/// worker (the arena entry is owned by the one worker that popped the
/// packet), so the merge only replays the enqueue.
pub(super) struct Push {
    pub(super) fi: u32,
    pub(super) pid: u32,
}

/// A trace event deferred out of Phase B (only `hop` and `stall` occur
/// there; the writer itself is not thread-safe and stays on the main
/// thread). Replayed in shard order at the merge, which reproduces the
/// serial emission order.
pub(super) enum TraceEv {
    Hop { t: u64, land: u64, pid: u32, from: usize, to: usize, port: usize, vc: u8, esc: bool },
    Stall { t: u64, node: usize, port: i64, vc: i64, cause: StallCause },
}

/// Per-shard outbox: every effect of a Phase-B shard scan that crosses a
/// shard boundary or targets global state, in emission order.
pub(super) struct ShardBuf {
    pub(super) pushes: Vec<Push>,
    /// Deferred calendar events as `(delay, event)`; scheduled at the
    /// merge while `now` still names the cycle that produced them. All
    /// Phase-B delays are in `[1, packet_size]`, so no merged event can
    /// land in the calendar slot the current cycle already drained.
    pub(super) events: Vec<(u64, Event)>,
    pub(super) stalls: StallCounters,
    pub(super) vc_phits: Vec<u64>,
    pub(super) trace: Vec<TraceEv>,
    /// Commutative fingerprint of the shard's arbitration draws.
    pub(super) digest: u64,
    pub(super) draws: u64,
}

impl ShardBuf {
    fn new(vcs: usize) -> Self {
        Self {
            pushes: Vec::new(),
            events: Vec::new(),
            stalls: StallCounters::default(),
            vc_phits: vec![0; vcs],
            trace: Vec::new(),
            digest: 0,
            draws: 0,
        }
    }
}

/// One worker's private per-run storage: its outbox and its arbitration
/// scratch.
pub(super) struct WorkerCtx {
    buf: ShardBuf,
    scratch: ArbScratch,
}

/// A worker's [`WorkerCtx`] slot, handed back and forth without a lock.
///
/// # Safety
///
/// Slot `w` has exactly one owner at any point of the cycle protocol:
/// worker `w` between the start and end barriers of a sharded cycle
/// (worker 0 being the main thread), and the main thread everywhere
/// else — including merge (Phase C), serial-fast-path cycles (helpers
/// never leave the start barrier), and final collection. Each ownership
/// transfer crosses a [`SpinBarrier`] generation, whose acquire/release
/// protocol publishes the old owner's writes to the new one (see the
/// barrier's ordering docs). So accesses are exclusive and ordered —
/// the `Sync` impl asserts that discipline, nothing more.
struct CtxCell(UnsafeCell<WorkerCtx>);
unsafe impl Sync for CtxCell {}

impl CtxCell {
    fn new(vcs: usize, out_ports: usize) -> Self {
        Self(UnsafeCell::new(WorkerCtx {
            buf: ShardBuf::new(vcs),
            scratch: ArbScratch::new(out_ports),
        }))
    }

    /// Callers uphold the exclusive-ownership protocol above.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut WorkerCtx {
        unsafe { &mut *self.0.get() }
    }
}

/// Shared `State` handle for the cycle workers. Safety contract: during
/// Phase B every worker mutates only state owned by nodes in its
/// planned shard (plus arena entries of packets it popped) and reads
/// only phase-constant fields elsewhere; the barriers order those
/// accesses against the serial phases.
struct SharedState(*mut State);
unsafe impl Sync for SharedState {}

impl SharedState {
    /// Callers uphold the shard-disjointness contract above.
    #[allow(clippy::mut_from_ref)]
    unsafe fn get(&self) -> &mut State {
        unsafe { &mut *self.0 }
    }
}

/// Cross-cycle high-water marks of the shard outboxes. After each merge
/// drains a buffer, its capacity is topped back up to the largest any
/// shard has needed so far, so Phase B does no steady-state allocation —
/// even when the balancer hands a worker a much larger shard than it had
/// last cycle.
#[derive(Default)]
struct BufHighs {
    pushes: usize,
    events: usize,
    trace: usize,
}

/// Static contiguous node ranges, one per worker — the lattice cut
/// planes, used under `scan_mode=full`. Sizes differ by at most one, so
/// a thread count that doesn't divide the node count (the CI matrix
/// includes 7) still covers every node.
fn static_shards(plan: &mut [(u32, u32)], nodes: usize) {
    let threads = plan.len();
    let base = nodes / threads;
    let extra = nodes % threads;
    let mut lo = 0usize;
    for (w, slot) in plan.iter_mut().enumerate() {
        let len = base + usize::from(w < extra);
        *slot = (lo as u32, (lo + len) as u32);
        lo += len;
    }
}

/// Balanced per-cycle shard plan under `scan_mode=active`: carve the
/// merged (sorted, duplicate-free) active list into contiguous *index*
/// ranges of near-equal queued work. A node's weight is its queued-FIFO
/// count plus its injection backlog flag (min 1), so a hot node with
/// every input occupied counts ~`ports×vcs`-fold against a node holding
/// a single packet; shard `k` closes at the first list index whose
/// weight prefix reaches `(k+1)/threads` of the total. Integer-only and
/// a function of Phase-A state alone, hence identical at every thread
/// count that computes it — and irrelevant to results either way (see
/// the module docs). A node heavy enough to span several quantiles
/// leaves the ranges after it empty.
fn plan_active_shards(st: &mut State, threads: usize) {
    let list = &st.active_nodes.list;
    let occ = &st.occ;
    let inj = &st.inj;
    let plan = &mut st.shard_plan;
    let weight = |u: u32| -> u64 {
        let u = u as usize;
        1 + u64::from(occ[u].count_ones()) + u64::from(inj[u].len > 0)
    };
    let total: u64 = list.iter().map(|&u| weight(u)).sum();
    let t = threads as u64;
    let mut prefix = 0u64;
    let mut lo = 0usize;
    let mut shard = 0usize;
    for (i, &u) in list.iter().enumerate() {
        prefix += weight(u);
        while shard + 1 < threads && prefix * t >= (shard as u64 + 1) * total {
            plan[shard] = (lo as u32, (i + 1) as u32);
            lo = i + 1;
            shard += 1;
        }
    }
    let n = list.len() as u32;
    plan[shard] = (lo as u32, n);
    for slot in plan.iter_mut().skip(shard + 1) {
        *slot = (n, n);
    }
}

impl Simulator {
    /// Run the phased cycle loop until `phase_a` returns `false`.
    ///
    /// `phase_a` owns the serial head of each cycle: it advances
    /// `st.now`, drains the calendar, injects/packetizes, and decides
    /// termination. The driver then plans the cycle's shards, runs the
    /// arbitration kernel (Phase B) — sharded across the workers, or on
    /// the calling thread when the active-work estimate is below
    /// `threads × serial_cutoff` — and merges the outboxes (Phase C)
    /// with `st.now` still at the cycle `phase_a` set.
    ///
    /// `threads = 1` runs the identical phase discipline on the calling
    /// thread alone (no helpers are spawned, every cycle takes the
    /// serial path), so the serial reference and the parallel engine
    /// are the same code path by construction.
    pub(super) fn run_phased(&self, st: &mut State, mut phase_a: impl FnMut(&mut State) -> bool) {
        let threads = self.cfg.threads.clamp(1, self.nodes);
        let active = self.cfg.scan_mode == ScanMode::ActiveSet;
        // Fast-path cutoff on the cycle's active-work estimate; 0 keeps
        // every cycle sharded (`threads = 1` is always serial).
        let cutoff = threads.saturating_mul(self.cfg.serial_cutoff);
        st.shard_plan.clear();
        st.shard_plan.resize(threads, (0, 0));
        let ctxs: Vec<CtxCell> =
            (0..threads).map(|_| CtxCell::new(self.cfg.num_vcs, self.ports + 1)).collect();
        let start = SpinBarrier::new(threads);
        let end = SpinBarrier::new(threads);
        let done = AtomicBool::new(false);
        let shared = SharedState(st as *mut State);
        let run_shard = |w: usize| {
            // Safety: worker w owns ctx slot w and its planned shard's
            // nodes; see `CtxCell` / `SharedState`.
            let st = unsafe { shared.get() };
            let ctx = unsafe { ctxs[w].get() };
            self.advance_shard(st, &mut ctx.buf, &mut ctx.scratch, w);
        };
        let helper = |w: usize| loop {
            start.wait();
            if done.load(Ordering::Acquire) {
                break;
            }
            run_shard(w);
            end.wait();
        };
        let mut highs = BufHighs::default();
        with_helpers(threads, &helper, || {
            loop {
                // Safety: helpers are parked at `start` (or `end` has
                // passed), so the main thread is the only `State` user
                // during Phases A and C.
                let st = unsafe { shared.get() };
                if !phase_a(st) {
                    break;
                }
                if active {
                    st.active_nodes.merge();
                }
                let work = if active { st.active_nodes.list.len() } else { self.nodes };
                if threads == 1 || work < cutoff {
                    // Serial fast path: one whole-range shard on the
                    // calling thread, no barrier round-trip. The serial
                    // scan emits effects in ascending node order — the
                    // shard-merge order — so results are unchanged.
                    st.profile.serial_cycles += 1;
                    st.shard_plan[0] = (0, work as u32);
                    run_shard(0);
                    let st = unsafe { shared.get() };
                    self.merge_shards(st, &ctxs[..1], &mut highs);
                    continue;
                }
                st.profile.parallel_cycles += 1;
                if active {
                    plan_active_shards(st, threads);
                } else {
                    // Static cut planes (rebuilt each sharded cycle
                    // because a fast-path cycle overwrites slot 0 with
                    // the whole range; O(threads), negligible).
                    static_shards(&mut st.shard_plan, self.nodes);
                }
                start.wait();
                run_shard(0);
                end.wait();
                let st = unsafe { shared.get() };
                self.merge_shards(st, &ctxs, &mut highs);
            }
            done.store(true, Ordering::Release);
            start.wait();
        });
    }

    /// Phase C: drain every shard's outbox into `State`, in shard order
    /// (= ascending producer-node order, the serial scan's emission
    /// order — which is why the merge needs no sort).
    ///
    /// Safety: called on the main thread while no worker is between the
    /// barriers, so it is the exclusive owner of every ctx slot.
    fn merge_shards(&self, st: &mut State, ctxs: &[CtxCell], highs: &mut BufHighs) {
        let vcs = self.cfg.num_vcs;
        let node_base = self.ports * vcs;
        let qcap = self.cfg.queue_packets as usize;
        // Compact the active list *before* the buffered activations land
        // in `pending`: a node dropped by its shard this cycle and
        // re-activated by an incoming push must re-enter through
        // `pending`, keeping `list ∪ pending` disjoint.
        if self.cfg.scan_mode == ScanMode::ActiveSet {
            st.active_nodes.retain_members();
        }
        for cell in ctxs {
            let buf = &mut unsafe { cell.get() }.buf;
            highs.pushes = highs.pushes.max(buf.pushes.len());
            highs.events = highs.events.max(buf.events.len());
            highs.trace = highs.trace.max(buf.trace.len());
            st.stalls.accumulate(&buf.stalls);
            buf.stalls = StallCounters::default();
            for (vc, phits) in buf.vc_phits.iter_mut().enumerate() {
                st.phits_by_vc[vc] += *phits;
                *phits = 0;
            }
            st.node_digest = st.node_digest.wrapping_add(buf.digest);
            st.node_draws += buf.draws;
            buf.digest = 0;
            buf.draws = 0;
            for (delay, ev) in buf.events.drain(..) {
                self.schedule(st, delay, ev);
            }
            for push in buf.pushes.drain(..) {
                let fi = push.fi as usize;
                let v = fi / node_base;
                let pkt = st.packets[push.pid as usize];
                let base = fi * qcap;
                st.inputs[fi].push(
                    &mut st.input_slots[base..base + qcap],
                    push.pid,
                    pkt.head_ready,
                    pkt.next_port,
                );
                st.occ[v] |= 1u64 << (fi - v * node_base);
                // The downstream node now holds queued traffic (its head
                // lands at now + latency, so whether it was scanned this
                // cycle moved nothing and drew no RNG either way).
                st.active_nodes.insert(v);
            }
            if let Some(tr) = st.trace.as_mut() {
                for ev in buf.trace.drain(..) {
                    match ev {
                        TraceEv::Hop { t, land, pid, from, to, port, vc, esc } => {
                            tr.hop(t, land, pid, from, to, port, vc, esc)
                        }
                        TraceEv::Stall { t, node, port, vc, cause } => {
                            tr.stall(t, node, port, vc, cause)
                        }
                    }
                }
            } else {
                buf.trace.clear();
            }
            // Pre-size for the next cycle: drained (len 0) buffers get
            // their capacity restored to the cross-worker high-water
            // mark, so a rebalanced (larger) shard next cycle still
            // allocates nothing.
            buf.pushes.reserve(highs.pushes);
            buf.events.reserve(highs.events);
            buf.trace.reserve(highs.trace);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_of(nodes: usize, threads: usize) -> Vec<(u32, u32)> {
        let mut plan = vec![(0, 0); threads];
        static_shards(&mut plan, nodes);
        plan
    }

    #[test]
    fn static_shards_partition_the_node_space() {
        for nodes in [1usize, 2, 5, 64, 511, 512] {
            for threads in [1usize, 2, 3, 4, 7] {
                let threads = threads.min(nodes);
                let b = plan_of(nodes, threads);
                assert_eq!(b.len(), threads);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[threads - 1].1 as usize, nodes);
                for w in 1..threads {
                    assert_eq!(b[w].0, b[w - 1].1, "contiguous");
                }
                for &(lo, hi) in &b {
                    let len = (hi - lo) as usize;
                    assert!(len >= nodes / threads && len <= nodes / threads + 1);
                }
            }
        }
    }
}
