//! Exact distance computation on lattice graphs.

use std::collections::VecDeque;

use crate::lattice::LatticeGraph;

/// Summary of a graph's distance structure.
#[derive(Clone, Debug, PartialEq)]
pub struct DistanceStats {
    /// Number of nodes.
    pub order: usize,
    /// Eccentricity histogram: `histogram[d]` = #nodes at distance `d`
    /// from the source (distribution is source-independent for
    /// vertex-transitive graphs).
    pub histogram: Vec<usize>,
    /// Graph diameter.
    pub diameter: usize,
    /// Average distance `k̄` over ordered pairs with distinct endpoints,
    /// matching the paper's convention (sum of distances / (N - 1)).
    pub avg_distance: f64,
}

/// Single-source BFS distances (u32::MAX for unreachable).
pub fn bfs_distances(g: &LatticeGraph, src: usize) -> Vec<u32> {
    let n = g.order();
    let mut dist = vec![u32::MAX; n];
    let mut queue = VecDeque::with_capacity(n);
    dist[src] = 0;
    queue.push_back(src);
    // Reuse a scratch label to avoid per-neighbor allocation.
    let dim = g.dim();
    let mut tmp = vec![0i64; dim];
    while let Some(u) = queue.pop_front() {
        let du = dist[u];
        let label = g.label_of(u);
        for axis in 0..dim {
            for sign in [1i64, -1] {
                tmp.copy_from_slice(&label);
                tmp[axis] += sign;
                g.reduce_in_place(&mut tmp);
                let v = g.index_of(&tmp);
                if dist[v] == u32::MAX {
                    dist[v] = du + 1;
                    queue.push_back(v);
                }
            }
        }
    }
    dist
}

/// Distance distribution from node 0 (exact for vertex-transitive graphs,
/// which covers every topology in the paper).
pub fn distance_distribution(g: &LatticeGraph) -> DistanceStats {
    let dist = bfs_distances(g, 0);
    let diameter = *dist.iter().max().unwrap() as usize;
    assert!(
        diameter != u32::MAX as usize,
        "graph is disconnected; distance stats undefined"
    );
    let mut histogram = vec![0usize; diameter + 1];
    let mut sum = 0u64;
    for &d in &dist {
        histogram[d as usize] += 1;
        sum += d as u64;
    }
    let order = g.order();
    DistanceStats {
        order,
        histogram,
        diameter,
        avg_distance: sum as f64 / (order as f64 - 1.0),
    }
}

/// The most distant node from `src` (used by the `antipodal` traffic
/// pattern). Deterministic: smallest index among the maxima.
pub fn antipodal_of(g: &LatticeGraph, src: usize) -> usize {
    let dist = bfs_distances(g, src);
    let max = dist.iter().max().copied().unwrap();
    dist.iter().position(|&d| d == max).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, pc, rtt, torus};

    #[test]
    fn ring_distances() {
        let g = torus(&[8]);
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, 4, 3, 2, 1]);
    }

    #[test]
    fn torus_diameter() {
        // Diameter of T(a1, ..., an) = sum floor(ai/2).
        for sides in [vec![4i64, 4], vec![5, 3], vec![4, 4, 4], vec![6, 3, 2]] {
            let g = torus(&sides);
            let s = distance_distribution(&g);
            let expect: usize = sides.iter().map(|&a| (a / 2) as usize).sum();
            assert_eq!(s.diameter, expect, "{sides:?}");
        }
    }

    #[test]
    fn table1_diameters() {
        // Table 1: PC 3*floor(a/2); FCC floor(3a/2); BCC floor(3a/2).
        for a in 2..7i64 {
            assert_eq!(
                distance_distribution(&pc(a)).diameter,
                3 * (a / 2) as usize,
                "PC({a})"
            );
            assert_eq!(
                distance_distribution(&fcc(a)).diameter,
                (3 * a / 2) as usize,
                "FCC({a})"
            );
            assert_eq!(
                distance_distribution(&bcc(a)).diameter,
                (3 * a / 2) as usize,
                "BCC({a})"
            );
        }
    }

    #[test]
    fn table1_mixed_tori_diameters() {
        // T(2a,a,a): a + 2*floor(a/2); T(2a,2a,a): floor(5a/2).
        for a in 2..6i64 {
            assert_eq!(
                distance_distribution(&torus(&[2 * a, a, a])).diameter,
                (a + 2 * (a / 2)) as usize
            );
            assert_eq!(
                distance_distribution(&torus(&[2 * a, 2 * a, a])).diameter,
                (5 * a / 2) as usize
            );
        }
    }

    #[test]
    fn histogram_sums_to_order() {
        for g in [pc(3), fcc(3), bcc(2), rtt(4)] {
            let s = distance_distribution(&g);
            assert_eq!(s.histogram.iter().sum::<usize>(), g.order());
            assert_eq!(s.histogram[0], 1);
        }
    }

    #[test]
    fn antipodal_is_at_diameter() {
        let g = fcc(2);
        let s = distance_distribution(&g);
        let anti = antipodal_of(&g, 0);
        assert_eq!(bfs_distances(&g, 0)[anti] as usize, s.diameter);
    }

    #[test]
    fn vertex_transitivity_spotcheck() {
        // Same distribution from several sources (Cayley ⇒ transitive).
        let g = bcc(2);
        let h0 = {
            let d = bfs_distances(&g, 0);
            let mut h = vec![0usize; 32];
            for &x in &d {
                h[x as usize] += 1;
            }
            h
        };
        for src in [1usize, 7, 19] {
            let d = bfs_distances(&g, src);
            let mut h = vec![0usize; 32];
            for &x in &d {
                h[x as usize] += 1;
            }
            assert_eq!(h, h0, "src={src}");
        }
    }
}
