//! Network partitioning (paper §6.1).
//!
//! Big machines are handed to users in partitions; the paper's point is
//! that lattice-graph machines partition naturally into the `a` disjoint
//! copies of their projection `G(B)` (and recursively into lower
//! projections), so a 4D crystal machine can give every user a *symmetric
//! crystal* partition instead of a mixed-radix torus — the BlueGene
//! midplane discussion of §6.1.

use super::{LatticeGraph, Projection};

/// One partition: the node set of a projection copy.
#[derive(Clone, Debug)]
pub struct Partition {
    /// The fixed last coordinate identifying the copy.
    pub copy: i64,
    /// Node indices (in the parent graph) of this copy.
    pub nodes: Vec<usize>,
}

impl LatticeGraph {
    /// Split into the `side` disjoint copies of the projection `G(B)`
    /// (grouping nodes by their last label coordinate).
    pub fn partitions(&self) -> Vec<Partition> {
        let n = self.dim();
        assert!(n >= 2, "cannot partition a 1-dimensional graph");
        let side = self.side();
        let mut parts: Vec<Partition> = (0..side)
            .map(|copy| Partition { copy, nodes: Vec::new() })
            .collect();
        for idx in 0..self.order() {
            let label = self.label_of(idx);
            parts[label[n - 1] as usize].nodes.push(idx);
        }
        parts
    }

    /// Does each partition induce exactly the projection graph? Checks
    /// that the intra-copy adjacency (generators `e_1..e_{n-1}`) matches
    /// `G(B)` node-for-node under the truncated-label mapping.
    pub fn partitions_are_projection_copies(&self) -> bool {
        let n = self.dim();
        let proj = self.projection_graph();
        for part in self.partitions() {
            if part.nodes.len() != proj.order() {
                return false;
            }
            for &u in &part.nodes {
                let label = self.label_of(u);
                let pu = proj.index_of(&label[..n - 1].to_vec());
                // Expected neighbors inside the copy.
                let mut expect: Vec<usize> = proj
                    .neighbors(pu)
                    .into_iter()
                    .collect();
                expect.sort_unstable();
                expect.dedup();
                // Actual intra-copy neighbors via parent generators.
                let mut actual: Vec<usize> = (0..n - 1)
                    .flat_map(|axis| {
                        [1i64, -1].into_iter().map(move |s| (axis, s))
                    })
                    .map(|(axis, s)| {
                        let v = self.step(u, axis, s);
                        let vl = self.label_of(v);
                        debug_assert_eq!(
                            vl[n - 1],
                            part.copy,
                            "generator e_{axis} escaped the copy"
                        );
                        proj.index_of(&vl[..n - 1].to_vec())
                    })
                    .collect();
                actual.sort_unstable();
                actual.dedup();
                if actual != expect {
                    return false;
                }
            }
        }
        true
    }

    /// Partition metadata convenience: `(projection, partitions)`.
    pub fn partition_report(&self) -> (Projection, Vec<Partition>) {
        (self.project(), self.partitions())
    }
}

#[cfg(test)]
mod tests {
    use crate::topology::{bcc, bcc4d, fcc, fcc4d, pc, torus};

    #[test]
    fn pc_partitions_into_2d_tori() {
        let g = pc(4);
        let parts = g.partitions();
        assert_eq!(parts.len(), 4);
        assert!(parts.iter().all(|p| p.nodes.len() == 16));
        assert!(g.partitions_are_projection_copies());
    }

    #[test]
    fn fcc_partitions_into_rtt() {
        // Lemma 14: each copy is RTT(a).
        let g = fcc(3);
        assert!(g.partitions_are_projection_copies());
        let proj = g.projection_graph();
        assert!(proj.right_equivalent(&crate::topology::rtt(3)));
    }

    #[test]
    fn bcc_partitions_into_t2a2a() {
        let g = bcc(3);
        assert!(g.partitions_are_projection_copies());
        assert!(g
            .projection_graph()
            .right_equivalent(&torus(&[6, 6])));
    }

    #[test]
    fn fcc4d_partitions_into_symmetric_crystals() {
        // §6.1: the 4D machine hands out FCC(a) crystals — themselves
        // symmetric — as partitions.
        let g = fcc4d(2);
        assert!(g.partitions_are_projection_copies());
        let proj = g.projection_graph();
        assert!(proj.isomorphic_linear(&fcc(2)));
        assert!(proj.is_symmetric());
    }

    #[test]
    fn bcc4d_partitions_into_pc() {
        let g = bcc4d(2);
        assert!(g.partitions_are_projection_copies());
        assert!(g.projection_graph().right_equivalent(&pc(4)));
        assert!(g.projection_graph().is_symmetric());
    }

    #[test]
    fn partitions_cover_disjointly() {
        let g = fcc(2);
        let parts = g.partitions();
        let mut seen = vec![false; g.order()];
        for p in &parts {
            for &u in &p.nodes {
                assert!(!seen[u], "node {u} in two partitions");
                seen[u] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mixed_radix_torus_partitions_are_smaller_tori() {
        let g = torus(&[4, 4, 2]);
        assert!(g.partitions_are_projection_copies());
        assert!(g.projection_graph().right_equivalent(&torus(&[4, 4])));
    }
}
