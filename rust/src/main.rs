//! `lattice-networks` — the leader binary.
//!
//! Subcommands (see `lattice-networks help`):
//!
//! ```text
//! topo <spec>                      topology properties (Table 1-style row)
//! route <spec> <src> <dst>         minimal routing record (Section 5)
//! sim <spec> --traffic T --load L  one simulation point
//! sweep <spec> --traffic T         load sweep (Figures 5-8 machinery)
//! workload --topology S --workload W   closed-loop completion time
//! experiment <name>                paper tables/figures; `all` for the lot
//! apsp <spec> [--kind minplus]     distance summary via PJRT artifacts
//! tree [--max-dim N]               Figure 4 lift tree
//! help
//! ```

use anyhow::{anyhow, bail, Context, Result};

use lattice_networks::coordinator::cli::Args;
use lattice_networks::coordinator::experiments as exp;
use lattice_networks::coordinator::report::{count, f, Table};
use lattice_networks::coordinator::sweep::LoadSweep;
use lattice_networks::coordinator::ExperimentConfig;
use lattice_networks::lattice::LatticeGraph;
use lattice_networks::metrics::{distance_distribution, max_throughput_bound};
use lattice_networks::routing::{norm, HierarchicalRouter, Router};
use lattice_networks::runtime::{ApspEngine, ApspKind};
use lattice_networks::sim::config::{check_fault_rate, parse_fault_links, parse_fault_nodes};
use lattice_networks::sim::{RoutePolicy, ScanMode, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology::catalog;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams, WorkloadRunner};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    if raw.is_empty() || raw[0] == "help" || raw[0] == "--help" {
        print!("{}", HELP);
        return Ok(());
    }
    let args = Args::parse(raw)?;
    let config = match args.opt("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    match args.subcommand.as_str() {
        "topo" => cmd_topo(&args),
        "route" => cmd_route(&args),
        "sim" => cmd_sim(&args, &config),
        "sweep" => cmd_sweep(&args, &config),
        "workload" => cmd_workload(&args, &config),
        "experiment" => cmd_experiment(&args, &config),
        "apsp" => cmd_apsp(&args),
        "tree" => cmd_tree(&args),
        other => bail!("unknown subcommand {other:?}; try `help`"),
    }
}

fn spec_arg(args: &Args) -> Result<catalog::TopologySpec> {
    let spec = args
        .positionals
        .first()
        .ok_or_else(|| anyhow!("missing topology spec (e.g. fcc:8)"))?;
    catalog::parse(spec)
}

fn cmd_topo(args: &Args) -> Result<()> {
    let spec = spec_arg(args)?;
    let g = &spec.graph;
    let s = distance_distribution(g);
    let b = max_throughput_bound(g);
    println!("{}", spec.name);
    println!("  matrix (Hermite):\n{}", indent(&g.hermite().to_string(), 4));
    println!("  nodes            {}", g.order());
    println!("  dimension        {} (degree {})", g.dim(), g.degree());
    println!("  diameter         {}", s.diameter);
    println!("  avg distance     {:.4}", s.avg_distance);
    println!("  symmetric        {}", g.is_symmetric());
    println!(
        "  throughput bound {:.4} phits/cycle/node ({})",
        b.phits_per_cycle_node,
        if b.edge_symmetric { "Δ/k̄" } else { "Δ/(n·k̄max)" }
    );
    if g.dim() >= 2 {
        let p = g.project();
        println!(
            "  projection       side {}, cycle len {}, {} copies",
            p.side, p.cycle_len, p.side
        );
    }
    if args.flag("histogram") {
        println!("  distance histogram:");
        for (d, c) in s.histogram.iter().enumerate() {
            println!("    {d:3}  {c}");
        }
    }
    Ok(())
}

fn parse_label(s: &str, dim: usize) -> Result<Vec<i64>> {
    let v: Result<Vec<i64>, _> = s.split(',').map(str::trim).map(str::parse).collect();
    let v = v.map_err(|_| anyhow!("bad label {s:?} (want comma-separated ints)"))?;
    if v.len() != dim {
        bail!("label {s:?} has {} coords; topology needs {dim}", v.len());
    }
    Ok(v)
}

fn cmd_route(args: &Args) -> Result<()> {
    let spec = spec_arg(args)?;
    let g = &spec.graph;
    let (src_s, dst_s) = match &args.positionals[..] {
        [_, s, d] => (s, d),
        _ => bail!("usage: route <spec> <src> <dst> (labels like 1,3,3)"),
    };
    let src = g.reduce(&parse_label(src_s, g.dim())?);
    let dst = g.reduce(&parse_label(dst_s, g.dim())?);
    let router = HierarchicalRouter::new(g.clone());
    let ties = router.route_ties(&src, &dst);
    println!("{}: route {:?} -> {:?}", spec.name, src, dst);
    println!("  minimal distance {}", norm(&ties[0]));
    for (i, r) in ties.iter().enumerate() {
        println!("  record[{i}] {r:?}");
    }
    Ok(())
}

fn sim_config(args: &Args, config: &ExperimentConfig) -> Result<SimConfig> {
    let mut cfg = config.sim_config();
    if let Some(c) = args.opt_usize("cycles")? {
        cfg.measure_cycles = c as u64;
    }
    if let Some(w) = args.opt_usize("warmup")? {
        cfg.warmup_cycles = w as u64;
    }
    // LogGP-style software overheads (closed-loop workload mode).
    if let Some(o) = args.opt_usize("send-overhead")? {
        cfg.send_overhead = o as u64;
    }
    if let Some(o) = args.opt_usize("recv-overhead")? {
        cfg.recv_overhead = o as u64;
    }
    if let Some(g) = args.opt_usize("packet-gap")? {
        cfg.packet_gap = g as u64;
    }
    // Route-selection policy. A comma list is an experiment sweep
    // (`policies_arg`); everywhere else the first entry is the run's
    // policy.
    if let Some(p) = policies_arg(args)?.and_then(|ps| ps.into_iter().next()) {
        cfg.route_policy = p;
    }
    // Virtual channels (VC 0 is the escape lane under the adaptive
    // policies). A comma list is an experiment sweep (`vcs_arg`);
    // everywhere else the first entry is the run's VC count.
    if let Some(v) = vcs_arg(args)?.and_then(|vs| vs.into_iter().next()) {
        cfg.num_vcs = v;
    }
    // LogGP L (per-hop wire latency) and per-axis channel widths.
    if let Some(l) = args.opt_usize("link-latency")? {
        if l == 0 {
            bail!("--link-latency must be at least 1 cycle");
        }
        cfg.link_latency = l as u64;
    }
    if let Some(w) = args.opt_u32s("axis-widths")? {
        cfg.axis_widths = w;
    }
    // Engine scan strategy (perf-only; both modes are bit-exact).
    if let Some(s) = args.opt("scan-mode") {
        cfg.scan_mode = ScanMode::parse(s)
            .ok_or_else(|| anyhow!("unknown scan mode {s:?} (active or full)"))?;
    }
    // Engine thread count (perf-only; every count is bit-exact with 1).
    if let Some(t) = args.opt_usize("threads")? {
        if t == 0 {
            bail!("--threads must be at least 1");
        }
        cfg.threads = t;
    }
    // Serial fast-path cutoff (perf-only; bit-exact at any value).
    if let Some(k) = args.opt_usize("serial-cutoff")? {
        cfg.serial_cutoff = k;
    }
    // Telemetry: packet-lifecycle JSONL trace plus optional periodic
    // probes (sim::telemetry). Off by default; results are bit-identical
    // either way.
    if let Some(path) = args.opt("trace") {
        cfg.trace = Some(path.to_string());
    }
    if let Some(n) = args.opt_usize("sample-every")? {
        cfg.sample_every = n as u64;
    }
    if cfg.sample_every > 0 && cfg.trace.is_none() {
        bail!("--sample-every needs --trace (probes are trace events)");
    }
    // Fault model: explicit dead links/nodes plus seeded random fault
    // rates (sim::fault). Range and adjacency are validated per command
    // by `check_faults`, where the graph is known.
    if let Some(spec) = args.opt("fault-links") {
        cfg.fault_links = parse_fault_links(spec).map_err(|e| anyhow!(e))?;
    }
    if let Some(spec) = args.opt("fault-nodes") {
        cfg.fault_nodes = parse_fault_nodes(spec).map_err(|e| anyhow!(e))?;
    }
    if let Some(r) = args.opt_f64("link-fault-rate")? {
        check_fault_rate("--link-fault-rate", r).map_err(|e| anyhow!(e))?;
        cfg.link_fault_rate = r;
    }
    if let Some(r) = args.opt_f64("node-fault-rate")? {
        check_fault_rate("--node-fault-rate", r).map_err(|e| anyhow!(e))?;
        cfg.node_fault_rate = r;
    }
    Ok(cfg)
}

/// Turn out-of-range or non-adjacent explicit fault specs into CLI errors
/// before the engine's construction asserts see them (the asserts remain
/// the last line of defense for config files and direct API use).
fn check_faults(cfg: &SimConfig, g: &LatticeGraph) -> Result<()> {
    let n = g.order();
    for &node in &cfg.fault_nodes {
        if node as usize >= n {
            bail!("--fault-nodes: node {node} out of range (network has {n} nodes)");
        }
    }
    for &(a, b) in &cfg.fault_links {
        if a as usize >= n || b as usize >= n {
            bail!("--fault-links: link {a}-{b} out of range (network has {n} nodes)");
        }
        if !g.neighbors(a as usize).contains(&(b as usize)) {
            bail!("--fault-links: nodes {a} and {b} are not adjacent in this topology");
        }
    }
    Ok(())
}

/// Reject a trace on commands that run more than one simulation: each run
/// truncates the trace file, so only the last would survive — silently.
fn check_single_run_trace(cfg: &SimConfig, what: &str) -> Result<()> {
    if cfg.trace.is_some() {
        bail!("--trace records one simulation; {what}. Trace a single `sim`/`workload` run instead");
    }
    Ok(())
}

/// Render the always-on stall-cause attribution as indented rows with
/// per-cause shares, plus the escape-drain count (escape drains are
/// forward progress, not stalls, so they sit outside the percentage).
fn print_stalls(stalls: &lattice_networks::sim::StallCounters, indent: &str) {
    let total = stalls.total();
    println!("{indent}stall cycles  {} (cause breakdown below)", count(total));
    for (label, n) in stalls.rows() {
        let share = if total == 0 { 0.0 } else { n as f64 / total as f64 * 100.0 };
        println!("{indent}  {label:<17} {:>14}  {share:5.1}%", count(n));
    }
    println!("{indent}  escape drains     {:>14}", count(stalls.escape_drains));
}

/// `--num-vcs N[,N...]` as a VC-count list (None when absent; zero
/// rejected by the underlying integer-list parser).
fn vcs_arg(args: &Args) -> Result<Option<Vec<usize>>> {
    Ok(args.opt_u32s("num-vcs")?.map(|vs| vs.into_iter().map(|v| v as usize).collect()))
}

/// The engine needs at least one VC and caps VC queues per node
/// ([`SimConfig::max_vcs`]); turn an out-of-range count — from the flag
/// or a config file — into a CLI error instead of an engine panic.
/// Called per swept VC count on every command that accepts the flag.
/// (Experiment drivers that only read config files keep the repo's
/// loud-config behaviour: a bad value panics at the engine assert.)
fn check_num_vcs(dim: usize, num_vcs: usize) -> Result<()> {
    if num_vcs == 0 {
        bail!("num_vcs must be at least 1");
    }
    let max = SimConfig::max_vcs(dim);
    if num_vcs > max {
        bail!("--num-vcs {num_vcs} is too large for a {dim}-D topology (at most {max} VCs)");
    }
    Ok(())
}

/// `--route-policy P[,P...]` as a policy list (None when absent).
fn policies_arg(args: &Args) -> Result<Option<Vec<RoutePolicy>>> {
    let Some(v) = args.opt("route-policy") else { return Ok(None) };
    let policies: Result<Vec<RoutePolicy>> = v
        .split(',')
        .map(str::trim)
        .map(|p| {
            RoutePolicy::parse(p)
                .ok_or_else(|| anyhow!("unknown route policy {p:?} (dor random adaptive)"))
        })
        .collect();
    policies.map(Some)
}

fn traffic_arg(args: &Args) -> Result<TrafficPattern> {
    let t = args.opt_or("traffic", "uniform");
    TrafficPattern::parse(&t).ok_or_else(|| anyhow!("unknown traffic {t:?}"))
}

fn cmd_sim(args: &Args, config: &ExperimentConfig) -> Result<()> {
    let spec = spec_arg(args)?;
    let pattern = traffic_arg(args)?;
    let load = args.opt_f64("load")?.unwrap_or(0.3);
    let cfg = sim_config(args, config)?;
    check_num_vcs(spec.graph.dim(), cfg.num_vcs)?;
    check_faults(&cfg, &spec.graph)?;
    let sim = Simulator::new(spec.graph.clone(), pattern, cfg);
    let r = sim.run(load);
    println!(
        "{} traffic={} offered={:.3}",
        spec.name,
        pattern.name(),
        load
    );
    println!("  accepted     {:.4} phits/cycle/node", r.accepted_load);
    println!(
        "  avg latency  {:.1} cycles (p50 {:.1}, p90 {:.1}, p99 {:.1}, p99.9 {:.1}, max {})",
        r.avg_latency, r.p50_latency, r.p90_latency, r.p99_latency, r.p999_latency, r.max_latency
    );
    println!(
        "  delivered    {} packets ({} dropped at source)",
        r.delivered_packets, r.source_dropped
    );
    print_stalls(&r.stalls, "  ");
    if sim.config().threads > 1 {
        println!(
            "  engine       {} cycles on the serial fast path, {} sharded across {} threads",
            r.engine.serial_cycles,
            r.engine.parallel_cycles,
            sim.config().threads
        );
    }
    Ok(())
}

fn cmd_sweep(args: &Args, config: &ExperimentConfig) -> Result<()> {
    let spec = spec_arg(args)?;
    let pattern = traffic_arg(args)?;
    let cfg = sim_config(args, config)?;
    check_num_vcs(spec.graph.dim(), cfg.num_vcs)?;
    check_faults(&cfg, &spec.graph)?;
    check_single_run_trace(&cfg, "a sweep runs load x seed points")?;
    let loads = args.opt_loads()?.unwrap_or_else(exp::default_loads);
    let seeds = args.opt_usize("seeds")?.unwrap_or(3);
    let sweep = LoadSweep {
        loads,
        seeds,
        sim: cfg,
        workers: args.opt_usize("workers")?.unwrap_or(0),
    };
    let points = sweep.run(&spec.graph, pattern);
    let mut t = Table::new(
        &format!("{} under {}", spec.name, pattern.name()),
        &["offered", "accepted", "avg latency", "p99"],
    );
    for p in &points {
        t.row(vec![
            f(p.offered_load, 2),
            f(p.accepted_load, 4),
            f(p.avg_latency, 1),
            f(p.p99_latency, 1),
        ]);
    }
    print!("{}", t.render());
    maybe_csv(args, &t, &format!("sweep_{}_{}", spec.name, pattern.name()))
}

fn cmd_workload(args: &Args, config: &ExperimentConfig) -> Result<()> {
    // Topology via --topology SPEC or a positional spec.
    let spec = match args.opt("topology") {
        Some(s) => catalog::parse(s)?,
        None => spec_arg(args)?,
    };
    let cfg = sim_config(args, config)?;
    check_num_vcs(spec.graph.dim(), cfg.num_vcs)?;
    check_faults(&cfg, &spec.graph)?;
    let which = args.opt_or("workload", "all");
    let kinds: Vec<WorkloadKind> = if which == "all" {
        WorkloadKind::ALL.to_vec()
    } else {
        vec![WorkloadKind::parse(&which).ok_or_else(|| {
            anyhow!(
                "unknown workload {which:?} (stencil alltoall allreduce-ring \
                 allreduce-rd permutation hotspot all)"
            )
        })?]
    };
    let hot = args.opt_usize("hot")?.unwrap_or(0);
    if hot >= spec.graph.order() {
        bail!("--hot {hot} out of range: {} has {} nodes", spec.name, spec.graph.order());
    }
    // `--msg-phits` sweeps the application payload (one table row per
    // workload × size; see workload::gen for the per-family mapping). The
    // default is one packet at the configured packet size — the
    // single-packet-per-message model under any `[sim] packet_size`.
    let sizes = args.opt_u32s("msg-phits")?.unwrap_or_else(|| vec![cfg.packet_size]);
    let iters = args.opt_usize("iters")?.unwrap_or(8);
    let runner = WorkloadRunner {
        sim: cfg.clone(),
        seeds: args.opt_usize("seeds")?.unwrap_or(1),
        workers: args.opt_usize("workers")?.unwrap_or(0),
        max_cycles: args.opt_usize("max-cycles")?.map(|c| c as u64),
    };
    // A trace file records exactly one simulation; multiple seeds (or
    // multiple table rows) would each truncate it in turn.
    if cfg.trace.is_some() {
        if runner.seeds > 1 {
            bail!("--trace needs --seeds 1 (each seed would overwrite the trace file)");
        }
        if kinds.len() > 1 || sizes.len() > 1 {
            bail!(
                "--trace needs a single workload row: pick one --workload \
                 (not `all`) and one --msg-phits value"
            );
        }
    }
    let sim = Simulator::for_workload(spec.graph.clone(), cfg);
    let mut t = Table::new(
        &format!("{} — closed-loop workload completion", spec.name),
        &["workload", "payload", "messages", "phases", "completion", "eff bw", "util spread", "esc share", "avg lat", "p50 lat", "p99 lat", "p99.9 lat", "drained"],
    );
    // The escape-share column is meaningful only when the escape protocol
    // is live (non-DOR policy with at least 2 VCs).
    let escape_on = sim.escape_active();
    // Companion table: the always-on stall-cause attribution per row
    // (counts summed over the row's seeds; see sim::telemetry).
    let mut st = Table::new(
        &format!("{} — stall-cause attribution (cycles, summed over seeds)", spec.name),
        &["workload", "payload", "credit-starved", "link-busy", "bubble-blocked", "nic-serialization", "escape drains"],
    );
    for kind in kinds {
        for &size in &sizes {
            let params = WorkloadParams { iters, hot, payload_phits: size, ..Default::default() };
            let wl = generate(kind, &spec.graph, &params);
            let p = runner.run_with(&sim, &spec.name, &wl);
            t.row(vec![
                kind.name().to_string(),
                size.to_string(),
                p.messages.to_string(),
                wl.phases().to_string(),
                f(p.completion_cycles, 0),
                f(p.effective_bandwidth, 4),
                f(p.link_util_spread, 2),
                if escape_on { f(p.escape_share, 3) } else { "-".into() },
                f(p.avg_latency, 1),
                f(p.p50_latency, 1),
                f(p.p99_latency, 1),
                f(p.p999_latency, 1),
                p.drained.to_string(),
            ]);
            st.row(vec![
                kind.name().to_string(),
                size.to_string(),
                count(p.stalls.credit_starved),
                count(p.stalls.link_busy),
                count(p.stalls.bubble_blocked),
                count(p.stalls.nic_serialization),
                count(p.stalls.escape_drains),
            ]);
        }
    }
    print!("{}", t.render());
    print!("{}", st.render());
    maybe_csv(args, &t, &format!("workload_{}", spec.name))?;
    maybe_csv(args, &st, &format!("workload_{}_stalls", spec.name))
}

fn maybe_csv(args: &Args, t: &Table, name: &str) -> Result<()> {
    if let Some(dir) = args.opt("out") {
        let safe: String = name
            .chars()
            .map(|c| if c.is_alphanumeric() || c == '_' || c == '-' { c } else { '_' })
            .collect();
        let path = t.write_csv(std::path::Path::new(dir), &safe)?;
        eprintln!("wrote {path:?}");
    }
    Ok(())
}

fn cmd_experiment(args: &Args, config: &ExperimentConfig) -> Result<()> {
    let name = args
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let full = args.flag("full") || std::env::var_os("LATTICE_FULL").is_some();
    let run_one = |n: &str| -> Result<()> {
        match n {
            "table1" => {
                let t = exp::table1(&[2, 4, 8, 16]);
                print!("{}", t.render());
                maybe_csv(args, &t, "table1")?;
            }
            "formulas" => {
                let max = if full { 40_000 } else { 5_000 };
                let t = exp::formulas_check(max);
                print!("{}", t.render());
                maybe_csv(args, &t, "formulas")?;
            }
            "bounds" => {
                let t = exp::bounds(&[4, 8, 16, 32]);
                print!("{}", t.render());
                maybe_csv(args, &t, "bounds")?;
            }
            "table2" => {
                let t = exp::table2(&[2, 4]);
                print!("{}", t.render());
                maybe_csv(args, &t, "table2")?;
            }
            "tree" => {
                let dim = args.opt_usize("max-dim")?.unwrap_or(4);
                print!("{}", exp::tree(dim));
            }
            "thm20" => {
                let t = exp::thm20(&[1, 2, 3]);
                print!("{}", t.render());
            }
            "cycles" => print!("{}", exp::cycles()),
            "ablation" => {
                let mut cfg = config.sim_config();
                if !full {
                    cfg.warmup_cycles = 500;
                    cfg.measure_cycles = 3000;
                }
                check_single_run_trace(&cfg, "ablation runs a configuration grid")?;
                let t = exp::ablation(cfg);
                print!("{}", t.render());
                maybe_csv(args, &t, "ablation")?;
            }
            "partition" => {
                let t = exp::partition_report();
                print!("{}", t.render());
                maybe_csv(args, &t, "partition")?;
            }
            "linkuse" => {
                let a = args.opt_usize("a")?.unwrap_or(4) as i64;
                let cfg = config.sim_config();
                check_single_run_trace(&cfg, "linkuse runs several topologies")?;
                let t = exp::link_usage(a, cfg);
                print!("{}", t.render());
                maybe_csv(args, &t, "linkuse")?;
            }
            "crystals" => {
                let a = args.opt_usize("a")?.unwrap_or(4) as i64;
                print!("{}", exp::crystals(a).render());
            }
            "appendix" => print!("{}", exp::appendix().render()),
            "collectives" => {
                let a = args.opt_usize("a")?.unwrap_or(3) as i64;
                let iters = args.opt_usize("iters")?.unwrap_or(8);
                let seeds = args.opt_usize("seeds")?.unwrap_or(1);
                // Payload sweep spanning two orders of magnitude by
                // default (the message-size axis the paper's evaluation
                // methodology calls for).
                let sizes = args
                    .opt_u32s("msg-phits")?
                    .unwrap_or_else(|| vec![16, 256, 4096]);
                let policies = policies_arg(args)?.unwrap_or_else(|| vec![RoutePolicy::Dor]);
                let cfg = sim_config(args, config)?;
                // The collectives topologies are at most 3-dimensional.
                check_num_vcs(3, cfg.num_vcs)?;
                check_single_run_trace(&cfg, "collectives runs a topology x workload grid")?;
                let t = exp::collectives(a, iters, seeds, &sizes, &policies, cfg);
                print!("{}", t.render());
                maybe_csv(args, &t, "collectives")?;
            }
            "policies" => {
                // The adaptive-routing throughput story: per-policy
                // accepted load + per-link utilization spread at and past
                // the mixed-radix torus's DOR saturation point.
                let a = args.opt_usize("a")?.unwrap_or(4) as i64;
                let loads = args.opt_loads()?.unwrap_or_else(|| vec![0.6, 0.8, 1.0]);
                let policies = policies_arg(args)?.unwrap_or_else(|| RoutePolicy::ALL.to_vec());
                let patterns = [TrafficPattern::Uniform, TrafficPattern::RandomPairings];
                // Per-VC rows: the single-VC column shows what adaptivity
                // costs without the escape channel; the configured VC
                // count (default 2) is the deadlock-free configuration.
                let cfg = sim_config(args, config)?;
                check_single_run_trace(&cfg, "policies runs a policy x load x VC grid")?;
                let vcs = vcs_arg(args)?.unwrap_or_else(|| {
                    if cfg.num_vcs == 1 { vec![1] } else { vec![1, cfg.num_vcs] }
                });
                // Both policy testbeds (T(2a,a,a), FCC(a)) are 3-D.
                for &nv in &vcs {
                    check_num_vcs(3, nv)?;
                }
                let t = exp::route_policies(a, &loads, &policies, &patterns, &vcs, cfg);
                print!("{}", t.render());
                maybe_csv(args, &t, "policies")?;
            }
            "degradation" => {
                // Resilience story: accepted throughput and completion
                // under rising link-fault rates, crystals vs matched
                // mixed-radix tori (the degraded-mode counterpart of the
                // policies experiment).
                let a = args.opt_usize("a")?.unwrap_or(4) as i64;
                let rates =
                    args.opt_f64s("rates")?.unwrap_or_else(|| vec![0.0, 0.02, 0.05, 0.10]);
                for &r in &rates {
                    check_fault_rate("--rates", r).map_err(|e| anyhow!(e))?;
                }
                let seeds = args.opt_usize("seeds")?.unwrap_or(3);
                let mut cfg = sim_config(args, config)?;
                if !full {
                    cfg.warmup_cycles = 500;
                    cfg.measure_cycles = 3000;
                }
                check_single_run_trace(&cfg, "degradation sweeps rate x topology x seed")?;
                let t = exp::degradation(a, &rates, seeds, cfg);
                print!("{}", t.render());
                maybe_csv(args, &t, "degradation")?;
            }
            "fig5" | "fig6" | "fig7" | "fig8" => {
                let spec = if n == "fig5" || n == "fig7" {
                    exp::fig5_spec(full)
                } else {
                    exp::fig6_spec(full)
                };
                let (mut cfg, default_seeds) = exp::fig_sim_config(full);
                if config.get("sim.measure_cycles").is_some() {
                    let pinned_vcs = cfg.num_vcs;
                    cfg = config.sim_config();
                    // Keep the Table 3 3-VC pin unless the file takes an
                    // explicit position on the VC count.
                    if config.get("sim.num_vcs").is_none() && config.get("sim.vc_count").is_none()
                    {
                        cfg.num_vcs = pinned_vcs;
                    }
                }
                check_single_run_trace(&cfg, "figures sweep traffic x load x seed")?;
                let seeds = args.opt_usize("seeds")?.unwrap_or(default_seeds);
                let loads = args.opt_loads()?.unwrap_or_else(exp::default_loads);
                let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &loads, seeds, cfg)?;
                if n == "fig5" || n == "fig6" {
                    print!("{}", exp::throughput_table(&fig).render());
                    print!("{}", exp::gain_table(&fig).render());
                    maybe_csv(args, &exp::throughput_table(&fig), n)?;
                } else {
                    print!("{}", exp::curve_table(&fig).render());
                    maybe_csv(args, &exp::curve_table(&fig), n)?;
                }
            }
            other => bail!("unknown experiment {other:?}; see `help`"),
        }
        Ok(())
    };
    if name == "all" {
        for n in [
            "table1", "formulas", "bounds", "table2", "tree", "thm20", "cycles",
            "crystals", "appendix", "partition", "linkuse", "ablation",
            "collectives", "policies", "degradation", "fig5", "fig6", "fig7", "fig8",
        ] {
            println!("\n### experiment {n}\n");
            run_one(n)?;
        }
        Ok(())
    } else {
        run_one(name)
    }
}

fn cmd_apsp(args: &Args) -> Result<()> {
    let spec = spec_arg(args)?;
    let kind = ApspKind::parse(&args.opt_or("kind", "minplus"))
        .ok_or_else(|| anyhow!("--kind must be minplus or gemm"))?;
    let engine = ApspEngine::open_default().context("opening PJRT APSP engine")?;
    let out = engine.distance_summary(&spec.graph, kind)?;
    let bfs = distance_distribution(&spec.graph);
    println!(
        "{} via {} artifact (padded to {})",
        spec.name,
        kind.model_name(),
        out.padded_to
    );
    println!("  PJRT: diameter {}  avg {:.6}", out.diameter, out.avg_distance);
    println!("  BFS : diameter {}  avg {:.6}", bfs.diameter, bfs.avg_distance);
    anyhow::ensure!(
        out.diameter as usize == bfs.diameter
            && (out.avg_distance - bfs.avg_distance).abs() < 1e-6,
        "PJRT and BFS disagree!"
    );
    println!("  agreement OK");
    Ok(())
}

fn cmd_tree(args: &Args) -> Result<()> {
    let dim = args.opt_usize("max-dim")?.unwrap_or(4);
    print!("{}", exp::tree(dim));
    Ok(())
}

fn indent(s: &str, by: usize) -> String {
    let pad = " ".repeat(by);
    s.lines().map(|l| format!("{pad}{l}\n")).collect()
}

const HELP: &str = "\
lattice-networks — symmetric interconnection networks from cubic crystal lattices

USAGE:
  lattice-networks <subcommand> [args] [--options]

SUBCOMMANDS:
  topo <spec> [--histogram]         topology properties
  route <spec> <src> <dst>          minimal routing record(s) (labels: 1,3,3)
  sim <spec> [--traffic T] [--load L] [--cycles N] [--warmup N]
  sweep <spec> [--traffic T] [--loads from:to:step] [--seeds K] [--out DIR]
  workload [<spec> | --topology SPEC] [--workload W] [--iters N] [--seeds K]
           [--hot NODE] [--msg-phits S1,S2,...] [--send-overhead O]
           [--recv-overhead O] [--packet-gap G] [--max-cycles N]
           [--workers K] [--out DIR]
      closed-loop completion time of a finite, dependency-ordered message
      set; messages packetize into ceil(phits/packet_size) packets and
      --msg-phits sweeps the payload; --workload all runs the whole suite
  experiment <name> [--full] [--out DIR] [--seeds K] [--loads ...]
      names: table1 formulas bounds table2 tree thm20 cycles crystals
             appendix partition linkuse ablation collectives policies
             degradation fig5 fig6 fig7 fig8 all
      collectives also takes [--a A] [--iters N] [--msg-phits S1,S2,...]
      [--route-policy P1,P2,...] (crystals vs matched tori; payload
      defaults to 16,256,4096 phits); policies sweeps route policies at
      high load on T(2a,a,a) vs FCC(a) with link-balance and per-VC
      columns ([--num-vcs N1,N2,...], default 1,2 — the single-VC column
      shows adaptive routing without its escape channel); degradation
      sweeps link-fault rates ([--rates R1,R2,...], default
      0,0.02,0.05,0.1) over crystals vs matched tori and reports
      surviving-fraction, accepted load and latency per rate
  apsp <spec> [--kind minplus|gemm]  distance summary via PJRT AOT artifacts
                                     (needs the `pjrt` cargo feature)
  tree [--max-dim N]                 Figure 4 lift tree
  help

TOPOLOGY SPECS:
  pc:A fcc:A bcc:A rtt:A 4d-fcc:A 4d-bcc:A lip:A torus:AxBxC...
  t-rtt:A pc-bcc:A pc-fcc:A bcc-fcc:A pcN:A fccN:A bccN:A (N = dim)

TRAFFIC: uniform antipodal centralsymmetric randompairings hotspot
  (hotspot = uniform plus a fixed hot destination drawing 1 packet in 8;
  post-paper stress pattern, excluded from the figure sweeps)

WORKLOADS: stencil alltoall allreduce-ring allreduce-rd permutation hotspot

ROUTING/LINK MODEL (sim, sweep, workload, experiments):
  --route-policy dor|random|adaptive   per-hop route selection over the
      minimal record (dor = historical DOR; adaptive = most downstream
      headroom; experiments accept a comma list and sweep it, other
      commands use the first entry)
  --link-latency L                     LogGP L: per-hop wire latency, cycles
  --axis-widths W1,W2,...              per-axis channel widths; axis i
      serializes a packet in ceil(packet_size/Wi) cycles (paper Sec. 6)
  --num-vcs N                          virtual channels per link (default
      2). Under random/adaptive, VC 0 is a DOR escape channel (Duato):
      blocked adaptive packets drain into it, making adaptivity
      deadlock-free; N=1 disables the escape protocol. The policies
      experiment accepts a comma list and sweeps it.
  --scan-mode active|full              per-cycle engine scan: active
      (default) visits only nodes with queued traffic via maintained
      worklists, full is the retained reference scan over every node —
      bit-identical results, different cost (DESIGN.md Engine-performance)
  --threads N                          engine worker threads (default 1).
      Each cycle's active nodes are carved into N work-balanced shards;
      per-node RNG streams make any N bit-identical to the serial run
      (DESIGN.md Parallel-engine)
  --serial-cutoff K                    with --threads N > 1: run a
      cycle's arbitration on the calling thread when fewer than N*K
      nodes are active, skipping the barrier round-trip (default 64;
      0 forces every cycle through the sharded path). Bit-identical
      either way; the sim command reports the serial/sharded cycle split

FAULT MODEL (sim, sweep, workload; fail-stop links and routers):
  --fault-links A-B,C-D,...            kill the listed bidirectional links
      (endpoints must be adjacent; both directions go down together)
  --fault-nodes N1,N2,...              kill the listed routers (all
      incident links go down; dead endpoints neither inject nor eject)
  --link-fault-rate R                  additionally kill each remaining
      link with probability R (0..=1), drawn from a dedicated RNG stream
      seeded only by the run seed — reproducible, and an empty fault set
      leaves every result bit-identical to the pristine engine
  --node-fault-rate R                  same, for routers
  Routing detours around faults within the minimal-record discipline:
  adaptive/random mask dead productive ports and drain to the DOR escape
  lane; DOR itself only admits packets whose fixed path is live. Packets
  are only admitted between mutually reachable live endpoints (the BFS
  oracle in metrics::bfs checks the engine against this); closed-loop
  workloads drop unroutable messages and rewire their dependents.

TELEMETRY (sim, workload — single runs only):
  --trace FILE                         stream packet-lifecycle events
      (inject, packetize, hop, stall with cause, deliver) as JSONL;
      results are bit-identical with tracing on or off. Summarize with
      scripts/trace_summary.py. Rejected on sweeps/experiments/multi-row
      workload runs, which would truncate the file per simulation
  --sample-every N                     with --trace: every N cycles emit
      a probe event (active-set size, in-flight phits, per-port and
      per-VC occupancy, injection backlog) — the time-series view
  Stall-cause attribution (credit-starved / link-busy / bubble-blocked /
  nic-serialization, plus escape-drain counts) is always on and printed
  by sim and workload; --trace additionally records each stall event.

CONFIG: --config file.toml ([sim] packet_size/num_vcs/route_policy/
        link_latency/axis_widths/..., see coordinator::config docs).
        --full (or LATTICE_FULL=1) runs the paper-size networks
        (8192/2048 nodes).
";
