//! PJRT CPU client wrapper with a compiled-executable cache.
//!
//! The real client needs the `xla` crate, which cannot be vendored into
//! this offline build; it is gated behind the `pjrt` cargo feature (see
//! rust/Cargo.toml). Without the feature, [`PjrtRuntime::cpu`] returns a
//! clear error and every caller degrades gracefully (the `apsp` CLI
//! subcommand reports the error; the PJRT integration tests skip).

use anyhow::Result;

/// A PJRT client plus a cache of compiled executables keyed by HLO path.
///
/// Compilation is the expensive step (tens to hundreds of ms); executing a
/// cached executable is micro/milliseconds. The cache is behind a mutex so
/// one runtime can serve concurrent experiment threads.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: std::sync::Mutex<
        std::collections::HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>,
    >,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        use anyhow::Context as _;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Platform string (e.g. "cpu") — handy for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it (cached).
    pub fn load_hlo(
        &self,
        path: &std::path::Path,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        use anyhow::Context as _;
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute a compiled module on literals, returning the decomposed
    /// output tuple (aot.py always lowers with `return_tuple=True`).
    pub fn execute_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        use anyhow::Context as _;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .context("executing PJRT module")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("decomposing result tuple")
    }
}

/// Stub used when the crate is built without the `pjrt` feature: carries
/// the same constructor surface but always fails to open, so callers get
/// one consistent, actionable error.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    /// Always errors: the build carries no XLA/PJRT backend.
    pub fn cpu() -> Result<Self> {
        anyhow::bail!(
            "PJRT/XLA runtime unavailable: built without the `pjrt` cargo feature \
             (add the `xla` crate to rust/Cargo.toml and build with --features pjrt)"
        )
    }

    /// Platform string — the stub never instantiates, but keep the surface.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}
