//! Engine telemetry: packet-lifecycle tracing, stall-cause attribution
//! and periodic time-series probes (DESIGN.md §Telemetry).
//!
//! Three layers, cheapest first:
//!
//! 1. **Stall-cause counters** ([`StallCounters`]) — always on. Every
//!    cycle a packet head sits blocked at an output port, the engine
//!    classifies *why* ([`StallCause`]) and bumps one `u64`. The
//!    classification runs only on the already-blocked path (the success
//!    path is untouched), re-reads state the eligibility check just
//!    touched, and draws no RNG — so the counters cannot perturb results,
//!    and the `telemetry_differential` suite pins that trace-off runs are
//!    bit-identical (whole `Debug` + `rng_digest`) to the pre-telemetry
//!    engine.
//! 2. **Packet-lifecycle trace** ([`Trace`]) — off unless
//!    `SimConfig::trace` names a file. Structured JSONL events for
//!    inject, packetize, hop (with VC, port, link and escape-drain flag),
//!    stall (with cause), delivery and message completion, one JSON
//!    object per line. Costs one branch per hook when off
//!    (`Option::is_none`).
//! 3. **Time-series probes** — with a trace open and
//!    `SimConfig::sample_every = N > 0`, every `N`-th cycle emits a
//!    `probe` event sampling active-set size, in-flight phits, per-VC and
//!    per-port-class input-queue occupancy, the single busiest link, and
//!    the injection/NIC backlogs.
//!
//! The event taxonomy and the per-field schema are documented on the
//! [`Trace`] methods and checked by CI (`trace-smoke` job); the
//! stall-cause semantics live on [`StallCause`]. A stdlib-only summary
//! helper lives at `scripts/trace_summary.py`.

mod trace;

pub use trace::Trace;

/// Why a packet head failed to advance this cycle (one attribution per
/// blocked head per arbitration visit).
///
/// Attribution mirrors the eligibility check, in the order the hardware
/// would discover the conflicts:
///
/// - the output link (or the ejection channel) is still serializing an
///   earlier packet → [`LinkBusy`](StallCause::LinkBusy);
/// - the downstream input queue lacks a free packet slot →
///   [`CreditStarved`](StallCause::CreditStarved);
/// - a slot exists, but the head is *entering* a dimensional ring and
///   bubble flow control demands a second free slot →
///   [`BubbleBlocked`](StallCause::BubbleBlocked);
/// - closed-loop only: a NIC finished its injection work for the cycle
///   with messages still queued behind the serialization/gap/overhead
///   model → [`NicSerialization`](StallCause::NicSerialization).
///
/// Heads that lose arbitration to a competing head at the same output
/// port are *not* counted: the port did useful work that cycle, and the
/// loser's next visit attributes whatever still blocks it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallCause {
    /// Downstream input queue has no free packet slot (no credit).
    CreditStarved,
    /// Output link (or ejection channel) busy serializing a prior packet.
    LinkBusy,
    /// Bubble flow control: one free slot downstream, but ring entry
    /// requires two (DESIGN.md §Virtual-channels).
    BubbleBlocked,
    /// Closed-loop NIC cycle ended with send-queue work left over
    /// (gap pacing, overheads, or a full injection queue).
    NicSerialization,
}

impl StallCause {
    /// Short spelling used in trace events (`credit`, `link`, `bubble`,
    /// `nic`).
    pub fn name(self) -> &'static str {
        match self {
            StallCause::CreditStarved => "credit",
            StallCause::LinkBusy => "link",
            StallCause::BubbleBlocked => "bubble",
            StallCause::NicSerialization => "nic",
        }
    }
}

/// Always-on stall-cause counters, plus the escape-drain count — the
/// run-level summary behind the CLI's stall breakdown table. Surfaced on
/// [`SimResult`](crate::sim::SimResult) and
/// [`WorkloadOutcome`](crate::workload::WorkloadOutcome); identical
/// between the scan modes (the active-set scan visits every node the
/// full scan would act on) and between trace-on and trace-off runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StallCounters {
    /// Head-cycles blocked on a missing downstream credit.
    pub credit_starved: u64,
    /// Head-cycles blocked on a busy output link / ejection channel.
    pub link_busy: u64,
    /// Head-cycles blocked by the bubble ring-entry condition alone.
    pub bubble_blocked: u64,
    /// Closed-loop NIC node-cycles with send-queue work left over.
    pub nic_serialization: u64,
    /// Transfers that drained a blocked adaptive head into the VC-0
    /// escape channel (Duato protocol; always 0 when the escape protocol
    /// is off).
    pub escape_drains: u64,
}

impl StallCounters {
    /// Bump the counter for `cause`.
    #[inline]
    pub fn note(&mut self, cause: StallCause) {
        match cause {
            StallCause::CreditStarved => self.credit_starved += 1,
            StallCause::LinkBusy => self.link_busy += 1,
            StallCause::BubbleBlocked => self.bubble_blocked += 1,
            StallCause::NicSerialization => self.nic_serialization += 1,
        }
    }

    /// Total attributed stall head-cycles (escape drains are transfers,
    /// not stalls, and are excluded).
    pub fn total(&self) -> u64 {
        self.credit_starved + self.link_busy + self.bubble_blocked + self.nic_serialization
    }

    /// Element-wise accumulate (multi-seed aggregation).
    pub fn accumulate(&mut self, other: &StallCounters) {
        self.credit_starved += other.credit_starved;
        self.link_busy += other.link_busy;
        self.bubble_blocked += other.bubble_blocked;
        self.nic_serialization += other.nic_serialization;
        self.escape_drains += other.escape_drains;
    }

    /// `(label, count)` rows for report tables, fixed order.
    pub fn rows(&self) -> [(&'static str, u64); 4] {
        [
            ("credit-starved", self.credit_starved),
            ("link-busy", self.link_busy),
            ("bubble-blocked", self.bubble_blocked),
            ("nic-serialization", self.nic_serialization),
        ]
    }
}

/// Execution profile of the phased parallel engine: how many cycles ran
/// Phase B on the calling thread (the serial fast path, taken when the
/// active-work estimate is below `threads × serial_cutoff`) versus
/// fanned out across the shard workers. Surfaced on
/// [`SimResult`](crate::sim::SimResult) and
/// [`WorkloadOutcome`](crate::workload::WorkloadOutcome) so the
/// fast-path decision is observable (DESIGN.md §Parallel-engine).
///
/// The counters describe the *execution schedule*, not the simulated
/// network, and legitimately differ across thread counts and cutoff
/// settings while the simulation output stays bit-identical. The
/// differential suites pin that identity by comparing whole-`Debug`
/// renderings of results — which is why this type's `Debug` impl is
/// deliberately opaque (it prints no counter values). Read the public
/// fields directly when the profile itself is under test.
#[derive(Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineProfile {
    /// Cycles whose Phase B ran on the calling thread, skipping the
    /// barrier round-trip (always all of them at `threads = 1`).
    pub serial_cycles: u64,
    /// Cycles whose Phase B was sharded across the worker threads.
    pub parallel_cycles: u64,
}

impl EngineProfile {
    /// Total cycles driven through Phase B.
    pub fn total(&self) -> u64 {
        self.serial_cycles + self.parallel_cycles
    }
}

impl std::fmt::Debug for EngineProfile {
    /// Deliberately constant: see the type docs — execution-schedule
    /// counters must not break whole-`Debug` equality across thread
    /// counts.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("EngineProfile(..)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_profile_debug_is_opaque() {
        let a = EngineProfile { serial_cycles: 3, parallel_cycles: 9 };
        let b = EngineProfile::default();
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "profile must not leak into Debug");
        assert_eq!(a.total(), 12);
    }

    #[test]
    fn cause_names_are_trace_spellings() {
        assert_eq!(StallCause::CreditStarved.name(), "credit");
        assert_eq!(StallCause::LinkBusy.name(), "link");
        assert_eq!(StallCause::BubbleBlocked.name(), "bubble");
        assert_eq!(StallCause::NicSerialization.name(), "nic");
    }

    #[test]
    fn counters_note_total_accumulate() {
        let mut c = StallCounters::default();
        c.note(StallCause::CreditStarved);
        c.note(StallCause::CreditStarved);
        c.note(StallCause::LinkBusy);
        c.note(StallCause::BubbleBlocked);
        c.note(StallCause::NicSerialization);
        c.escape_drains = 7;
        assert_eq!(c.credit_starved, 2);
        assert_eq!(c.total(), 5, "escape drains are not stalls");
        let mut sum = StallCounters::default();
        sum.accumulate(&c);
        sum.accumulate(&c);
        assert_eq!(sum.link_busy, 2);
        assert_eq!(sum.escape_drains, 14);
        assert_eq!(sum.total(), 10);
        let labels: Vec<&str> = c.rows().iter().map(|r| r.0).collect();
        assert_eq!(
            labels,
            ["credit-starved", "link-busy", "bubble-blocked", "nic-serialization"]
        );
    }
}
