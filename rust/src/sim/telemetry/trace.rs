//! JSONL packet-lifecycle trace writer.
//!
//! One JSON object per line, discriminated by the `"ev"` field; every
//! other field is numeric (no string escaping anywhere — the only string
//! values are the fixed `ev` and `cause` spellings), so the format is
//! hand-rolled over a `BufWriter` with no serialization dependency. The
//! per-event schema is documented on each method and validated by the CI
//! `trace-smoke` job; `scripts/trace_summary.py` consumes it.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use super::StallCause;

/// Buffered JSONL event stream (see [`crate::sim::telemetry`]).
///
/// Opened per run by the engine when `SimConfig::trace` is set; the file
/// is truncated, so multi-run surfaces (seed averaging, load sweeps,
/// experiments) refuse `--trace` rather than silently clobbering it.
/// Write failures panic: a trace that silently drops events is worse
/// than no trace.
#[derive(Debug)]
pub struct Trace {
    out: BufWriter<File>,
}

impl Trace {
    /// Create (truncate) the trace file at `path`.
    pub fn create(path: &Path) -> std::io::Result<Trace> {
        Ok(Trace { out: BufWriter::new(File::create(path)?) })
    }

    #[inline]
    fn line(&mut self, args: std::fmt::Arguments<'_>) {
        self.out
            .write_fmt(args)
            .and_then(|()| self.out.write_all(b"\n"))
            .expect("telemetry: trace write failed");
    }

    /// `{"ev":"inject","t":..,"pkt":..,"src":..,"dst":..,"vc":..}` —
    /// a packet entered a source injection queue (open- and closed-loop).
    #[inline]
    pub fn inject(&mut self, t: u64, pkt: u32, src: usize, dst: usize, vc: u8) {
        self.line(format_args!(
            "{{\"ev\":\"inject\",\"t\":{t},\"pkt\":{pkt},\"src\":{src},\"dst\":{dst},\"vc\":{vc}}}"
        ));
    }

    /// `{"ev":"packetize","t":..,"msg":..,"src":..,"dst":..,"phits":..,"packets":..}`
    /// — a closed-loop message reached the head of its NIC and started
    /// packetizing into its injection train.
    #[inline]
    pub fn packetize(&mut self, t: u64, msg: u32, src: usize, dst: usize, phits: u64, packets: u64) {
        self.line(format_args!(
            "{{\"ev\":\"packetize\",\"t\":{t},\"msg\":{msg},\"src\":{src},\"dst\":{dst},\
             \"phits\":{phits},\"packets\":{packets}}}"
        ));
    }

    /// `{"ev":"hop","t":..,"land":..,"pkt":..,"from":..,"to":..,"port":..,"vc":..,"esc":0|1}`
    /// — a link transfer started at `t`; the head lands downstream at
    /// `land` (`t + link_latency`). `vc` is the channel occupied at the
    /// *receiving* input; `esc:1` marks a Duato escape drain (a blocked
    /// adaptive head falling into VC 0). Ejection transfers are reported
    /// as [`deliver`](Trace::deliver), not hops.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn hop(
        &mut self,
        t: u64,
        land: u64,
        pkt: u32,
        from: usize,
        to: usize,
        port: usize,
        vc: u8,
        esc: bool,
    ) {
        self.line(format_args!(
            "{{\"ev\":\"hop\",\"t\":{t},\"land\":{land},\"pkt\":{pkt},\"from\":{from},\
             \"to\":{to},\"port\":{port},\"vc\":{vc},\"esc\":{}}}",
            esc as u8
        ));
    }

    /// `{"ev":"stall","t":..,"node":..,"port":..,"vc":..,"cause":"credit"|"link"|"bubble"|"nic"}`
    /// — a blocked head-cycle with its attributed cause
    /// ([`StallCause`]). NIC-serialization stalls carry `port:-1,vc:-1`
    /// (they are per-NIC, not per-port).
    #[inline]
    pub fn stall(&mut self, t: u64, node: usize, port: i64, vc: i64, cause: StallCause) {
        self.line(format_args!(
            "{{\"ev\":\"stall\",\"t\":{t},\"node\":{node},\"port\":{port},\"vc\":{vc},\
             \"cause\":\"{}\"}}",
            cause.name()
        ));
    }

    /// `{"ev":"deliver","t":..,"pkt":..,"node":..,"inj_t":..,"lat":..}` —
    /// the packet's tail fully drained at its destination NIC at `t`;
    /// `lat = t - inj_t` is the latency the summary statistics record.
    #[inline]
    pub fn deliver(&mut self, t: u64, pkt: u32, node: usize, inj_t: u64) {
        self.line(format_args!(
            "{{\"ev\":\"deliver\",\"t\":{t},\"pkt\":{pkt},\"node\":{node},\"inj_t\":{inj_t},\
             \"lat\":{}}}",
            t - inj_t
        ));
    }

    /// `{"ev":"msg_done","t":..,"msg":..,"lat":..}` — a closed-loop
    /// message completed (last packet drained plus `recv_overhead`),
    /// releasing its dependents; `lat` is measured from the message's
    /// first packet injection.
    #[inline]
    pub fn msg_done(&mut self, t: u64, msg: u32, lat: u64) {
        self.line(format_args!("{{\"ev\":\"msg_done\",\"t\":{t},\"msg\":{msg},\"lat\":{lat}}}"));
    }

    /// `{"ev":"probe","t":..,"active":..,"inflight_phits":..,"inj_backlog":..,"send_backlog":..,"vc_occ":[..],"port_occ":[..],"max_link_occ":..}`
    /// — periodic network state sample (`SimConfig::sample_every`):
    /// active-worklist size, in-flight phits, injection-queue backlog
    /// (packets), closed-loop NIC send backlog (messages; 0 in open
    /// loop), input-queue occupancy in phits summed per VC and per
    /// directed port class, and the occupancy of the single fullest
    /// (node, port) input across the network.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub fn probe(
        &mut self,
        t: u64,
        active: usize,
        inflight_phits: u64,
        inj_backlog: u64,
        send_backlog: u64,
        vc_occ: &[u64],
        port_occ: &[u64],
        max_link_occ: u64,
    ) {
        // Occupancy vectors are tiny (num_vcs, 2·dim entries): building
        // the two array strings per sample is far off the hot path.
        let join = |xs: &[u64]| {
            let mut s = String::with_capacity(xs.len() * 4);
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&x.to_string());
            }
            s
        };
        self.line(format_args!(
            "{{\"ev\":\"probe\",\"t\":{t},\"active\":{active},\"inflight_phits\":{inflight_phits},\
             \"inj_backlog\":{inj_backlog},\"send_backlog\":{send_backlog},\"vc_occ\":[{}],\
             \"port_occ\":[{}],\"max_link_occ\":{max_link_occ}}}",
            join(vc_occ),
            join(port_occ)
        ));
    }

    /// Flush buffered events to disk (end of run; also happens on drop,
    /// but only an explicit flush surfaces I/O errors).
    pub fn flush(&mut self) {
        self.out.flush().expect("telemetry: trace flush failed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_one_json_object_per_line() {
        let path = std::env::temp_dir()
            .join(format!("lattice_trace_unit_{}.jsonl", std::process::id()));
        let mut tr = Trace::create(&path).expect("create trace");
        tr.inject(5, 0, 1, 14, 1);
        tr.packetize(5, 3, 1, 14, 80, 5);
        tr.hop(6, 7, 0, 1, 2, 0, 1, false);
        tr.stall(8, 2, 0, 1, StallCause::CreditStarved);
        tr.stall(8, 2, -1, -1, StallCause::NicSerialization);
        tr.deliver(40, 0, 14, 5);
        tr.msg_done(41, 3, 36);
        tr.probe(50, 4, 96, 2, 1, &[32, 64], &[48, 48, 0, 0], 64);
        tr.flush();
        let text = std::fs::read_to_string(&path).expect("read trace");
        std::fs::remove_file(&path).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 8);
        for line in &lines {
            assert!(line.starts_with('{') && line.ends_with('}'), "not an object: {line}");
            assert!(line.contains("\"ev\":\""), "no discriminator: {line}");
        }
        assert_eq!(
            lines[0],
            "{\"ev\":\"inject\",\"t\":5,\"pkt\":0,\"src\":1,\"dst\":14,\"vc\":1}"
        );
        assert!(lines[2].contains("\"esc\":0"));
        assert!(lines[3].contains("\"cause\":\"credit\""));
        assert!(lines[4].contains("\"port\":-1"));
        assert!(lines[5].contains("\"lat\":35"));
        assert!(lines[7].contains("\"vc_occ\":[32,64]"));
        assert!(lines[7].contains("\"port_occ\":[48,48,0,0]"));
    }
}
