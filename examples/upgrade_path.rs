//! Section 4 in action: lifts, projections, the `⊞` common-lift operator
//! and the Figure 4 tree — how higher-dimensional networks embedding the
//! crystals are composed and partitioned.
//!
//! ```sh
//! cargo run --release --example upgrade_path
//! ```

use lattice_networks::coordinator::experiments;
use lattice_networks::lattice::{common_lift, LatticeGraph};
use lattice_networks::metrics::distance_distribution;
use lattice_networks::topology;

fn main() {
    // 1. Lifting: 4D-BCC(2) embeds PC(4) as its projection (Prop. 17) —
    //    the network partitioning story of §4/§6.1.
    let g = topology::bcc4d(2);
    println!(
        "4D-BCC(2): {} nodes, dim {}, symmetric={}",
        g.order(),
        g.dim(),
        g.is_symmetric()
    );
    let p = g.project();
    println!(
        "  decomposes into {} disjoint copies of its projection, joined by \
         {} cycles of length {}",
        p.side, p.num_cycles, p.cycle_len
    );
    let proj = g.projection_graph();
    println!(
        "  projection = PC(4)? {}",
        proj.right_equivalent(&topology::pc(4))
    );

    // 2. The ⊞ common lift (Theorem 24): embed PC(4) and BCC(2) in one 4D
    //    network (Example 25).
    let hybrid = LatticeGraph::new(common_lift(
        topology::pc(4).matrix(),
        topology::bcc(2).matrix(),
    ));
    println!(
        "\nPC(4) ⊞ BCC(2): dim {}, {} nodes (direct sum would be dim {})",
        hybrid.dim(),
        hybrid.order(),
        topology::pc(4).dim() + topology::bcc(2).dim()
    );
    let s = distance_distribution(&hybrid);
    println!("  diameter {}, avg distance {:.3}", s.diameter, s.avg_distance);

    // 3. Routing on the hybrid picks the easy projection (§5.3): the
    //    hierarchical router recurses through PC(4).
    let router = lattice_networks::routing::HierarchicalRouter::new(hybrid.clone());
    use lattice_networks::routing::Router;
    let r = router.route(&vec![0; 4], &hybrid.label_of(hybrid.order() - 1));
    println!("  sample minimal record to the last node: {r:?}");

    // 4. The Figure 4 tree of symmetric lifts.
    println!("\nFigure 4 lift/projection tree (to dim 4):");
    print!("{}", experiments::tree(4));

    // 5. Theorem 20: BCC is a leaf — no symmetric lift exists.
    let found = lattice_networks::lattice::symmetry::symmetric_bcc_lifts(2);
    println!("symmetric lifts of BCC(2) found by exhaustive search: {}", found.len());
}
