//! Simulation parameters — defaults follow the paper's Table 3, plus a
//! LogGP-style software overhead model for the closed-loop workload mode
//! (all overheads default to zero, i.e. the pure Table 3 hardware model)
//! and the routing/link extensions (route-selection policy, per-hop wire
//! latency, per-axis channel widths — all defaulting to the historical
//! DOR engine with 1-cycle hops and symmetric links).
//!
//! One deliberate deviation from Table 3: the default virtual-channel
//! count is `num_vcs = 2`, not 3, because the VCs now carry the escape
//! protocol (VC 0 is the DOR escape channel, VCs ≥ 1 are adaptive — see
//! DESIGN.md §Virtual-channels). Table 3's 3-VC router is reachable with
//! `num_vcs = 3`.

use super::policy::RoutePolicy;

/// Per-cycle engine scan strategy (DESIGN.md §Engine-performance).
///
/// Both modes produce bit-identical results — same `SimResult` /
/// `WorkloadOutcome`, same RNG end-state — because the active-set path
/// visits the same nodes the full scan would act on, in the same
/// ascending order (pinned by the `engine_differential` test suite).
/// They differ only in per-cycle cost: active-set work is proportional
/// to in-flight traffic, full-scan work to network size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScanMode {
    /// Maintained active worklists (the default): arbitration visits only
    /// nodes with queued packets, the closed-loop packetizer only NICs
    /// with eligible messages. Low-activity regimes — drain windows,
    /// closed-loop dependency tails, low-load latency sweeps — cost
    /// per-cycle work proportional to what is actually moving.
    ActiveSet,
    /// The historical reference path: scan every node every cycle.
    /// Retained for differential testing and as the perf baseline the
    /// `engine_scaling` bench measures speedups against.
    FullScan,
}

impl ScanMode {
    pub const ALL: [ScanMode; 2] = [ScanMode::ActiveSet, ScanMode::FullScan];

    /// Parse a CLI/config spelling (`active` or `full`).
    pub fn parse(s: &str) -> Option<ScanMode> {
        match s.to_ascii_lowercase().as_str() {
            "active" | "active-set" | "activeset" => Some(ScanMode::ActiveSet),
            "full" | "full-scan" | "fullscan" => Some(ScanMode::FullScan),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScanMode::ActiveSet => "active",
            ScanMode::FullScan => "full",
        }
    }
}

/// Simulator configuration (Table 3 defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Packet size in phits (Table 3: 16).
    pub packet_size: u32,
    /// Virtual channels per physical link. VC 0 is the escape channel:
    /// under the non-DOR route policies (and `num_vcs >= 2`) it is pinned
    /// to dimension-order routing with bubble flow control, and a blocked
    /// adaptive packet drains into it — Duato's protocol, which makes the
    /// adaptive policies deadlock-free (DESIGN.md §Virtual-channels). VCs
    /// `1..num_vcs` are free for adaptive use. With `num_vcs = 1` the
    /// escape protocol is off and the engine is bit-exact with the
    /// single-VC pre-escape engine (Table 3's count is 3; the default of
    /// 2 is one escape + one adaptive channel).
    pub num_vcs: usize,
    /// Input queue capacity in packets per VC (Table 3: 4).
    pub queue_packets: u32,
    /// Injection queue capacity in packets (Table 3: "Injectors 6" — INSEE
    /// models six independent injectors; we model the aggregate as a
    /// 6-packet source queue, the arrangement that affects behaviour at
    /// and past saturation).
    pub injection_queue_packets: u32,
    /// Bubble deadlock avoidance on dimensional rings (Table 3: Bubble).
    pub bubble: bool,
    /// Warmup cycles before statistics.
    pub warmup_cycles: u64,
    /// Measured cycles (paper: 10 000).
    pub measure_cycles: u64,
    /// Drain cycles after measurement window (latency stragglers).
    pub drain_cycles: u64,
    /// RNG seed.
    pub seed: u64,
    /// In-transit priority over injection (BG/Q congestion control, §6.2).
    pub transit_priority: bool,
    /// LogGP `o_send`: per-message software overhead (cycles) between a
    /// message's dependencies completing and its first packet becoming
    /// eligible for injection. Closed-loop workload mode only.
    pub send_overhead: u64,
    /// LogGP `o_recv`: per-message software overhead (cycles) between the
    /// last packet of a message draining at its destination and the message
    /// counting as complete (releasing its dependents). Closed-loop
    /// workload mode only.
    pub recv_overhead: u64,
    /// LogGP `g`: minimum cycles between successive packet injections
    /// from one NIC (injection gap) — within a message's train and across
    /// consecutive messages from the same source. Values at or below the
    /// wire serialization time `packet_size` are absorbed by link
    /// serialization. Closed-loop workload mode only.
    pub packet_gap: u64,
    /// Per-hop output-port selection policy (see [`RoutePolicy`]). `Dor`
    /// is bit-exact with the historical engine.
    pub route_policy: RoutePolicy,
    /// LogGP `L`: per-hop wire latency in cycles (>= 1). With the default
    /// of 1 a cut-through head advances one link per cycle, the
    /// historical timing.
    pub link_latency: u64,
    /// Per-axis physical channel widths (paper §6: wider channels on
    /// chosen axes). Axis `i` serializes a packet in
    /// `ceil(packet_size / axis_widths[i])` cycles; missing entries
    /// default to width 1, and an empty vector is the symmetric Table 3
    /// model.
    pub axis_widths: Vec<u32>,
    /// Per-cycle scan strategy ([`ScanMode`]): activity-proportional
    /// worklists (default) or the retained full-network reference scan.
    /// Bit-exact with each other; performance-only.
    pub scan_mode: ScanMode,
    /// Packet-lifecycle trace output path (JSONL; `--trace` / `[sim]
    /// trace`). `None` (the default) disables tracing entirely, and a
    /// disabled run is bit-identical — same results, same `rng_digest` —
    /// to the untraced engine (see
    /// [`telemetry`](crate::sim::telemetry); pinned by
    /// `rust/tests/telemetry_differential.rs`). The file is truncated
    /// per run, so multi-run surfaces (seed averaging, sweeps,
    /// experiments) reject the option.
    pub trace: Option<String>,
    /// With a trace open, emit a `probe` network-state sample every this
    /// many cycles (`--sample-every`); 0 (the default) disables probes.
    /// Ignored without `trace`.
    pub sample_every: u64,
    /// Worker threads for the per-cycle engine kernels (`--threads` /
    /// `[sim] threads`; >= 1). The node space is sharded into contiguous
    /// index ranges (lattice cut planes) and every thread count produces
    /// **bit-identical** results — same `Debug` output, same
    /// `rng_digest` — because all in-run draws come from counter-based
    /// per-node streams and cross-shard effects are merged in node-index
    /// order at a cycle barrier (DESIGN.md §Parallel-engine; pinned by
    /// `rust/tests/parallel_differential.rs`). The default of 1 is the
    /// serial differential reference, the way `ScanMode::FullScan` is
    /// for the active-set scan.
    pub threads: usize,
    /// Explicit dead links as unordered endpoint pairs `(u, v)` in node
    /// indices (`--fault-links u-v,u-v,...` / `[sim] fault_links`). Both
    /// directions of the physical link die together. Endpoints must be
    /// adjacent in the topology — validation happens where the graph is
    /// known (`Simulator::with_table` asserts; the CLI turns violations
    /// into errors first). Empty (the default) together with zero fault
    /// rates and no dead nodes means the fault machinery is entirely
    /// inert: the engine is bit-identical to the fault-free build
    /// (pinned by `rust/tests/fault_properties.rs`).
    pub fault_links: Vec<(u32, u32)>,
    /// Explicit dead nodes (`--fault-nodes n,n,...` / `[sim]
    /// fault_nodes`). A dead node loses every incident link, never
    /// injects, and is excluded as a destination by fault-aware traffic.
    pub fault_nodes: Vec<u32>,
    /// Random link fault rate in `[0, 1]` (`--link-fault-rate`): each
    /// undirected link independently dies with this probability, drawn
    /// from a dedicated construction-time stream keyed by `seed` — the
    /// draw order is canonical (node-major), so a fault set depends only
    /// on `(seed, rate, topology)`, never on thread count or scan mode.
    pub link_fault_rate: f64,
    /// Random node fault rate in `[0, 1]` (`--node-fault-rate`); same
    /// deterministic derivation as [`link_fault_rate`](Self::link_fault_rate).
    pub node_fault_rate: f64,
    /// Per-thread serial fast-path cutoff for the parallel engine
    /// (`--serial-cutoff` / `[sim] serial_cutoff`). A cycle whose
    /// active-work estimate — active-list length under
    /// `ScanMode::ActiveSet`, the node count under `ScanMode::FullScan`
    /// — is below `threads × serial_cutoff` runs its arbitration phase
    /// on the calling thread and skips the barrier round-trip entirely.
    /// Bit-identical by construction: the whole-range serial scan emits
    /// effects in exactly the shard-merge order (DESIGN.md
    /// §Parallel-engine), so only wall-clock changes. 0 disables the
    /// fast path (every cycle is sharded; the differential suites use
    /// this to pin the sharded path on small networks). The default of
    /// 64 active nodes per thread keeps `--threads 4` from losing to
    /// the serial engine on near-idle networks and dependency-chain
    /// tails; the decision is observable via the `engine` execution
    /// profile on results.
    pub serial_cutoff: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            packet_size: 16,
            num_vcs: 2,
            queue_packets: 4,
            injection_queue_packets: 6,
            bubble: true,
            warmup_cycles: 2_000,
            measure_cycles: 10_000,
            drain_cycles: 0,
            seed: 0x1ce_b00da,
            transit_priority: true,
            send_overhead: 0,
            recv_overhead: 0,
            packet_gap: 0,
            route_policy: RoutePolicy::Dor,
            link_latency: 1,
            axis_widths: Vec::new(),
            scan_mode: ScanMode::ActiveSet,
            trace: None,
            sample_every: 0,
            threads: 1,
            fault_links: Vec::new(),
            fault_nodes: Vec::new(),
            link_fault_rate: 0.0,
            node_fault_rate: 0.0,
            serial_cutoff: 64,
        }
    }
}

impl SimConfig {
    /// A fast configuration for unit tests and CI benches.
    ///
    /// Carries a small nonzero drain so packets injected near the end of
    /// the short measurement window still get their latencies recorded
    /// (with `drain_cycles: 0` the latency tail is silently truncated —
    /// see the `drain_records_straggler_latencies` engine test).
    pub fn fast() -> Self {
        Self {
            warmup_cycles: 300,
            measure_cycles: 1_500,
            drain_cycles: 200,
            ..Self::default()
        }
    }

    /// Buffer capacity in phits per VC queue.
    pub fn queue_phits(&self) -> u32 {
        self.queue_packets * self.packet_size
    }

    /// Physical channel width of `axis` (1 when unspecified).
    pub fn axis_width(&self, axis: usize) -> u32 {
        self.axis_widths.get(axis).copied().unwrap_or(1)
    }

    /// Most virtual channels a `dim`-dimensional topology supports: the
    /// engine's per-node occupancy bitmask is 64 bits wide, one bit per
    /// (input port × VC) queue, so `2 * dim * num_vcs <= 64`. The single
    /// source of the bound for both the engine assert and CLI validation.
    pub fn max_vcs(dim: usize) -> usize {
        64 / (2 * dim.max(1))
    }

    /// Link serialization time in cycles for one packet on `axis`: a
    /// `w`-wide channel moves `w` phits per cycle, so the tail clears in
    /// `ceil(packet_size / w)` cycles (never less than one).
    pub fn serialization_cycles(&self, axis: usize) -> u64 {
        self.packet_size.div_ceil(self.axis_width(axis).max(1)).max(1) as u64
    }

    /// True when any fault source is configured. The engine keeps every
    /// fault check behind this predicate, so a fault-free config runs the
    /// historical code paths — and draw sequences — untouched.
    pub fn has_faults(&self) -> bool {
        !self.fault_links.is_empty()
            || !self.fault_nodes.is_empty()
            || self.link_fault_rate > 0.0
            || self.node_fault_rate > 0.0
    }
}

/// Parse a `--fault-links` spec: comma-separated `u-v` endpoint pairs,
/// e.g. `3-7,12-0`. Returns a diagnosable message (not a panic) on
/// malformed pairs, self-links, or non-numeric ids; adjacency is checked
/// later, where the graph is known.
pub fn parse_fault_links(spec: &str) -> Result<Vec<(u32, u32)>, String> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let Some((a, b)) = part.split_once('-') else {
            return Err(format!("bad link spec {part:?} (want u-v, e.g. 3-7)"));
        };
        let u: u32 = a
            .trim()
            .parse()
            .map_err(|_| format!("bad node id {:?} in link spec {part:?}", a.trim()))?;
        let v: u32 = b
            .trim()
            .parse()
            .map_err(|_| format!("bad node id {:?} in link spec {part:?}", b.trim()))?;
        if u == v {
            return Err(format!("link spec {part:?} is a self-link"));
        }
        out.push((u, v));
    }
    if out.is_empty() {
        return Err(format!("empty fault-links spec {spec:?}"));
    }
    Ok(out)
}

/// Parse a `--fault-nodes` spec: comma-separated node ids, e.g. `4,9`.
pub fn parse_fault_nodes(spec: &str) -> Result<Vec<u32>, String> {
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let n: u32 =
            part.parse().map_err(|_| format!("bad node id {part:?} in fault-nodes spec"))?;
        out.push(n);
    }
    if out.is_empty() {
        return Err(format!("empty fault-nodes spec {spec:?}"));
    }
    Ok(out)
}

/// Validate a fault rate parsed from the CLI or a config file: must be a
/// finite probability in `[0, 1]`.
pub fn check_fault_rate(name: &str, rate: f64) -> Result<(), String> {
    if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
        return Err(format!("{name} {rate} out of range (want a probability in [0, 1])"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_defaults() {
        let c = SimConfig::default();
        assert_eq!(c.packet_size, 16);
        // Deliberate Table 3 deviation: 2 VCs (escape + adaptive), not 3.
        assert_eq!(c.num_vcs, 2);
        assert_eq!(c.queue_packets, 4);
        assert_eq!(c.injection_queue_packets, 6);
        assert!(c.bubble);
        assert!(c.transit_priority);
        assert_eq!(c.measure_cycles, 10_000);
        // Software overheads default off: the pure Table 3 hardware model.
        assert_eq!(c.send_overhead, 0);
        assert_eq!(c.recv_overhead, 0);
        assert_eq!(c.packet_gap, 0);
        // Routing/link extensions default to the historical engine.
        assert_eq!(c.route_policy, RoutePolicy::Dor);
        assert_eq!(c.link_latency, 1);
        assert!(c.axis_widths.is_empty());
        // The activity-proportional scan is the default engine path.
        assert_eq!(c.scan_mode, ScanMode::ActiveSet);
        // Telemetry defaults off: the bit-identical untraced engine.
        assert_eq!(c.trace, None);
        assert_eq!(c.sample_every, 0);
        // Serial engine by default: the parallel differential reference.
        assert_eq!(c.threads, 1);
        // Fast-path cutoff: 64 active nodes per thread (0 = always shard).
        assert_eq!(c.serial_cutoff, 64);
        // Fault model defaults off: the pristine Cayley graph.
        assert!(c.fault_links.is_empty());
        assert!(c.fault_nodes.is_empty());
        assert_eq!(c.link_fault_rate, 0.0);
        assert_eq!(c.node_fault_rate, 0.0);
        assert!(!c.has_faults());
    }

    #[test]
    fn has_faults_tracks_every_source() {
        let d = SimConfig::default();
        assert!(SimConfig { fault_links: vec![(0, 1)], ..d.clone() }.has_faults());
        assert!(SimConfig { fault_nodes: vec![3], ..d.clone() }.has_faults());
        assert!(SimConfig { link_fault_rate: 0.01, ..d.clone() }.has_faults());
        assert!(SimConfig { node_fault_rate: 0.5, ..d }.has_faults());
    }

    #[test]
    fn fault_links_spec_parses() {
        assert_eq!(parse_fault_links("3-7").unwrap(), vec![(3, 7)]);
        assert_eq!(parse_fault_links("3-7,12-0, 1-2 ").unwrap(), vec![(3, 7), (12, 0), (1, 2)]);
    }

    /// Negative paths: every malformed spec must produce a diagnosable
    /// error string, never a panic deep in the engine.
    #[test]
    fn fault_links_spec_rejects_malformed_input() {
        for bad in ["", ",", "3", "3-", "-7", "a-b", "3-7-9", "3-x", "4-4", "1.5-2"] {
            let err = parse_fault_links(bad).expect_err(&format!("accepted {bad:?}"));
            assert!(!err.is_empty(), "{bad:?} produced an empty diagnostic");
        }
        // The self-link diagnostic names the offending pair.
        assert!(parse_fault_links("4-4").unwrap_err().contains("4-4"));
    }

    #[test]
    fn fault_nodes_spec_parses_and_rejects() {
        assert_eq!(parse_fault_nodes("4").unwrap(), vec![4]);
        assert_eq!(parse_fault_nodes("4, 9,0").unwrap(), vec![4, 9, 0]);
        for bad in ["", ",", "x", "1,-2", "1,2.5"] {
            assert!(parse_fault_nodes(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn fault_rate_range_checked() {
        assert!(check_fault_rate("--link-fault-rate", 0.0).is_ok());
        assert!(check_fault_rate("--link-fault-rate", 1.0).is_ok());
        assert!(check_fault_rate("--link-fault-rate", 0.25).is_ok());
        for bad in [-0.1, 1.01, f64::NAN, f64::INFINITY] {
            let err = check_fault_rate("--node-fault-rate", bad);
            assert!(err.is_err(), "accepted rate {bad}");
            assert!(err.unwrap_err().contains("--node-fault-rate"));
        }
    }

    #[test]
    fn scan_mode_parses() {
        assert_eq!(ScanMode::parse("active"), Some(ScanMode::ActiveSet));
        assert_eq!(ScanMode::parse("ACTIVE-SET"), Some(ScanMode::ActiveSet));
        assert_eq!(ScanMode::parse("full"), Some(ScanMode::FullScan));
        assert_eq!(ScanMode::parse("fullscan"), Some(ScanMode::FullScan));
        assert_eq!(ScanMode::parse("bogus"), None);
        for m in ScanMode::ALL {
            assert_eq!(ScanMode::parse(m.name()), Some(m));
        }
    }

    #[test]
    fn queue_phits() {
        assert_eq!(SimConfig::default().queue_phits(), 64);
    }

    #[test]
    fn max_vcs_tracks_occupancy_bitmask() {
        // 64 occupancy bits / (2 ports per axis): 10 VCs at dim 3, 5 at
        // the engine's MAX_DIM of 6; the degenerate dim 0 cannot divide
        // by zero.
        assert_eq!(SimConfig::max_vcs(3), 10);
        assert_eq!(SimConfig::max_vcs(6), 5);
        assert_eq!(SimConfig::max_vcs(0), 32);
    }

    #[test]
    fn axis_serialization() {
        let c = SimConfig { axis_widths: vec![2, 1, 5], ..SimConfig::default() };
        assert_eq!(c.axis_width(0), 2);
        assert_eq!(c.axis_width(1), 1);
        assert_eq!(c.axis_width(3), 1, "missing axes default to width 1");
        assert_eq!(c.serialization_cycles(0), 8);
        assert_eq!(c.serialization_cycles(1), 16);
        assert_eq!(c.serialization_cycles(2), 4, "16/5 rounds up");
        assert_eq!(c.serialization_cycles(5), 16);
        let wide = SimConfig { axis_widths: vec![64], ..SimConfig::default() };
        assert_eq!(wide.serialization_cycles(0), 1, "clamped to one cycle");
    }
}
