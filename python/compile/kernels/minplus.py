"""L1 Pallas kernel: blocked min-plus matrix product (tropical semiring).

``C[i, j] = min_k (A[i, k] + B[k, j])``

This is the inner step of APSP-by-repeated-squaring: if ``D_t`` holds the
shortest distances using at most ``t`` intermediate expansions, then
``minplus(D_t, D_t)`` holds distances using at most ``2t``, so
``ceil(log2(N))`` squarings of the one-hop matrix yield all-pairs shortest
paths.

TPU mapping (see DESIGN.md §Hardware-Adaptation): min-plus has no
multiply-accumulate, so it cannot use the MXU; it is a VPU kernel. The
BlockSpec tiles (bm, bk) x (bk, bn) panels into VMEM with the reduction
dimension ``k`` as the *innermost* grid axis, accumulating elementwise
``min`` into the resident output block — the same HBM<->VMEM schedule a
blocked GEMM would use, with ``min``/``+`` in place of ``+``/``*``.

The kernel MUST be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain HLO
that round-trips through the HLO-text AOT path (see aot.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Large-but-finite "infinity" for f32 distance matrices. Using an actual
# jnp.inf would work for min/+, but finite sentinels keep inf-inf NaN hazards
# out of downstream subtractions and compare identically through HLO text.
INF = jnp.float32(1e9)

# Default block sizes. 128 matches the TPU lane width (and MXU tile edge);
# 8 sublanes x 128 lanes is the f32 VREG shape, so (128, 128) f32 blocks are
# layout-aligned and three resident blocks (A, B, C panels) occupy
# 3 * 64 KiB = 192 KiB of VMEM — comfortably within a 16 MiB VMEM budget
# with room for double buffering.
DEFAULT_BLOCK = 128


def _minplus_kernel(a_ref, b_ref, c_ref):
    """One (i, j, k) grid step: c[i, j] = min(c[i, j], minplus(a[i,k], b[k,j])).

    Grid iteration order makes ``k`` innermost, so ``c_ref`` stays resident
    in VMEM across the whole reduction for a given (i, j) tile.
    """
    k = pl.program_id(2)

    # (bm, bk, 1) + (1, bk, bn) broadcast -> (bm, bk, bn); reduce-min over k.
    # Materializing the broadcast inside the block keeps it in VMEM/VREGs.
    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)
    partial = jnp.min(a[:, :, None] + b[None, :, :], axis=1)  # (bm, bn)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = partial

    @pl.when(k != 0)
    def _accum():
        c_ref[...] = jnp.minimum(c_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("block",))
def minplus(a: jax.Array, b: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Min-plus product of two square f32 matrices via the Pallas kernel.

    Shapes must be (n, n) with n divisible by ``block`` (aot.py pads to the
    artifact size; callers inside model.py always satisfy this).
    """
    n = a.shape[0]
    assert a.shape == (n, n) and b.shape == (n, n), (a.shape, b.shape)
    bs = min(block, n)
    assert n % bs == 0, f"n={n} not divisible by block={bs}"
    grid = (n // bs, n // bs, n // bs)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bs), lambda i, j, k: (i, k)),
            pl.BlockSpec((bs, bs), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bs, bs), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)


def minplus_square(d: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """One APSP squaring step: d <- minplus(d, d)."""
    return minplus(d, d, block=block)
