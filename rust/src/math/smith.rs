//! Smith normal form: `S = U M V` with `U, V` unimodular and `S`
//! diagonal with `d_1 | d_2 | ... | d_n`.
//!
//! The invariant factors `d_i` describe the quotient group
//! `Z^n / M Z^n ≅ Z_{d_1} × ... × Z_{d_n}` (Fiol [16]), giving a *group*
//! isomorphism invariant for lattice graphs: isomorphic `G(M)` necessarily
//! share invariant factors (the converse needs the generator images too),
//! so differing SNFs are a cheap non-isomorphism certificate used by the
//! topology layer and tests.

use super::matrix::IMat;

/// Result of a Smith reduction: `s = u * m * v`.
#[derive(Clone, Debug)]
pub struct SnfResult {
    pub s: IMat,
    pub u: IMat,
    pub v: IMat,
}

/// Compute the Smith normal form of a non-singular square matrix.
pub fn smith_normal_form(m: &IMat) -> SnfResult {
    let n = m.dim();
    assert!(m.det() != 0, "smith_normal_form: singular matrix");
    let mut s = m.clone();
    let mut u = IMat::identity(n);
    let mut v = IMat::identity(n);

    for k in 0..n {
        loop {
            // Find the minimal-|.| nonzero entry in the trailing block and
            // move it to (k, k).
            let mut piv: Option<(usize, usize)> = None;
            for i in k..n {
                for j in k..n {
                    if s[(i, j)] != 0 {
                        piv = match piv {
                            None => Some((i, j)),
                            Some(p) if s[(i, j)].abs() < s[p].abs() => Some((i, j)),
                            keep => keep,
                        };
                    }
                }
            }
            let (pi, pj) = piv.expect("singular during SNF");
            if pi != k {
                s.swap_rows(k, pi);
                u.swap_rows(k, pi);
            }
            if pj != k {
                s.swap_cols(k, pj);
                v.swap_cols(k, pj);
            }
            // Clear row k and column k by the pivot.
            let mut dirty = false;
            for i in k + 1..n {
                let q = s[(i, k)] / s[(k, k)];
                if q != 0 {
                    add_row_multiple(&mut s, i, k, -q);
                    add_row_multiple(&mut u, i, k, -q);
                }
                if s[(i, k)] != 0 {
                    dirty = true;
                }
            }
            for j in k + 1..n {
                let q = s[(k, j)] / s[(k, k)];
                if q != 0 {
                    s.add_col_multiple(j, k, -q);
                    v.add_col_multiple(j, k, -q);
                }
                if s[(k, j)] != 0 {
                    dirty = true;
                }
            }
            if dirty {
                continue;
            }
            // Divisibility: the pivot must divide every trailing entry.
            let mut fixed = true;
            'scan: for i in k + 1..n {
                for j in k + 1..n {
                    if s[(i, j)] % s[(k, k)] != 0 {
                        // Fold row i into row k and retry.
                        add_row_multiple(&mut s, k, i, 1);
                        add_row_multiple(&mut u, k, i, 1);
                        fixed = false;
                        break 'scan;
                    }
                }
            }
            if fixed {
                break;
            }
        }
        if s[(k, k)] < 0 {
            negate_row(&mut s, k);
            negate_row(&mut u, k);
        }
    }
    debug_assert!(is_smith(&s), "SNF postcondition: {s:?}");
    debug_assert_eq!(u.mul(m).mul(&v), s);
    SnfResult { s, u, v }
}

fn add_row_multiple(m: &mut IMat, a: usize, b: usize, k: i64) {
    for j in 0..m.cols() {
        let v = m[(b, j)];
        m[(a, j)] += k * v;
    }
}

fn negate_row(m: &mut IMat, i: usize) {
    for j in 0..m.cols() {
        m[(i, j)] = -m[(i, j)];
    }
}

/// Is `s` in Smith normal form?
pub fn is_smith(s: &IMat) -> bool {
    let n = s.dim();
    for i in 0..n {
        for j in 0..n {
            if i != j && s[(i, j)] != 0 {
                return false;
            }
        }
        if s[(i, i)] <= 0 {
            return false;
        }
        if i > 0 && s[(i, i)] % s[(i - 1, i - 1)] != 0 {
            return false;
        }
    }
    true
}

/// Invariant factors of `Z^n / M Z^n` (the SNF diagonal).
pub fn invariant_factors(m: &IMat) -> Vec<i64> {
    let r = smith_normal_form(m);
    (0..m.dim()).map(|i| r.s[(i, i)]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, pc};

    #[test]
    fn diag_already_smith_when_divisible() {
        let m = IMat::diag(&[2, 4, 8]);
        let r = smith_normal_form(&m);
        assert_eq!(r.s, m);
    }

    #[test]
    fn diag_reorders_to_divisibility() {
        let m = IMat::diag(&[4, 6]);
        // invariant factors of Z_4 x Z_6 = Z_2 x Z_12
        assert_eq!(invariant_factors(&m), vec![2, 12]);
    }

    #[test]
    fn crystals_group_structure() {
        // PC(a): Z_a^3.
        assert_eq!(invariant_factors(pc(4).matrix()), vec![4, 4, 4]);
        // FCC(a): |det| = 2a^3; for a=2: order 16.
        let f = invariant_factors(fcc(2).matrix());
        assert_eq!(f.iter().product::<i64>(), 16);
        // BCC(a): order 4a^3; a=2 -> 32.
        let b = invariant_factors(bcc(2).matrix());
        assert_eq!(b.iter().product::<i64>(), 32);
        // divisibility chains
        for w in [f, b] {
            for i in 1..w.len() {
                assert_eq!(w[i] % w[i - 1], 0, "{w:?}");
            }
        }
    }

    #[test]
    fn snf_invariant_under_unimodular_actions() {
        let m = fcc(3).matrix().clone();
        let p = IMat::from_rows(&[&[1, 2, 0], &[0, 1, 0], &[3, 0, 1]]); // unimodular
        assert!(p.is_unimodular());
        assert_eq!(invariant_factors(&m), invariant_factors(&p.mul(&m)));
        assert_eq!(invariant_factors(&m), invariant_factors(&m.mul(&p)));
    }

    #[test]
    fn snf_distinguishes_nonisomorphic_groups() {
        // T(4,4) vs T(8,2): same order, different groups.
        let a = invariant_factors(&IMat::diag(&[4, 4]));
        let b = invariant_factors(&IMat::diag(&[8, 2]));
        assert_ne!(a, b);
    }

    #[test]
    fn random_matrices_roundtrip() {
        let mut rng = crate::sim::rng::Rng::new(31337);
        let mut tested = 0;
        while tested < 60 {
            let n = 2 + rng.below(3);
            let data: Vec<i64> = (0..n * n).map(|_| rng.below(11) as i64 - 5).collect();
            let m = IMat::from_flat(n, &data);
            if m.det() == 0 {
                continue;
            }
            let r = smith_normal_form(&m);
            assert!(is_smith(&r.s), "{:?}", r.s);
            assert!(r.u.is_unimodular() && r.v.is_unimodular());
            assert_eq!(r.u.mul(&m).mul(&r.v), r.s);
            let prod: i64 = (0..n).map(|i| r.s[(i, i)]).product();
            assert_eq!(prod, m.det().abs());
            tested += 1;
        }
    }
}
