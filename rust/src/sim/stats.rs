//! Simulation measurement: accepted load, latency statistics.
//!
//! Latency percentiles come from an HDR-style log-bucketed histogram
//! ([`LatencyStats`]): exact below 64 cycles, then 32 sub-buckets per
//! octave, which bounds the relative error of any reported percentile by
//! the bucket width — ≤ 1/32 ≈ 3.2%, comfortably inside the documented
//! ≤ 5% bound (pinned by the `hdr_*` tests below against exact
//! sorted-sample percentiles). Mean, max and count are exact
//! accumulators, untouched by the bucketing.

use super::telemetry::{EngineProfile, StallCounters};

/// Result of one simulation run at one offered load.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Offered load (phits/cycle/node).
    pub offered_load: f64,
    /// Accepted throughput (phits/cycle/node) over the measurement window.
    pub accepted_load: f64,
    /// Mean packet latency (cycles, injection to full reception) over
    /// packets delivered in the window.
    pub avg_latency: f64,
    /// Median latency (HDR estimate, ≤ 5% relative error).
    pub p50_latency: f64,
    /// 90th-percentile latency (HDR estimate, ≤ 5% relative error).
    pub p90_latency: f64,
    /// 99th-percentile latency (HDR estimate, ≤ 5% relative error).
    pub p99_latency: f64,
    /// 99.9th-percentile latency (HDR estimate, ≤ 5% relative error).
    pub p999_latency: f64,
    /// Max observed latency.
    pub max_latency: u64,
    /// Packets delivered in the window.
    pub delivered_packets: u64,
    /// Packets whose latency was recorded: injected inside the window and
    /// delivered before the run ended (drain cycles extend this set to the
    /// stragglers; see `SimConfig::drain_cycles`).
    pub measured_packets: u64,
    /// Packets generated but dropped at a full source queue.
    pub source_dropped: u64,
    /// Total packets injected into the network during the whole run.
    pub injected_packets: u64,
    /// Per-dimension link utilization over the window: fraction of
    /// link-cycle capacity occupied by phits in each axis (2N
    /// unidirectional links per axis; a `w`-wide axis carries `w` phits
    /// per link-cycle). Backs the §3.4 resource-usage analysis.
    pub link_utilization: Vec<f64>,
    /// Utilization per directed port class (`2·dim` entries in
    /// `+e1, -e1, +e2, ...` order, aggregated over nodes): separates the
    /// two directions of each axis, which `link_utilization` folds
    /// together. Route-selection policies move load between these classes.
    pub port_utilization: Vec<f64>,
    /// Balance of the individual directed links: max/mean utilization over
    /// all `N·2·dim` links in the window (1.0 = perfectly balanced; 0.0
    /// when nothing moved). Fixed DOR ordering on asymmetric tori drives
    /// this up; the adaptive policies are measured by how far they pull it
    /// back down.
    pub link_util_spread: f64,
    /// Phits transferred per virtual channel in the window (`num_vcs`
    /// entries). When the escape protocol is live (adaptive policy,
    /// `num_vcs >= 2`), entry 0 is the escape lane, so
    /// `vc_phits[0] / vc_phits.sum()` is the fraction of hop traffic that
    /// had to drain through the deadlock-free DOR channel.
    pub vc_phits: Vec<u64>,
    /// Whole-run stall-cause attribution (credit-starved / link-busy /
    /// bubble-blocked; NIC serialization is closed-loop-only and stays 0
    /// here) plus the escape-drain count — see
    /// [`StallCounters`](crate::sim::telemetry::StallCounters).
    pub stalls: StallCounters,
    /// Measurement window length (cycles).
    pub cycles: u64,
    /// Node count.
    pub nodes: usize,
    /// RNG fingerprint of the run: the sequential setup stream's
    /// end-state digest combined with the commutative per-node
    /// counter-stream fingerprint (see [`crate::sim::rng`]). Two runs
    /// with equal digests consumed the identical draw sequences; the
    /// scan-mode and thread-count differential tests pin on it.
    pub rng_digest: u64,
    /// Total draws consumed from the per-node counter streams
    /// (arbitration visits + injection processes). Idle nodes consume
    /// none, so this is the direct measure of the engine's
    /// activity-proportional RNG cost (a zero-load run reports 0).
    pub rng_draws: u64,
    /// Parallel-engine execution profile (serial-fast-path vs. sharded
    /// cycles). Debug-opaque by design: the schedule differs across
    /// thread counts while every other field stays bit-identical (see
    /// [`EngineProfile`]).
    pub engine: EngineProfile,
}

impl SimResult {
    /// Fraction of hop traffic carried by the escape channel (VC 0), in
    /// `[0, 1]`; 0.0 when nothing moved. Only meaningful when the escape
    /// protocol is live (adaptive policy, `num_vcs >= 2`).
    pub fn escape_share(&self) -> f64 {
        escape_share(&self.vc_phits)
    }
}

/// VC-0 share of a per-VC phit histogram (0.0 when nothing moved) — the
/// one definition behind [`SimResult::escape_share`] and
/// [`WorkloadOutcome::escape_share`](crate::workload::WorkloadOutcome).
pub fn escape_share(vc_phits: &[u64]) -> f64 {
    let total: u64 = vc_phits.iter().sum();
    if total == 0 {
        0.0
    } else {
        vc_phits.first().copied().unwrap_or(0) as f64 / total as f64
    }
}

/// Sub-bucket resolution: `2^SUB_BITS` buckets per octave.
const SUB_BITS: u32 = 5;
/// Exact-bucket region: one bucket per value below `2^(SUB_BITS + 1)`.
const EXACT: usize = 1 << (SUB_BITS + 1);
/// Bucket count covering the whole `u64` range with no overflow bucket:
/// the top value (exponent 63) maps to index `NBUCKETS - 1`.
const NBUCKETS: usize = (65 - SUB_BITS as usize) << SUB_BITS; // 60 octave groups · 32 = 1920

/// Bucket index of `v` (values clamp up to 1; 0 shares bucket 1).
#[inline]
fn bucket_of(v: u64) -> usize {
    let v = v.max(1);
    let exp = 63 - v.leading_zeros();
    if exp <= SUB_BITS {
        v as usize
    } else {
        (((exp - SUB_BITS + 1) << SUB_BITS) + ((v >> (exp - SUB_BITS)) as u32 & 31)) as usize
    }
}

/// Lowest value mapping to bucket `i` (buckets tile `u64` contiguously).
#[inline]
fn bucket_low(i: usize) -> u64 {
    if i < EXACT {
        i as u64
    } else {
        let oct = (i >> SUB_BITS) - 1;
        ((32 + (i & 31)) as u64) << oct
    }
}

/// Width of bucket `i` in values.
#[inline]
fn bucket_width(i: usize) -> u64 {
    if i < EXACT {
        1
    } else {
        1u64 << ((i >> SUB_BITS) - 1)
    }
}

/// Online latency accumulator: exact mean/max/count plus an HDR-style
/// log-bucketed histogram for percentiles (≤ 5% relative error; see the
/// module docs for the bound).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    count: u64,
    sum: u64,
    max: u64,
    /// Log-bucketed histogram: exact below 64, then 32 sub-buckets per
    /// octave; covers all of `u64` with no overflow bucket.
    hist: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self { count: 0, sum: 0, max: 0, hist: vec![0; NBUCKETS] }
    }

    #[inline]
    pub fn record(&mut self, latency: u64) {
        self.count += 1;
        self.sum += latency;
        self.max = self.max.max(latency);
        self.hist[bucket_of(latency)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Percentile estimate: the midpoint of the bucket holding the
    /// `ceil(count · p)`-th smallest sample. The bucket spans at most
    /// `low/32` values, so the estimate is within ~1.6% of every sample
    /// in the bucket (≤ 5% documented bound).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (self.count as f64 * p).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (bucket_low(i) + (bucket_width(i) - 1) / 2) as f64;
            }
        }
        self.max as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(0.99), 0.0);
    }

    #[test]
    fn mean_and_max() {
        let mut s = LatencyStats::new();
        for l in [10u64, 20, 30] {
            s.record(l);
        }
        assert_eq!(s.mean(), 20.0);
        assert_eq!(s.max(), 30);
        assert_eq!(s.count(), 3);
    }

    #[test]
    fn percentile_monotone() {
        let mut s = LatencyStats::new();
        for l in 0..1000u64 {
            s.record(l);
        }
        let p50 = s.percentile(0.5);
        let p99 = s.percentile(0.99);
        assert!(p50 < p99);
        assert!((p50 - 500.0).abs() < 10.0, "p50={p50}");
        assert!((p99 - 990.0).abs() < 12.0, "p99={p99}");
    }

    #[test]
    fn overflow_bucket() {
        let mut s = LatencyStats::new();
        s.record(1_000_000);
        assert_eq!(s.max(), 1_000_000);
        assert!(s.percentile(1.0) >= 4096.0);
    }

    /// The buckets tile `u64` contiguously: every value maps to the
    /// bucket whose `[low, low + width)` range contains it, boundaries
    /// included, across the exact→log transition and up to `u64::MAX`.
    #[test]
    fn hdr_buckets_tile_the_value_range() {
        for v in 0..10_000u64 {
            let i = bucket_of(v);
            let lo = bucket_low(i);
            assert!(
                lo <= v.max(1) && v.max(1) < lo + bucket_width(i),
                "v={v} bucket={i} lo={lo} w={}",
                bucket_width(i)
            );
            if v.max(1) > 1 {
                assert!(bucket_of(v.max(1)) >= bucket_of(v.max(1) - 1), "monotone at {v}");
            }
        }
        for v in [1u64 << 32, (1 << 40) + 12345, u64::MAX / 3, u64::MAX] {
            let i = bucket_of(v);
            assert!(i < NBUCKETS);
            let lo = bucket_low(i);
            assert!(lo <= v && v - lo < bucket_width(i).max(1));
            // Relative bucket width ≤ 1/32 everywhere past the exact region.
            assert!(bucket_width(i) <= lo / 32 + 1, "width bound at {v}");
        }
        assert_eq!(bucket_of(u64::MAX), NBUCKETS - 1, "top value lands in the last bucket");
    }

    /// Deterministic xorshift for the synthetic-distribution tests (no
    /// external RNG crates in the offline build).
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x
    }

    /// HDR percentiles vs exact sorted-sample percentiles, with the
    /// documented ≤ 5% relative-error bound. The exact reference uses the
    /// same rank convention as `percentile` (the `ceil(count·p)`-th
    /// smallest sample).
    fn assert_hdr_close(samples: &[u64], what: &str) {
        let mut s = LatencyStats::new();
        let mut sorted = samples.to_vec();
        for &v in samples {
            s.record(v);
        }
        sorted.sort_unstable();
        for p in [0.5, 0.9, 0.99, 0.999] {
            let rank = ((samples.len() as f64 * p).ceil() as usize).max(1) - 1;
            let exact = sorted[rank] as f64;
            let est = s.percentile(p);
            let err = (est - exact).abs() / exact.max(1.0);
            assert!(err <= 0.05, "{what} p{p}: est {est} vs exact {exact} (err {err:.4})");
        }
    }

    #[test]
    fn hdr_matches_exact_percentiles_uniform() {
        let mut st = 0x1234_5678_9abc_def0u64;
        let samples: Vec<u64> = (0..20_000).map(|_| xorshift(&mut st) % 5_000 + 1).collect();
        assert_hdr_close(&samples, "uniform[1,5000]");
    }

    #[test]
    fn hdr_matches_exact_percentiles_bimodal() {
        // A low cut-through mode plus a congested mode 40x slower — the
        // shape saturating runs actually produce.
        let mut st = 0xfeed_f00d_dead_beefu64;
        let samples: Vec<u64> = (0..20_000)
            .map(|i| {
                if i % 10 < 7 {
                    40 + xorshift(&mut st) % 20
                } else {
                    1_600 + xorshift(&mut st) % 800
                }
            })
            .collect();
        assert_hdr_close(&samples, "bimodal");
    }

    #[test]
    fn hdr_matches_exact_percentiles_heavy_tail() {
        // Pareto-ish tail over ~4 decades: exactly where the old coarse
        // 4-cycle linear buckets lost the p99.9.
        let mut st = 0x0bad_cafe_1234_5678u64;
        let samples: Vec<u64> = (0..20_000)
            .map(|_| {
                let u = (xorshift(&mut st) % 1_000_000) as f64 / 1_000_000.0 + 1e-9;
                (20.0 / u.powf(0.7)) as u64
            })
            .collect();
        assert_hdr_close(&samples, "heavy-tail");
    }
}
