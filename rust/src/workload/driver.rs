//! Closed-loop workload driver: multi-seed completion-time measurement
//! over the cycle engine, parallelized like the load sweeps.

use crate::lattice::LatticeGraph;
use crate::sim::{SimConfig, Simulator};

use super::spec::{Workload, WorkloadOutcome};

/// One averaged completion-time measurement.
#[derive(Clone, Debug)]
pub struct CompletionPoint {
    pub topology: String,
    pub workload: String,
    pub messages: usize,
    /// Total payload over the workload's messages, in phits.
    pub total_phits: u64,
    /// Mean cycles-to-drain over the seeds.
    pub completion_cycles: f64,
    /// Mean effective bandwidth (phits/cycle/node).
    pub effective_bandwidth: f64,
    pub avg_latency: f64,
    /// Mean median-latency over the seeds (each seed's p50 is an HDR
    /// estimate, ≤ 5% relative error).
    pub p50_latency: f64,
    pub p99_latency: f64,
    /// Mean 99.9th-percentile latency over the seeds.
    pub p999_latency: f64,
    /// Stall-cause attribution **summed** over the seeds (counts, not
    /// means: the per-cause shares are the meaningful figures, and sums
    /// keep them exact integers).
    pub stalls: crate::sim::StallCounters,
    /// Mean max/mean per-link utilization spread over the seeds — the
    /// closed-loop balance column (ROADMAP §3.4 at the application level).
    pub link_util_spread: f64,
    /// Mean VC-0 share of hop traffic over the seeds. Only meaningful
    /// when the escape protocol is live (non-DOR policy, `num_vcs >= 2`
    /// — gate on [`Simulator::escape_active`](crate::sim::Simulator)):
    /// otherwise VC 0 is a plain lane and this is just its traffic share
    /// (1.0 on single-VC runs, ~1/num_vcs under DOR).
    pub escape_share: f64,
    /// Every seed drained before its cycle cap.
    pub drained: bool,
    pub seeds: usize,
}

/// Driver configuration (the completion-time analogue of
/// [`crate::coordinator::LoadSweep`]).
#[derive(Clone, Debug)]
pub struct WorkloadRunner {
    /// Simulator parameters.
    pub sim: SimConfig,
    /// Seeds averaged per point.
    pub seeds: usize,
    /// Worker threads for the seed fan-out (0 = auto).
    pub workers: usize,
    /// Cycle cap override (default: [`Workload::suggested_max_cycles_for`]).
    pub max_cycles: Option<u64>,
}

impl Default for WorkloadRunner {
    fn default() -> Self {
        Self { sim: SimConfig::default(), seeds: 1, workers: 0, max_cycles: None }
    }
}

impl WorkloadRunner {
    /// Build a simulator for `g` and measure `wl` on it.
    pub fn run(&self, topology: &str, g: &LatticeGraph, wl: &Workload) -> CompletionPoint {
        let sim = Simulator::for_workload(g.clone(), self.sim.clone());
        self.run_with(&sim, topology, wl)
    }

    /// Measure over a prebuilt simulator — every seed reuses its shared
    /// [`TopologyArtifacts`](crate::sim::TopologyArtifacts) bundle.
    pub fn run_with(&self, sim: &Simulator, topology: &str, wl: &Workload) -> CompletionPoint {
        if let Err(e) = wl.validate() {
            panic!("invalid workload {}: {e}", wl.name);
        }
        // Derive the cap from the simulator actually running the workload:
        // a prebuilt `sim` may carry different overhead knobs than the
        // runner's own config, and the cap must cover *its* dynamics.
        let cap = self
            .max_cycles
            .unwrap_or_else(|| wl.suggested_max_cycles_for(sim.config()));
        let seeds = self.seeds.max(1);
        let base = self.sim.seed;
        let outcomes: Vec<WorkloadOutcome> = par_map(seeds, self.workers, |s| {
            let seed = base.wrapping_add((s as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            sim.run_workload_seeded(wl, seed, cap)
        });
        let k = outcomes.len() as f64;
        CompletionPoint {
            topology: topology.to_string(),
            workload: wl.name.clone(),
            messages: wl.len(),
            total_phits: wl.total_phits(),
            completion_cycles: outcomes.iter().map(|o| o.completion_cycles as f64).sum::<f64>() / k,
            effective_bandwidth: outcomes.iter().map(|o| o.effective_bandwidth()).sum::<f64>() / k,
            avg_latency: outcomes.iter().map(|o| o.avg_latency).sum::<f64>() / k,
            p50_latency: outcomes.iter().map(|o| o.p50_latency).sum::<f64>() / k,
            p99_latency: outcomes.iter().map(|o| o.p99_latency).sum::<f64>() / k,
            p999_latency: outcomes.iter().map(|o| o.p999_latency).sum::<f64>() / k,
            stalls: {
                let mut s = crate::sim::StallCounters::default();
                for o in &outcomes {
                    s.accumulate(&o.stalls);
                }
                s
            },
            link_util_spread: outcomes.iter().map(|o| o.link_util_spread).sum::<f64>() / k,
            escape_share: outcomes.iter().map(|o| o.escape_share()).sum::<f64>() / k,
            drained: outcomes.iter().all(|o| o.drained),
            seeds,
        }
    }
}

/// Order-preserving parallel map over `0..n` (re-exported from
/// [`crate::util::pool`], the scoped pool the parallel cycle engine also
/// builds on). Used by the runner for seed fan-out and by the
/// coordinator experiments for (topology × workload) job fan-out.
pub use crate::util::par_map;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::torus;
    use crate::workload::gen::{generate, WorkloadKind, WorkloadParams};

    fn quick() -> SimConfig {
        SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() }
    }

    #[test]
    fn par_map_matches_serial_in_order() {
        let serial: Vec<usize> = (0..37).map(|i| i * i).collect();
        assert_eq!(par_map(37, 4, |i| i * i), serial);
        assert_eq!(par_map(3, 1, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn runner_measures_stencil() {
        let g = torus(&[4, 4]);
        let wl = generate(WorkloadKind::Stencil, &g, &WorkloadParams { iters: 2, ..Default::default() });
        let runner = WorkloadRunner { sim: quick(), seeds: 2, workers: 2, ..Default::default() };
        let p = runner.run("T(4,4)", &g, &wl);
        assert!(p.drained, "stencil must drain");
        assert_eq!(p.messages, 2 * 16 * 4);
        assert_eq!(p.total_phits, 2 * 16 * 4 * 16, "default payload is 16 phits/message");
        assert!(p.completion_cycles > 16.0, "completion {}", p.completion_cycles);
        assert!(p.effective_bandwidth > 0.0);
        assert_eq!(p.seeds, 2);
    }

    #[test]
    fn seed_fanout_is_deterministic() {
        let g = torus(&[4, 4]);
        let wl = generate(WorkloadKind::Permutation, &g, &WorkloadParams { iters: 3, ..Default::default() });
        let runner = WorkloadRunner { sim: quick(), seeds: 3, workers: 3, ..Default::default() };
        let a = runner.run("T(4,4)", &g, &wl);
        let b = runner.run("T(4,4)", &g, &wl);
        assert_eq!(a.completion_cycles, b.completion_cycles);
        assert_eq!(a.avg_latency, b.avg_latency);
    }

    #[test]
    #[should_panic(expected = "invalid workload")]
    fn invalid_workload_panics() {
        use crate::workload::{Workload, WorkloadMessage};
        let g = torus(&[4, 4]);
        let wl = Workload {
            name: "bad".into(),
            nodes: 16,
            messages: vec![WorkloadMessage::new(3, 3, 0, vec![])],
        };
        WorkloadRunner { sim: quick(), ..Default::default() }.run("T(4,4)", &g, &wl);
    }
}
