//! Packet creation and source enqueue: the geometric inter-arrival draw
//! behind the open-loop Bernoulli process, the shared
//! route-allocate-enqueue path used by both injection regimes (including
//! the virtual-channel draw — adaptive packets start on an adaptive VC,
//! never on the reserved escape lane), and the route-selection policy
//! dispatch with its escape-commitment override.
//!
//! Every draw here comes from a node's *injection* stream
//! (`st.inj_rng[u]`, keyed [`crate::sim::rng::STREAM_INJECT`]): a
//! persistent counter stream whose position advances only when the node
//! actually injects — an idle node consumes zero RNG state, and the
//! sequence is independent of scan mode and thread count.

use crate::sim::fault::FaultSet;
use crate::sim::policy::dor_port;
use crate::sim::rng::{Draw, NodeRng};

use super::state::{Fifo, Packet, State};
use super::{Simulator, MAX_DIM};

/// Draw the gap (in cycles, ≥ 1) until a Bernoulli(`prob`) process next
/// fires, by inverse transform of the geometric distribution:
/// `P(gap = g) = (1-prob)^(g-1) · prob`. Sampling the gaps instead of one
/// trial per cycle reproduces the *exact* per-cycle Bernoulli law (the
/// gap chain and the trial chain induce the same process) while drawing
/// RNG state only at arrivals. `None` means the next arrival is
/// effectively never (numerically > 1e18 cycles, including `prob = 0`).
pub(super) fn geometric_gap(rng: &mut NodeRng, prob: f64) -> Option<u64> {
    if prob >= 1.0 {
        return Some(1); // fires every cycle; no draw needed
    }
    if !(prob > 0.0) {
        return None; // never fires (zero/negative/NaN load); no draw either
    }
    let u = rng.f64();
    // Inverse CDF: gap = ceil(ln(1-u) / ln(1-prob)); u = 0 gives 0,
    // clamped up to the minimum legal gap of one cycle.
    let g = ((1.0 - u).ln() / (1.0 - prob).ln()).ceil();
    if !(g < 1e18) {
        return None; // overflow guard (u rounded to 1.0)
    }
    Some((g as u64).max(1))
}

impl Simulator {
    /// Route, allocate and source-enqueue one packet from `u` to `dest`
    /// (shared by the open-loop arrival calendar and the closed-loop
    /// workload driver). Draws from `u`'s injection stream. The caller
    /// must ensure the source queue has room.
    ///
    /// Under a fault set, records are drawn uniformly among the
    /// *admissible* minimal ties (`Simulator::record_admissible` — the
    /// degraded-mode admission gate); `None` means no minimal record can
    /// deliver the pair and nothing was enqueued or drawn. On a pristine
    /// network the gate does not exist and the result is always `Some`,
    /// with the exact historical draw sequence.
    pub(super) fn new_packet(
        &self,
        st: &mut State,
        u: usize,
        dest: usize,
        scratch: &mut [i64],
    ) -> Option<u32> {
        // Difference label -> routing tie set -> random minimal record.
        for (i, s) in scratch.iter_mut().enumerate() {
            *s = self.art.labels[dest * self.dim + i] - self.art.labels[u * self.dim + i];
        }
        self.art.graph().reduce_in_place(scratch);
        let diff_idx = self.art.graph().index_of(scratch);
        let ties = self.art.routes.ties(diff_idx);
        let record = match self.faults.as_deref() {
            None => ties[st.inj_rng[u].below(ties.len())],
            Some(f) => {
                // Two-pass draw over the admissible ties (count, then
                // index) — no allocation, and an undeliverable pair
                // consumes zero RNG state, so skipped arrivals stay
                // deterministic across scan modes and thread counts.
                let live = ties.iter().filter(|r| self.record_admissible(f, u, r)).count();
                if live == 0 {
                    return None;
                }
                let pick = st.inj_rng[u].below(live);
                *ties
                    .iter()
                    .filter(|r| self.record_admissible(f, u, r))
                    .nth(pick)
                    .expect("admissible tie count changed between passes")
            }
        };
        // VC draw: with the escape protocol live, packets inject on a
        // uniformly random *adaptive* VC (VC 0 is reserved for escapes);
        // otherwise on any VC — one RNG draw either way, so `Dor` (and
        // any single-VC configuration) draws the same stream positions as
        // the escape configurations.
        let vc = if self.escape_active() {
            (1 + st.inj_rng[u].below(self.cfg.num_vcs - 1)) as u8
        } else {
            st.inj_rng[u].below(self.cfg.num_vcs) as u8
        };
        let next_port = self.route_port(u, &record, vc as usize, &st.inputs, &mut st.inj_rng[u]);
        let pid = self.alloc_packet(
            st,
            Packet {
                record,
                vc,
                inject_time: st.now,
                head_ready: st.now,
                next_port,
            },
            dest as u32,
        );
        let icap = self.cfg.injection_queue_packets as usize;
        let base = u * icap;
        st.inj[u].push(&mut st.inj_slots[base..base + icap], pid, st.now, next_port);
        // The source now holds queued traffic: put it on the arbitration
        // worklist before this cycle's Phase-B scan (the driver merges
        // pending activations after Phase A, so a packet ready at
        // `st.now` is seen this cycle — exactly when the full scan would
        // first move it).
        st.active_nodes.insert(u);
        if st.trace.is_some() {
            let now = st.now;
            if let Some(tr) = st.trace.as_mut() {
                tr.inject(now, pid, u, dest, vc);
            }
        }
        Some(pid)
    }

    #[inline]
    pub(super) fn alloc_packet(&self, st: &mut State, p: Packet, dest: u32) -> u32 {
        if let Some(pid) = st.free_pids.pop() {
            st.packets[pid as usize] = p;
            st.dests[pid as usize] = dest;
            pid
        } else {
            st.packets.push(p);
            st.dests.push(dest);
            (st.packets.len() - 1) as u32
        }
    }

    /// Route-selection policy dispatch: the output port for a packet at
    /// `node` whose remaining record is `record`, riding virtual channel
    /// `vc`. A packet on VC 0 while the escape protocol is live is
    /// committed to the escape lane: it takes the DOR port, RNG-free,
    /// regardless of the configured policy. Otherwise the headroom
    /// closure exposes the downstream free slots behind each output port
    /// on the packet's VC (only `AdaptiveMin` calls it); `Dor` consumes
    /// no RNG. `rng` is the stream of the *deciding* node: the injection
    /// stream at packet creation, the forwarding node's per-cycle
    /// arbitration stream at each hop.
    #[inline]
    pub(super) fn route_port(
        &self,
        node: usize,
        record: &[i16; MAX_DIM],
        vc: usize,
        inputs: &[Fifo],
        rng: &mut NodeRng,
    ) -> u8 {
        if let Some(f) = self.faults.as_deref() {
            return self.route_port_masked(f, node, record, vc, inputs, rng);
        }
        if vc == 0 && self.escape_active() {
            return dor_port(record, self.dim, self.ports);
        }
        let cap = self.cfg.queue_packets;
        let vcc = self.cfg.num_vcs;
        self.cfg.route_policy.select_port(
            record,
            self.dim,
            self.ports,
            |p| {
                let v = self.art.neighbor[node * self.ports + p] as usize;
                let fifo = &inputs[(v * self.ports + p) * vcc + vc];
                cap.saturating_sub(fifo.reserved as u32)
            },
            rng,
        )
    }

    /// Degraded-mode [`route_port`](Self::route_port): the productive
    /// set is masked to hops that keep a live DOR completion
    /// (`Simulator::hop_allowed`), so a requested port is never a dead
    /// link and never a live link into a region the packet could not
    /// leave. VC 0 under the escape protocol stays committed to plain
    /// DOR — by the suffix-liveness invariant its port is live for every
    /// reachable packet state, which is exactly what makes the escape
    /// drain safe under damage. An empty masked set is an invariant
    /// violation (admission guarantees at least one allowed hop, and
    /// every allowed hop preserves that), so it panics loudly rather
    /// than wedging the run.
    fn route_port_masked(
        &self,
        f: &FaultSet,
        node: usize,
        record: &[i16; MAX_DIM],
        vc: usize,
        inputs: &[Fifo],
        rng: &mut NodeRng,
    ) -> u8 {
        if vc == 0 && self.escape_active() {
            let p = dor_port(record, self.dim, self.ports);
            debug_assert!(
                p as usize == self.ports || self.dor_suffix_live(f, node, record),
                "escape packet at node {node} lost its live DOR completion"
            );
            return p;
        }
        let cap = self.cfg.queue_packets;
        let vcc = self.cfg.num_vcs;
        self.cfg
            .route_policy
            .select_port_masked(
                record,
                self.dim,
                self.ports,
                |axis| self.hop_allowed(f, node, record, axis),
                |p| {
                    let v = self.art.neighbor[node * self.ports + p] as usize;
                    let fifo = &inputs[(v * self.ports + p) * vcc + vc];
                    cap.saturating_sub(fifo.reserved as u32)
                },
                rng,
            )
            .unwrap_or_else(|| {
                panic!(
                    "fault-routing invariant violated: node {node} has no live productive \
                     hop for record {record:?} (vc {vc})"
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::STREAM_INJECT;

    #[test]
    fn geometric_gap_is_at_least_one_cycle() {
        let mut rng = NodeRng::new(11, 0, STREAM_INJECT);
        for prob in [0.01, 0.3, 0.9, 1.0, 1.5] {
            for _ in 0..200 {
                let g = geometric_gap(&mut rng, prob).expect("positive prob fires");
                assert!(g >= 1, "gap {g} at prob {prob}");
            }
        }
    }

    #[test]
    fn geometric_gap_never_fires_at_zero_load() {
        let mut rng = NodeRng::new(11, 0, STREAM_INJECT);
        assert_eq!(geometric_gap(&mut rng, 0.0), None);
        assert_eq!(geometric_gap(&mut rng, -0.5), None);
        assert_eq!(rng.draws, 0, "zero load must not consume RNG state");
    }

    #[test]
    fn geometric_gap_matches_bernoulli_mean() {
        // Mean gap of Bernoulli(p) arrivals is 1/p; the inverse-transform
        // sampler must reproduce it (law equality is asserted end-to-end
        // by tests/parallel_differential.rs).
        for prob in [0.05f64, 0.25, 0.5] {
            let mut rng = NodeRng::new(42, 9, STREAM_INJECT);
            let n = 20_000u64;
            let total: u64 = (0..n).map(|_| geometric_gap(&mut rng, prob).unwrap()).sum();
            let mean = total as f64 / n as f64;
            let expect = 1.0 / prob;
            assert!(
                (mean - expect).abs() / expect < 0.05,
                "prob {prob}: mean gap {mean}, expected {expect}"
            );
        }
    }

    #[test]
    fn deterministic_gap_at_saturation() {
        // prob >= 1 fires every cycle without consuming RNG state.
        let mut rng = NodeRng::new(1, 2, STREAM_INJECT);
        assert_eq!(geometric_gap(&mut rng, 1.0), Some(1));
        assert_eq!(rng.draws, 0);
    }
}
