//! Closed-form router dispatch: recognize the catalog families from the
//! Hermite normal form and route them with their Remark 33 closed forms
//! instead of the generic hierarchical recursion.
//!
//! The Hermite form is the canonical representative of the
//! right-equivalence class, so recognition is a literal shape match on
//! `g.hermite()` (any generator matrix of the family — symmetric crystal
//! form or upper-triangular — classifies identically):
//!
//! - diagonal                                  → [`TorusRouter`] (`nD-PC`
//!   and every mixed-radix torus);
//! - `[[2a, a...a], [0, aI]]`                  → [`FccNdRouter`]
//!   (`nD-FCC`; `n = 2` is the RTT);
//! - `diag(2a, ..., 2a, a)` with last column `a` → [`BccNdRouter`]
//!   (`nD-BCC`);
//! - anything else                             → [`HierarchicalRouter`]
//!   (Algorithm 1 — exactly minimal for any lattice graph).
//!
//! The dispatched routers emit tie sets **record-for-record identical**
//! to the hierarchical builder's, order included — the engine draws
//! `rng.below(ties.len())` into them, so both count and order are
//! RNG-stream-load-bearing. The equality is pinned across the catalog by
//! `tests/routing_dispatch.rs`; no tie-order re-pin was needed.

use crate::lattice::LatticeGraph;

use super::hierarchical::HierarchicalRouter;
use super::nd::{BccNdRouter, FccNdRouter};
use super::torus::TorusRouter;
use super::{Record, Router};

/// The routing family a Hermite form classifies into.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RouterKind {
    /// Diagonal Hermite form: `T(a_1, ..., a_n)`.
    Torus { sides: Vec<i64> },
    /// `[[2a, a...a], [0, aI]]`: `nD-FCC(a)` (RTT when `n == 2`).
    FccNd { n: usize, a: i64 },
    /// `diag(2a, ..., 2a, a)` with last column `a`: `nD-BCC(a)`.
    BccNd { n: usize, a: i64 },
    /// Off-catalog: generic hierarchical routing.
    Hierarchical,
}

/// Classify a lattice graph by its Hermite normal form.
pub fn classify(g: &LatticeGraph) -> RouterKind {
    let n = g.dim();
    let h = g.hermite();
    let diagonal =
        (0..n).all(|i| (0..n).all(|j| i == j || h[(i, j)] == 0));
    if diagonal {
        return RouterKind::Torus { sides: g.box_sides().to_vec() };
    }
    // Both crystal shapes pivot on the small box side `a`. (`n == 2`
    // makes the two patterns the same matrix `[[2a, a], [0, a]]`; the
    // FCC arm claims it — that is the RTT.)
    if n >= 2 {
        let a = h[(n - 1, n - 1)];
        let fcc = a >= 1
            && h[(0, 0)] == 2 * a
            && (1..n).all(|j| h[(0, j)] == a)
            && (1..n).all(|i| (0..n).all(|j| h[(i, j)] == if i == j { a } else { 0 }));
        if fcc {
            return RouterKind::FccNd { n, a };
        }
        let bcc = a >= 1
            && (0..n - 1).all(|i| {
                h[(i, i)] == 2 * a
                    && h[(i, n - 1)] == a
                    && (0..n - 1).all(|j| i == j || h[(i, j)] == 0)
            })
            && (0..n - 1).all(|j| h[(n - 1, j)] == 0);
        if bcc {
            return RouterKind::BccNd { n, a };
        }
    }
    RouterKind::Hierarchical
}

/// A router chosen by [`classify`]: the catalog closed forms, or the
/// hierarchical fallback. Tie emission is record-for-record equal to
/// [`HierarchicalRouter`] in every arm.
pub enum DispatchRouter {
    Torus(TorusRouter),
    FccNd(FccNdRouter),
    BccNd(BccNdRouter),
    Hierarchical(HierarchicalRouter),
}

impl DispatchRouter {
    /// Build the best router for `g`.
    pub fn new(g: &LatticeGraph) -> Self {
        match classify(g) {
            RouterKind::Torus { .. } => Self::Torus(TorusRouter::new(g.clone())),
            RouterKind::FccNd { n, a } => {
                let r = FccNdRouter::new(n, a);
                debug_assert_eq!(r.graph().hermite(), g.hermite());
                Self::FccNd(r)
            }
            RouterKind::BccNd { n, a } => {
                let r = BccNdRouter::new(n, a);
                debug_assert_eq!(r.graph().hermite(), g.hermite());
                Self::BccNd(r)
            }
            RouterKind::Hierarchical => Self::Hierarchical(HierarchicalRouter::new(g.clone())),
        }
    }

    /// Which arm was chosen (for logs / tests).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Self::Torus(_) => "torus",
            Self::FccNd(_) => "fcc_nd",
            Self::BccNd(_) => "bcc_nd",
            Self::Hierarchical(_) => "hierarchical",
        }
    }
}

impl Router for DispatchRouter {
    fn graph(&self) -> &LatticeGraph {
        match self {
            Self::Torus(r) => r.graph(),
            Self::FccNd(r) => r.graph(),
            Self::BccNd(r) => r.graph(),
            Self::Hierarchical(r) => r.graph(),
        }
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        match self {
            Self::Torus(r) => r.route(src, dst),
            Self::FccNd(r) => r.route(src, dst),
            Self::BccNd(r) => r.route(src, dst),
            Self::Hierarchical(r) => r.route(src, dst),
        }
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        match self {
            Self::Torus(r) => r.route_ties(src, dst),
            Self::FccNd(r) => r.route_ties(src, dst),
            Self::BccNd(r) => r.route_ties(src, dst),
            Self::Hierarchical(r) => r.route_ties(src, dst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{
        bcc, bcc_nd, fcc, fcc_nd, hybrid_pc_bcc, pc, rtt, torus,
    };

    #[test]
    fn catalog_families_classify_to_their_closed_forms() {
        assert_eq!(classify(&pc(3)), RouterKind::Torus { sides: vec![3, 3, 3] });
        assert_eq!(
            classify(&torus(&[6, 4, 2])),
            RouterKind::Torus { sides: vec![6, 4, 2] }
        );
        assert_eq!(classify(&rtt(3)), RouterKind::FccNd { n: 2, a: 3 });
        for a in 1..4 {
            assert_eq!(classify(&fcc(a)), RouterKind::FccNd { n: 3, a });
            assert_eq!(classify(&bcc(a)), RouterKind::BccNd { n: 3, a });
        }
        assert_eq!(classify(&fcc_nd(5, 2)), RouterKind::FccNd { n: 5, a: 2 });
        assert_eq!(classify(&bcc_nd(4, 3)), RouterKind::BccNd { n: 4, a: 3 });
    }

    #[test]
    fn off_catalog_falls_back_to_hierarchical() {
        assert_eq!(classify(&hybrid_pc_bcc(2)), RouterKind::Hierarchical);
        // Example 10's matrix: torus-like but with a twist column.
        let g = crate::lattice::LatticeGraph::new(crate::math::IMat::from_rows(&[
            &[4, 0, 0],
            &[0, 4, 2],
            &[0, 0, 4],
        ]));
        assert_eq!(classify(&g), RouterKind::Hierarchical);
    }

    #[test]
    fn dispatch_router_arm_matches_classification() {
        assert_eq!(DispatchRouter::new(&pc(2)).kind_name(), "torus");
        assert_eq!(DispatchRouter::new(&rtt(2)).kind_name(), "fcc_nd");
        assert_eq!(DispatchRouter::new(&bcc(2)).kind_name(), "bcc_nd");
        assert_eq!(
            DispatchRouter::new(&hybrid_pc_bcc(2)).kind_name(),
            "hierarchical"
        );
    }
}
