//! Cycle-accurate interconnection-network simulator (paper §6.2).
//!
//! An INSEE-equivalent model rebuilt from the paper's Table 3 and §6.2
//! description (the original INSEE is a separate C code base that was not
//! released with the paper — see DESIGN.md §Substitutions):
//!
//! - synchronous, cycle-based; links move one phit per cycle per direction,
//! - **virtual cut-through**: a packet advances only when the downstream
//!   buffer can hold the *whole* packet; its head moves one hop per cycle
//!   while the 16-phit tail streams behind,
//! - **`num_vcs` virtual channels** per link (default 2). Under DOR they
//!   are plain parallel lanes assigned at injection and kept end-to-end;
//!   under the adaptive policies VC 0 is the **escape channel** (Duato's
//!   protocol): adaptive packets ride VCs ≥ 1, and a blocked packet
//!   drains into VC 0 where it follows deadlock-free DOR to its
//!   destination — see DESIGN.md §Virtual-channels,
//! - **bubble flow control** for deadlock freedom: entering a
//!   dimensional ring (from injection, a dimension turn, or a VC change)
//!   requires room for *two* packets downstream; continuing in-ring
//!   requires one,
//! - **pluggable route selection** ([`policy`]) over precomputed minimal
//!   routing records with random tie choice among minimal records
//!   (Remark 30): DOR service order (dimension 0 first — the default,
//!   bit-exact with the historical engine), random productive-axis order,
//!   or headroom-adaptive minimal routing (`SimConfig::route_policy`),
//! - **random arbitration** with in-transit traffic strictly prioritized
//!   over new injections (the BG/Q congestion-control behaviour §6.2
//!   notes),
//! - Bernoulli injection at offered load `l`: probability `l/s` per node
//!   per cycle of generating an `s = 16`-phit packet (realized as exact
//!   geometric inter-arrival gaps from per-node counter RNG streams —
//!   see [`rng`] and `engine::open_loop`),
//! - the LogGP `L` term (`SimConfig::link_latency`, per-hop wire latency
//!   in cycles) and per-axis physical channel widths
//!   (`SimConfig::axis_widths`: a `w`-wide axis serializes a packet in
//!   `ceil(s / w)` cycles — the paper's §6 bandwidth-asymmetry knob).
//!
//! Measured: accepted throughput in phits/(cycle·node) and mean packet
//! latency over a measurement window following a warmup. Latency samples
//! follow the packet's *injection* time, so configuring `drain_cycles > 0`
//! lets stragglers injected near the window's end contribute their tails.
//!
//! Besides the steady-state open loop, the engine has a **closed-loop
//! finite-workload mode** ([`Simulator::run_workload`]): a
//! dependency-ordered message set from [`crate::workload`] is injected as
//! its dependencies complete, and the run measures completion time.
//!
//! Every run additionally attributes *why* blocked packets stalled
//! (credit starvation vs. busy links vs. the bubble ring-entry condition
//! vs. NIC serialization — [`telemetry::StallCounters`], always on), and
//! can stream a packet-lifecycle JSONL trace with periodic network-state
//! probes (`SimConfig::trace` / `SimConfig::sample_every`) — see
//! [`telemetry`] and DESIGN.md §Telemetry. With tracing off the engine
//! is bit-identical to the untraced one (same results, same
//! `rng_digest`), pinned by `rust/tests/telemetry_differential.rs`.
//!
//! The cycle loop runs on `SimConfig::threads` threads (default 1) with
//! bit-identical results for every thread count — per-node counter RNG
//! streams plus a deterministic shard merge, with per-cycle work-balanced
//! shard plans and a serial fast path for light cycles
//! (`SimConfig::serial_cutoff`; decisions surfaced as
//! [`telemetry::EngineProfile`]); see `engine::parallel`, DESIGN.md
//! §Parallel-engine, and `rust/tests/parallel_differential.rs`.
//!
//! The network can run **degraded** ([`fault`], DESIGN.md §Fault-model):
//! `SimConfig` fault knobs (explicit dead links/nodes plus seeded random
//! fault rates) derive an immutable [`FaultSet`] at construction, route
//! selection masks itself to hops that keep a live DOR completion (so
//! every admitted packet is deliverable and no packet ever touches a
//! dead link or router), and injection skips dead or unreachable
//! endpoints deterministically. An empty fault set is bit-identical to
//! the unfaulted engine, pinned by `rust/tests/fault_properties.rs`.

pub mod artifacts;
pub mod config;
pub mod engine;
pub mod fault;
pub mod policy;
pub mod rng;
pub mod stats;
pub mod telemetry;
pub mod traffic;

pub use artifacts::TopologyArtifacts;
pub use config::{ScanMode, SimConfig};
pub use engine::Simulator;
pub use fault::FaultSet;
pub use policy::RoutePolicy;
pub use stats::SimResult;
pub use telemetry::{EngineProfile, StallCause, StallCounters};
pub use traffic::TrafficPattern;
