//! Exact distance computation on lattice graphs.
//!
//! All kernels run on the **flat plane**: a neighbor table
//! (`neighbor[u * ports + p]`, `p = 2*axis + (sign < 0)` — the exact
//! layout the engine's [`crate::sim::TopologyArtifacts`] shares) is
//! derived once per call, and the BFS loops then walk plain `u32` reads
//! instead of allocating a label vector and reducing `2n` coordinate
//! vectors per popped node. Callers that already hold a table (the
//! engine, the fault suite) use the `*_flat` variants directly.

use std::collections::VecDeque;

use crate::lattice::LatticeGraph;

/// Flat neighbor table of `g`: `ports = 2 * dim` entries per node,
/// `p = 2*axis + (sign < 0)` — the layout shared with the engine.
pub fn neighbor_table(g: &LatticeGraph) -> Vec<u32> {
    let dim = g.dim();
    let ports = 2 * dim;
    let n = g.order();
    let mut out = vec![0u32; n * ports];
    let mut tmp = vec![0i64; dim];
    for u in 0..n {
        let label = g.label_of(u);
        for axis in 0..dim {
            for (s, sign) in [(0usize, 1i64), (1, -1)] {
                tmp.copy_from_slice(&label);
                tmp[axis] += sign;
                g.reduce_in_place(&mut tmp);
                out[u * ports + 2 * axis + s] = g.index_of(&tmp) as u32;
            }
        }
    }
    out
}

/// Summary of a graph's distance structure.
#[derive(Clone, Debug, PartialEq)]
pub struct DistanceStats {
    /// Number of nodes.
    pub order: usize,
    /// Eccentricity histogram: `histogram[d]` = #nodes at distance `d`
    /// from the source (distribution is source-independent for
    /// vertex-transitive graphs).
    pub histogram: Vec<usize>,
    /// Graph diameter.
    pub diameter: usize,
    /// Average distance `k̄` over ordered pairs with distinct endpoints,
    /// matching the paper's convention (sum of distances / (N - 1)).
    pub avg_distance: f64,
}

/// Single-source BFS distances (u32::MAX for unreachable).
pub fn bfs_distances(g: &LatticeGraph, src: usize) -> Vec<u32> {
    bfs_distances_flat(&neighbor_table(g), 2 * g.dim(), src)
}

/// [`bfs_distances`] over a prebuilt flat neighbor table.
pub fn bfs_distances_flat(neighbor: &[u32], ports: usize, src: usize) -> Vec<u32> {
    let n = neighbor.len() / ports;
    let mut dist = vec![u32::MAX; n];
    let mut queue = VecDeque::with_capacity(n);
    dist[src] = 0;
    queue.push_back(src);
    while let Some(u) = queue.pop_front() {
        let du = dist[u];
        for &v in &neighbor[u * ports..(u + 1) * ports] {
            let v = v as usize;
            if dist[v] == u32::MAX {
                dist[v] = du + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// Distance distribution from node 0 (exact for vertex-transitive graphs,
/// which covers every topology in the paper).
pub fn distance_distribution(g: &LatticeGraph) -> DistanceStats {
    let dist = bfs_distances(g, 0);
    let diameter = *dist.iter().max().unwrap() as usize;
    assert!(
        diameter != u32::MAX as usize,
        "graph is disconnected; distance stats undefined"
    );
    let mut histogram = vec![0usize; diameter + 1];
    let mut sum = 0u64;
    for &d in &dist {
        histogram[d as usize] += 1;
        sum += d as u64;
    }
    let order = g.order();
    DistanceStats {
        order,
        histogram,
        diameter,
        avg_distance: sum as f64 / (order as f64 - 1.0),
    }
}

/// The most distant node from `src` (used by the `antipodal` traffic
/// pattern). Deterministic: smallest index among the maxima.
pub fn antipodal_of(g: &LatticeGraph, src: usize) -> usize {
    let dist = bfs_distances(g, src);
    let max = dist.iter().max().copied().unwrap();
    dist.iter().position(|&d| d == max).unwrap()
}

/// Single-source BFS distances on the *faulted* graph: `dead_node[v]`
/// removes a router and `dead_edge(u, axis, sign)` removes the directed
/// edge leaving `u` along `±axis` (matching
/// `crate::sim::FaultSet::is_edge_dead`, so the engine's fault set plugs
/// in without a `metrics → sim` dependency). Unreachable — including
/// every dead node, and everything when `src` itself is dead — is
/// `u32::MAX`.
///
/// This is the resilience oracle: the fault property suite compares the
/// engine's degraded-mode delivery against reachability in this graph.
pub fn bfs_distances_faulted(
    g: &LatticeGraph,
    src: usize,
    dead_node: &[bool],
    dead_edge: impl FnMut(usize, usize, i64) -> bool,
) -> Vec<u32> {
    bfs_distances_faulted_flat(&neighbor_table(g), 2 * g.dim(), src, dead_node, dead_edge)
}

/// [`bfs_distances_faulted`] over a prebuilt flat neighbor table. The
/// fault callback keeps the `(u, axis, sign)` interface; ports decode as
/// `axis = p / 2`, `sign = +1` for even `p`, `-1` for odd.
pub fn bfs_distances_faulted_flat(
    neighbor: &[u32],
    ports: usize,
    src: usize,
    dead_node: &[bool],
    mut dead_edge: impl FnMut(usize, usize, i64) -> bool,
) -> Vec<u32> {
    let n = neighbor.len() / ports;
    let mut dist = vec![u32::MAX; n];
    if dead_node[src] {
        return dist;
    }
    let mut queue = VecDeque::with_capacity(n);
    dist[src] = 0;
    queue.push_back(src);
    while let Some(u) = queue.pop_front() {
        let du = dist[u];
        for p in 0..ports {
            let sign = if p % 2 == 0 { 1i64 } else { -1 };
            if dead_edge(u, p / 2, sign) {
                continue;
            }
            let v = neighbor[u * ports + p] as usize;
            if !dead_node[v] && dist[v] == u32::MAX {
                dist[v] = du + 1;
                queue.push_back(v);
            }
        }
    }
    dist
}

/// Connected-component partition of the faulted graph (same fault
/// interface as [`bfs_distances_faulted`]): component id per node, with
/// `u32::MAX` for dead nodes. Ids are assigned in ascending order of each
/// component's smallest member, so the partition is canonical — two nodes
/// are mutually reachable through live hardware iff their ids are equal
/// and not `u32::MAX`. (Links are symmetric under the engine's fail-stop
/// model, so forward reachability is component membership.)
pub fn faulted_components(
    g: &LatticeGraph,
    dead_node: &[bool],
    dead_edge: impl FnMut(usize, usize, i64) -> bool,
) -> Vec<u32> {
    faulted_components_flat(&neighbor_table(g), 2 * g.dim(), dead_node, dead_edge)
}

/// [`faulted_components`] over a prebuilt flat neighbor table (port
/// decoding as in [`bfs_distances_faulted_flat`]).
pub fn faulted_components_flat(
    neighbor: &[u32],
    ports: usize,
    dead_node: &[bool],
    mut dead_edge: impl FnMut(usize, usize, i64) -> bool,
) -> Vec<u32> {
    let n = neighbor.len() / ports;
    let mut comp = vec![u32::MAX; n];
    let mut queue = VecDeque::new();
    let mut next_id = 0u32;
    for seed in 0..n {
        if dead_node[seed] || comp[seed] != u32::MAX {
            continue;
        }
        comp[seed] = next_id;
        queue.push_back(seed);
        while let Some(u) = queue.pop_front() {
            for p in 0..ports {
                let sign = if p % 2 == 0 { 1i64 } else { -1 };
                if dead_edge(u, p / 2, sign) {
                    continue;
                }
                let v = neighbor[u * ports + p] as usize;
                if !dead_node[v] && comp[v] == u32::MAX {
                    comp[v] = next_id;
                    queue.push_back(v);
                }
            }
        }
        next_id += 1;
    }
    comp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, pc, rtt, torus};

    #[test]
    fn neighbor_table_matches_graph_steps() {
        for g in [torus(&[5, 4]), bcc(2), rtt(3)] {
            let ports = 2 * g.dim();
            let nb = neighbor_table(&g);
            assert_eq!(nb.len(), g.order() * ports);
            for u in 0..g.order() {
                for axis in 0..g.dim() {
                    for (s, sign) in [(0usize, 1i64), (1, -1)] {
                        assert_eq!(
                            nb[u * ports + 2 * axis + s] as usize,
                            g.step(u, axis, sign),
                            "node {u} axis {axis} sign {sign}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ring_distances() {
        let g = torus(&[8]);
        let d = bfs_distances(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, 4, 3, 2, 1]);
    }

    #[test]
    fn torus_diameter() {
        // Diameter of T(a1, ..., an) = sum floor(ai/2).
        for sides in [vec![4i64, 4], vec![5, 3], vec![4, 4, 4], vec![6, 3, 2]] {
            let g = torus(&sides);
            let s = distance_distribution(&g);
            let expect: usize = sides.iter().map(|&a| (a / 2) as usize).sum();
            assert_eq!(s.diameter, expect, "{sides:?}");
        }
    }

    #[test]
    fn table1_diameters() {
        // Table 1: PC 3*floor(a/2); FCC floor(3a/2); BCC floor(3a/2).
        for a in 2..7i64 {
            assert_eq!(
                distance_distribution(&pc(a)).diameter,
                3 * (a / 2) as usize,
                "PC({a})"
            );
            assert_eq!(
                distance_distribution(&fcc(a)).diameter,
                (3 * a / 2) as usize,
                "FCC({a})"
            );
            assert_eq!(
                distance_distribution(&bcc(a)).diameter,
                (3 * a / 2) as usize,
                "BCC({a})"
            );
        }
    }

    #[test]
    fn table1_mixed_tori_diameters() {
        // T(2a,a,a): a + 2*floor(a/2); T(2a,2a,a): floor(5a/2).
        for a in 2..6i64 {
            assert_eq!(
                distance_distribution(&torus(&[2 * a, a, a])).diameter,
                (a + 2 * (a / 2)) as usize
            );
            assert_eq!(
                distance_distribution(&torus(&[2 * a, 2 * a, a])).diameter,
                (5 * a / 2) as usize
            );
        }
    }

    #[test]
    fn histogram_sums_to_order() {
        for g in [pc(3), fcc(3), bcc(2), rtt(4)] {
            let s = distance_distribution(&g);
            assert_eq!(s.histogram.iter().sum::<usize>(), g.order());
            assert_eq!(s.histogram[0], 1);
        }
    }

    #[test]
    fn faulted_bfs_matches_plain_bfs_without_faults() {
        let g = fcc(2);
        let dead = vec![false; g.order()];
        let plain = bfs_distances(&g, 3);
        let faulted = bfs_distances_faulted(&g, 3, &dead, |_, _, _| false);
        assert_eq!(plain, faulted);
        let comp = faulted_components(&g, &dead, |_, _, _| false);
        assert!(comp.iter().all(|&c| c == 0), "pristine graph is one component");
    }

    #[test]
    fn cutting_a_ring_splits_it_in_two() {
        // An 8-ring with both directed copies of edges (1,2) and (5,6)
        // dead: {2,3,4,5} and {6,7,0,1} become separate components.
        let g = torus(&[8]);
        let dead = vec![false; g.order()];
        let dead_edge = |u: usize, _axis: usize, sign: i64| {
            matches!((u, sign), (1, 1) | (2, -1) | (5, 1) | (6, -1))
        };
        let comp = faulted_components(&g, &dead, dead_edge);
        assert_eq!(comp, vec![0, 0, 1, 1, 1, 1, 0, 0]);
        let d = bfs_distances_faulted(&g, 0, &dead, dead_edge);
        assert_eq!(d[1], 1);
        assert_eq!(d[7], 1);
        assert_eq!(d[2], u32::MAX, "severed side unreachable");
        // Distances inside the surviving arc detour the long way round.
        let d = bfs_distances_faulted(&g, 2, &dead, dead_edge);
        assert_eq!(d[5], 3);
        assert_eq!(d[0], u32::MAX);
    }

    #[test]
    fn dead_node_is_unreachable_and_componentless() {
        let g = torus(&[4, 4]);
        let mut dead = vec![false; g.order()];
        dead[5] = true;
        let comp = faulted_components(&g, &dead, |_, _, _| false);
        assert_eq!(comp[5], u32::MAX, "dead node belongs to no component");
        assert!(
            (0..g.order()).filter(|&v| v != 5).all(|v| comp[v] == 0),
            "a 2D torus minus one node stays connected"
        );
        let d = bfs_distances_faulted(&g, 0, &dead, |_, _, _| false);
        assert_eq!(d[5], u32::MAX);
        // BFS from the dead node itself sees nothing.
        let d = bfs_distances_faulted(&g, 5, &dead, |_, _, _| false);
        assert!(d.iter().all(|&x| x == u32::MAX));
    }

    #[test]
    fn antipodal_is_at_diameter() {
        let g = fcc(2);
        let s = distance_distribution(&g);
        let anti = antipodal_of(&g, 0);
        assert_eq!(bfs_distances(&g, 0)[anti] as usize, s.diameter);
    }

    #[test]
    fn vertex_transitivity_spotcheck() {
        // Same distribution from several sources (Cayley ⇒ transitive).
        let g = bcc(2);
        let h0 = {
            let d = bfs_distances(&g, 0);
            let mut h = vec![0usize; 32];
            for &x in &d {
                h[x as usize] += 1;
            }
            h
        };
        for src in [1usize, 7, 19] {
            let d = bfs_distances(&g, src);
            let mut h = vec![0usize; 32];
            for &x in &d {
                h[x as usize] += 1;
            }
            assert_eq!(h, h0, "src={src}");
        }
    }
}
