//! Microbench: route-selection policies at saturation — engine speed per
//! policy (node-cycles/s; the adaptive policies pay a per-hop headroom
//! scan + RNG draw) and the accepted-throughput / link-balance comparison
//! the policy layer exists for, on the edge-asymmetric mixed-radix torus
//! vs the matched crystal.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::routing::RoutingTable;
use lattice_networks::sim::{RoutePolicy, SimConfig, Simulator, TrafficPattern};
use lattice_networks::topology;

fn main() {
    let mut b = Bench::new("policy_comparison");
    b.max_iters = 20;

    for (name, g) in [
        ("T(8,4,4)", topology::torus(&[8, 4, 4])),
        ("FCC(4)", topology::fcc(4)),
    ] {
        // One routing table per network, shared by the per-policy sims.
        let table = RoutingTable::build_hierarchical(&g);
        let nodes = g.order() as u64;
        for policy in RoutePolicy::ALL {
            let cfg = SimConfig {
                warmup_cycles: 500,
                measure_cycles: 2_000,
                route_policy: policy,
                ..SimConfig::default()
            };
            let cycles = cfg.warmup_cycles + cfg.measure_cycles;
            let sim = Simulator::with_table(g.clone(), &table, TrafficPattern::Uniform, cfg);
            b.run_throughput(
                &format!("{name}/{}@0.9", policy.name()),
                nodes * cycles,
                "node-cycles",
                || {
                    black_box(sim.run(0.9));
                },
            );
            // The headline numbers the policies are judged by: accepted
            // throughput at 90% offered load and the per-link balance.
            let r = sim.run(0.9);
            println!(
                "policy_comparison/{name}/{:<8} accepted {:.4} phits/cycle/node  \
                 spread {:.2}  p99 {:.0}",
                policy.name(),
                r.accepted_load,
                r.link_util_spread,
                r.p99_latency,
            );
        }
    }
}
