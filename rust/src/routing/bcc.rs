//! Algorithm 4: minimal routing in `BCC(a)`.
//!
//! Hierarchical over the projection `T(2a, 2a)`: `ord(e_3) = 2a`, two
//! intersections with the destination copy — offsets `(0, 0)` after `z'`
//! cycle hops and `(a, a)` after `z' - a`.
//!
//! **Erratum**: as printed, Algorithm 4 computes `ŷ := x + a(z<0)` and
//! `y' := x̂ + 2a(ŷ<0) - 2a(ŷ>=2a)`; both are obvious copy-paste slips for
//! `ŷ := y + ...` / `y' := ŷ + ...` (with them, the output would not even
//! be congruent to the input for `y != x`). The corrected algorithm is
//! implemented here and verified minimal against the BFS oracle for all
//! pairs and several `a`.

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;
use crate::topology::bcc as bcc_graph;

use super::torus::TorusRouter;
use super::{norm, Record, Router};

/// Closed-form minimal router for `BCC(a)` (labels in the Hermite box
/// `0 <= x, y < 2a, 0 <= z < a`).
pub struct BccRouter {
    g: LatticeGraph,
    a: i64,
}

impl BccRouter {
    pub fn new(a: i64) -> Self {
        Self { g: bcc_graph(a), a }
    }

    /// Corrected Algorithm 4 on a difference `(x, y, z) ∈ L - L`.
    pub fn route_diff(&self, x: i64, y: i64, z: i64) -> Record {
        let a = self.a;
        // Normalize into the box: lifting z by +a drags x and y by +a
        // (Hermite column 3 is (a, a, a)).
        let zp = z + a * i64::from(z < 0);
        let xh = x + a * i64::from(z < 0);
        let yh = y + a * i64::from(z < 0);
        let xp = rem_euclid(xh, 2 * a);
        let yp = rem_euclid(yh, 2 * a);
        debug_assert!(0 <= zp && zp < a);

        // Intersection 1: (0, 0) offset, z' cycle hops.
        let r1 = vec![
            TorusRouter::ring_route(xp, 2 * a),
            TorusRouter::ring_route(yp, 2 * a),
            zp,
        ];
        // Intersection 2: (a, a) offset, z' - a cycle hops.
        let r2 = vec![
            TorusRouter::ring_route(xp - a, 2 * a),
            TorusRouter::ring_route(yp - a, 2 * a),
            zp - a,
        ];
        if norm(&r1) <= norm(&r2) {
            r1
        } else {
            r2
        }
    }

    /// All minimal candidates (tie set).
    pub fn route_diff_ties(&self, x: i64, y: i64, z: i64) -> Vec<Record> {
        let a = self.a;
        let zp = z + a * i64::from(z < 0);
        let xh = x + a * i64::from(z < 0);
        let yh = y + a * i64::from(z < 0);
        let xp = rem_euclid(xh, 2 * a);
        let yp = rem_euclid(yh, 2 * a);
        let mut out: Vec<Record> = Vec::new();
        for (ox, oy, dz) in [(0i64, 0i64, zp), (a, a, zp - a)] {
            for rx in TorusRouter::ring_route_ties(xp - ox, 2 * a) {
                for ry in TorusRouter::ring_route_ties(yp - oy, 2 * a) {
                    out.push(vec![rx, ry, dz]);
                }
            }
        }
        let best = out.iter().map(|r| norm(r)).min().unwrap();
        out.retain(|r| norm(r) == best);
        out.dedup();
        out
    }
}

impl Router for BccRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        self.route_diff(dst[0] - src[0], dst[1] - src[1], dst[2] - src[2])
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        self.route_diff_ties(dst[0] - src[0], dst[1] - src[1], dst[2] - src[2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::is_valid_record;

    #[test]
    fn all_pairs_minimal_vs_oracle() {
        for a in 1..6i64 {
            let router = BccRouter::new(a);
            let g = router.graph().clone();
            let dist = crate::metrics::bfs_distances(&g, 0);
            let src = vec![0i64, 0, 0];
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r), "a={a} dst={dst:?}");
                assert_eq!(
                    norm(&r),
                    dist[v] as i64,
                    "a={a} dst={dst:?} got {r:?}"
                );
            }
        }
    }

    #[test]
    fn nonzero_sources() {
        let a = 3;
        let router = BccRouter::new(a);
        let g = router.graph().clone();
        for s in [[1i64, 5, 2], [3, 0, 1], [5, 5, 0]] {
            let dists = crate::metrics::bfs_distances(&g, g.index_of(&s));
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&s, &dst);
                assert!(is_valid_record(&g, &s, &dst, &r));
                assert_eq!(norm(&r), dists[v] as i64, "src={s:?} dst={dst:?}");
            }
        }
    }

    #[test]
    fn ties_all_minimal() {
        let a = 2;
        let router = BccRouter::new(a);
        let g = router.graph().clone();
        let dist = crate::metrics::bfs_distances(&g, 0);
        for v in 0..g.order() {
            let dst = g.label_of(v);
            for r in router.route_ties(&[0, 0, 0], &dst) {
                assert!(is_valid_record(&g, &[0, 0, 0], &dst, &r));
                assert_eq!(norm(&r), dist[v] as i64);
            }
        }
    }

    #[test]
    fn bcc_diameter_via_router() {
        // Max over all destinations of the routed norm = floor(3a/2).
        for a in 2..6i64 {
            let router = BccRouter::new(a);
            let g = router.graph().clone();
            let max = (0..g.order())
                .map(|v| norm(&router.route(&[0, 0, 0], &g.label_of(v))))
                .max()
                .unwrap();
            assert_eq!(max, 3 * a / 2);
        }
    }
}
