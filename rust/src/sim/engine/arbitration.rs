//! Per-node output arbitration and link transfers: the per-cycle scan
//! over non-empty input FIFOs plus the injection head, random arbitration
//! per output port (with optional strict transit-over-injection
//! priority), and the transfer commit that advances a packet one hop —
//! consuming one productive axis of its record via the route-selection
//! policy.
//!
//! The scan runs as the Phase-B kernel of the phased cycle driver
//! (`parallel.rs`), over one contiguous node shard per worker, in two
//! flavours ([`ScanMode`], DESIGN.md §Engine-performance). Both run the
//! same per-node kernel ([`Simulator::scan_node`]) so they are bit-exact
//! with each other:
//!
//! - **active-set** (the default): visit only the shard's slice of the
//!   maintained worklist of nodes with queued traffic, in ascending node
//!   order — per-cycle cost proportional to in-flight traffic, not
//!   network size;
//! - **full-scan**: visit every node of the shard every cycle — the
//!   historical reference path, retained for differential testing and
//!   baselines.
//!
//! The kernel is pure per node given the phase-start state: every draw
//! comes from the node's own counter stream (`NodeRng`, keyed by cycle),
//! node-owned state (its FIFOs, occupancy bits, link timers, popped
//! packets) is mutated in place, and every cross-node or global effect —
//! the downstream push, calendar events, stall counters, per-VC phits,
//! trace events — is deferred into the worker's [`ShardBuf`] for the
//! node-index-ordered Phase-C merge. Cross-shard *reads* (downstream
//! `reserved` for eligibility/headroom) need no snapshot because pushes
//! are deferred and releases happen only in Phase A.
//!
//! Winner slots are generation-stamped per node visit instead of being
//! cleared per node (the old O(ports) wipe), and only the ports that
//! actually received a candidate are fired.
//!
//! This is also where the escape protocol fires (DESIGN.md
//! §Virtual-channels): when the head of an adaptive-VC FIFO cannot move
//! through its preferred output, the scan retries the other productive
//! ports on the same VC (per-hop re-selection), and if *every* adaptive
//! request is blocked it offers the DOR port on VC 0 — the escape
//! channel — instead. The escape hop always counts as entering a new
//! ring, so the full 2-slot bubble is enforced on the escape lane.

use crate::sim::config::ScanMode;
use crate::sim::fault::FaultSet;
use crate::sim::policy::{dor_port, port_of};
use crate::sim::rng::{Draw, NodeRng};
use crate::sim::telemetry::StallCause;

use super::parallel::{Push, ShardBuf, TraceEv};
use super::state::{Event, State};
use super::Simulator;

/// Per-`advance` config reads, hoisted out of the per-node kernel.
struct ScanCtx<'a> {
    vcs: usize,
    cap: u32,
    qcap: usize,
    icap: usize,
    node_base: usize,
    transit_class: bool,
    escape_on: bool,
    /// Fault set, when the network is degraded (`None` on a pristine
    /// network — the fault branches below then cost one untaken test).
    /// Immutable for the life of the simulator, so reading it from any
    /// shard during Phase B is race-free and phase-constant.
    faults: Option<&'a FaultSet>,
}

impl Simulator {
    /// Arbitration + transfers for one cycle over worker `w`'s shard of
    /// the cycle plan `st.shard_plan` (Phase B; one call per worker per
    /// cycle — or one whole-range call from the serial fast path). The
    /// plan's ranges are node-id ranges under [`ScanMode::FullScan`] and
    /// index ranges into the frozen `active_nodes.list` under
    /// [`ScanMode::ActiveSet`] (see `State::shard_plan`).
    pub(super) fn advance_shard(
        &self,
        st: &mut State,
        buf: &mut ShardBuf,
        sc: &mut ArbScratch,
        w: usize,
    ) {
        let (lo, hi) = st.shard_plan[w];
        let cx = ScanCtx {
            vcs: self.cfg.num_vcs,
            cap: self.cfg.queue_packets,
            qcap: self.cfg.queue_packets as usize,
            icap: self.cfg.injection_queue_packets as usize,
            node_base: self.ports * self.cfg.num_vcs,
            // In-transit traffic outranks injection only when configured
            // (Table 3 / BG/Q behaviour); otherwise both compete in one
            // class.
            transit_class: self.cfg.transit_priority,
            escape_on: self.escape_active(),
            faults: self.faults.as_deref(),
        };
        match self.cfg.scan_mode {
            ScanMode::FullScan => {
                for u in lo..hi {
                    self.scan_node(st, buf, u as usize, sc, &cx);
                }
            }
            ScanMode::ActiveSet => {
                // The shard's slice of the sorted worklist (merged and
                // carved serially in Phase A, so both the list and the
                // plan are frozen here). The list is sorted and
                // duplicate-free, so disjoint index slices mean
                // disjoint node sets: every node-owned write — and the
                // membership flag cleared when a node is observed idle
                // — belongs to exactly one worker. The list itself is
                // compacted serially at the Phase-C merge.
                for i in lo as usize..hi as usize {
                    let u = st.active_nodes.list[i] as usize;
                    if !self.scan_node(st, buf, u, sc, &cx) {
                        st.active_nodes.member[u] = false;
                    }
                }
            }
        }
    }

    /// Arbitration + transfers for node `u`. Returns whether the node
    /// still holds queued traffic afterwards (the active-set keep
    /// criterion); an idle node returns `false` without touching any RNG
    /// — exactly the case the full scan skips, which is what lets the
    /// two scan modes (and every thread count) share one draw sequence.
    fn scan_node(
        &self,
        st: &mut State,
        buf: &mut ShardBuf,
        u: usize,
        sc: &mut ArbScratch,
        cx: &ScanCtx<'_>,
    ) -> bool {
        let mut mask = st.occ[u];
        let inj_head = st.inj[u].front(&st.inj_slots[u * cx.icap..(u + 1) * cx.icap]);
        if mask == 0 && inj_head.is_none() {
            return false; // idle node: nothing can move, no stream opened
        }
        // The node's arbitration stream for this cycle: draw `i` is a
        // pure hash of `(seed, u, now, i)`, so the sequence is identical
        // whichever thread runs the visit and whatever other nodes do.
        let mut rng = NodeRng::new(st.seed, u as u32, st.now);
        // One generation stamp per node visit: a winner slot whose stamp
        // is stale counts as empty, so no per-node O(ports) clear runs.
        sc.visit += 1;
        let visit = sc.visit;
        debug_assert!(sc.touched.is_empty());
        // Transit candidates: heads of the non-empty input FIFOs only.
        // Everything needed (ready time, output port, VC, bubble
        // "entering" test) is derivable from the FIFO entry itself; the
        // packet arena is touched only on the blocked escape path.
        while mask != 0 {
            let bit = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let fifo_idx = u * cx.node_base + bit;
            let fifo = st.inputs[fifo_idx];
            if fifo.head_ready > st.now {
                continue;
            }
            let port = fifo.head_port as usize;
            let vc = bit % cx.vcs;
            let in_axis = (bit / cx.vcs) / 2;
            let entering = port < self.ports && in_axis != port / 2;
            let (out_port, escape) = if self.eligible(st, u, port, entering, vc, cx.cap) {
                (port, false)
            } else if cx.escape_on && vc != 0 && port < self.ports {
                // Blocked adaptive head: re-select among the other
                // productive ports on its own VC, else drain into the
                // DOR escape channel (VC 0).
                let pid = st.input_slots[fifo_idx * cx.qcap + fifo.head as usize] as usize;
                let record = st.packets[pid].record;
                let mut pick = None;
                for (axis, &h) in record.iter().enumerate().take(self.dim) {
                    if h == 0 {
                        continue;
                    }
                    let p = port_of(axis, h) as usize;
                    if p == port {
                        continue;
                    }
                    // Degraded network: an alternative is only legal if it
                    // keeps a live DOR completion (the same mask the route
                    // policy applied when it picked the preferred port) —
                    // never steer a blocked head onto a dead link or into
                    // a region it could not finish from.
                    if let Some(f) = cx.faults {
                        if !self.hop_allowed(f, u, &record, axis) {
                            continue;
                        }
                    }
                    if self.eligible(st, u, p, axis != in_axis, vc, cx.cap) {
                        pick = Some((p, false));
                        break;
                    }
                }
                if pick.is_none() {
                    let eport = dor_port(&record, self.dim, self.ports) as usize;
                    // Every in-transit packet state keeps a live DOR
                    // completion (the suffix-liveness invariant: admission
                    // establishes it and every legal hop preserves it), so
                    // the escape port is live even under faults.
                    debug_assert!(
                        cx.faults.is_none_or(|f| self.dor_suffix_live(f, u, &record)),
                        "in-transit packet at node {u} lost its live DOR completion"
                    );
                    // An escape transfer always enters the VC-0 ring.
                    if self.eligible(st, u, eport, true, 0, cx.cap) {
                        pick = Some((eport, true));
                    }
                }
                let Some(pick) = pick else {
                    // Preferred port, every adaptive alternative and the
                    // escape lane all blocked: attribute the head's
                    // preferred request.
                    self.note_stall(st, buf, u, port, vc, cx.cap);
                    continue;
                };
                pick
            } else {
                self.note_stall(st, buf, u, port, vc, cx.cap);
                continue;
            };
            offer(
                &mut sc.winners[out_port],
                &mut sc.touched,
                out_port as u8,
                visit,
                cx.transit_class,
                Cand { fifo: fifo_idx as u32, is_inj: false, escape },
                &mut rng,
            );
        }
        // Injection candidate (always "entering" for the bubble rule).
        if let Some(pid) = inj_head {
            let fifo = &st.inj[u];
            if fifo.head_ready <= st.now {
                let port = fifo.head_port as usize;
                let vc = st.packets[pid as usize].vc as usize;
                if self.eligible(st, u, port, true, vc, cx.cap) {
                    offer(
                        &mut sc.winners[port],
                        &mut sc.touched,
                        port as u8,
                        visit,
                        false,
                        Cand { fifo: u as u32, is_inj: true, escape: false },
                        &mut rng,
                    );
                } else {
                    self.note_stall(st, buf, u, port, vc, cx.cap);
                }
            }
        }
        // Fire winners — only the ports that received a candidate, in
        // ascending port order (the order the full 0..=ports loop fired
        // them in, so the route-draw sequence is position-independent).
        sc.touched.sort_unstable();
        for &port in &sc.touched {
            let Some(cand) = sc.winners[port as usize].get(visit) else { continue };
            self.start_transfer(st, buf, u, port as usize, cand, &mut rng);
        }
        sc.touched.clear();
        // Fold the visit's draws into the shard fingerprint (commutative
        // across nodes, so the Phase-C merge order cannot matter).
        buf.digest = buf.digest.wrapping_add(rng.digest);
        buf.draws += rng.draws;
        // Keep criterion, evaluated after the transfers: forwarding the
        // last queued packet idles the node (dropped now, not next
        // cycle); an incoming push — even a self-loop — re-activates it
        // at the merge.
        st.occ[u] != 0 || st.inj[u].len > 0
    }

    /// Can the head packet move through output `port` of node `u` now,
    /// requesting virtual channel `vc` downstream? `entering` = the hop
    /// starts a new dimensional ring (bubble rule; ring identity is
    /// (axis direction, VC), so a VC change is always an entry).
    ///
    /// The downstream `reserved` count read here may belong to another
    /// shard: it is constant throughout Phase B (pushes are deferred to
    /// the merge, releases to Phase A's calendar drain), so the answer
    /// is independent of scan interleaving.
    #[inline]
    fn eligible(&self, st: &State, u: usize, port: usize, entering: bool, vc: usize, cap: u32) -> bool {
        if port == self.ports {
            // Ejection.
            return st.eject_busy[u] <= st.now;
        }
        if st.link_busy[u * self.ports + port] > st.now {
            return false;
        }
        let need = if self.cfg.bubble && entering { 2 } else { 1 };
        let v = self.art.neighbor[u * self.ports + port] as usize;
        let fifo = &st.inputs[(v * self.ports + port) * self.cfg.num_vcs + vc];
        (fifo.reserved as u32) + need <= cap
    }

    /// Attribute why [`eligible`](Self::eligible) just rejected this
    /// head's request through `port` on `vc`, bump the matching
    /// per-shard counter, and buffer a `stall` trace event when a trace
    /// is open. Only called on already-blocked paths; re-reads the state
    /// the eligibility check touched and draws no RNG, so it cannot
    /// perturb results. The causes mirror the check's order: busy link
    /// (or ejection channel) first, then missing credit, and — when a
    /// slot was free yet the head still failed — the bubble ring-entry
    /// rule (the only remaining way `eligible` says no).
    fn note_stall(&self, st: &State, buf: &mut ShardBuf, u: usize, port: usize, vc: usize, cap: u32) {
        let cause = if port == self.ports || st.link_busy[u * self.ports + port] > st.now {
            StallCause::LinkBusy
        } else {
            let v = self.art.neighbor[u * self.ports + port] as usize;
            let fifo = &st.inputs[(v * self.ports + port) * self.cfg.num_vcs + vc];
            if (fifo.reserved as u32) < cap {
                StallCause::BubbleBlocked
            } else {
                StallCause::CreditStarved
            }
        };
        buf.stalls.note(cause);
        if st.trace.is_some() {
            buf.trace.push(TraceEv::Stall {
                t: st.now,
                node: u,
                port: port as i64,
                vc: vc as i64,
                cause,
            });
        }
    }

    /// Commit a transfer of the head packet of `cand` through `port`.
    /// Node-owned state (the upstream FIFO, `u`'s occupancy/link/eject
    /// timers, the popped packet's arena entry, `u`'s per-link phit
    /// counters) is written directly; everything else goes through `buf`.
    fn start_transfer(
        &self,
        st: &mut State,
        buf: &mut ShardBuf,
        u: usize,
        port: usize,
        cand: Cand,
        rng: &mut NodeRng,
    ) {
        let ps = self.cfg.packet_size as u64;
        let vcs = self.cfg.num_vcs;
        let node_base = self.ports * vcs;
        let qcap = self.cfg.queue_packets as usize;
        let icap = self.cfg.injection_queue_packets as usize;
        // The tail clears the upstream slot once the packet has fully
        // serialized onto the chosen output: the axis serialization time
        // for a link, the ejection-channel time (`packet_size`) otherwise.
        let hold = if port == self.ports { ps } else { self.ser[port] };
        let pid = if cand.is_inj {
            let base = u * icap;
            let slots = &st.inj_slots[base..base + icap];
            let pid = st.inj[u].pop(slots);
            st.inj[u].refresh_head(slots, &st.packets);
            buf.events.push((hold, Event::FreeInj(u as u32)));
            pid
        } else {
            let fi = cand.fifo as usize;
            let base = fi * qcap;
            let slots = &st.input_slots[base..base + qcap];
            let pid = st.inputs[fi].pop(slots);
            st.inputs[fi].refresh_head(slots, &st.packets);
            if st.inputs[fi].len == 0 {
                st.occ[u] &= !(1u64 << (fi - u * node_base));
            }
            buf.events.push((hold, Event::FreeInput(cand.fifo)));
            pid
        };
        if port == self.ports {
            // Ejection: tail fully received at now + ps.
            debug_assert_eq!(st.dests[pid as usize] as usize, u, "eject at wrong node");
            if let Some(f) = self.faults.as_deref() {
                assert!(!f.is_node_dead(u), "fault violation: dead node {u} ejected packet {pid}");
            }
            st.eject_busy[u] = st.now + ps;
            buf.events.push((ps, Event::Deliver(pid)));
            return;
        }
        let axis = port / 2;
        let sign: i16 = if port % 2 == 0 { 1 } else { -1 };
        let v = self.art.neighbor[u * self.ports + port] as usize;
        // Hard safety net for every degraded run (release asserts — the
        // property suite and any faulted experiment self-check): no
        // transfer may ever drive a dead link or land in a dead router.
        if let Some(f) = self.faults.as_deref() {
            assert!(
                !f.is_link_dead(u, port),
                "fault violation: packet {pid} driven onto dead link ({u}, port {port})"
            );
            assert!(
                !f.is_node_dead(v),
                "fault violation: packet {pid} forwarded into dead node {v}"
            );
        }
        st.link_busy[u * self.ports + port] = st.now + hold;
        // Advance the record one hop; an escape transfer first rewrites
        // the packet's VC to 0, where it stays committed to DOR. The head
        // lands downstream after the wire latency, where the route policy
        // picks the next output port (for `AdaptiveMin`, using the
        // downstream headroom visible now — phase-constant, see
        // `eligible`).
        let lat = self.cfg.link_latency;
        if cand.escape {
            buf.stalls.escape_drains += 1;
        }
        let (vc, record) = {
            let pkt = &mut st.packets[pid as usize];
            if cand.escape {
                pkt.vc = 0;
            }
            pkt.record[axis] -= sign;
            pkt.head_ready = st.now + lat;
            (pkt.vc as usize, pkt.record)
        };
        if st.now >= st.measure_start && st.now < st.measure_end {
            st.phits_by_link[u * self.ports + port] += ps;
            buf.vc_phits[vc] += ps;
        }
        let next_port = self.route_port(v, &record, vc, &st.inputs, rng);
        st.packets[pid as usize].next_port = next_port;
        let fi = v * node_base + port * vcs + vc;
        // The enqueue itself crosses into `v`'s shard: deferred to the
        // node-index-ordered merge. At most one push targets any input
        // FIFO per cycle (one upstream producer per directed (link, VC),
        // serialized by `link_busy`), so merged pushes can never exceed
        // the capacity `eligible` checked.
        buf.pushes.push(Push { fi: fi as u32, pid });
        if st.trace.is_some() {
            buf.trace.push(TraceEv::Hop {
                t: st.now,
                land: st.now + lat,
                pid,
                from: u,
                to: v,
                port,
                vc: vc as u8,
                esc: cand.escape,
            });
        }
    }
}

/// Offer `cand` for `port`, refreshing the slot's generation stamp on the
/// first offer of this node visit (which is also when the port joins the
/// fire list).
#[inline]
fn offer(
    slot: &mut CandSlot,
    touched: &mut Vec<u8>,
    port: u8,
    visit: u64,
    is_transit: bool,
    cand: Cand,
    rng: &mut NodeRng,
) {
    if slot.visit != visit {
        *slot = CandSlot { visit, ..CandSlot::NONE };
        touched.push(port);
    }
    slot.offer(is_transit, cand, rng);
}

/// A transfer candidate: which FIFO holds it, and whether the transfer is
/// an escape (the packet moves onto VC 0 and commits to DOR).
#[derive(Clone, Copy, Debug)]
pub(super) struct Cand {
    pub(super) fifo: u32,
    pub(super) is_inj: bool,
    pub(super) escape: bool,
}

/// Reservoir-sampling winner slot per output port: random arbitration with
/// strict transit-over-injection priority (when the priority class is
/// asserted by the caller). Slots are generation-stamped by node visit —
/// a stale stamp means "empty", so the scan never wipes the slot array.
#[derive(Clone, Copy, Debug)]
pub(super) struct CandSlot {
    /// Node-visit generation this slot's contents belong to.
    visit: u64,
    cand: Option<Cand>,
    transit: bool,
    count: u32,
}

impl CandSlot {
    pub(super) const NONE: CandSlot = CandSlot { visit: 0, cand: None, transit: false, count: 0 };

    #[inline]
    fn offer(&mut self, is_transit: bool, cand: Cand, rng: &mut NodeRng) {
        if is_transit && !self.transit {
            // Transit preempts any injection candidate.
            *self = CandSlot { visit: self.visit, cand: Some(cand), transit: true, count: 1 };
            return;
        }
        if is_transit == self.transit {
            self.count += 1;
            if self.count == 1 || rng.below(self.count as usize) == 0 {
                self.cand = Some(cand);
            }
        }
        // injection offered while transit held: ignored.
    }

    #[inline]
    fn get(&self, visit: u64) -> Option<Cand> {
        if self.visit == visit {
            self.cand
        } else {
            None
        }
    }
}

/// Per-worker arbitration scratch: the generation-stamped winner slots
/// (one per output port, +1 for ejection), the list of ports offered
/// during the current node visit, and the visit counter the stamps come
/// from.
pub(super) struct ArbScratch {
    winners: Vec<CandSlot>,
    touched: Vec<u8>,
    visit: u64,
}

impl ArbScratch {
    /// Scratch for a router with `out_ports` outputs (ejection included).
    pub(super) fn new(out_ports: usize) -> Self {
        Self {
            winners: vec![CandSlot::NONE; out_ports],
            touched: Vec::with_capacity(out_ports),
            visit: 0,
        }
    }
}
