//! Column-style Hermite normal form (Definition 8 of the paper).
//!
//! `H = M * U` with `U` unimodular, `H` upper triangular with positive
//! diagonal and each off-diagonal entry `H[i][j]` (j > i) reduced into
//! `0 <= H[i][j] < H[i][i]`. Right-equivalent matrices generate isomorphic
//! lattice graphs ([16] via Definition 6), so the HNF is the canonical
//! representative our lattice layer computes everything from: labelling
//! boxes, projections, sides, and the `⊞` common lift all read it.

use super::matrix::IMat;

/// Result of a Hermite reduction.
#[derive(Clone, Debug)]
pub struct HnfResult {
    /// The Hermite normal form `H = M * U`.
    pub h: IMat,
    /// The unimodular column transform applied.
    pub u: IMat,
}

/// Compute the column Hermite normal form of a non-singular square `M`.
///
/// Panics if `M` is singular (lattice graphs require `det != 0`).
pub fn hermite_normal_form(m: &IMat) -> HnfResult {
    let n = m.dim();
    assert!(m.det() != 0, "hermite_normal_form: singular matrix");
    let mut h = m.clone();
    let mut u = IMat::identity(n);

    // Eliminate below the diagonal, bottom-right to top-left in the usual
    // column-HNF order: for each row i from n-1 down, use columns 0..=i to
    // produce a single nonzero at (i, i).
    for i in (0..n).rev() {
        // gcd-reduce columns 0..=i on row i until only column i is nonzero.
        loop {
            // Find column with minimal nonzero |h[i][j]|, j <= i.
            let mut piv: Option<usize> = None;
            for j in 0..=i {
                if h[(i, j)] != 0 {
                    piv = match piv {
                        None => Some(j),
                        Some(p) if h[(i, j)].abs() < h[(i, p)].abs() => Some(j),
                        keep => keep,
                    };
                }
            }
            let p = piv.expect("singular matrix encountered during HNF");
            // Reduce all other columns 0..=i by the pivot.
            let mut all_zero = true;
            for j in 0..=i {
                if j == p || h[(i, j)] == 0 {
                    continue;
                }
                let q = h[(i, j)] / h[(i, p)]; // truncated is fine; loop re-runs
                h.add_col_multiple(j, p, -q);
                u.add_col_multiple(j, p, -q);
                if h[(i, j)] != 0 {
                    all_zero = false;
                }
            }
            if all_zero {
                // Move the pivot into column i.
                if p != i {
                    h.swap_cols(p, i);
                    u.swap_cols(p, i);
                }
                break;
            }
        }
        // Positive diagonal.
        if h[(i, i)] < 0 {
            h.negate_col(i);
            u.negate_col(i);
        }
    }

    // Reduce off-diagonal entries: for j > i bring H[i][j] into [0, H[i][i]).
    // Work bottom row up: subtracting col i from col j perturbs rows < i of
    // col j, which are re-reduced by the later (smaller i) iterations.
    for i in (0..n).rev() {
        let d = h[(i, i)];
        debug_assert!(d > 0);
        for j in i + 1..n {
            let q = crate::math::floor_div(h[(i, j)], d);
            if q != 0 {
                h.add_col_multiple(j, i, -q);
                u.add_col_multiple(j, i, -q);
            }
        }
    }

    debug_assert!(is_hermite(&h), "HNF postcondition failed: {h:?}");
    debug_assert!(u.is_unimodular());
    debug_assert_eq!(m.mul(&u), h);
    HnfResult { h, u }
}

/// Is `h` in (column) Hermite normal form per Definition 8?
pub fn is_hermite(h: &IMat) -> bool {
    let n = h.dim();
    for i in 0..n {
        if h[(i, i)] <= 0 {
            return false;
        }
        for j in 0..i {
            if h[(i, j)] != 0 {
                return false;
            }
        }
        for j in i + 1..n {
            if h[(i, j)] < 0 || h[(i, j)] >= h[(i, i)] {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(m: IMat) {
        let HnfResult { h, u } = hermite_normal_form(&m);
        assert!(is_hermite(&h), "not hermite: {h:?}");
        assert!(u.is_unimodular());
        assert_eq!(m.mul(&u), h);
        assert_eq!(h.det().abs(), m.det().abs());
    }

    #[test]
    fn diag_is_fixed_point() {
        let m = IMat::diag(&[4, 4, 4]);
        let HnfResult { h, .. } = hermite_normal_form(&m);
        assert_eq!(h, m);
    }

    #[test]
    fn fcc_hermite_matches_paper() {
        // Paper §3.2: FCC(a) ~ [[2a, a, a], [0, a, 0], [0, 0, a]].
        for a in 1..6 {
            let m = IMat::from_rows(&[&[a, a, 0], &[a, 0, a], &[0, a, a]]);
            let HnfResult { h, .. } = hermite_normal_form(&m);
            let expect = IMat::from_rows(&[&[2 * a, a, a], &[0, a, 0], &[0, 0, a]]);
            assert_eq!(h, expect, "a={a}");
        }
    }

    #[test]
    fn bcc_hermite_matches_paper() {
        // Paper §3.3: BCC(a) ~ [[2a, 0, a], [0, 2a, a], [0, 0, a]].
        for a in 1..6 {
            let m = IMat::from_rows(&[&[-a, a, a], &[a, -a, a], &[a, a, -a]]);
            let HnfResult { h, .. } = hermite_normal_form(&m);
            let expect = IMat::from_rows(&[&[2 * a, 0, a], &[0, 2 * a, a], &[0, 0, a]]);
            assert_eq!(h, expect, "a={a}");
        }
    }

    #[test]
    fn random_matrices_roundtrip() {
        // Deterministic pseudo-random small matrices.
        let mut seed = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            (seed % 11) as i64 - 5
        };
        let mut tested = 0;
        while tested < 50 {
            let n = 2 + (next().unsigned_abs() as usize % 3); // 2..4
            let data: Vec<i64> = (0..n * n).map(|_| next()).collect();
            let m = IMat::from_flat(n, &data);
            if m.det() == 0 {
                continue;
            }
            check(m);
            tested += 1;
        }
    }

    #[test]
    fn negative_diag_normalized() {
        let m = IMat::from_rows(&[&[-3, 0], &[0, -5]]);
        let HnfResult { h, .. } = hermite_normal_form(&m);
        assert_eq!(h, IMat::diag(&[3, 5]));
    }

    #[test]
    fn offdiag_reduced() {
        let m = IMat::from_rows(&[&[4, 9], &[0, 4]]);
        let HnfResult { h, .. } = hermite_normal_form(&m);
        assert_eq!(h, IMat::from_rows(&[&[4, 1], &[0, 4]]));
    }

    #[test]
    fn example10_matrix() {
        // Example 10: already Hermite.
        let m = IMat::from_rows(&[&[4, 0, 0], &[0, 4, 2], &[0, 0, 4]]);
        let HnfResult { h, .. } = hermite_normal_form(&m);
        assert_eq!(h, m);
    }
}
