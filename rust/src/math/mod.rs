//! Exact integer linear algebra over `Z^{n x n}`.
//!
//! Lattice graphs (the paper's Definition 3) are quotients `Z^n / M Z^n` of
//! the integer lattice by the column span of a non-singular integral matrix
//! `M`. Everything topological about the resulting network — order,
//! labelling, wrap-around pattern, embedded subgraphs, symmetry — is a
//! statement about `M` under *column* (right, unimodular) equivalence, so
//! this module provides the exact arithmetic those manipulations need:
//!
//! - [`IMat`]: dense `i64` matrices with exact determinant/adjugate,
//! - column-style Hermite normal form ([`IMat::hermite_normal_form`])
//!   with the reducing unimodular transform,
//! - unimodularity / integrality predicates used by the symmetry tests,
//! - gcd helpers ([`gcd`], [`gcd_slice`]) used for element orders.
//!
//! Values stay within `i64`; all paper-relevant matrices have entries
//! `O(a)` with `a <= 64` and dimension `n <= 6`, so determinants are far
//! below overflow (checked arithmetic is used in debug builds regardless).

pub mod hnf;
pub mod matrix;
pub mod smith;

pub use hnf::{hermite_normal_form, HnfResult};
pub use matrix::IMat;
pub use smith::{invariant_factors, smith_normal_form, SnfResult};

/// Greatest common divisor (always non-negative; `gcd(0, 0) = 0`).
pub fn gcd(a: i64, b: i64) -> i64 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// gcd of a slice (0 for an empty slice).
pub fn gcd_slice(xs: &[i64]) -> i64 {
    xs.iter().fold(0, |acc, &x| gcd(acc, x))
}

/// Least common multiple. Panics on overflow in debug builds.
pub fn lcm(a: i64, b: i64) -> i64 {
    if a == 0 || b == 0 {
        0
    } else {
        (a / gcd(a, b)).abs() * b.abs()
    }
}

/// Floor division (Euclidean-style quotient for positive divisors):
/// `floor_div(-1, 4) == -1`, matching the coordinate reduction the
/// Hermite-box labelling needs.
pub fn floor_div(a: i64, b: i64) -> i64 {
    debug_assert!(b != 0);
    let q = a / b;
    if (a % b != 0) && ((a < 0) != (b < 0)) {
        q - 1
    } else {
        q
    }
}

/// Mathematical (non-negative for positive modulus) remainder.
pub fn rem_euclid(a: i64, b: i64) -> i64 {
    a - floor_div(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(-12, 18), 6);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(0, 0), 0);
        assert_eq!(gcd(7, 13), 1);
    }

    #[test]
    fn gcd_slice_basics() {
        assert_eq!(gcd_slice(&[4, 6, 8]), 2);
        assert_eq!(gcd_slice(&[]), 0);
        assert_eq!(gcd_slice(&[0, 0, 7]), 7);
    }

    #[test]
    fn lcm_basics() {
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 5), 0);
        assert_eq!(lcm(-4, 6), 12);
    }

    #[test]
    fn floor_div_negatives() {
        assert_eq!(floor_div(-1, 4), -1);
        assert_eq!(floor_div(-4, 4), -1);
        assert_eq!(floor_div(-5, 4), -2);
        assert_eq!(floor_div(7, 4), 1);
        assert_eq!(floor_div(7, -4), -2);
    }

    #[test]
    fn rem_euclid_negatives() {
        assert_eq!(rem_euclid(-1, 4), 3);
        assert_eq!(rem_euclid(-4, 4), 0);
        assert_eq!(rem_euclid(7, 4), 3);
    }
}
