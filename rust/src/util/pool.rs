//! Scoped helper-thread primitives (offline build — no rayon).
//!
//! One abstraction, two consumers:
//!
//! - [`par_map`] — fork/join over an index range, returning results in
//!   input order. Used by the workload runner's multi-seed fan-out.
//! - [`with_helpers`] — raw scoped helpers running alongside the calling
//!   thread. Used by the parallel cycle engine, whose workers park on
//!   barriers across many cycles instead of forking per call.
//!
//! Both are built on `std::thread::scope`, so helper lifetimes are
//! bounded by the call and borrowed captures need no `'static`.
//!
//! # Send/Sync contract
//!
//! Results crossing from a helper back to the caller must be `T: Send`
//! (enforced by the bound on [`par_map`]); the closures run concurrently
//! on several threads and so must be `Sync` (shared by reference) with
//! any interior mutation synchronized by the caller — the engine does
//! this with per-worker `Mutex`es and cycle barriers, `par_map` with an
//! atomic work cursor and per-slot locks.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `main` on the calling thread while `threads - 1` scoped helpers
/// run `helper(w)` for `w` in `1..threads` (the caller is worker 0).
/// Returns `main`'s value after every helper has exited.
///
/// With `threads <= 1` no thread is spawned and `main` simply runs —
/// callers get a zero-overhead serial path for free.
pub fn with_helpers<R>(
    threads: usize,
    helper: impl Fn(usize) + Sync,
    main: impl FnOnce() -> R,
) -> R {
    if threads <= 1 {
        return main();
    }
    std::thread::scope(|scope| {
        for w in 1..threads {
            let helper = &helper;
            scope.spawn(move || helper(w));
        }
        main()
    })
}

/// Map `f` over `0..n` on up to `workers` threads (`0` = one per
/// available core), returning results in input order. Work is claimed
/// dynamically (atomic cursor), so uneven item costs balance
/// automatically. One worker (or `n <= 1`) runs serially on the caller
/// with no spawning or locking.
///
/// Results land in a pre-sized slot per job: the cursor hands each `i`
/// to exactly one worker, which writes job `i`'s result straight into
/// slot `i` — no shared results vector to fight over, no post-run sort.
/// Slots are `Mutex<Option<T>>` rather than `OnceLock<T>` only because
/// sharing a `OnceLock` across threads would force `T: Sync` onto the
/// public bound; each slot's lock is taken exactly once, by the one
/// worker that owns the index, so the locks are never contended. A
/// worker panic propagates out of the scope, so every slot is filled by
/// the time the results are collected.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = if workers > 0 {
        workers
    } else {
        std::thread::available_parallelism().map_or(1, |w| w.get())
    }
    .min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let work = |_w: usize| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        *slots[i].lock().expect("par_map worker panicked") = Some(f(i));
    };
    with_helpers(workers, &work, || work(0));
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("par_map worker panicked")
                .expect("par_map slot left unfilled")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn par_map_matches_serial_in_order() {
        let serial: Vec<usize> = (0..37).map(|i| i * i).collect();
        for workers in [1, 2, 3, 8, 64] {
            assert_eq!(par_map(37, workers, |i| i * i), serial, "workers={workers}");
        }
        assert_eq!(par_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn with_helpers_runs_every_worker_once() {
        let hits = AtomicUsize::new(0);
        let r = with_helpers(
            5,
            |w| {
                assert!((1..5).contains(&w));
                hits.fetch_add(w, Ordering::Relaxed);
            },
            || 42,
        );
        assert_eq!(r, 42);
        assert_eq!(hits.load(Ordering::Relaxed), 1 + 2 + 3 + 4);
    }

    #[test]
    fn with_helpers_serial_spawns_nothing() {
        // threads <= 1: the helper closure must never run.
        let r = with_helpers(1, |_| panic!("helper ran"), || 7);
        assert_eq!(r, 7);
        let r = with_helpers(0, |_| panic!("helper ran"), || 8);
        assert_eq!(r, 8);
    }
}
