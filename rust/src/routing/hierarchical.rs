//! Algorithm 1: generic hierarchical minimal routing for *any* lattice
//! graph (Theorem 29).
//!
//! Routing in `G(M)` with `M ≅ [[B, c], [0, a]]` reduces to routing along
//! the cycle `<e_n>` to each of the `ord(e_n)/a` intersection vertices
//! lying in the destination copy of `G(B)`, plus a recursive route inside
//! that copy; the minimum-norm composition is returned. The recursion
//! bottoms out at `n = 1` (ring routing).
//!
//! This is the reference router for hybrids and arbitrary `G(M)`; the
//! closed-form routers (Algorithms 2–4) are the fast paths the simulator
//! prefers where they apply.

use crate::lattice::LatticeGraph;

use super::{norm, Record, Router};

/// Generic minimal router (Algorithm 1).
pub struct HierarchicalRouter {
    g: LatticeGraph,
    /// Projection router (recursive), `None` at `n = 1`.
    inner: Option<Box<HierarchicalRouter>>,
    /// `ord(e_n)` in `G(M)`.
    cycle_len: i64,
    /// Cycle steps `k ∈ [0, ord)` as label displacements: walking `k`
    /// `+e_n` hops from a label adds `cycle_disp[k]` before reduction.
    /// Precomputed once: displacement of `k * e_n` reduced from 0.
    cycle_disp: Vec<Vec<i64>>,
}

impl HierarchicalRouter {
    pub fn new(g: LatticeGraph) -> Self {
        let n = g.dim();
        if n == 1 {
            return Self { g, inner: None, cycle_len: 0, cycle_disp: Vec::new() };
        }
        let inner = Box::new(HierarchicalRouter::new(g.projection_graph()));
        let cycle_len = g.generator_order(n - 1);
        // Walk the cycle from the zero label, recording each visited label.
        let mut cycle_disp = Vec::with_capacity(cycle_len as usize);
        let mut cur = vec![0i64; n];
        for _ in 0..cycle_len {
            cycle_disp.push(cur.clone());
            cur[n - 1] += 1;
            g.reduce_in_place(&mut cur);
        }
        debug_assert!(cur.iter().all(|&x| x == 0), "cycle failed to close");
        Self { g, inner: Some(inner), cycle_len, cycle_disp }
    }

    /// Ring route at the `n = 1` base case.
    fn ring(&self, src: i64, dst: i64) -> i64 {
        let a = self.g.box_sides()[0];
        super::torus::TorusRouter::ring_route(dst - src, a)
    }

    fn route_impl(&self, src: &[i64], dst: &[i64], collect_ties: bool) -> Vec<Record> {
        let n = self.g.dim();
        if n == 1 {
            let a = self.g.box_sides()[0];
            return if collect_ties {
                super::torus::TorusRouter::ring_route_ties(dst[0] - src[0], a)
                    .into_iter()
                    .map(|r| vec![r])
                    .collect()
            } else {
                vec![vec![self.ring(src[0], dst[0])]]
            };
        }
        let inner = self.inner.as_ref().unwrap();
        let y_d = dst[n - 1];
        let mut best: Vec<Record> = Vec::new();
        let mut best_norm = i64::MAX;
        let mut scratch = vec![0i64; n];
        for (k, disp) in self.cycle_disp.iter().enumerate() {
            // Position after k +e_n hops from src.
            for i in 0..n {
                scratch[i] = src[i] + disp[i];
            }
            self.g.reduce_in_place(&mut scratch);
            if scratch[n - 1] != y_d {
                continue;
            }
            // Two ways around the cycle to this intersection.
            let k = k as i64;
            let cycle_opts: &[i64] = if k == 0 {
                &[0]
            } else {
                // k forward, k - ord backward.
                &[k, k - self.cycle_len][..]
            };
            let proj_src = &scratch[..n - 1];
            let proj_dst = &dst[..n - 1];
            let proj_routes = inner.route_impl(proj_src, proj_dst, collect_ties);
            for &steps in cycle_opts {
                for pr in &proj_routes {
                    let total = norm(pr) + steps.abs();
                    if total < best_norm {
                        best_norm = total;
                        best.clear();
                    }
                    if total == best_norm {
                        let mut r = pr.clone();
                        r.push(steps);
                        if !collect_ties {
                            if best.is_empty() {
                                best.push(r);
                            }
                        } else if !best.contains(&r) {
                            best.push(r);
                        }
                    }
                }
            }
        }
        debug_assert!(!best.is_empty());
        best
    }
}

impl Router for HierarchicalRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        self.route_impl(src, dst, false).pop().unwrap()
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        self.route_impl(src, dst, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::is_valid_record;
    use crate::topology::{bcc, bcc4d, fcc, fcc4d, hybrid_pc_bcc, hybrid_t_rtt, lip, rtt, torus};

    fn check_all_pairs(g: LatticeGraph, tag: &str) {
        let router = HierarchicalRouter::new(g.clone());
        let dist = crate::metrics::bfs_distances(&g, 0);
        let src = vec![0i64; g.dim()];
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let r = router.route(&src, &dst);
            assert!(is_valid_record(&g, &src, &dst, &r), "{tag} dst={dst:?}");
            assert_eq!(norm(&r), dist[v] as i64, "{tag} dst={dst:?} got {r:?}");
        }
    }

    #[test]
    fn minimal_on_tori() {
        check_all_pairs(torus(&[5]), "T(5)");
        check_all_pairs(torus(&[4, 4]), "T(4,4)");
        check_all_pairs(torus(&[4, 3, 2]), "T(4,3,2)");
    }

    #[test]
    fn minimal_on_crystals() {
        for a in 1..4i64 {
            check_all_pairs(fcc(a), "FCC");
            check_all_pairs(bcc(a), "BCC");
            check_all_pairs(rtt(a + 1), "RTT");
        }
    }

    #[test]
    fn minimal_on_4d_lifts() {
        check_all_pairs(fcc4d(2), "4D-FCC(2)");
        check_all_pairs(bcc4d(1), "4D-BCC(1)");
        check_all_pairs(lip(1), "Lip(1)");
    }

    #[test]
    fn minimal_on_hybrids() {
        check_all_pairs(hybrid_t_rtt(2), "T⊞RTT(2)");
        check_all_pairs(hybrid_pc_bcc(1), "PC⊞BCC(1)");
    }

    #[test]
    fn minimal_on_example10() {
        check_all_pairs(
            LatticeGraph::new(crate::math::IMat::from_rows(&[
                &[4, 0, 0],
                &[0, 4, 2],
                &[0, 0, 4],
            ])),
            "Example10",
        );
    }

    #[test]
    fn ties_contain_route_and_are_minimal() {
        let g = fcc(2);
        let router = HierarchicalRouter::new(g.clone());
        let dist = crate::metrics::bfs_distances(&g, 0);
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let ties = router.route_ties(&[0, 0, 0], &dst);
            assert!(!ties.is_empty());
            for r in &ties {
                assert!(is_valid_record(&g, &[0, 0, 0], &dst, r));
                assert_eq!(norm(r), dist[v] as i64);
            }
        }
    }

    #[test]
    fn nonzero_source_agreement() {
        let g = bcc(2);
        let router = HierarchicalRouter::new(g.clone());
        let src = [3i64, 1, 1];
        let dists = crate::metrics::bfs_distances(&g, g.index_of(&src));
        for v in 0..g.order() {
            let dst = g.label_of(v);
            let r = router.route(&src, &dst);
            assert_eq!(norm(&r), dists[v] as i64);
        }
    }
}
