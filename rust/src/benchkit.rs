//! Minimal benchmarking harness (offline build — no criterion; see
//! DESIGN.md §Substitutions).
//!
//! Provides warmup + repeated timed runs with median/mean/min reporting in
//! a criterion-like text format, plus throughput annotations. Benches are
//! `harness = false` binaries that call [`Bench::run`].

use std::time::{Duration, Instant};

/// One benchmark group.
pub struct Bench {
    name: String,
    /// Minimum wall time to spend measuring each case.
    pub budget: Duration,
    /// Max iterations per case.
    pub max_iters: u32,
}

/// Measurement summary.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let budget_ms = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(600u64);
        Self {
            name: name.to_string(),
            budget: Duration::from_millis(budget_ms),
            max_iters: 1000,
        }
    }

    /// Time `f`, printing a criterion-like line. Returns the sample.
    pub fn run<F: FnMut()>(&self, case: &str, mut f: F) -> Sample {
        // Warmup.
        f();
        let mut times: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && (times.len() as u32) < self.max_iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let min = times[0];
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let s = Sample { iters: times.len() as u32, mean, median, min };
        println!(
            "{}/{:<40} time: [{} {} {}]  ({} iters)",
            self.name,
            case,
            fmt_dur(min),
            fmt_dur(median),
            fmt_dur(mean),
            s.iters
        );
        s
    }

    /// Like [`run`](Self::run) but annotates a throughput figure computed
    /// from the median (`items` per iteration).
    pub fn run_throughput<F: FnMut()>(&self, case: &str, items: u64, unit: &str, f: F) -> Sample {
        let s = self.run(case, f);
        let per_sec = items as f64 / s.median.as_secs_f64();
        println!("{}/{:<40} thrpt: {:.3e} {unit}/s", self.name, case, per_sec);
        s
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new("test");
        b.budget = Duration::from_millis(5);
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(s.iters >= 1);
        assert!(s.min <= s.median && s.median <= s.mean.max(s.median));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(Duration::from_nanos(5)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).contains(" s"));
    }
}
