//! Core state machine data: packets, FIFO bookkeeping over slot arenas,
//! the deferred-event calendar, and the per-run mutable [`State`]. (The
//! compact routing store lives in [`crate::routing::compact`] now and is
//! shared across simulators via [`crate::sim::TopologyArtifacts`].)

use crate::sim::config::ScanMode;
use crate::sim::rng::{NodeRng, Rng, STREAM_INJECT};
use crate::sim::stats::LatencyStats;
use crate::sim::telemetry::{EngineProfile, StallCounters, Trace};

use super::{Simulator, MAX_DIM};

/// Index-sorted worklist of "possibly active" ids (DESIGN.md
/// §Engine-performance).
///
/// The per-cycle scans visit only members, in ascending id order, so the
/// RNG stream is consumed in exactly the full-scan order and the engine
/// stays bit-exact with [`ScanMode::FullScan`]. Membership is maintained
/// conservatively: producers [`insert`](Self::insert) an id whenever they
/// enqueue work for it (packet push, injection-queue entry, NIC send-queue
/// eligibility), and the scan lazily drops an id once it observes the id
/// idle — a stale member costs one no-op visit, never a correctness or
/// RNG-stream difference, because an idle id is exactly the case the
/// full scan skips without touching the RNG.
///
/// Inserts land in `pending` (duplicate-free via `member`) and are folded
/// into the sorted `list` by [`merge`](Self::merge) — one two-way merge
/// per cycle, O(active + newly-activated), called before the scan. Under
/// [`ScanMode::FullScan`] the sets are still fed by the producers (the
/// shared enqueue paths don't branch on the mode) but never merged or
/// consumed; `pending` is bounded by the id universe via `member`.
pub(super) struct ActiveSet {
    /// Ascending ids the per-cycle scan visits (disjoint from `pending`).
    pub(super) list: Vec<u32>,
    /// Ids activated since the last `merge` (duplicate-free, unsorted).
    pub(super) pending: Vec<u32>,
    /// Membership over `list ∪ pending`.
    pub(super) member: Vec<bool>,
    /// Merge scratch, kept allocated across cycles.
    scratch: Vec<u32>,
}

impl ActiveSet {
    pub(super) fn new(universe: usize) -> Self {
        Self {
            list: Vec::new(),
            pending: Vec::new(),
            member: vec![false; universe],
            scratch: Vec::new(),
        }
    }

    /// Mark `u` active (idempotent, O(1)).
    #[inline]
    pub(super) fn insert(&mut self, u: usize) {
        if !self.member[u] {
            self.member[u] = true;
            self.pending.push(u as u32);
        }
    }

    /// Fold `pending` into the sorted `list`.
    pub(super) fn merge(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.pending.sort_unstable();
        self.scratch.clear();
        self.scratch.reserve(self.list.len() + self.pending.len());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.list.len() && j < self.pending.len() {
            // `list` and `pending` are disjoint (the `member` guard), so
            // strict comparison is total here.
            if self.list[i] < self.pending[j] {
                self.scratch.push(self.list[i]);
                i += 1;
            } else {
                self.scratch.push(self.pending[j]);
                j += 1;
            }
        }
        self.scratch.extend_from_slice(&self.list[i..]);
        self.scratch.extend_from_slice(&self.pending[j..]);
        std::mem::swap(&mut self.list, &mut self.scratch);
        self.pending.clear();
    }

    /// No member anywhere (listed or pending).
    pub(super) fn is_empty(&self) -> bool {
        self.list.is_empty() && self.pending.is_empty()
    }

    /// Compact `list` down to ids whose membership flag is still set.
    ///
    /// The parallel arbitration kernel drops a node by clearing
    /// `member[u]` from the worker that owns `u`'s shard (each worker
    /// only writes flags of ids inside its slice of the sorted list);
    /// this serial pass then compacts the list at the cycle barrier —
    /// and it must run *before* buffered activations are applied, so a
    /// dropped-then-reactivated id lands in `pending` and the
    /// `list ∪ pending` disjointness invariant holds.
    pub(super) fn retain_members(&mut self) {
        let member = &self.member;
        self.list.retain(|&u| member[u as usize]);
    }
}

/// One scan over an [`ActiveSet`]: merge pending activations, visit the
/// members in ascending id order, and drop every member for which the
/// visit body returns `false` (clearing its membership flag so producers
/// can re-insert it). The body runs with the sorted list taken out of
/// the set, so it may borrow the set's owner mutably — e.g. the
/// arbitration kernel takes `&mut State` while scanning
/// `state.active_nodes` — and inserts made during the scan land in
/// `pending` for the *next* cycle, exactly when the full scan would
/// first act on them.
///
/// A macro rather than a closure-taking method because the visit body
/// must borrow the struct that owns the set (no closure signature can
/// express that field-disjoint split); the expansion is plain sequential
/// code, so the borrows stay field-precise. Shared by the arbitration
/// node scan and the closed-loop NIC sender scan — the lazy-removal
/// protocol `assert_quiescent` polices lives in exactly one place.
macro_rules! scan_active {
    ($set:expr, |$u:ident| $keep:expr) => {{
        $set.merge();
        let mut list = std::mem::take(&mut $set.list);
        let (mut r, mut w) = (0usize, 0usize);
        while r < list.len() {
            let $u = list[r] as usize;
            if $keep {
                list[w] = list[r];
                w += 1;
            } else {
                $set.member[$u] = false;
            }
            r += 1;
        }
        list.truncate(w);
        $set.list = list;
    }};
}
pub(super) use scan_active;

/// A packet in flight.
///
/// The bubble "entering a new ring" test does not need per-packet state:
/// the arbitration scan derives it from the input-FIFO index vs the
/// output port (see `advance`).
#[derive(Clone, Copy, Debug)]
pub(super) struct Packet {
    /// Remaining signed hops per dimension — consumed one productive axis
    /// per hop by the route-selection policy.
    pub(super) record: [i16; MAX_DIM],
    /// Virtual channel (0..num_vcs) the packet currently occupies — and
    /// therefore requests downstream. Fixed end-to-end under `Dor`; under
    /// the adaptive policies a blocked packet's escape transfer rewrites
    /// it to 0 (the DOR escape channel), where it stays committed.
    pub(super) vc: u8,
    /// Injection cycle (for latency).
    pub(super) inject_time: u64,
    /// Cycle at which the head is present and routable at the current node.
    pub(super) head_ready: u64,
    /// Cached desired output port (recomputed on every hop by the route
    /// policy; `ports` value means ejection). Avoids re-deriving the
    /// routing decision per cycle on the hot scan.
    pub(super) next_port: u8,
}

/// FIFO bookkeeping over an externally owned slot arena.
///
/// Capacities come from `SimConfig` at run time, so the packet-id slots
/// live in per-run arenas (`State::input_slots` / `State::inj_slots`, one
/// contiguous `cap`-sized window per queue) instead of a fixed-size inline
/// array; every method takes its window. `len` counts queued packets;
/// `reserved` additionally counts slots whose packet has been forwarded but
/// whose tail has not yet fully left (VCT keeps the space claimed until the
/// tail drains).
#[derive(Clone, Copy, Debug)]
pub(super) struct Fifo {
    pub(super) head: u16,
    pub(super) len: u16,
    pub(super) reserved: u16,
    /// Cached output port of the head packet — the arbitration scan reads
    /// only the FIFO metadata, never the packet arena (cache locality is
    /// the engine's top bottleneck; see EXPERIMENTS.md §Perf).
    pub(super) head_port: u8,
    /// Cached `head_ready` of the head packet.
    pub(super) head_ready: u64,
}

impl Fifo {
    pub(super) const EMPTY: Fifo = Fifo {
        head: 0,
        len: 0,
        reserved: 0,
        head_port: 0,
        head_ready: 0,
    };

    #[inline]
    pub(super) fn push(&mut self, slots: &mut [u32], pid: u32, ready: u64, port: u8) {
        debug_assert!((self.len as usize) < slots.len());
        let tail = (self.head as usize + self.len as usize) % slots.len();
        slots[tail] = pid;
        if self.len == 0 {
            self.head_ready = ready;
            self.head_port = port;
        }
        self.len += 1;
        self.reserved += 1;
    }

    #[inline]
    pub(super) fn front(&self, slots: &[u32]) -> Option<u32> {
        (self.len > 0).then(|| slots[self.head as usize])
    }

    /// Refresh the cached head metadata after a pop.
    #[inline]
    pub(super) fn refresh_head(&mut self, slots: &[u32], packets: &[Packet]) {
        if self.len > 0 {
            let pkt = &packets[slots[self.head as usize] as usize];
            self.head_ready = pkt.head_ready;
            self.head_port = pkt.next_port;
        }
    }

    #[inline]
    pub(super) fn pop(&mut self, slots: &[u32]) -> u32 {
        debug_assert!(self.len > 0);
        let pid = slots[self.head as usize];
        self.head = ((self.head as usize + 1) % slots.len()) as u16;
        self.len -= 1;
        // `reserved` stays up; released by the tail-departure event.
        pid
    }

    #[inline]
    pub(super) fn release(&mut self) {
        debug_assert!(self.reserved > 0);
        self.reserved -= 1;
    }
}

/// Deferred events, bucketed on a calendar ring (all delays are at most
/// the packet serialization time, so the ring is tiny).
#[derive(Clone, Copy, Debug)]
pub(super) enum Event {
    /// Tail left an input buffer: release its reservation.
    FreeInput(u32),
    /// Tail left an injection queue slot.
    FreeInj(u32),
    /// Tail fully received at the destination: complete delivery.
    Deliver(u32),
}

/// Per-run mutable state.
pub(super) struct State {
    pub(super) packets: Vec<Packet>,
    pub(super) free_pids: Vec<u32>,
    /// Input FIFOs: `(u * ports + p) * num_vcs + vc`.
    pub(super) inputs: Vec<Fifo>,
    /// Slot arena for the input FIFOs: `queue_packets` ids per queue.
    pub(super) input_slots: Vec<u32>,
    /// Injection queue per node.
    pub(super) inj: Vec<Fifo>,
    /// Slot arena for the injection queues: `injection_queue_packets` ids
    /// per node.
    pub(super) inj_slots: Vec<u32>,
    /// Per-node occupancy bitmask over the local input FIFOs
    /// (bit = p_in * num_vcs + vc): lets the arbitration scan visit only
    /// non-empty queues (the dominant cost at low/mid load).
    pub(super) occ: Vec<u64>,
    /// Link busy-until per `(u, p)`.
    pub(super) link_busy: Vec<u64>,
    /// Ejection channel busy-until per node.
    pub(super) eject_busy: Vec<u64>,
    /// Calendar ring of deferred events.
    pub(super) calendar: Vec<Vec<Event>>,
    /// Sequential setup stream (traffic-pattern construction only — no
    /// in-run draw touches it; see [`crate::sim::rng`]).
    pub(super) rng: Rng,
    /// Key for the counter-based per-node streams every in-run draw
    /// comes from: arbitration visits open `NodeRng::new(seed, u, now)`,
    /// the injection processes use the persistent [`Self::inj_rng`].
    pub(super) seed: u64,
    /// Per-node injection streams (`NodeRng::new(seed, u,
    /// STREAM_INJECT)`): destination draws, VC picks and inter-arrival
    /// gaps for packets sourced at `u`. Persistent so the counter runs
    /// across cycles; an idle node's stream is simply never advanced.
    pub(super) inj_rng: Vec<NodeRng>,
    /// Commutative fingerprint of the arbitration-visit draws (wrapping
    /// sum of values / count), folded in per shard at each cycle
    /// barrier. The injection streams keep their own accumulators; see
    /// [`State::node_stream_fingerprint`].
    pub(super) node_digest: u64,
    pub(super) node_draws: u64,
    // measurement
    pub(super) now: u64,
    pub(super) measure_start: u64,
    pub(super) measure_end: u64,
    pub(super) delivered_phits: u64,
    pub(super) delivered_packets: u64,
    /// Phits transferred per directed link `(u, p)` during the measurement
    /// window — the §3.4 link-utilization instrumentation, kept per link
    /// so the per-port balance spread is measurable.
    pub(super) phits_by_link: Vec<u64>,
    /// Phits transferred per virtual channel during the measurement
    /// window (`num_vcs` entries) — makes escape-channel usage visible
    /// (entry 0 is the escape lane when the protocol is active).
    pub(super) phits_by_vc: Vec<u64>,
    pub(super) injected_packets: u64,
    pub(super) source_dropped: u64,
    pub(super) latency: LatencyStats,
    /// Always-on stall-cause attribution (plus escape drains) — bumped
    /// only on already-blocked paths, no RNG, so it cannot perturb
    /// results (see [`crate::sim::telemetry`]).
    pub(super) stalls: StallCounters,
    /// Packet-lifecycle JSONL stream, open iff `SimConfig::trace` is set.
    /// Every hook is observation-only behind an `Option` check: with
    /// `None` the engine is bit-identical to the untraced one.
    pub(super) trace: Option<Trace>,
    /// Destination node per live packet (parallel to `packets`).
    pub(super) dests: Vec<u32>,
    /// Active-node worklist for the arbitration scan: nodes with at least
    /// one queued packet (input FIFO or injection queue). Fed by the
    /// enqueue paths, drained lazily by `advance` under
    /// [`ScanMode::ActiveSet`].
    pub(super) active_nodes: ActiveSet,
    /// The cycle's Phase-B shard plan, one `(lo, hi)` range per worker,
    /// rebuilt serially before the workers are released. Under
    /// [`ScanMode::FullScan`] the ranges are node-id ranges (the static
    /// lattice cut planes); under [`ScanMode::ActiveSet`] they are
    /// *index ranges into the frozen `active_nodes.list`*, carved to
    /// balance queued work across workers (DESIGN.md §Parallel-engine).
    pub(super) shard_plan: Vec<(u32, u32)>,
    /// Execution profile: serial-fast-path vs. sharded cycle counts.
    pub(super) profile: EngineProfile,
}

impl State {
    /// Fresh per-run state with the given RNG seed and measurement window.
    pub(super) fn new(
        sim: &Simulator,
        rng_seed: u64,
        measure_start: u64,
        measure_end: u64,
    ) -> State {
        let cfg = &sim.cfg;
        let cal_len = cfg.packet_size as usize + 2;
        let qcap = cfg.queue_packets as usize;
        let icap = cfg.injection_queue_packets as usize;
        let n_inputs = sim.nodes * sim.ports * cfg.num_vcs;
        State {
            packets: Vec::with_capacity(4096),
            free_pids: Vec::new(),
            inputs: vec![Fifo::EMPTY; n_inputs],
            input_slots: vec![0u32; n_inputs * qcap],
            inj: vec![Fifo::EMPTY; sim.nodes],
            inj_slots: vec![0u32; sim.nodes * icap],
            occ: vec![0u64; sim.nodes],
            link_busy: vec![0u64; sim.nodes * sim.ports],
            eject_busy: vec![0u64; sim.nodes],
            calendar: vec![Vec::new(); cal_len],
            rng: Rng::new(rng_seed),
            seed: rng_seed,
            inj_rng: (0..sim.nodes)
                .map(|u| NodeRng::new(rng_seed, u as u32, STREAM_INJECT))
                .collect(),
            node_digest: 0,
            node_draws: 0,
            now: 0,
            measure_start,
            measure_end,
            delivered_phits: 0,
            delivered_packets: 0,
            phits_by_link: vec![0u64; sim.nodes * sim.ports],
            phits_by_vc: vec![0u64; cfg.num_vcs],
            injected_packets: 0,
            source_dropped: 0,
            latency: LatencyStats::new(),
            stalls: StallCounters::default(),
            trace: cfg.trace.as_deref().map(|path| {
                Trace::create(std::path::Path::new(path)).unwrap_or_else(|e| {
                    panic!("telemetry: cannot create trace file {path:?}: {e}")
                })
            }),
            dests: Vec::with_capacity(4096),
            active_nodes: ActiveSet::new(sim.nodes),
            shard_plan: Vec::new(),
            profile: EngineProfile::default(),
        }
    }

    /// Total `(digest, draws)` over every per-node counter stream this
    /// run consumed: the arbitration accumulator plus each node's
    /// injection stream. Both components are wrapping sums, so the total
    /// is independent of node grouping and visit order — `threads = k`
    /// reproduces the serial value exactly.
    pub(super) fn node_stream_fingerprint(&self) -> (u64, u64) {
        let mut digest = self.node_digest;
        let mut draws = self.node_draws;
        for r in &self.inj_rng {
            digest = digest.wrapping_add(r.digest);
            draws += r.draws;
        }
        (digest, draws)
    }

    /// The run's RNG fingerprint (`SimResult::rng_digest` /
    /// `WorkloadOutcome::rng_digest`): the sequential setup stream's
    /// end-state combined with the per-node stream fingerprint. Any
    /// extra, missing or re-keyed draw anywhere changes it.
    pub(super) fn rng_digest(&self) -> u64 {
        let (digest, draws) = self.node_stream_fingerprint();
        self.rng.state_digest()
            ^ crate::sim::rng::splitmix64(digest)
            ^ crate::sim::rng::splitmix64(draws).rotate_left(31)
    }
}

impl Simulator {
    #[inline]
    pub(super) fn apply_events(&self, st: &mut State) {
        let ps = self.cfg.packet_size as u64;
        let slot = (st.now % (ps + 2)) as usize;
        let events = std::mem::take(&mut st.calendar[slot]);
        for ev in events {
            match ev {
                Event::FreeInput(fifo) => st.inputs[fifo as usize].release(),
                Event::FreeInj(node) => st.inj[node as usize].release(),
                Event::Deliver(pid) => {
                    let p = st.packets[pid as usize];
                    let lat = st.now - p.inject_time;
                    // Throughput counts deliveries inside the window;
                    // latency follows the *injection* time, so stragglers
                    // delivered during the drain still contribute their
                    // (long) latencies instead of silently vanishing.
                    if st.now >= st.measure_start && st.now < st.measure_end {
                        st.delivered_phits += ps;
                        st.delivered_packets += 1;
                    }
                    if p.inject_time >= st.measure_start && p.inject_time < st.measure_end {
                        st.latency.record(lat);
                    }
                    if st.trace.is_some() {
                        let node = st.dests[pid as usize] as usize;
                        let now = st.now;
                        if let Some(tr) = st.trace.as_mut() {
                            tr.deliver(now, pid, node, p.inject_time);
                        }
                    }
                    st.free_pids.push(pid);
                }
            }
        }
    }

    #[inline]
    pub(super) fn schedule(&self, st: &mut State, delay: u64, ev: Event) {
        let ps = self.cfg.packet_size as u64;
        let slot = ((st.now + delay) % (ps + 2)) as usize;
        st.calendar[slot].push(ev);
    }

    /// Per-directed-port-class utilization and the max/mean balance spread
    /// over the individual directed links, for a measurement window of
    /// `cycles` — shared by the open-loop statistics and the closed-loop
    /// workload outcome (ROADMAP's per-workload balance column).
    pub(super) fn port_stats(&self, st: &State, cycles: u64) -> (Vec<f64>, f64) {
        let mc = cycles.max(1) as f64;
        let port_utilization: Vec<f64> = (0..self.ports)
            .map(|p| {
                let phits: u64 =
                    (0..self.nodes).map(|u| st.phits_by_link[u * self.ports + p]).sum();
                phits as f64 / (self.nodes as f64 * mc * self.cfg.axis_width(p / 2) as f64)
            })
            .collect();
        let mut max_util = 0.0f64;
        let mut sum_util = 0.0f64;
        for u in 0..self.nodes {
            for p in 0..self.ports {
                let cap = mc * self.cfg.axis_width(p / 2) as f64;
                let util = st.phits_by_link[u * self.ports + p] as f64 / cap;
                max_util = max_util.max(util);
                sum_util += util;
            }
        }
        let mean_util = sum_util / (self.nodes * self.ports) as f64;
        let spread = if mean_util > 0.0 { max_util / mean_util } else { 0.0 };
        (port_utilization, spread)
    }

    /// Emit one `probe` trace event sampling current network state:
    /// active-worklist size, in-flight phits, input-queue occupancy per
    /// VC and per directed port class (plus the single fullest link), and
    /// the injection/NIC backlogs. Only called when a trace is open and
    /// `SimConfig::sample_every` divides the cycle, so the O(queues) scan
    /// costs nothing on untraced runs; `send_backlog` is the closed-loop
    /// NIC send-queue depth (0 in open loop).
    pub(super) fn sample_probe(&self, st: &mut State, send_backlog: u64) {
        let vcs = self.cfg.num_vcs;
        let ps = self.cfg.packet_size as u64;
        let mut vc_occ = vec![0u64; vcs];
        let mut port_occ = vec![0u64; self.ports];
        let mut max_link = 0u64;
        for u in 0..self.nodes {
            for p in 0..self.ports {
                let mut link = 0u64;
                for (vc, occ) in vc_occ.iter_mut().enumerate() {
                    let f = &st.inputs[(u * self.ports + p) * vcs + vc];
                    let phits = f.len as u64 * ps;
                    *occ += phits;
                    link += phits;
                }
                port_occ[p] += link;
                max_link = max_link.max(link);
            }
        }
        let inj_backlog: u64 = st.inj.iter().map(|f| f.len as u64).sum();
        let active = st.active_nodes.list.len() + st.active_nodes.pending.len();
        let inflight = (st.packets.len() - st.free_pids.len()) as u64 * ps;
        let now = st.now;
        if let Some(tr) = st.trace.as_mut() {
            tr.probe(now, active, inflight, inj_backlog, send_backlog, &vc_occ, &port_occ, max_link);
        }
    }

    /// Per-VC credit-conservation invariant: a drained network must have
    /// returned every buffer reservation it ever took. Every input FIFO
    /// (per port, per VC), every injection queue and every occupancy bit
    /// must be clear — a leaked `reserved` count means a credit was lost
    /// somewhere on the escape path and would eventually wedge a longer
    /// run. Checked at the end of every drained closed-loop run (O(queues)
    /// once per run).
    pub(super) fn assert_quiescent(&self, st: &State) {
        let vcs = self.cfg.num_vcs;
        for (i, f) in st.inputs.iter().enumerate() {
            assert!(
                f.len == 0 && f.reserved == 0,
                "credit leak: input fifo {i} (node {}, port {}, vc {}) drained with len {} reserved {}",
                i / (self.ports * vcs),
                (i / vcs) % self.ports,
                i % vcs,
                f.len,
                f.reserved
            );
        }
        for (u, f) in st.inj.iter().enumerate() {
            assert!(
                f.len == 0 && f.reserved == 0,
                "credit leak: injection queue of node {u} drained with len {} reserved {}",
                f.len,
                f.reserved
            );
        }
        for (u, &occ) in st.occ.iter().enumerate() {
            assert!(occ == 0, "occupancy bits stuck at node {u}: {occ:#b}");
        }
        // The active-set path must converge to an empty worklist on a
        // drained network: every node that went idle is lazily dropped on
        // its next visit, and a drained network has had that visit. A
        // leftover member means the set maintenance leaked — the same
        // class of bug as a lost buffer credit. (Under the full-scan
        // reference path the sets are fed but never drained, so the check
        // only applies to the active-set engine.)
        if self.cfg.scan_mode == ScanMode::ActiveSet {
            assert!(
                st.active_nodes.is_empty(),
                "active-node set not empty after drain: {} listed, {} pending",
                st.active_nodes.list.len(),
                st.active_nodes.pending.len()
            );
        }
        // Degraded network: dead hardware must have stayed cold for the
        // whole run — a dead link never carried a phit inside the
        // measurement window, and a dead node never sourced or sank a
        // packet. (The per-transfer asserts in `start_transfer` catch a
        // violation at commit time; this is the drained-run summary the
        // fault property suite leans on.)
        if let Some(f) = self.faults.as_deref() {
            for u in 0..self.nodes {
                for p in 0..self.ports {
                    if f.is_link_dead(u, p) {
                        assert_eq!(
                            st.phits_by_link[u * self.ports + p],
                            0,
                            "dead link ({u}, port {p}) carried phits"
                        );
                    }
                }
                if f.is_node_dead(u) {
                    assert!(
                        st.inj[u].len == 0 && st.inj[u].reserved == 0,
                        "dead node {u} holds injection-queue state"
                    );
                    assert_eq!(st.eject_busy[u], 0, "dead node {u} ejected a packet");
                }
            }
        }
    }
}
