//! Closed-loop application workloads on the cycle engine.
//!
//! The open-loop simulator ([`crate::sim`]) measures steady-state latency
//! and throughput under synthetic injection; this subsystem measures what
//! applications feel: the **completion time** of finite, dependency-ordered
//! communication patterns — halo exchange, all-to-all, ring and
//! recursive-doubling all-reduce, random permutation, and hotspot incast —
//! the scenario diversity behind the paper's near-neighbor vs global
//! traffic claims.
//!
//! - [`spec`]: the [`Workload`] message-set model (sized messages with
//!   happens-before deps), validation, and [`WorkloadOutcome`].
//! - [`gen`]: the pattern generators ([`WorkloadKind`]), mapping an
//!   application payload to per-family message sizes.
//! - [`driver`]: [`WorkloadRunner`] — multi-seed averaged completion-time
//!   measurement over a shared simulator, plus the [`par_map`] worker pool
//!   reused by the coordinator experiments.
//!
//! Execution itself lives in the engine
//! ([`crate::sim::Simulator::run_workload`]): messages are injected as
//! their dependencies complete and the run lasts until the network drains.
//!
//! # Packetization and the software overhead model
//!
//! Every message carries a payload of
//! [`size_phits`](WorkloadMessage::size_phits) phits and the engine sends
//! it as a train of `ceil(size_phits / packet_size)` packets, serialized
//! by the source NIC (one in-progress train per node, packets entering the
//! injection queue in order). Three LogGP-style knobs on
//! [`SimConfig`](crate::sim::SimConfig) model the software side:
//!
//! - `send_overhead` (`o_send`): cycles of CPU work between a message's
//!   dependencies completing and its first packet becoming eligible;
//! - `recv_overhead` (`o_recv`): cycles between the last packet of a
//!   message draining and the message *completing* — dependents are
//!   released only then;
//! - `packet_gap` (`g`): minimum cycles between successive packet
//!   injections from one NIC (injection bandwidth) — within a train and
//!   between the last packet of one message and the first of the next;
//!   gaps at or below the wire serialization time `packet_size` are
//!   absorbed by link serialization.
//!
//! All three default to zero, and the default payload is one Table 3
//! packet (16 phits), so at the default `packet_size` the model is
//! exactly the original single-packet engine — bit-identical dynamics and
//! RNG stream. (Under a smaller configured `packet_size` a 16-phit
//! payload packetizes into several packets; the `workload` CLI therefore
//! defaults its payload to one configured packet.)
//!
//! ## Worked example
//!
//! `packet_size = 16`, `o_send = 10`, `o_recv = 20`, `g = 0`, and a
//! 64-phit message over `h = 3` uncontended hops, followed by a dependent
//! 16-phit reply over the same 3 hops:
//!
//! 1. the 64-phit message packetizes into `64/16 = 4` packets; the first
//!    becomes eligible at cycle `o_send = 10`;
//! 2. the source link serializes the train: packet `k` starts at
//!    `10 + 16k`, the last at cycle 58;
//! 3. the last packet's head arrives after 3 one-cycle hops and its tail
//!    drains one serialization later: `58 + 3 + 16 = 77`;
//! 4. the message completes at `77 + o_recv = 97`, releasing the reply;
//! 5. the reply (one packet) becomes eligible at `97 + o_send = 107` and
//!    completes at `107 + 3 + 16 + o_recv = 146` — the workload's
//!    completion time.
//!
//! ```no_run
//! use lattice_networks::sim::SimConfig;
//! use lattice_networks::topology;
//! use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams, WorkloadRunner};
//!
//! let g = topology::fcc(4);
//! // 4096-phit all-to-all chunks under a 10-cycle send/recv overhead.
//! let wl = generate(
//!     WorkloadKind::AllToAll,
//!     &g,
//!     &WorkloadParams { payload_phits: 4096, ..Default::default() },
//! );
//! let sim = SimConfig { send_overhead: 10, recv_overhead: 10, ..SimConfig::fast() };
//! let runner = WorkloadRunner { sim, ..Default::default() };
//! let point = runner.run("FCC(4)", &g, &wl);
//! println!("all-to-all drained in {:.0} cycles", point.completion_cycles);
//! ```

pub mod driver;
pub mod gen;
pub mod spec;

pub use driver::{par_map, CompletionPoint, WorkloadRunner};
pub use gen::{generate, WorkloadKind, WorkloadParams};
pub use spec::{Workload, WorkloadMessage, WorkloadOutcome, DEFAULT_MSG_PHITS};
