//! The common-lift operator `⊞` (Theorem 24) building hybrid graphs.
//!
//! Given `M1 ≅ H1 = [[C, R_A], [0, A]]` and `M2 ≅ H2 = [[C, R_B], [0, B]]`
//! sharing the leading block `C`, the common lift is
//!
//! ```text
//! M1 ⊞ M2 = [ C  R_A  R_B ]
//!           [ 0   A    0  ]
//!           [ 0   0    B  ]
//! ```
//!
//! Both `G(M1)` and `G(M2)` are projections of `G(M1 ⊞ M2)`, and the
//! dimension is minimized against the Cartesian-product (direct-sum)
//! alternative: `max(n1, n2) <= n1 + n2 - k <= n1 + n2`.

use crate::math::{hermite_normal_form, IMat};

use super::LatticeGraph;

/// Size of the largest common leading Hermite block of `h1`, `h2`.
pub fn common_block_size(h1: &IMat, h2: &IMat) -> usize {
    let kmax = h1.dim().min(h2.dim());
    let mut k = 0;
    // The leading k columns must agree entirely (they are zero below row k
    // in Hermite form, so comparing the leading k x k blocks suffices).
    while k < kmax {
        let next = k + 1;
        let mut same = true;
        'outer: for i in 0..next {
            for j in 0..next {
                if h1[(i, j)] != h2[(i, j)] {
                    same = false;
                    break 'outer;
                }
            }
        }
        if !same {
            break;
        }
        k = next;
    }
    k
}

/// Compute `M1 ⊞ M2` (Theorem 24). Inputs may be any generator matrices;
/// they are Hermite-normalized internally.
pub fn common_lift(m1: &IMat, m2: &IMat) -> IMat {
    let h1 = hermite_normal_form(m1).h;
    let h2 = hermite_normal_form(m2).h;
    let n1 = h1.dim();
    let n2 = h2.dim();
    let k = common_block_size(&h1, &h2);
    let n = n1 + n2 - k;
    let mut out = IMat::zeros(n, n);
    // C block + R_A (from h1).
    for i in 0..n1 {
        for j in 0..n1 {
            out[(i, j)] = h1[(i, j)];
        }
    }
    // R_B: top k rows of h2's trailing columns.
    for i in 0..k {
        for j in k..n2 {
            out[(i, n1 + j - k)] = h2[(i, j)];
        }
    }
    // B block: bottom-right of h2.
    for i in k..n2 {
        for j in k..n2 {
            out[(n1 + i - k, n1 + j - k)] = h2[(i, j)];
        }
    }
    out
}

/// Common lift as a lattice graph.
pub fn common_lift_graph(g1: &LatticeGraph, g2: &LatticeGraph) -> LatticeGraph {
    LatticeGraph::new(common_lift(g1.matrix(), g2.matrix()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{bcc, fcc, pc};

    #[test]
    fn example25_pc_boxplus_bcc() {
        // PC(2a) ⊞ BCC(a) = 4D matrix from Example 25.
        for a in [1i64, 2, 3] {
            let got = common_lift(pc(2 * a).matrix(), bcc(a).matrix());
            let expect = IMat::from_rows(&[
                &[2 * a, 0, 0, a],
                &[0, 2 * a, 0, a],
                &[0, 0, 2 * a, 0],
                &[0, 0, 0, a],
            ]);
            assert_eq!(got, expect, "a={a}");
        }
    }

    #[test]
    fn example25_pc_boxplus_fcc() {
        // PC(2a) ⊞ FCC(a) = 5D matrix from Example 25.
        for a in [1i64, 2] {
            let got = common_lift(pc(2 * a).matrix(), fcc(a).matrix());
            let expect = IMat::from_rows(&[
                &[2 * a, 0, 0, a, a],
                &[0, 2 * a, 0, 0, 0],
                &[0, 0, 2 * a, 0, 0],
                &[0, 0, 0, a, 0],
                &[0, 0, 0, 0, a],
            ]);
            assert_eq!(got, expect, "a={a}");
        }
    }

    #[test]
    fn example25_fcc_boxplus_bcc() {
        // FCC(a) ⊞ BCC(a) = 5D matrix from Example 25.
        for a in [1i64, 2] {
            let got = common_lift(fcc(a).matrix(), bcc(a).matrix());
            let expect = IMat::from_rows(&[
                &[2 * a, a, a, 0, a],
                &[0, a, 0, 0, 0],
                &[0, 0, a, 0, 0],
                &[0, 0, 0, 2 * a, a],
                &[0, 0, 0, 0, a],
            ]);
            assert_eq!(got, expect, "a={a}");
        }
    }

    #[test]
    fn no_common_columns_gives_direct_sum() {
        // Remark 22 / Theorem 24: disjoint leading blocks -> Cartesian product.
        let m1 = IMat::diag(&[3]);
        let m2 = IMat::diag(&[5]);
        let got = common_lift(&m1, &m2);
        assert_eq!(got, IMat::diag(&[3, 5]));
    }

    #[test]
    fn both_projections_recoverable() {
        // Theorem 24(i): G(M1) and G(M2) are projections of the lift.
        let a = 2;
        let g1 = pc(2 * a);
        let g2 = bcc(a);
        let lift = common_lift_graph(&g1, &g2);
        assert_eq!(lift.dim(), 4);
        // Project away the BCC tail (axis 3) then verify PC; project away
        // axis 2 (the A block) then verify BCC.
        let p_pc = lift.project_over(3);
        assert!(p_pc.right_equivalent(&g1));
        let p_bcc = lift.project_over(2);
        assert!(p_bcc.right_equivalent(&LatticeGraph::new(
            crate::math::hermite_normal_form(g2.matrix()).h
        )));
    }

    #[test]
    fn dimension_bounds() {
        // Theorem 24(ii).
        let g1 = pc(4);
        let g2 = bcc(2);
        let lift = common_lift(g1.matrix(), g2.matrix());
        let dim = lift.dim();
        assert!(dim >= g1.dim().max(g2.dim()));
        assert!(dim <= g1.dim() + g2.dim());
    }

    #[test]
    fn order_of_table2_hybrid() {
        // Table 2: PC(2a) ⊞ BCC(a) has order 8a^4.
        for a in [1i64, 2] {
            let lift = common_lift_graph(&pc(2 * a), &bcc(a));
            assert_eq!(lift.order(), (8 * a * a * a * a) as usize);
        }
        // Table 2: PC(2a) ⊞ FCC(a) has order 8a^5.
        for a in [1i64, 2] {
            let lift = common_lift_graph(&pc(2 * a), &fcc(a));
            assert_eq!(lift.order(), (8 * a * a * a * a * a) as usize);
        }
        // Table 2: BCC(a) ⊞ FCC(a) has order 4a^5.
        for a in [1i64, 2] {
            let lift = common_lift_graph(&bcc(a), &fcc(a));
            assert_eq!(lift.order(), (4 * a * a * a * a * a) as usize);
        }
    }

    #[test]
    fn t2a2a_boxplus_rtt() {
        // Table 2 row 1: T(2a,2a) ⊞ RTT(a), a 3D graph of order 4a^3.
        for a in [2i64, 3] {
            let t = LatticeGraph::torus(&[2 * a, 2 * a]);
            let rtt = LatticeGraph::new(IMat::from_rows(&[&[2 * a, a], &[0, a]]));
            let lift = common_lift_graph(&t, &rtt);
            assert_eq!(lift.dim(), 3);
            assert_eq!(lift.order(), (4 * a * a * a) as usize);
        }
    }
}
