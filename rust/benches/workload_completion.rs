//! Microbench: closed-loop workload completion — the engine's finite
//! injection mode end-to-end (generation excluded; routing tables built
//! once per network), across the payload-size axis and with the software
//! overhead model engaged.

use lattice_networks::benchkit::{black_box, Bench};
use lattice_networks::sim::{SimConfig, Simulator};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};

fn main() {
    let mut b = Bench::new("workload_completion");
    b.max_iters = 20;

    let cfg = SimConfig::default();
    for (name, g) in [
        ("T(8,4,4)", topology::torus(&[8, 4, 4])),
        ("FCC(4)", topology::fcc(4)),
        ("BCC(2)", topology::bcc(2)),
    ] {
        let sim = Simulator::for_workload(g.clone(), cfg.clone());
        for kind in [
            WorkloadKind::Stencil,
            WorkloadKind::AllToAll,
            WorkloadKind::RingAllReduce,
        ] {
            // Payload axis: single-packet vs multi-packet trains. Ring
            // all-reduce chunks its vector V/N, so it needs a much larger
            // payload before its per-step messages span several packets.
            let payloads: [u32; 2] = if kind == WorkloadKind::RingAllReduce {
                [16, 16 * 1024]
            } else {
                [16, 256]
            };
            for phits in payloads {
                let params =
                    WorkloadParams { iters: 8, payload_phits: phits, ..Default::default() };
                let wl = generate(kind, &g, &params);
                let cap = wl.suggested_max_cycles_for(&cfg);
                // Messages drained per second is the closed-loop metric.
                b.run_throughput(
                    &format!("{name}/{}@{phits}ph", kind.name()),
                    wl.len() as u64,
                    "messages",
                    || {
                        black_box(sim.run_workload_seeded(&wl, cfg.seed, cap));
                    },
                );
            }
        }
    }

    // Software overheads on the hardest pattern: LogGP o/g engaged.
    let loaded = SimConfig {
        send_overhead: 20,
        recv_overhead: 20,
        packet_gap: 4,
        ..SimConfig::default()
    };
    let g = topology::fcc(4);
    let sim = Simulator::for_workload(g.clone(), loaded.clone());
    let params = WorkloadParams { iters: 8, payload_phits: 256, ..Default::default() };
    let wl = generate(WorkloadKind::AllToAll, &g, &params);
    let cap = wl.suggested_max_cycles_for(&loaded);
    b.run_throughput("FCC(4)/alltoall@256ph+loggp", wl.len() as u64, "messages", || {
        black_box(sim.run_workload_seeded(&wl, loaded.seed, cap));
    });
}
