//! Algorithm 2: minimal routing in `FCC(a)`.
//!
//! Hierarchical over the projection RTT(a): the cycle `<e_3>` has order
//! `2a`, intersecting the destination copy twice, so two RTT routes are
//! compared — one reaching the copy after `z'` cycle hops (RTT offset
//! `(0, 0)`), one after `z' - a` hops (RTT offset `(a, 0)`).

use crate::lattice::LatticeGraph;
use crate::math::rem_euclid;
use crate::topology::fcc as fcc_graph;

use super::rtt::RttRouter;
use super::{norm, Record, Router};

/// Closed-form minimal router for `FCC(a)` (labels in the Hermite box
/// `0 <= x < 2a, 0 <= y < a, 0 <= z < a`).
pub struct FccRouter {
    g: LatticeGraph,
    a: i64,
}

impl FccRouter {
    pub fn new(a: i64) -> Self {
        Self { g: fcc_graph(a), a }
    }

    /// Algorithm 2 on a difference `(x, y, z) ∈ L - L`.
    pub fn route_diff(&self, x: i64, y: i64, z: i64) -> Record {
        let a = self.a;
        // Normalize the difference into the labelling box L. Columns of
        // the Hermite matrix [[2a,a,a],[0,a,0],[0,0,a]]: lifting y by +a
        // drags x by +a (column 2), lifting z by +a drags x by +a
        // (column 3); both together wrap 2a (xor).
        let yp = y + a * i64::from(y < 0);
        let zp = z + a * i64::from(z < 0);
        let xh = x + a * i64::from((y < 0) != (z < 0));
        let xp = rem_euclid(xh, 2 * a);
        debug_assert!(0 <= xp && xp < 2 * a && 0 <= yp && yp < a && 0 <= zp && zp < a);

        // Two cycle intersections with the destination copy.
        let (r1x, r1y) = RttRouter::route_diff_min(a, xp, yp);
        let (r2x, r2y) = RttRouter::route_diff_min(a, xp - a, yp);
        let cand1 = vec![r1x, r1y, zp];
        let cand2 = vec![r2x, r2y, zp - a];
        if norm(&cand1) <= norm(&cand2) {
            cand1
        } else {
            cand2
        }
    }

    /// Both candidates (for tie-aware callers).
    pub fn route_diff_ties(&self, x: i64, y: i64, z: i64) -> Vec<Record> {
        let a = self.a;
        let yp = y + a * i64::from(y < 0);
        let zp = z + a * i64::from(z < 0);
        let xh = x + a * i64::from((y < 0) != (z < 0));
        let xp = rem_euclid(xh, 2 * a);
        let mut out = Vec::new();
        let rtt = RttRouter::new(a);
        for (ties, dz) in [
            (rtt.route_ties(&[0, 0], &[xp, yp]), zp),
            (rtt.route_ties(&[a, 0], &[xp, yp]), zp - a),
        ] {
            for t in ties {
                out.push(vec![t[0], t[1], dz]);
            }
        }
        let best = out.iter().map(|r| norm(r)).min().unwrap();
        out.retain(|r| norm(r) == best);
        out.dedup();
        out
    }
}

impl Router for FccRouter {
    fn graph(&self) -> &LatticeGraph {
        &self.g
    }

    fn route(&self, src: &[i64], dst: &[i64]) -> Record {
        self.route_diff(dst[0] - src[0], dst[1] - src[1], dst[2] - src[2])
    }

    fn route_ties(&self, src: &[i64], dst: &[i64]) -> Vec<Record> {
        self.route_diff_ties(dst[0] - src[0], dst[1] - src[1], dst[2] - src[2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::is_valid_record;

    #[test]
    fn example32_full() {
        // FCC(4): route (1,3,3) -> (6,0,1); the paper finds r = (1,1,-2)
        // with norm 4.
        let router = FccRouter::new(4);
        let r = router.route(&[1, 3, 3], &[6, 0, 1]);
        assert_eq!(norm(&r), 4);
        assert!(is_valid_record(router.graph(), &[1, 3, 3], &[6, 0, 1], &r));
    }

    #[test]
    fn all_pairs_minimal_vs_oracle() {
        for a in 1..6i64 {
            let router = FccRouter::new(a);
            let g = router.graph().clone();
            let dist = crate::metrics::bfs_distances(&g, 0);
            let src = vec![0i64, 0, 0];
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&src, &dst);
                assert!(is_valid_record(&g, &src, &dst, &r), "a={a} dst={dst:?}");
                assert_eq!(
                    norm(&r),
                    dist[v] as i64,
                    "a={a} dst={dst:?} got {r:?}"
                );
            }
        }
    }

    #[test]
    fn nonzero_sources() {
        let a = 3;
        let router = FccRouter::new(a);
        let g = router.graph().clone();
        for s in [[1i64, 2, 0], [5, 1, 2], [0, 2, 2]] {
            let dists = crate::metrics::bfs_distances(&g, g.index_of(&s));
            for v in 0..g.order() {
                let dst = g.label_of(v);
                let r = router.route(&s, &dst);
                assert!(is_valid_record(&g, &s, &dst, &r));
                assert_eq!(norm(&r), dists[v] as i64, "src={s:?} dst={dst:?}");
            }
        }
    }

    #[test]
    fn ties_all_minimal() {
        let a = 3;
        let router = FccRouter::new(a);
        let g = router.graph().clone();
        let dist = crate::metrics::bfs_distances(&g, 0);
        for v in 0..g.order() {
            let dst = g.label_of(v);
            for r in router.route_ties(&[0, 0, 0], &dst) {
                assert!(is_valid_record(&g, &[0, 0, 0], &dst, &r));
                assert_eq!(norm(&r), dist[v] as i64);
            }
        }
    }
}
