use super::state::ActiveSet;
use super::*;
use crate::sim::config::ScanMode;
use crate::sim::policy::RoutePolicy;
use crate::topology::{fcc, torus};
use crate::workload::{Workload, WorkloadMessage};

fn quick_cfg() -> SimConfig {
    SimConfig {
        warmup_cycles: 200,
        measure_cycles: 1000,
        drain_cycles: 0,
        ..SimConfig::default()
    }
}

#[test]
fn active_set_inserts_dedupe_and_merge_sorts() {
    let mut s = ActiveSet::new(10);
    assert!(s.is_empty());
    for u in [7usize, 2, 7, 9, 2, 0] {
        s.insert(u);
    }
    assert_eq!(s.pending.len(), 4, "duplicate inserts are dropped");
    assert!(!s.is_empty());
    s.merge();
    assert_eq!(s.list, vec![0, 2, 7, 9], "merge sorts ascending");
    assert!(s.pending.is_empty());
    // Merging new ids interleaves them into the sorted list.
    s.insert(5);
    s.insert(1);
    s.insert(2); // already a member: no-op
    s.merge();
    assert_eq!(s.list, vec![0, 1, 2, 5, 7, 9]);
    // The scan's lazy-removal protocol: clear the member flag, compact
    // the list, and the id is re-insertable afterwards.
    s.member[7] = false;
    s.list.retain(|&u| u != 7);
    s.insert(7);
    s.merge();
    assert_eq!(s.list, vec![0, 1, 2, 5, 7, 9]);
}

/// Regression for the active-set drain invariant: a drained closed-loop
/// run must leave every worklist empty — `run_workload_seeded` asserts it
/// internally (`assert_quiescent` checks the arbitration node set, the
/// closed loop its NIC sender set), so any membership leak in the set
/// maintenance panics this test rather than silently idling nodes
/// forever. Swept across policies × VC counts to cover the escape path's
/// enqueue sites too.
#[test]
fn drained_closed_loop_leaves_active_sets_empty() {
    let g = torus(&[4, 4]);
    let n = g.order() as u32;
    let mut messages = Vec::new();
    for phase in 0..3u32 {
        for u in 0..n {
            let deps = if phase == 0 { vec![] } else { vec![(phase - 1) * n + u] };
            messages.push(WorkloadMessage::new(u, (u + 7) % n, phase, deps));
        }
    }
    let wl = Workload { name: "shift-chain".into(), nodes: g.order(), messages };
    for policy in RoutePolicy::ALL {
        for num_vcs in [1usize, 2] {
            let cfg = SimConfig { route_policy: policy, num_vcs, ..quick_cfg() };
            assert_eq!(cfg.scan_mode, ScanMode::ActiveSet);
            let sim = Simulator::for_workload(g.clone(), cfg);
            let out = sim.run_workload_seeded(&wl, 11, 200_000);
            assert!(out.drained, "{} x {num_vcs} VCs", policy.name());
        }
    }
}

/// Unit-level smoke of the scan-mode equivalence (the exhaustive sweep
/// lives in `tests/engine_differential.rs`): one open-loop run per mode
/// must agree on every counter and on the RNG end-state.
#[test]
fn scan_modes_agree_on_one_open_loop_point() {
    let run = |mode: ScanMode| {
        let cfg = SimConfig { scan_mode: mode, ..quick_cfg() };
        Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, cfg).run(0.4)
    };
    let a = run(ScanMode::ActiveSet);
    let b = run(ScanMode::FullScan);
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
    assert_eq!(a.rng_digest, b.rng_digest);
}

#[test]
fn zero_load_zero_traffic() {
    let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(0.0);
    assert_eq!(r.delivered_packets, 0);
    assert_eq!(r.accepted_load, 0.0);
}

#[test]
fn low_load_accepted_equals_offered() {
    let sim = Simulator::new(torus(&[4, 4, 4]), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(0.1);
    assert!(r.delivered_packets > 0);
    // At 10% load a torus is far from saturation: accepted ~ offered.
    assert!(
        (r.accepted_load - 0.1).abs() < 0.03,
        "accepted {} vs offered 0.1",
        r.accepted_load
    );
    assert_eq!(r.source_dropped, 0, "no drops far below saturation");
}

#[test]
fn latency_bounded_below_by_distance() {
    // At very low load latency ~ hops + packet_size.
    let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(0.02);
    let ps = sim.config().packet_size as f64;
    assert!(r.avg_latency >= ps, "latency {} < packet size", r.avg_latency);
    assert!(
        r.avg_latency < ps + 30.0,
        "uncongested latency too high: {}",
        r.avg_latency
    );
}

#[test]
fn saturation_accepts_less_than_offered() {
    let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(1.0);
    assert!(r.accepted_load < 0.99);
    assert!(r.source_dropped > 0);
    // but still substantial:
    assert!(r.accepted_load > 0.2, "throughput collapsed: {}", r.accepted_load);
}

#[test]
fn no_deadlock_at_high_load_twisted() {
    // Twisted topology + full load; bubble must keep packets moving.
    let sim = Simulator::new(fcc(2), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(1.0);
    assert!(r.delivered_packets > 100, "only {} delivered", r.delivered_packets);
}

#[test]
fn deterministic_given_seed() {
    let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
    let a = sim.run(0.3);
    let b = sim.run(0.3);
    assert_eq!(a.delivered_packets, b.delivered_packets);
    assert_eq!(a.avg_latency, b.avg_latency);
}

#[test]
fn all_patterns_deliver() {
    for pattern in TrafficPattern::ALL {
        let sim = Simulator::new(torus(&[4, 4]), pattern, quick_cfg());
        let r = sim.run(0.2);
        assert!(r.delivered_packets > 0, "{:?}", pattern);
    }
}

#[test]
fn throughput_monotone_then_saturates() {
    let sim = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, quick_cfg());
    let lo = sim.run(0.1).accepted_load;
    let mid = sim.run(0.3).accepted_load;
    assert!(mid > lo);
}

#[test]
fn deep_queues_beyond_legacy_cap() {
    // Queue capacities now come from SimConfig (the engine used to
    // hard-cap FIFO slots at 8 packets and assert on deeper configs).
    let cfg = SimConfig {
        queue_packets: 16,
        injection_queue_packets: 12,
        ..quick_cfg()
    };
    let deep = Simulator::new(torus(&[4, 4]), TrafficPattern::Uniform, cfg).run(1.0);
    assert!(deep.delivered_packets > 0);
    assert!(deep.accepted_load > 0.2, "throughput collapsed: {}", deep.accepted_load);
}

#[test]
fn drain_records_straggler_latencies() {
    // Identical dynamics inside the window; the drain additionally
    // records packets injected in the window but delivered after it.
    let g = torus(&[4, 4]);
    let no_drain =
        Simulator::new(g.clone(), TrafficPattern::Uniform, quick_cfg()).run(1.0);
    let cfg = SimConfig { drain_cycles: 800, ..quick_cfg() };
    let drain = Simulator::new(g, TrafficPattern::Uniform, cfg).run(1.0);
    assert_eq!(drain.delivered_packets, no_drain.delivered_packets);
    assert!(
        drain.measured_packets > no_drain.measured_packets,
        "drain {} vs {}",
        drain.measured_packets,
        no_drain.measured_packets
    );
    assert!(drain.max_latency >= no_drain.max_latency);
}

#[test]
fn workload_single_message_delivers() {
    let g = torus(&[4, 4]);
    let wl = Workload {
        name: "one".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage::new(0, 5, 0, vec![])],
    };
    let sim = Simulator::for_workload(g, quick_cfg());
    let out = sim.run_workload(&wl);
    assert!(out.drained);
    assert_eq!(out.delivered_messages, 1);
    assert_eq!(out.delivered_packets, 1);
    // Node 5 of T(4,4) is 2 hops from node 0: head flight + tail
    // serialization exactly.
    let ps = sim.config().packet_size as u64;
    assert_eq!(out.completion_cycles, 2 + ps);
}

#[test]
fn workload_multi_packet_train_serializes() {
    // A 4-packet message on a unique minimal path: the source link
    // serializes the train, so completion is hops + 4·ps exactly.
    let g = torus(&[4, 4]);
    let ps = quick_cfg().packet_size;
    let wl = Workload {
        name: "train".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage {
            size_phits: 4 * ps,
            ..WorkloadMessage::new(0, 1, 0, vec![])
        }],
    };
    let sim = Simulator::for_workload(g, quick_cfg());
    let out = sim.run_workload(&wl);
    assert!(out.drained);
    assert_eq!(out.delivered_messages, 1);
    assert_eq!(out.delivered_packets, 4);
    assert_eq!(out.delivered_phits, 4 * ps as u64);
    assert_eq!(out.completion_cycles, 1 + 4 * ps as u64);
}

#[test]
fn workload_chain_slower_than_independent_pair() {
    let g = torus(&[4, 4]);
    let pair = Workload {
        name: "pair".into(),
        nodes: g.order(),
        messages: vec![
            WorkloadMessage::new(0, 2, 0, vec![]),
            WorkloadMessage::new(1, 3, 0, vec![]),
        ],
    };
    let chain = Workload {
        name: "chain".into(),
        nodes: g.order(),
        messages: vec![
            WorkloadMessage::new(0, 2, 0, vec![]),
            WorkloadMessage::new(2, 0, 1, vec![0]),
        ],
    };
    let sim = Simulator::for_workload(g, quick_cfg());
    let a = sim.run_workload(&pair);
    let b = sim.run_workload(&chain);
    assert!(a.drained && b.drained);
    let ps = sim.config().packet_size as u64;
    assert!(
        b.completion_cycles >= a.completion_cycles + ps,
        "chain {} vs pair {}",
        b.completion_cycles,
        a.completion_cycles
    );
}

#[test]
fn workload_deterministic_and_capped() {
    let g = fcc(2);
    let n = g.order();
    let messages: Vec<WorkloadMessage> = (0..n as u32)
        .map(|u| WorkloadMessage::new(u, (u + 3) % n as u32, 0, vec![]))
        .collect();
    let wl = Workload { name: "shift".into(), nodes: n, messages };
    let sim = Simulator::for_workload(g, quick_cfg());
    let a = sim.run_workload_seeded(&wl, 7, 100_000);
    let b = sim.run_workload_seeded(&wl, 7, 100_000);
    assert_eq!(a.completion_cycles, b.completion_cycles);
    assert_eq!(a.avg_latency, b.avg_latency);
    // An absurdly small cap reports an undrained run, not a hang.
    let capped = sim.run_workload_seeded(&wl, 7, 4);
    assert!(!capped.drained);
    assert_eq!(capped.completion_cycles, 4);
    assert!(capped.delivered_messages < wl.messages.len() as u64);
}

#[test]
#[should_panic(expected = "malformed workload")]
fn workload_bad_dep_panics_diagnosably() {
    // A dep index past the end must fail validation with a message,
    // not an opaque index-out-of-bounds deep in the cycle loop.
    let g = torus(&[4, 4]);
    let wl = Workload {
        name: "bad-dag".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage::new(0, 1, 0, vec![99])],
    };
    let sim = Simulator::for_workload(g, quick_cfg());
    sim.run_workload(&wl);
}

#[test]
#[should_panic(expected = "malformed workload")]
fn workload_bad_endpoint_panics_diagnosably() {
    // Same guarantee for an out-of-range endpoint: the pre-validation
    // cycle-cap computation must not index-panic on it.
    let g = torus(&[4, 4]);
    let wl = Workload {
        name: "bad-endpoint".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage::new(0, 99, 0, vec![])],
    };
    let sim = Simulator::for_workload(g, quick_cfg());
    sim.run_workload(&wl);
}

// ---------------------------------------------------------------------------
// Route-policy, wire-latency and channel-width extensions.
// ---------------------------------------------------------------------------

#[test]
fn link_latency_stretches_head_flight_exactly() {
    // Node 5 of T(4,4) is 2 hops from node 0 on a unique minimal path:
    // completion = L·hops + ps exactly (the cut-through head takes L
    // cycles per link; the tail streams behind).
    let g = torus(&[4, 4]);
    let wl = Workload {
        name: "one".into(),
        nodes: g.order(),
        messages: vec![WorkloadMessage::new(0, 5, 0, vec![])],
    };
    for lat in [1u64, 3, 7] {
        let cfg = SimConfig { link_latency: lat, ..quick_cfg() };
        let sim = Simulator::for_workload(g.clone(), cfg);
        let out = sim.run_workload(&wl);
        assert!(out.drained);
        let ps = sim.config().packet_size as u64;
        assert_eq!(out.completion_cycles, 2 * lat + ps, "L = {lat}");
    }
}

#[test]
fn axis_width_drains_contended_link_faster() {
    // Two messages from node 0 share the +x spine of T(8,4) toward
    // different destinations, (2,0) and (3,0): the second packet waits
    // out the first's link serialization at the source, so the last
    // delivery lands at exactly ser + 3 + ps with ser = ceil(ps /
    // width_x) — 35 on symmetric links, 27 with a double-width x axis.
    // Widening the unused y axis must change nothing.
    let g = torus(&[8, 4]);
    let wl = Workload {
        name: "spine".into(),
        nodes: g.order(),
        messages: vec![
            WorkloadMessage::new(0, g.index_of_vec(&[2, 0]) as u32, 0, vec![]),
            WorkloadMessage::new(0, g.index_of_vec(&[3, 0]) as u32, 0, vec![]),
        ],
    };
    let run = |widths: Vec<u32>| {
        let cfg = SimConfig { axis_widths: widths, ..quick_cfg() };
        let sim = Simulator::for_workload(g.clone(), cfg);
        let out = sim.run_workload(&wl);
        assert!(out.drained, "undrained");
        out.completion_cycles
    };
    let ps = quick_cfg().packet_size as u64;
    assert_eq!(run(vec![]), ps + 3 + ps, "symmetric baseline");
    assert_eq!(run(vec![2, 1]), ps / 2 + 3 + ps, "wide x drains sooner");
    assert_eq!(run(vec![1, 2]), ps + 3 + ps, "wide y is irrelevant here");
}

#[test]
fn per_vc_credits_conserve_and_hop_phits_balance() {
    use crate::metrics::bfs_distances;
    let g = torus(&[4, 4]);
    let n = g.order();
    // Chained global shifts with enough contention to exercise both the
    // adaptive and (at >= 2 VCs) the escape paths.
    let mut messages = Vec::new();
    for phase in 0..4u32 {
        for u in 0..n as u32 {
            let dst = (u + 5) % n as u32;
            let deps = if phase == 0 { vec![] } else { vec![(phase - 1) * n as u32 + u] };
            messages.push(WorkloadMessage::new(u, dst, phase, deps));
        }
    }
    let wl = Workload { name: "shift-chain".into(), nodes: n, messages };
    // Exact hop-phit budget: every policy is minimal (the escape path
    // included — DOR on the remaining record is still minimal), so the
    // per-VC phit counters must sum to exactly
    // `sum over messages of distance * packet_size`, on any VC split.
    let ps = SimConfig::default().packet_size as u64;
    let expected: u64 =
        (0..n).map(|u| bfs_distances(&g, u)[(u + 5) % n] as u64).sum::<u64>() * 4 * ps;
    for policy in RoutePolicy::ALL {
        for num_vcs in [1usize, 2, 3] {
            let cfg = SimConfig {
                route_policy: policy,
                num_vcs,
                warmup_cycles: 0,
                measure_cycles: 0,
                ..SimConfig::default()
            };
            let sim = Simulator::for_workload(g.clone(), cfg);
            let out = sim.run_workload_seeded(&wl, 9, 500_000);
            // `run_workload_seeded` asserts full network quiescence on
            // drain — every buffer credit returned on every VC.
            assert!(out.drained, "{} x {num_vcs} VCs", policy.name());
            assert_eq!(out.delivered_packets, 4 * n as u64);
            assert_eq!(out.vc_phits.len(), num_vcs, "{}", policy.name());
            assert_eq!(
                out.vc_phits.iter().sum::<u64>(),
                expected,
                "hop-phit imbalance for {} x {num_vcs} VCs: {:?}",
                policy.name(),
                out.vc_phits
            );
            // Closed-loop balance instrumentation is live.
            assert_eq!(out.port_utilization.len(), 4);
            assert!(out.link_util_spread >= 1.0, "spread {}", out.link_util_spread);
        }
    }
}

#[test]
fn nondor_policies_deliver_conserve_and_are_seed_deterministic() {
    for policy in [RoutePolicy::RandomOrder, RoutePolicy::AdaptiveMin] {
        let cfg = SimConfig { route_policy: policy, ..quick_cfg() };
        let sim = Simulator::new(torus(&[8, 4, 4]), TrafficPattern::Uniform, cfg);
        let r = sim.run(0.6);
        assert!(r.delivered_packets > 0, "{}", policy.name());
        assert!(
            r.delivered_packets <= r.injected_packets,
            "{}: delivered {} > injected {}",
            policy.name(),
            r.delivered_packets,
            r.injected_packets
        );
        let again = sim.run(0.6);
        assert_eq!(r.delivered_packets, again.delivered_packets, "{}", policy.name());
        assert_eq!(r.avg_latency, again.avg_latency, "{}", policy.name());
    }
}

#[test]
fn utilization_spread_and_port_classes_are_reported() {
    let sim = Simulator::new(torus(&[8, 4, 4]), TrafficPattern::Uniform, quick_cfg());
    let r = sim.run(0.8);
    assert_eq!(r.port_utilization.len(), 6, "2·dim directed port classes");
    // A transfer that starts inside the window counts its full tail, so a
    // link can nominally exceed 1.0 by one packet's worth.
    assert!(
        r.port_utilization.iter().all(|&u| (0.0..=1.05).contains(&u)),
        "{:?}",
        r.port_utilization
    );
    // Both directions of one axis carry comparable load under uniform.
    for a in 0..3 {
        let (fwd, bwd) = (r.port_utilization[2 * a], r.port_utilization[2 * a + 1]);
        assert!((fwd - bwd).abs() < 0.15, "axis {a}: {fwd} vs {bwd}");
    }
    assert!(r.link_util_spread >= 1.0, "max/mean >= 1, got {}", r.link_util_spread);
    // Idle run: spread degenerates to 0 rather than NaN.
    let idle = sim.run(0.0);
    assert_eq!(idle.link_util_spread, 0.0);
    assert!(idle.port_utilization.iter().all(|&u| u == 0.0));
}
