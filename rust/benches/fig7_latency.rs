//! Bench: regenerate Figure 7 — packet latency vs offered load for
//! T(16,8,8,8) vs 4D-FCC(8). Scaled by default; `LATTICE_FULL=1` for the
//! paper configuration.

use lattice_networks::coordinator::experiments as exp;
use lattice_networks::sim::TrafficPattern;

fn main() {
    let full = std::env::var_os("LATTICE_FULL").is_some();
    let spec = exp::fig5_spec(full); // fig7 shares fig5's networks
    let (cfg, seeds) = exp::fig_sim_config(full);
    let loads: Vec<f64> = if full {
        exp::default_loads()
    } else {
        vec![0.1, 0.3, 0.5, 0.7, 0.9]
    };
    let fig = exp::run_figure(&spec, &TrafficPattern::ALL, &loads, seeds, cfg)
        .expect("figure run");
    print!("{}", exp::curve_table(&fig).render());
}
