//! Closed-loop collectives on a crystal vs its matched torus: generate
//! each workload at several payload sizes, run it to completion on the
//! cycle engine, and compare completion times (the application-level view
//! of the paper's near-neighbor vs global story, with the message-size
//! axis that exposes NIC serialization).
//!
//! ```sh
//! cargo run --release --example collectives
//! ```

use lattice_networks::coordinator::report::{f, Table};
use lattice_networks::sim::{RoutePolicy, SimConfig, Simulator};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams, WorkloadRunner};

fn main() {
    let a = 3;
    let fcc = topology::fcc(a);
    let torus = topology::torus(&[2 * a, a, a]);
    println!(
        "FCC({a}) vs T({},{a},{a}) — {} nodes each\n",
        2 * a,
        fcc.order()
    );

    // A light LogGP software model (10-cycle send/recv overheads) with
    // adaptive per-hop route selection: the tie sets of Remark 30 spread
    // over productive ports by downstream headroom instead of fixed
    // dimension order (swap in RoutePolicy::Dor for the classic engine).
    let sim_cfg = SimConfig {
        send_overhead: 10,
        recv_overhead: 10,
        route_policy: RoutePolicy::AdaptiveMin,
        ..SimConfig::default()
    };
    let runner = WorkloadRunner { sim: sim_cfg.clone(), seeds: 2, ..Default::default() };
    // Routing tables are the expensive part: build each network once and
    // reuse it across every workload and payload size.
    let sim_f = Simulator::for_workload(fcc.clone(), sim_cfg.clone());
    let sim_t = Simulator::for_workload(torus.clone(), sim_cfg);

    let mut t = Table::new(
        "closed-loop completion vs payload (cycles; lower is better)",
        &["workload", "payload", "messages", "FCC", "torus", "torus/FCC"],
    );
    for kind in WorkloadKind::ALL {
        for phits in [16u32, 256, 1024] {
            let params = WorkloadParams { iters: 4, payload_phits: phits, ..Default::default() };
            let wl_f = generate(kind, &fcc, &params);
            let wl_t = generate(kind, &torus, &params);
            let pf = runner.run_with(&sim_f, "FCC", &wl_f);
            let pt = runner.run_with(&sim_t, "torus", &wl_t);
            t.row(vec![
                kind.name().to_string(),
                phits.to_string(),
                wl_f.len().to_string(),
                f(pf.completion_cycles, 0),
                f(pt.completion_cycles, 0),
                format!("{:.2}x", pt.completion_cycles / pf.completion_cycles.max(1.0)),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\nNear-neighbor stencil rides the torus's strength; the global");
    println!("patterns are where the crystal's distance/symmetry advantage shows,");
    println!("and it widens as payloads grow past one packet.");
}
