//! Telemetry differential and conservation pins (DESIGN.md §Telemetry).
//!
//! The telemetry layer is observation-only, and these tests are the
//! contract's teeth:
//!
//! - **trace-on/off differential**: enabling the JSONL trace (and the
//!   periodic probes) must leave every result field and the RNG end-state
//!   (`rng_digest`) bit-identical, across policies, VC counts, loads,
//!   seeds and both run regimes — the telemetry sibling of
//!   `engine_differential.rs`;
//! - **conservation**: the streamed events must reconcile *exactly* with
//!   the engine's own counters — a trace that disagrees with
//!   `SimResult` is worse than no trace.

use std::sync::atomic::{AtomicUsize, Ordering};

use lattice_networks::sim::{RoutePolicy, SimConfig, SimResult, Simulator, TrafficPattern};
use lattice_networks::topology;
use lattice_networks::workload::{generate, WorkloadKind, WorkloadParams};

/// Fresh trace path per run: the tests run concurrently in one process,
/// so a per-process counter disambiguates beyond the pid.
fn trace_path(tag: &str) -> std::path::PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "lattice_tmtry_{}_{}_{}.jsonl",
        std::process::id(),
        tag,
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Thread count under test (CI's `parallel-differential` job sweeps
/// `LATTICE_THREADS`; unset means the serial default).
fn env_threads() -> usize {
    std::env::var("LATTICE_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Quick windows with a drain tail (the `engine_differential.rs` shape).
fn base_cfg(policy: RoutePolicy, num_vcs: usize) -> SimConfig {
    SimConfig {
        warmup_cycles: 100,
        measure_cycles: 500,
        drain_cycles: 150,
        route_policy: policy,
        num_vcs,
        threads: env_threads(),
        ..SimConfig::default()
    }
}

/// Extract the numeric value of `key` from a one-line JSON object written
/// by the trace layer. Substring match is unambiguous because the pattern
/// includes both quotes and the colon (`"t":` cannot match inside
/// `"inj_t":`, nor `"port":` inside `"port_occ":`).
fn field(line: &str, key: &str) -> i64 {
    let pat = format!("\"{key}\":");
    let start = line
        .find(&pat)
        .unwrap_or_else(|| panic!("no field {key:?} in {line}"))
        + pat.len();
    let rest = &line[start..];
    let end = rest
        .find([',', '}'])
        .unwrap_or_else(|| panic!("unterminated {key:?} in {line}"));
    rest[..end].parse().unwrap_or_else(|e| panic!("bad {key:?} in {line}: {e}"))
}

fn is_event(line: &str, ev: &str) -> bool {
    line.contains(&format!("\"ev\":\"{ev}\""))
}

#[test]
fn open_loop_trace_on_is_bit_identical_across_policy_vc_load_seed() {
    for g in [topology::torus(&[8, 4]), topology::fcc(2)] {
        for policy in RoutePolicy::ALL {
            for num_vcs in [1usize, 2] {
                for load in [0.1, 0.9] {
                    for seed in [1u64, 0xdead_beef] {
                        let off = Simulator::new(
                            g.clone(),
                            TrafficPattern::Uniform,
                            base_cfg(policy, num_vcs),
                        )
                        .run_seeded(load, seed);
                        let path = trace_path("open");
                        let on = Simulator::new(
                            g.clone(),
                            TrafficPattern::Uniform,
                            SimConfig {
                                trace: Some(path.to_string_lossy().into_owned()),
                                sample_every: 25,
                                ..base_cfg(policy, num_vcs)
                            },
                        )
                        .run_seeded(load, seed);
                        let text = std::fs::read_to_string(&path).expect("read trace");
                        std::fs::remove_file(&path).ok();
                        assert!(!text.is_empty(), "trace came out empty");
                        assert_eq!(
                            off.rng_digest,
                            on.rng_digest,
                            "tracing perturbed the RNG stream: {} vcs={num_vcs} load={load} seed={seed}",
                            policy.name()
                        );
                        assert_eq!(
                            format!("{off:?}"),
                            format!("{on:?}"),
                            "tracing perturbed the result: {} vcs={num_vcs} load={load} seed={seed}",
                            policy.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn closed_loop_trace_on_is_bit_identical_across_policy_vc_seed() {
    let g = topology::torus(&[4, 4]);
    let wl = generate(WorkloadKind::AllToAll, &g, &WorkloadParams::default());
    for policy in RoutePolicy::ALL {
        for num_vcs in [1usize, 2, 3] {
            for seed in [7u64, 99] {
                let cfg = base_cfg(policy, num_vcs);
                let cap = wl.suggested_max_cycles_for(&cfg);
                let off = Simulator::for_workload(g.clone(), cfg.clone())
                    .run_workload_seeded(&wl, seed, cap);
                let path = trace_path("closed");
                let on = Simulator::for_workload(
                    g.clone(),
                    SimConfig {
                        trace: Some(path.to_string_lossy().into_owned()),
                        sample_every: 25,
                        ..cfg
                    },
                )
                .run_workload_seeded(&wl, seed, cap);
                let text = std::fs::read_to_string(&path).expect("read trace");
                std::fs::remove_file(&path).ok();
                assert!(off.drained, "{} vcs={num_vcs}", policy.name());
                // The closed-loop trace must carry the NIC lifecycle too.
                assert!(text.lines().any(|l| is_event(l, "packetize")), "no packetize events");
                assert!(text.lines().any(|l| is_event(l, "msg_done")), "no msg_done events");
                assert_eq!(
                    off.rng_digest,
                    on.rng_digest,
                    "tracing perturbed the RNG stream: {} vcs={num_vcs} seed={seed}",
                    policy.name()
                );
                assert_eq!(
                    format!("{off:?}"),
                    format!("{on:?}"),
                    "tracing perturbed the outcome: {} vcs={num_vcs} seed={seed}",
                    policy.name()
                );
            }
        }
    }
}

/// Reconcile the streamed events with the engine's own counters — the
/// trace must be an *exact* account of the run, not an approximation:
///
/// - every injection is one `inject` event;
/// - hop events started inside the measurement window reproduce
///   `vc_phits` exactly and `port_utilization` to float round-off;
/// - `deliver` events partition into `delivered_packets` (delivery cycle
///   in the window) and `measured_packets` (injection cycle in the
///   window) exactly as the statistics do;
/// - per-cause `stall` events match the always-on counters, and `esc:1`
///   hops match the escape-drain counter;
/// - probes fire every `sample_every` cycles from cycle 0.
#[test]
fn open_loop_trace_events_reconcile_with_sim_result() {
    let g = topology::torus(&[8, 4]);
    let nodes = g.order();
    let ports = 2 * g.dim();
    let path = trace_path("conserve");
    let cfg = SimConfig {
        trace: Some(path.to_string_lossy().into_owned()),
        sample_every: 50,
        ..base_cfg(RoutePolicy::AdaptiveMin, 2)
    };
    let (w, m) = (cfg.warmup_cycles, cfg.measure_cycles);
    let ps = cfg.packet_size as u64;
    let total_cycles = w + m + cfg.drain_cycles;
    let r: SimResult = Simulator::new(g, TrafficPattern::Uniform, cfg).run_seeded(0.9, 42);
    let text = std::fs::read_to_string(&path).expect("read trace");
    std::fs::remove_file(&path).ok();

    let window = |t: i64| (t as u64) >= w && (t as u64) < w + m;
    let mut injects = 0u64;
    let mut delivered_in_window = 0u64;
    let mut measured = 0u64;
    let mut vc_phits = vec![0u64; 2];
    let mut port_phits = vec![0u64; ports];
    let mut escapes = 0u64;
    let mut stalls = std::collections::HashMap::<String, u64>::new();
    let mut probes = 0u64;
    for line in text.lines() {
        if is_event(line, "inject") {
            injects += 1;
        } else if is_event(line, "hop") {
            if window(field(line, "t")) {
                vc_phits[field(line, "vc") as usize] += ps;
                port_phits[field(line, "port") as usize] += ps;
            }
            escapes += field(line, "esc") as u64; // whole run, like the counter
        } else if is_event(line, "deliver") {
            if window(field(line, "t")) {
                delivered_in_window += 1;
            }
            if window(field(line, "inj_t")) {
                measured += 1;
            }
        } else if is_event(line, "stall") {
            let cause = line.split("\"cause\":\"").nth(1).unwrap().split('"').next().unwrap();
            *stalls.entry(cause.to_string()).or_insert(0) += 1;
        } else if is_event(line, "probe") {
            assert!(line.contains("\"vc_occ\":["), "probe without vc_occ: {line}");
            assert!(line.contains("\"port_occ\":["), "probe without port_occ: {line}");
            probes += 1;
        }
    }

    assert_eq!(injects, r.injected_packets, "inject events vs injected_packets");
    assert_eq!(delivered_in_window, r.delivered_packets, "deliver events vs delivered_packets");
    assert_eq!(measured, r.measured_packets, "deliver inj_t events vs measured_packets");
    assert_eq!(vc_phits, r.vc_phits, "in-window hop events vs vc_phits");
    for (p, &phits) in port_phits.iter().enumerate() {
        let util = phits as f64 / (nodes as f64 * m as f64);
        assert!(
            (util - r.port_utilization[p]).abs() < 1e-9,
            "port {p}: trace util {util} vs result {}",
            r.port_utilization[p]
        );
    }
    assert_eq!(escapes, r.stalls.escape_drains, "esc:1 hops vs escape_drains");
    let by = |c: &str| stalls.get(c).copied().unwrap_or(0);
    assert_eq!(by("credit"), r.stalls.credit_starved, "credit stall events");
    assert_eq!(by("link"), r.stalls.link_busy, "link stall events");
    assert_eq!(by("bubble"), r.stalls.bubble_blocked, "bubble stall events");
    assert_eq!(by("nic"), 0, "NIC stalls are closed-loop-only");
    assert_eq!(r.stalls.nic_serialization, 0);
    // Probes fire at t = 0, 50, ... — ceil(total / sample_every) of them.
    assert_eq!(probes, total_cycles.div_ceil(50), "probe count");
    // Saturating adaptive traffic on the asymmetric torus must actually
    // exercise the interesting events, or the reconciliation above is
    // vacuous.
    assert!(escapes > 0, "no escape drains at 0.9 load");
    assert!(by("credit") + by("link") + by("bubble") > 0, "no stalls at 0.9 load");
}
